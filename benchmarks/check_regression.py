"""Bench-regression guard: fresh BENCH_*.json vs committed baselines.

The perf job snapshots the committed ``BENCH_*.json`` files before
re-running the benchmarks, then calls this script to compare the fresh
dumps against the snapshot:

    python -m benchmarks.check_regression --baseline /tmp/bench_baseline

Three families of keys are guarded (everything else — raw ``*_us``
timings, counts, payload tables — is reported but never gated, because
absolute wall-clock on shared CI runners is too noisy to fail on):

* ``*_speedup_x`` — higher is better; fails when a fresh value drops
  more than ``--tolerance`` (default 20%) below its baseline;
* ``*_overhead_x`` / ``*_dispatches_per_drain`` — lower is better;
  fails when a fresh value rises more than ``--tolerance`` above
  baseline;
* boolean correctness keys (``*_match`` / ``*_ok`` / ``*_bitwise``) —
  fail on any True -> False flip, tolerance-free.

Keys present only in the fresh dump (new benchmarks) or only in the
baseline (renamed/removed) are listed as informational, not failures —
the guard gates regressions, not schema churn.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

HIGHER_BETTER = ("_speedup_x",)
LOWER_BETTER = ("_overhead_x", "_dispatches_per_drain")
BOOL_SUFFIXES = ("_match", "_ok", "_bitwise")
# Keys every dump stamps for format versioning — neither gated nor
# worth a missing/new note when dumps gain them.
METADATA_KEYS = ("schema",)


def _load(d: str) -> dict:
    out = {}
    for fn in sorted(glob.glob(os.path.join(d, "BENCH_*.json"))):
        with open(fn) as f:
            try:
                data = json.load(f)
            except json.JSONDecodeError as e:
                raise SystemExit(f"unreadable bench dump {fn}: {e}")
        for k, v in data.items():
            out[k] = (os.path.basename(fn), v)
    return out


def compare(baseline: dict, fresh: dict, tolerance: float):
    failures, notes = [], []
    for key, (src, base_v) in sorted(baseline.items()):
        if key in METADATA_KEYS:
            continue
        if key not in fresh:
            notes.append(f"  - {key} ({src}): missing from fresh run")
            continue
        new_v = fresh[key][1]
        if any(key.endswith(s) for s in BOOL_SUFFIXES):
            if base_v is True and new_v is not True:
                failures.append(
                    f"  ! {key} ({src}): correctness flip "
                    f"{base_v} -> {new_v}")
            continue
        if not isinstance(base_v, (int, float)) or isinstance(base_v, bool):
            continue
        if any(key.endswith(s) for s in HIGHER_BETTER):
            floor = base_v * (1.0 - tolerance)
            if new_v < floor:
                failures.append(
                    f"  ! {key} ({src}): {new_v:.3f} < {floor:.3f} "
                    f"(baseline {base_v:.3f}, -{tolerance:.0%} floor)")
        elif any(key.endswith(s) for s in LOWER_BETTER):
            ceil = base_v * (1.0 + tolerance)
            if new_v > ceil:
                failures.append(
                    f"  ! {key} ({src}): {new_v:.3f} > {ceil:.3f} "
                    f"(baseline {base_v:.3f}, +{tolerance:.0%} ceiling)")
    for key, (src, _) in sorted(fresh.items()):
        if key not in baseline and key not in METADATA_KEYS:
            notes.append(f"  + {key} ({src}): new key (not gated)")
    return failures, notes


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", required=True,
                    help="directory holding the committed BENCH_*.json "
                         "snapshot")
    ap.add_argument("--fresh", default=".",
                    help="directory holding the freshly generated dumps "
                         "(default: cwd)")
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed relative regression (default 0.2 = 20%%)")
    args = ap.parse_args()

    baseline = _load(args.baseline)
    fresh = _load(args.fresh)
    if not baseline:
        raise SystemExit(f"no BENCH_*.json under --baseline {args.baseline}")
    if not fresh:
        raise SystemExit(f"no BENCH_*.json under --fresh {args.fresh}")

    failures, notes = compare(baseline, fresh, args.tolerance)
    gated = [k for k in baseline
             if any(k.endswith(s) for s in
                    HIGHER_BETTER + LOWER_BETTER + BOOL_SUFFIXES)]
    print(f"bench-regression guard: {len(gated)} gated keys, "
          f"tolerance {args.tolerance:.0%}")
    if notes:
        print("notes:")
        print("\n".join(notes))
    if failures:
        print("FAILURES:")
        print("\n".join(failures))
        return 1
    print("OK — no gated regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
