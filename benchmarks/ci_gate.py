"""Tier-1 floor gate over pytest's terminal summary.

Extracted from the inline python in ``.github/workflows/ci.yml``: the
tier-1 job tees pytest's output to a file and this gate decides whether
the run clears the floor::

    PYTHONPATH=src python -m pytest -q --tb=short | tee pytest.out
    python -m benchmarks.ci_gate --floor 375 pytest.out

The rules are deliberately simple and load-bearing:

* any ``failed`` or ``error`` count > 0 fails, regardless of passes;
* ``passed`` must meet the floor — the floor trips when a whole suite
  silently stops being *collected* (a green run with 25 fewer tests is
  a regression pytest's exit code cannot see);
* a summary with no recognizable counts (empty file, crash before the
  summary line) reads as 0 passed and therefore fails any floor > 0.

The regexes intentionally match the historical inline gate:
``(\\d+) passed`` / ``(\\d+) failed`` / ``(\\d+) error`` — the last one
matches both "1 error" and "2 errors".
"""

from __future__ import annotations

import argparse
import re
import sys


def parse_counts(text: str) -> dict:
    """Extract pass/fail/error counts from pytest terminal output."""

    def grab(pattern: str) -> int:
        m = re.search(pattern, text)
        return int(m.group(1)) if m else 0

    return {
        "passed": grab(r"(\d+) passed"),
        "failed": grab(r"(\d+) failed"),
        "errors": grab(r"(\d+) error"),
    }


def gate(text: str, floor: int) -> tuple[bool, str]:
    """Apply the floor; returns (ok, human-readable verdict line)."""
    c = parse_counts(text)
    ok = c["failed"] == 0 and c["errors"] == 0 and c["passed"] >= floor
    msg = (f"tier-1 gate: {c['passed']} passed, {c['failed']} failed, "
           f"{c['errors']} errors (floor {floor}/0) -> "
           f"{'OK' if ok else 'FAIL'}")
    return ok, msg


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="pytest-output floor gate for the tier-1 CI job")
    ap.add_argument("report",
                    help="file holding pytest's output, or '-' for stdin")
    ap.add_argument("--floor", type=int, required=True,
                    help="minimum number of passed tests")
    args = ap.parse_args(argv)
    if args.report == "-":
        text = sys.stdin.read()
    else:
        with open(args.report, encoding="utf-8", errors="replace") as f:
            text = f.read()
    ok, msg = gate(text, args.floor)
    print(msg)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
