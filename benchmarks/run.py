"""Benchmark harness — one function per paper table/figure + kernel micro.

Prints ``name,us_per_call,derived`` CSV rows (derived = the figure's
headline quantity).  Use --full for paper-scale replication (10 seeds,
full instance counts); the default is a reduced-but-faithful pass sized
for CI.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig6,...]
"""

from __future__ import annotations

import argparse
import gc
import json
import os
import time

import numpy as np

RESULTS = []


def _row(name: str, us: float, derived: str):
    RESULTS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timed(fn, *args, repeat=3, warmup=True, **kw):
    """Mean wall time per call in µs, excluding a warmup call.

    The warmup keeps JIT compilation (and other first-call setup) out of
    the reported mean — perf numbers track the steady state across PRs.
    """
    if warmup:
        fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) / repeat * 1e6


# ---------------------------------------------------------------------- fig1
def bench_fig1_bwa(full: bool):
    """Fig. 1: BWA peak distribution + memory-over-time profile."""
    from repro.traces import eager
    wf = eager(40 if full else 20)
    data = wf.generate(seed=0)
    bwa = data["bwa"]

    def stats():
        peaks = np.asarray([e.peak for e in bwa])
        e = bwa[0]
        flat_frac = float(np.mean(e.mem < 0.6 * e.peak))
        return peaks, flat_frac
    (peaks, flat_frac), us = _timed(stats)
    _row("fig1a_bwa_peak_median_gb", us, f"{np.median(peaks):.2f} (paper 10.6)")
    _row("fig1b_bwa_flat_fraction", us, f"{flat_frac:.2f} (paper ~0.8)")


# ---------------------------------------------------------------------- fig5
def bench_fig5_overview(full: bool):
    """Fig. 5: per-workflow instance counts and average peaks."""
    from repro.traces import eager, sarek
    for wff, n, paper in ((eager, 40 if full else 20, 2.31),
                          (sarek, 70 if full else 24, 1.67)):
        wf = wff(n)
        data = wf.generate(seed=0)
        peaks = [e.peak for ex in data.values() for e in ex]
        cnt = sum(len(v) for v in data.values())
        _row(f"fig5_{wf.name}_avg_peak_gb", 0.0,
             f"{np.mean(peaks):.2f} (paper {paper}) n={cnt}")


# ---------------------------------------------------------------------- fig6
def bench_fig6_wastage(full: bool):
    """Fig. 6: aggregated wastage per method x training fraction."""
    from repro.sched.simulator import run_paper_experiment
    from repro.traces import eager, sarek
    seeds = range(10) if full else range(3)
    for wff, n in ((eager, 30 if full else 18), (sarek, 40 if full else 20)):
        wf = wff(n)
        t0 = time.perf_counter()
        table = run_paper_experiment(wf, seeds=seeds,
                                     train_fracs=(0.25, 0.5, 0.75))
        us = (time.perf_counter() - t0) * 1e6
        for frac, per_m in table.items():
            best_baseline = min(v for k, v in per_m.items()
                                if not k.startswith("ks+"))
            red = (best_baseline - per_m["ks+"]) / best_baseline
            red_ppm = (per_m["ppm-improved"] - per_m["ks+"]) \
                / per_m["ppm-improved"]
            _row(f"fig6_{wf.name}_frac{int(frac*100)}_ks+_gbs",
                 us / len(list(seeds)), f"{per_m['ks+']:.0f}")
            _row(f"fig6_{wf.name}_frac{int(frac*100)}_reduction_vs_best",
                 0.0, f"{100*red:.0f}% (paper 28-40%)")
            _row(f"fig6_{wf.name}_frac{int(frac*100)}_reduction_vs_ppm",
                 0.0, f"{100*red_ppm:.0f}% (paper 45-54%)")
            if "ks+auto" in per_m:
                red_auto = (per_m["ks+"] - per_m["ks+auto"]) / per_m["ks+"]
                _row(f"fig6_{wf.name}_frac{int(frac*100)}_auto_k_vs_fixed",
                     0.0, f"{100*red_auto:+.0f}% (beyond-paper: paper future work)")
        os.makedirs("experiments/paper", exist_ok=True)
        with open(f"experiments/paper/fig6_{wf.name}.json", "w") as f:
            json.dump({str(k): v for k, v in table.items()}, f, indent=1)


# ---------------------------------------------------------------------- fig7
def bench_fig7_segments(full: bool):
    """Fig. 7: KS+ wastage as a function of the number of segments."""
    from repro.sched.simulator import evaluate_workflow
    from repro.traces import eager
    wf = eager(24 if full else 14)
    out = {}
    for k in (2, 3, 4, 6, 8):
        res = evaluate_workflow(wf, seed=0, train_frac=0.5, k=k,
                                methods=["ks+"])
        out[k] = res.methods["ks+"].total_gbs
        _row(f"fig7_eager_k{k}_gbs", 0.0, f"{out[k]:.0f}")
    spread = (max(out.values()) - min(out.values())) / max(out.values())
    _row("fig7_robustness_spread", 0.0,
         f"{100*spread:.0f}% (paper: no significant outliers)")
    os.makedirs("experiments/paper", exist_ok=True)
    with open("experiments/paper/fig7.json", "w") as f:
        json.dump(out, f, indent=1)


# ---------------------------------------------------------------------- fig8
def bench_fig8_per_task(full: bool):
    """Fig. 8: per-task wastage in eager (KS+ vs best baseline)."""
    from repro.sched.simulator import evaluate_workflow
    from repro.traces import eager
    wf = eager(36 if full else 30)
    res = evaluate_workflow(wf, seed=0, train_frac=0.5, k=4,
                            methods=["ks+", "k-segments-selective"])
    ks = res.methods["ks+"].per_family_gbs
    base = res.methods["k-segments-selective"].per_family_gbs
    for fam in ks:
        red = (base[fam] - ks[fam]) / base[fam] if base[fam] > 0 else 0.0
        _row(f"fig8_eager_{fam}_gbs", 0.0,
             f"{ks[fam]:.0f} ({100*red:+.0f}% vs k-seg-sel)")
    bwa_red = (base["bwa"] - ks["bwa"]) / base["bwa"]
    _row("fig8_bwa_reduction", 0.0, f"{100*bwa_red:.0f}% (paper 37-42%)")
    os.makedirs("experiments/paper", exist_ok=True)
    with open("experiments/paper/fig8.json", "w") as f:
        json.dump({"ks+": ks, "k-segments-selective": base}, f, indent=1)


# ----------------------------------------------------------------- fleet_sim
def bench_fleet_sim(full: bool):
    """Batched fleet engine vs the per-execution Python oracle.

    Replays the fig6 workload (reduced scale: one seed, one training
    fraction, more instances) through both paths and reports the speedup
    plus the worst per-method wastage disagreement.
    """
    from repro.core import (
        bucket_traces, concat_packed, packed_predict, simulate_execution,
        simulate_fleet_many,
    )
    from repro.sched.simulator import _fit_methods, default_methods
    from repro.traces import eager

    machine = 128.0
    wf = eager(200 if full else 150)
    train, test = wf.split(0, 0.25, 1.0)
    names = list(default_methods(4, machine, 8.0).keys())
    fitted = _fit_methods(wf, train, names, 4, machine)
    flat = [(f, e) for f in train for e in test[f]]
    traces = bucket_traces([e.mem for _, e in flat])

    def fleet_replay():
        jobs = []
        for mname in names:
            parts = [
                packed_predict(fitted[f][mname],
                               [e.input_gb for e in test[f]])
                for f in train if test[f]
            ]
            jobs.append((concat_packed(parts),
                         fitted[next(iter(train))][mname].retry_spec))
        return simulate_fleet_many(jobs, traces, 1.0,
                                   machine_memory=machine)

    def oracle_replay():
        out = {}
        for mname in names:
            tot = 0.0
            for f, e in flat:
                m = fitted[f][mname]
                tot += simulate_execution(
                    m.predict(e.input_gb), m.retry, e.mem, e.dt,
                    machine_memory=machine).wastage_gbs
            out[mname] = tot
        return out

    fres, us_f = _timed(fleet_replay, repeat=3)
    ores, us_o = _timed(oracle_replay, repeat=1, warmup=False)
    totals_f = {m: float(fr.wastage_gbs.sum()) for m, fr in zip(names, fres)}
    err = max(abs(totals_f[m] - ores[m]) / ores[m] for m in names)

    def reduction(tot):
        best = min(v for k, v in tot.items() if not k.startswith("ks+"))
        return (best - tot["ks+"]) / best

    red_f, red_o = reduction(totals_f), reduction(ores)
    _row("fleet_sim_speedup", us_f,
         f"{us_o / us_f:.1f}x vs oracle (target >=10x)")
    _row("fleet_sim_oracle_us", us_o,
         f"{len(flat)} execs x {len(names)} methods")
    _row("fleet_sim_max_rel_err", 0.0, f"{100 * err:.3f}% (target <1%)")
    _row("fleet_sim_reduction_match", 0.0,
         f"fleet {100 * red_f:.1f}% vs oracle {100 * red_o:.1f}% "
         f"(ks+ vs best baseline)")

    # Pallas-probe row: the same replay (one method) through the
    # `oom_probe` kernel — interpret mode off-TPU, so a real-HBM run is
    # one flag (the backend auto-resolves to the compiled kernel there).
    import jax
    pb = "pallas" if jax.default_backend() == "tpu" else "pallas-interpret"

    def one_method_replay(backend):
        parts = [
            packed_predict(fitted[f]["ks+"], [e.input_gb for e in test[f]])
            for f in train if test[f]
        ]
        jobs = [(concat_packed(parts),
                 fitted[next(iter(train))]["ks+"].retry_spec)]
        return simulate_fleet_many(jobs, traces, 1.0,
                                   machine_memory=machine,
                                   backend=backend)[0]

    jres, us_j = _timed(lambda: one_method_replay("jnp"), repeat=1)
    pres, us_p = _timed(lambda: one_method_replay(pb), repeat=1)
    werr = float(np.max(np.abs(pres.wastage_gbs - jres.wastage_gbs)))
    att_ok = bool(np.array_equal(pres.attempts, jres.attempts))
    _row(f"fleet_sim_{pb.replace('-', '_')}_us", us_p,
         f"jnp={us_j:.0f}us max|dw|={werr:.2e} attempts_match={att_ok}")


# ------------------------------------------------------------- online_replay
def bench_online_replay(full: bool):
    """Online (observe/refit rounds) vs offline replay at fleet scale.

    Replays a 240+-execution test split through `evaluate_workflow` three
    ways: offline, online with `refit="never"` (same models — isolates the
    pure *streaming machinery* overhead: per-round subset dispatches,
    prediction caching, lifecycle bookkeeping; must stay <=2x AND
    reproduce the offline result bitwise) and online with
    `refit="on_failure"` (the production feedback policy; its extra cost
    is genuine model-update work — tail segmentation + regression
    re-solves for OOMing families — reported separately together with the
    wastage the feedback buys back).  Dumps BENCH_online.json and the
    per-method comparison into experiments/paper/online_replay.json.
    """
    from repro.sched.simulator import evaluate_workflow
    from repro.traces import eager

    n = 60 if full else 36  # test split = 0.75 * n * 9 families >= 240
    wf = eager(n)
    kw = dict(seed=0, train_frac=0.25, k=4)
    n_jobs = sum(len(v) for v in wf.split(0, 0.25, 1.0)[1].values())

    def offline():
        return evaluate_workflow(wf, **kw)

    def online_never():
        return evaluate_workflow(wf, **kw, mode="online", refit="never",
                                 round_size=5)

    def online_feedback():
        return evaluate_workflow(wf, **kw, mode="online",
                                 refit="on_failure", round_size=5)

    def timed_min(fn, repeat=4):
        # Min-of-N: the overhead *ratio* is the headline here, and a mean
        # is hostage to whatever else the CI box ran just before.
        out = fn()  # warmup (jit compiles for every round shape)
        best = min(
            (lambda t0: (fn(), time.perf_counter() - t0))(
                time.perf_counter())[1]
            for _ in range(repeat))
        return out, best * 1e6

    off, us_off = timed_min(offline)
    never, us_never = timed_min(online_never)
    on, us_on = timed_min(online_feedback)
    for m, mr in off.methods.items():
        assert never.methods[m].total_gbs == mr.total_gbs, \
            f"online refit='never' diverged from offline for {m}"

    overhead = us_never / us_off
    assert overhead <= 2.0, \
        f"online streaming overhead regressed: {overhead:.2f}x offline " \
        "(contract: <=2x at 240+ jobs, refit='never')"
    overhead_fb = us_on / us_off
    fb = (off.methods["tovar-ppm"].total_gbs
          - on.methods["tovar-feedback"].total_gbs) \
        / off.methods["tovar-ppm"].total_gbs
    _row("online_replay_offline_us", us_off,
         f"{n_jobs} execs x {len(off.methods)} methods")
    _row("online_replay_streaming_us", us_never,
         f"{overhead:.2f}x offline (target <=2x, refit=never, bitwise ok)")
    _row("online_replay_feedback_us", us_on,
         f"{overhead_fb:.2f}x offline incl. refit work (refit=on_failure)")
    _row("online_replay_feedback_gain", 0.0,
         f"tovar-feedback online vs tovar-ppm offline: {100 * fb:.0f}% less "
         "wastage")
    os.makedirs("experiments/paper", exist_ok=True)
    with open("experiments/paper/online_replay.json", "w") as f:
        json.dump({
            "offline": {m: r.total_gbs for m, r in off.methods.items()},
            "online_on_failure": {m: r.total_gbs
                                  for m, r in on.methods.items()},
        }, f, indent=1)
    with open("BENCH_online.json", "w") as f:
        json.dump({
            "schema": 1,
            "online_replay_jobs": n_jobs,
            "online_replay_overhead_x": overhead,
            "online_replay_feedback_overhead_x": overhead_fb,
            "online_replay_offline_us": us_off,
            "online_replay_streaming_us": us_never,
            "online_replay_feedback_us": us_on,
            "online_replay_never_bitwise": True,
            "online_replay_feedback_gain_frac": fb,
        }, f, indent=1)


# --------------------------------------------------------------- cluster_sim
def bench_cluster_sim(full: bool):
    """Packed ClusterSim vs the legacy per-job event loop (same workload).

    Replays a seeded 3-node workload through both engines, asserts the
    admission logs are identical decision for decision, and reports the
    replay speedup (target >=5x at >=200 jobs) plus the offset-sweep
    amortization.  Dumps its own rows into BENCH_cluster.json.
    """
    import numpy as _np

    from repro.core import AllocationPlan, RetrySpec, ksplus_retry
    from repro.sched import ClusterSim, Job, Node, OffsetCandidate

    n_jobs = 600 if full else 240

    def build_jobs():
        rng = _np.random.default_rng(0)
        jobs = []
        for j in range(n_jobs):
            L = int(rng.integers(24, 90))
            split = int(rng.uniform(0.4, 0.8) * L)
            lo = float(rng.uniform(1.5, 3.0))
            hi = float(rng.uniform(5.0, 11.0))
            mem = _np.concatenate([_np.full(split, lo),
                                   _np.full(L - split, hi)])
            mem = mem * (1.0 + 0.02 * _np.sin(_np.arange(L)))
            scale = 0.9 if rng.uniform() < 0.2 else 1.12
            plan = AllocationPlan(
                starts=_np.asarray([0.0, max(split - 2.0, 1.0)]),
                peaks=_np.asarray([lo * 1.15, hi * scale]))
            jobs.append(Job(jid=j, family="t", input_gb=1.0, mem=mem,
                            dt=1.0, plan=plan, est_runtime=float(L)))
        return jobs

    def nodes():
        return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0)]

    def packed():
        return ClusterSim(nodes(), engine="packed").run(
            build_jobs(), RetrySpec("ksplus"))

    def fused():
        return ClusterSim(nodes(), engine="fused").run(
            build_jobs(), RetrySpec("ksplus"))

    def legacy():
        return ClusterSim(nodes(), engine="legacy").run(
            build_jobs(), ksplus_retry)

    pres, us_p = _timed(packed, repeat=3)
    fres, us_fu = _timed(fused, repeat=3)
    lres, us_l = _timed(legacy, repeat=1, warmup=False)

    assert pres.placements == lres.placements, \
        "packed ClusterSim diverged from the legacy event loop"
    assert fres.placements == lres.placements, \
        "fused ClusterSim diverged from the legacy event loop"
    assert fres.retries == lres.retries
    assert pres.retries == lres.retries
    assert pres.unschedulable == lres.unschedulable
    rel_err = abs(pres.total_wastage_gbs - lres.total_wastage_gbs) \
        / max(lres.total_wastage_gbs, 1e-9)
    assert rel_err <= 1e-6, \
        f"packed wastage diverged from legacy: rel_err={rel_err:.2e}"

    cands = [OffsetCandidate(), OffsetCandidate(peak=0.10),
             OffsetCandidate(peak=-0.10), OffsetCandidate(start=0.15),
             OffsetCandidate(peak=0.10, last_peak_bump=0.5)]

    def sweep():
        return ClusterSim(nodes()).run(build_jobs(), RetrySpec("ksplus"),
                                       offsets=cands)

    sres, us_sweep = _timed(sweep, repeat=1)
    best = min(sres, key=lambda r: r.total_wastage_gbs)

    _row("cluster_sim_speedup", us_p,
         f"{us_l / us_p:.1f}x vs legacy (target >=5x, {n_jobs} jobs)")
    _row("cluster_sim_fused_us", us_fu,
         f"{us_l / us_fu:.1f}x vs legacy (fused engine, bitwise placements; "
         "deep-queue wins measured by --only admission)")
    _row("cluster_sim_legacy_us", us_l,
         f"{lres.retries} retries, makespan {lres.makespan:.0f}s")
    _row("cluster_sim_wastage_rel_err", 0.0,
         f"{rel_err:.2e} (target <=1e-6)")
    _row("cluster_sim_offset_sweep_us", us_sweep,
         f"{len(cands)} candidates, {us_sweep / us_p:.1f}x one run; "
         f"best offset (peak={best.offset.peak:+.2f}, "
         f"start={best.offset.start:+.2f}) "
         f"{best.total_wastage_gbs:.0f} GBs vs base "
         f"{sres[0].total_wastage_gbs:.0f}")
    with open("BENCH_cluster.json", "w") as f:
        json.dump({
            "schema": 1,
            "cluster_sim_jobs": n_jobs,
            "cluster_sim_speedup_x": us_l / us_p,
            "cluster_sim_fused_speedup_x": us_l / us_fu,
            "cluster_sim_packed_us": us_p,
            "cluster_sim_fused_us": us_fu,
            "cluster_sim_legacy_us": us_l,
            "cluster_sim_wastage_rel_err": rel_err,
            "cluster_sim_offset_sweep_us": us_sweep,
            "cluster_sim_offset_candidates": len(cands),
            "cluster_sim_placements_match": True,
        }, f, indent=1)


# ----------------------------------------------------------------- admission
def bench_admission(full: bool):
    """Fused vs numpy admission path at 10k queued jobs (high churn).

    Drives the shared :class:`repro.sched.admission.AdmissionState`
    protocol — the per-event hot path of the fused ClusterSim engine —
    through a scripted event sequence over a 10k-deep queue on loaded
    nodes: every event advances the clock (full invalidation + one fused
    refresh dispatch) and then admits greedily, with the incremental
    fits-column invalidation mask bounding the per-admission recompute.
    The comparator replays the exact same script through the numpy
    admission path with the packed engine's recompute strategy (one
    :func:`fits_column` per node per event, and a full recompute of the
    placed node's column per admission — the `cols.pop(ni)` protocol of
    `ClusterSim._run_packed`).  Asserts the two paths place
    bitwise-identically and dumps BENCH_admission.json (target: fused
    >= 3x at 10k queued jobs).
    """
    import numpy as _np

    from repro.core.envelope import PAD_START, alloc_at_packed, fits_column
    from repro.sched.admission import AdmissionState

    B = 10_000
    K, G = 4, 64
    caps = [48.0, 64.0, 32.0, 96.0]
    res_per_node = 8
    events, admits = (3, 12) if full else (2, 6)

    def build(backend):
        rng = _np.random.default_rng(0)
        adm = AdmissionState(caps, K=K, G=G, backend=backend, use_dur=True)
        starts = _np.full((B, K), PAD_START)
        peaks = _np.zeros((B, K))
        est = rng.uniform(30, 120, B)
        grid = _np.linspace(0.0, est, G, axis=1)
        for i in range(B):
            k = int(rng.integers(1, K + 1))
            starts[i, :k] = _np.sort(_np.concatenate(
                [[0.0], rng.uniform(1, 60, k - 1)]))
            peaks[i, :k] = _np.sort(rng.uniform(2, 12, k))
            peaks[i, k:] = peaks[i, k - 1]
        need = alloc_at_packed(starts, peaks, grid)
        adm.add_lanes(starts, peaks, need, grid, dur=est)
        lane = 0
        for ni in range(len(caps)):  # pre-loaded residents
            for _ in range(res_per_node):
                adm.place(ni, lane, 0.0)
                lane += 1
        return adm, list(range(lane, B))

    def drive_fused():
        adm, queue = build("fused")
        adm.columns(0.0, queue)  # warmup: jit compile outside the timing
        placements = []
        t0 = time.perf_counter()
        now = 0.0
        for _ in range(events):
            now += 7.0  # event tick: time advance invalidates everything
            adm.sync_now(now)
            for _ in range(admits):
                M = adm.columns(now, queue)
                anyfit = M.any(axis=0)
                if not anyfit.any():
                    break
                col = int(_np.argmax(anyfit))
                ni = int(_np.argmax(M[:, col]))
                ji = queue[col]
                queue.remove(ji)
                adm.place(ni, ji, now)
                placements.append((now, ni, ji))
        return placements, time.perf_counter() - t0

    def drive_numpy():
        # The packed engine's host strategy, verbatim: per event, each
        # node's column is computed once over the whole queue; a placement
        # invalidates (only) the placed node's column, which is then fully
        # recomputed — no incremental mask, no cross-node sharing.
        adm, queue = build("numpy")  # reuse the state container for setup
        placements = []
        t0 = time.perf_counter()
        now = 0.0
        for _ in range(events):
            now += 7.0
            cols = {}  # ni -> B-wide fits column (valid for current queue)
            for _ in range(admits):
                q = _np.asarray(queue)
                for ni in range(len(caps)):
                    if ni not in cols:
                        run = adm.running[ni]
                        ok, _ = fits_column(
                            adm.caps[ni], adm.starts[run], adm.peaks[run],
                            adm.admit_t[run], adm.need[q],
                            now + adm.grid[q], dur=adm.dur[run])
                        cols[ni] = _np.zeros(B, bool)
                        cols[ni][q] = ok
                M = _np.stack([cols[ni] for ni in range(len(caps))])[:, q]
                anyfit = M.any(axis=0)
                if not anyfit.any():
                    break
                col = int(_np.argmax(anyfit))
                ni = int(_np.argmax(M[:, col]))
                ji = queue[col]
                queue.remove(ji)
                adm.running[ni].append(ji)
                adm.admit_t[ji] = now
                cols.pop(ni)  # only the placed node's column is stale
                placements.append((now, ni, ji))
        return placements, time.perf_counter() - t0

    pf, us_f = drive_fused()
    pn, us_n = drive_numpy()
    us_f *= 1e6
    us_n *= 1e6
    assert pf == pn, "fused admission diverged from the numpy path"
    speedup = us_n / us_f
    _row("admission_fused_us", us_f,
         f"{speedup:.1f}x vs numpy path (target >=3x, {B} queued jobs, "
         f"{events} events, {len(pf)} placements)")
    _row("admission_numpy_us", us_n,
         f"{len(caps)} nodes x {res_per_node} residents")
    with open("BENCH_admission.json", "w") as f:
        json.dump({
            "schema": 1,
            "admission_queued_jobs": B,
            "admission_speedup_x": speedup,
            "admission_fused_us": us_f,
            "admission_numpy_us": us_n,
            "admission_events": events,
            "admission_placements": len(pf),
            "admission_placements_match": True,
        }, f, indent=1)


# ------------------------------------------------------------ workload_replay
def bench_workload_replay(full: bool):
    """DAG-aware cluster replay on a generated workload (repro.workloads).

    Three measurements, dumped into BENCH_workloads.json:

    * generation throughput — the ``workload_replay`` scenario (layered
      random DAG, 4 task families) synthesized straight into packed
      lanes at >=5k tasks;
    * differential speedup — the same scenario at a few hundred tasks
      replayed through the fused engine AND the legacy per-job loop with
      dependency-release order, placements asserted identical;
    * fleet-scale replay — the >=5k-task DAG through
      ``ClusterSim(engine="fused")``, release order verified against the
      DAG (every task placed only after all parents finished).
    """
    from repro.core import RetrySpec, ksplus_retry
    from repro.sched import ClusterSim, Node
    from repro.workloads import assert_release_order, scenarios

    def nodes():
        return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0), Node(3, 96.0)]

    n_small = 600 if full else 400
    n_big = 8192 if full else 5120

    wf_small = scenarios.get("workload_replay", n_tasks=n_small, seed=0)

    def fused_small():
        return ClusterSim(nodes(), engine="fused").run(
            wf_small.to_jobs(under_frac=0.2, seed=0), RetrySpec("ksplus"))

    def legacy_small():
        return ClusterSim(nodes(), engine="legacy").run(
            wf_small.to_jobs(under_frac=0.2, seed=0), ksplus_retry)

    fres, us_f = _timed(fused_small, repeat=3)
    lres, us_l = _timed(legacy_small, repeat=1, warmup=False)
    assert fres.placements == lres.placements, \
        "fused DAG replay diverged from the legacy loop"
    assert fres.retries == lres.retries
    assert fres.unschedulable == lres.unschedulable
    assert_release_order(wf_small.to_jobs(seed=0), fres.placements)
    speedup = us_l / us_f

    def gen_big():
        return scenarios.get("workload_replay", n_tasks=n_big, seed=1)

    wf_big, us_gen = _timed(gen_big, repeat=1)  # warmup amortizes the jit
    big_jobs = wf_big.to_jobs(under_frac=0.1, seed=1)
    t0 = time.perf_counter()
    bres = ClusterSim(nodes(), engine="fused").run(
        big_jobs, RetrySpec("ksplus"))
    us_big = (time.perf_counter() - t0) * 1e6
    assert_release_order(big_jobs, bres.placements)
    assert bres.unschedulable == 0

    _row("workload_gen_us", us_gen,
         f"{n_big} tasks -> {len(wf_big.batch.buckets)} packed buckets "
         f"({n_big / (us_gen / 1e6):,.0f} tasks/s)")
    _row("workload_replay_speedup", us_f,
         f"{speedup:.1f}x vs legacy (DAG release, {n_small} tasks, "
         f"{fres.retries} retries, placements bitwise)")
    _row("workload_replay_legacy_us", us_l,
         f"makespan {lres.makespan:.0f}s")
    _row("workload_replay_5k_us", us_big,
         f"{n_big}-task layered DAG via fused engine, "
         f"{bres.retries} retries, release order verified")
    with open("BENCH_workloads.json", "w") as f:
        json.dump({
            "schema": 1,
            "workload_gen_tasks": n_big,
            "workload_gen_us": us_gen,
            "workload_replay_tasks": n_small,
            "workload_replay_speedup_x": speedup,
            "workload_replay_fused_us": us_f,
            "workload_replay_legacy_us": us_l,
            "workload_replay_placements_match": True,
            "workload_replay_big_tasks": n_big,
            "workload_replay_big_fused_us": us_big,
            "workload_replay_big_retries": bres.retries,
            "workload_replay_big_release_order_ok": True,
        }, f, indent=1)


# ---------------------------------------------------------------------- drain
def bench_drain(full: bool):
    """Device-resident drain vs the host fused drain (BENCH_drain.json).

    Three measurements:

    * replay timing — the ``workload_replay`` DAG through the fused
      engine with ``drain="host"`` vs the default ``drain="device"``,
      placements asserted bitwise;
    * dispatch accounting — :class:`AdmissionState` stats over a
      multi-drain protocol run: the device path must report exactly ONE
      jitted dispatch per drain (the tentpole invariant; queues wider
      than ``DRAIN_CAP`` first shrink through the candidate pre-filter,
      and a pre-filter that finds nothing skips the program entirely);
    * sharding — a 2-shard ``shard_map`` drain (subprocess with 8 forced
      host devices; the main process keeps its single-device view) must
      match the unsharded drain's placements decision-for-decision.
    """
    import subprocess
    import sys as _sys

    from repro.core import RetrySpec
    from repro.core.envelope import PAD_START, alloc_at_packed
    from repro.sched import ClusterSim, Node
    from repro.sched.admission import AdmissionState
    from repro.workloads import scenarios

    def nodes():
        return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0), Node(3, 96.0)]

    n = 1024 if full else 600
    wf = scenarios.get("workload_replay", n_tasks=n, seed=0)

    def replay(drain):
        return ClusterSim(nodes(), engine="fused", drain=drain).run(
            wf.to_jobs(under_frac=0.2, seed=0), RetrySpec("ksplus"))

    dres, us_d = _timed(lambda: replay("device"), repeat=3)
    hres, us_h = _timed(lambda: replay("host"), repeat=1, warmup=False)
    match = dres.placements == hres.placements

    def lanes_for(adm, rng, B):
        K, G = adm.K, adm.G
        starts = np.full((B, K), PAD_START)
        peaks = np.zeros((B, K))
        grid = np.linspace(0.0, rng.uniform(30, 120, B), G, axis=1)
        for i in range(B):
            k = int(rng.integers(1, K + 1))
            starts[i, :k] = np.sort(np.concatenate(
                [[0.0], rng.uniform(1.0, 60.0, k - 1)]))
            peaks[i, :k] = np.sort(rng.uniform(2.0, 20.0, k))
            peaks[i, k:] = peaks[i, k - 1]
        need = alloc_at_packed(starts, peaks, grid)
        return adm.add_lanes(starts, peaks, need, grid,
                             dur=rng.uniform(20.0, 100.0, B))

    adm = AdmissionState((48.0, 64.0, 32.0, 96.0), K=3, G=16,
                         backend="fused")
    remaining = list(lanes_for(adm, np.random.default_rng(0), 64))
    for now in (0.0, 10.0, 40.0, 90.0):
        placed = adm.drain(now, remaining)
        done = {ji for ji, _ in placed}
        remaining = [ji for ji in remaining if ji not in done]
    per_drain = adm.stats["drain_dispatches"] / adm.stats["drains"]

    shard_code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, time
import numpy as np
from repro.core.envelope import PAD_START, alloc_at_packed
from repro.sched.admission import AdmissionState

def build(shard):
    rng = np.random.default_rng(5)
    caps = tuple(rng.uniform(24.0, 96.0, 16))
    adm = AdmissionState(caps, K=3, G=16, backend="fused", shard=shard)
    B, K, G = 96, adm.K, adm.G
    starts = np.full((B, K), PAD_START)
    peaks = np.zeros((B, K))
    grid = np.linspace(0.0, rng.uniform(30, 120, B), G, axis=1)
    for i in range(B):
        k = int(rng.integers(1, K + 1))
        starts[i, :k] = np.sort(np.concatenate(
            [[0.0], rng.uniform(1.0, 60.0, k - 1)]))
        peaks[i, :k] = np.sort(rng.uniform(2.0, 20.0, k))
        peaks[i, k:] = peaks[i, k - 1]
    need = alloc_at_packed(starts, peaks, grid)
    lanes = adm.add_lanes(starts, peaks, need, grid,
                          dur=rng.uniform(20.0, 100.0, B))
    return adm, list(lanes)

out, us = {}, {}
for shard in (None, 2):
    adm, lanes = build(shard)
    adm.drain(0.0, lanes)            # compile
    adm, lanes = build(shard)        # fresh state, warm kernel cache
    t0 = time.perf_counter()
    out[shard] = adm.drain(0.0, lanes)
    us[shard] = (time.perf_counter() - t0) * 1e6
    assert adm.stats["drain_dispatches"] == 1
print(json.dumps({
    "match": out[None] == out[2],
    "placed": len(out[None]),
    "us_sharded": us[2],
    "us_unsharded": us[None],
}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([_sys.executable, "-c", shard_code],
                          capture_output=True, text=True, env=env,
                          timeout=540)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded drain subprocess failed:\n"
                           f"{proc.stderr[-4000:]}")
    shard_out = json.loads(proc.stdout.strip().splitlines()[-1])

    _row("drain_speedup", us_d,
         f"{us_h / us_d:.1f}x vs host drain ({n}-task DAG replay, "
         f"placements {'bitwise' if match else 'DIVERGED'})")
    _row("drain_host_us", us_h, f"makespan {hres.makespan:.0f}s")
    _row("drain_dispatches_per_drain", 0.0,
         f"{per_drain:.2f} (target 1.0, {adm.stats['drains']} drains)")
    _row("drain_sharded", shard_out["us_sharded"],
         f"2-shard shard_map, match={shard_out['match']}, "
         f"{shard_out['placed']} placements, "
         f"unsharded={shard_out['us_unsharded']:.0f}us")
    with open("BENCH_drain.json", "w") as f:
        json.dump({
            "schema": 1,
            "drain_replay_tasks": n,
            "drain_speedup_x": us_h / us_d,
            "drain_device_us": us_d,
            "drain_host_us": us_h,
            "drain_placements_match": bool(match),
            "drain_dispatches_per_drain": per_drain,
            "drain_shards": 2,
            "drain_sharded_match": bool(shard_out["match"]),
            "drain_sharded_placements": shard_out["placed"],
            "drain_sharded_us": shard_out["us_sharded"],
            "drain_unsharded_us": shard_out["us_unsharded"],
        }, f, indent=1)


# --------------------------------------------------------------- churn_replay
def _churn_nodes():
    from repro.sched import Node
    return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0), Node(3, 96.0)]


def _churn_jobs(n_jobs, seed=0, parents_every=0):
    """The seeded churn workload shared by bench_churn_replay/bench_obs."""
    import numpy as _np

    from repro.core import AllocationPlan
    from repro.sched import Job

    rng = _np.random.default_rng(seed)
    jobs = []
    for j in range(n_jobs):
        L = int(rng.integers(24, 90))
        split = int(rng.uniform(0.4, 0.8) * L)
        lo = float(rng.uniform(1.5, 3.0))
        hi = float(rng.uniform(5.0, 11.0))
        mem = _np.concatenate([_np.full(split, lo),
                               _np.full(L - split, hi)])
        mem = mem * (1.0 + 0.02 * _np.sin(_np.arange(L)))
        scale = 0.9 if rng.uniform() < 0.2 else 1.12
        plan = AllocationPlan(
            starts=_np.asarray([0.0, max(split - 2.0, 1.0)]),
            peaks=_np.asarray([lo * 1.15, hi * scale]))
        parents = ((j - parents_every,) if parents_every
                   and j >= parents_every else ())
        jobs.append(Job(jid=j, family="t", input_gb=1.0, mem=mem,
                        dt=1.0, plan=plan, est_runtime=float(L),
                        parents=parents))
    return jobs


def bench_churn_replay(full: bool):
    """Fused fault path vs the no-fault fused replay, plus the robustness
    suite's differential guarantee.

    Three measurements, dumped into BENCH_churn.json:

    * fault-path overhead — a seeded 1k-job workload replayed through the
      fused engine with and without a Poisson churn schedule; the faulted
      replay must stay within 2x of the no-fault replay (the eviction
      path reuses AdmissionState's join/leave row protocol, so churn adds
      bookkeeping, not dispatches);
    * oracle check — a ~300-job storm-over-DAG replay (preemption storm
      with dependency chains) through fused AND legacy, placements
      asserted bitwise;
    * suite smoke — three make_suite grid points (storm, churn, arrivals)
      with ``check_oracle=True``.
    """
    from repro.core import RetrySpec, ksplus_retry
    from repro.sched import ClusterSim, FaultSchedule
    from repro.workloads import SuiteCase, run_suite

    nodes = _churn_nodes
    build_jobs = _churn_jobs

    n_jobs = 1000
    churn = FaultSchedule.node_churn(nodes(), rate=1.0 / 60.0,
                                     horizon=2000.0, seed=0,
                                     mean_down=45.0)

    def fused_plain():
        return ClusterSim(nodes(), engine="fused").run(
            build_jobs(n_jobs), RetrySpec("ksplus"))

    def fused_churn():
        return ClusterSim(nodes(), engine="fused").run(
            build_jobs(n_jobs), RetrySpec("ksplus"), faults=churn)

    pres, us_plain = _timed(fused_plain, repeat=3)
    cres, us_churn = _timed(fused_churn, repeat=3)
    overhead = us_churn / us_plain
    assert cres.evictions > 0, "churn schedule produced no evictions"
    assert overhead <= 2.0, \
        f"fused fault path regressed: {overhead:.2f}x the no-fault " \
        f"replay (contract: <=2x at {n_jobs} jobs)"

    # Oracle check: preemption storm over a chained DAG, ~300 jobs.
    n_mid = 300
    storm = FaultSchedule.preemption_storm(
        nodes(), t=60.0, frac=0.5, seed=1, down_time=90.0, window=20.0)
    fres = ClusterSim(nodes(), engine="fused").run(
        build_jobs(n_mid, seed=1, parents_every=50), RetrySpec("ksplus"),
        faults=storm)
    t0 = time.perf_counter()
    lres = ClusterSim(nodes(), engine="legacy").run(
        build_jobs(n_mid, seed=1, parents_every=50), ksplus_retry,
        faults=storm)
    us_l = (time.perf_counter() - t0) * 1e6
    assert fres.placements == lres.placements, \
        "fused fault path diverged from the legacy oracle"
    assert fres.evictions == lres.evictions
    assert fres.doomed == lres.doomed
    assert fres.unschedulable == lres.unschedulable

    # Suite smoke grid (fused vs legacy per case).
    smoke = [SuiteCase("burst_arrival", "poisson", "storm", seed=0),
             SuiteCase("deep_chain", "none", "churn", seed=0),
             SuiteCase("wide_fanout", "diurnal", "storm", seed=0)]
    t0 = time.perf_counter()
    rows = run_suite(smoke, nodes=nodes, n_tasks=96 if full else 48,
                     check_oracle=True)
    us_suite = (time.perf_counter() - t0) * 1e6
    total_evict = sum(r["evictions"] for r in rows)

    _row("churn_replay_overhead", us_churn,
         f"{overhead:.2f}x no-fault fused (target <=2x, {n_jobs} jobs, "
         f"{cres.evictions} evictions, {len(churn)} fault events)")
    _row("churn_replay_plain_us", us_plain,
         f"makespan {pres.makespan:.0f}s, {pres.retries} retries")
    _row("churn_replay_storm_oracle_us", us_l,
         f"fused bitwise vs legacy ({n_mid} jobs, {lres.evictions} "
         f"evictions, {lres.doomed} doomed)")
    _row("churn_replay_suite_us", us_suite,
         f"{len(rows)} smoke cases, oracle-checked, "
         f"{total_evict} evictions")
    with open("BENCH_churn.json", "w") as f:
        json.dump({
            "schema": 1,
            "churn_replay_jobs": n_jobs,
            "churn_replay_overhead_x": overhead,
            "churn_replay_plain_us": us_plain,
            "churn_replay_churn_us": us_churn,
            "churn_replay_evictions": cres.evictions,
            "churn_replay_fault_events": len(churn),
            "churn_replay_storm_jobs": n_mid,
            "churn_replay_storm_evictions": lres.evictions,
            "churn_replay_storm_doomed": lres.doomed,
            "churn_replay_storm_bitwise": True,
            "churn_replay_suite_cases": len(rows),
            "churn_replay_suite_oracle_ok": True,
            "churn_replay_suite_rows": rows,
        }, f, indent=1)


# --------------------------------------------------------------------- serve
def bench_serve(full: bool):
    """serve_saturation: the multi-tenant prediction service under load.

    Wraps :func:`repro.serve.bench.run_saturation` (same payload as
    ``python -m repro.serve``) and dumps BENCH_serve.json:

    * throughput — one seeded mixed-tenant tape through a micro-batched
      server vs an unbatched one (identical dispatch code, batch size 1);
      the speedup is gated (``serve_speedup_x``) and every batched plan
      must be bitwise equal to its unbatched twin (``serve_bitwise``);
    * latency — virtual-clock open-loop Poisson arrivals; p50/p99 are
      reported, not gated (wall-clock on shared runners is noisy);
    * discipline — prediction-cache hit rate on repeat traffic
      (``serve_cache_hit_ok``) and the warm zero-compile /
      zero-re-upload pin under dispatch_budget (``serve_warm_ok``).
    """
    from repro.serve.bench import run_saturation

    n = 4096 if full else 2048
    out = run_saturation(tenants=8, n_requests=n, rate_rps=2000.0, seed=0)
    thr, lat, disc = out["throughput"], out["latency"], out["discipline"]
    assert thr["bitwise"], "batched plans diverged from unbatched twins"
    assert disc["warm_zero_compiles"], \
        "warm serving path compiled or re-uploaded traces"

    _row("serve_speedup", 0.0,
         f"{thr['speedup_x']:.2f}x unbatched ({thr['n_requests']} reqs, "
         f"8 tenants, mean batch {thr['mean_batch']:.1f}, bitwise)")
    _row("serve_req_s_batched", 0.0, f"{thr['req_s_batched']:.0f} req/s")
    _row("serve_latency", 0.0,
         f"p50 {lat['p50_ms']:.2f} ms, p99 {lat['p99_ms']:.2f} ms "
         f"@ {lat['rate_rps']:.0f} req/s open-loop")
    _row("serve_cache_hit_rate", 0.0,
         f"{disc['cache_hit_rate']:.2f} on repeat-pool traffic")
    _row("serve_warm_discipline", 0.0,
         f"zero compiles, {disc['distinct_shapes']} distinct bucket "
         f"shapes after warmup")
    with open("BENCH_serve.json", "w") as f:
        json.dump({
            "schema": 1,
            "serve_requests": thr["n_requests"],
            "serve_tenants": thr["tenants"],
            "serve_speedup_x": thr["speedup_x"],
            "serve_req_s_batched": thr["req_s_batched"],
            "serve_req_s_unbatched": thr["req_s_unbatched"],
            "serve_mean_batch": thr["mean_batch"],
            "serve_bitwise": bool(thr["bitwise"]),
            "serve_p50_ms": lat["p50_ms"],
            "serve_p99_ms": lat["p99_ms"],
            "serve_latency_rate_rps": lat["rate_rps"],
            "serve_cache_hit_rate": disc["cache_hit_rate"],
            "serve_cache_hit_ok": bool(disc["cache_hit_ok"]),
            "serve_warm_ok": bool(disc["warm_zero_compiles"]),
            "serve_distinct_shapes": disc["distinct_shapes"],
        }, f, indent=1)


# ----------------------------------------------------------------------- obs
def bench_obs(full: bool):
    """Observability overhead + timeline artifacts (BENCH_obs.json).

    * overhead — the seeded churn workload replayed through the fused
      engine untraced vs ``trace=True``; placements must stay bitwise
      and the traced replay within 10% wall-clock (the measured
      ``obs_overhead_x`` is what the regression guard gates — the
      steady-state budget is <=3%, the in-bench ceiling leaves room for
      runner noise);
    * artifacts — the traced replay plus a traced serve tape exported as
      a Perfetto/Chrome trace (``obs_trace.perfetto.json``), Prometheus
      text (``obs_metrics.prom``) and a JSON metrics snapshot
      (``obs_metrics.json``); the summarize CLI's ``read_events`` must
      round-trip the trace.
    """
    from repro import obs
    from repro.core import RetrySpec
    from repro.sched import ClusterSim, FaultSchedule
    from repro.serve.bench import _run_tape, build_server, request_tape

    n_jobs = 600 if full else 300
    churn = FaultSchedule.node_churn(_churn_nodes(), rate=1.0 / 60.0,
                                     horizon=2000.0, seed=0,
                                     mean_down=45.0)

    def replay(trace):
        return ClusterSim(_churn_nodes(), engine="fused").run(
            _churn_jobs(n_jobs, seed=0, parents_every=3),
            RetrySpec("ksplus"), faults=churn, trace=trace)

    replay(False)  # warm the shared programs once
    obs.clear()
    obs.REGISTRY.clear()
    # Paired-ratio median: runner-load drift between replays dwarfs the
    # tracing delta, so time off/on back-to-back, take each pair's
    # ratio, and gate on the median — pairing cancels the drift, the
    # median rejects the outliers a min-of-N would anchor on.  GC is
    # held off during the timed region: the traced replay's extra
    # allocations otherwise pull collector passes into its half of the
    # pair, and late in a long bench process (big gen2 heap) those
    # pauses double the apparent overhead.
    pairs = []
    offs, ons = [], []
    gc.collect()
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        for _ in range(7):
            t0 = time.perf_counter()
            pres = replay(False)
            offs.append(time.perf_counter() - t0)
            obs.clear()
            obs.REGISTRY.clear()
            t0 = time.perf_counter()
            tres = replay(True)
            ons.append(time.perf_counter() - t0)
            pairs.append(ons[-1] / offs[-1])
    finally:
        if gc_was_enabled:
            gc.enable()
        gc.collect()
    us_off = min(offs) * 1e6
    us_on = min(ons) * 1e6
    overhead = sorted(pairs)[len(pairs) // 2]
    assert pres.placements == tres.placements, \
        "tracing perturbed placements"
    assert pres.total_wastage_gbs == tres.total_wastage_gbs
    assert overhead <= 1.10, \
        f"tracing overhead {overhead:.3f}x the untraced replay " \
        f"(budget: <=3% steady-state, 10% in-bench ceiling)"

    # A traced serve burst rides the same ring/registry.
    clock = [0.0]
    srv = build_server(tenants=4, clock=lambda: clock[0])
    tape = request_tape(512, tenants=4, seed=7, repeat_pool=64)
    with obs.tracing():
        _run_tape(srv, tape)

    n_events = obs.write_chrome_trace("obs_trace.perfetto.json")
    obs.write_prometheus("obs_metrics.prom")
    obs.write_metrics_snapshot("obs_metrics.json")
    with open("obs_trace.perfetto.json") as f:
        doc = json.load(f)
    trace_valid = (isinstance(doc.get("traceEvents"), list)
                   and len(doc["traceEvents"]) == n_events
                   and all("ph" in ev and "ts" in ev and "name" in ev
                           for ev in doc["traceEvents"]))
    rt = obs.read_events("obs_trace.perfetto.json")
    summary = obs.summarize(rt)
    summarize_ok = ("cluster.run" in summary
                    and "admission.drain" in summary
                    and len(rt) == n_events)
    drains = obs.REGISTRY.hist("admission.drain.lanes",
                               buckets=obs.metrics.COUNT_BUCKETS).count()

    _row("obs_overhead", us_on,
         f"{overhead:.3f}x untraced ({n_jobs}-job churn replay, "
         f"{n_events} trace events, {drains} drains)")
    _row("obs_untraced_us", us_off,
         f"makespan {pres.makespan:.0f}s, {pres.retries} retries")
    with open("BENCH_obs.json", "w") as f:
        json.dump({
            "schema": 1,
            "obs_replay_jobs": n_jobs,
            "obs_overhead_x": overhead,
            "obs_untraced_us": us_off,
            "obs_traced_us": us_on,
            "obs_bitwise": True,
            "obs_trace_events": n_events,
            "obs_trace_valid_ok": bool(trace_valid),
            "obs_summarize_ok": bool(summarize_ok),
            "obs_serve_requests": len(tape),
        }, f, indent=1)


# ------------------------------------------------------------------- kernels
def bench_kernels(full: bool):
    """Interpret-mode kernel micro-benchmarks vs their jnp oracles."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import flash_attention, ssd_pallas, wastage_eval
    from repro.core.wastage import wastage_eval_ref
    rng = np.random.default_rng(0)

    q = jnp.asarray(rng.standard_normal((1, 256, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 256, 2, 64)), jnp.float32)
    _, us = _timed(lambda: flash_attention(
        q, k, v, causal=True, block_q=128, block_k=128,
        interpret=True).block_until_ready())
    _row("kernel_flash_attn_256_interpret", us, "validated-vs-ref")

    X = jnp.asarray(rng.standard_normal((1, 256, 4, 32)) * 0.3, jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal((1, 256, 4))) * 0.3,
                    jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((1, 256, 1, 32)) * 0.3, jnp.float32)
    _, us = _timed(lambda: ssd_pallas(X, A, Bm, Bm, chunk=64,
                                      interpret=True)[0].block_until_ready())
    _row("kernel_ssd_256_interpret", us, "validated-vs-ref")

    B, T, kk = 64, 1024, 4
    starts = np.sort(rng.uniform(0, 800, (B, kk)), 1)
    starts[:, 0] = 0
    peaks = np.sort(rng.uniform(1, 10, (B, kk)), 1)
    mems = np.abs(rng.normal(3, 1, (B, T)))
    lens = rng.integers(200, T, B)
    _, us_k = _timed(lambda: np.asarray(
        wastage_eval(starts, peaks, mems, lens, interpret=True)))
    _, us_r = _timed(lambda: wastage_eval_ref(starts, peaks, mems, lens, 1.0))
    _row("kernel_wastage_64x1024_interpret", us_k, f"ref_np={us_r:.0f}us")

    from repro.kernels.wastage.ops import oom_probe
    from repro.core.wastage import oom_probe_ref
    _, us_k = _timed(lambda: jax.block_until_ready(
        oom_probe(starts, peaks, mems, lens, interpret=True)))
    _, us_r = _timed(lambda: oom_probe_ref(starts, peaks, mems, lens, 1.0))
    _row("kernel_oom_probe_64x1024_interpret", us_k, f"ref_np={us_r:.0f}us")

    # batched JAX segmentation (the fleet-scale path)
    from repro.core import get_segments
    pad = jnp.asarray(np.abs(rng.normal(3, 1, (128, 512))), jnp.float32)
    lens2 = jnp.asarray(rng.integers(64, 512, 128), jnp.int32)
    seg = jax.jit(jax.vmap(lambda m, l: get_segments(m, l, 4)))
    jax.block_until_ready(seg(pad, lens2))  # compile
    _, us = _timed(lambda: jax.block_until_ready(seg(pad, lens2)))
    _row("core_segmentation_vmap128x512", us, "alg1-batched")


# ------------------------------------------------------------------ roofline
def bench_roofline_summary(full: bool):
    """Summarize experiments/roofline/*.json into the §Roofline table."""
    d = "experiments/roofline"
    if not os.path.isdir(d):
        _row("roofline_summary", 0.0,
             "no artifacts (run python -m repro.launch.roofline)")
        return
    rows = []
    for fn in sorted(os.listdir(d)):
        with open(os.path.join(d, fn)) as f:
            r = json.load(f)
        if r.get("status") != "ok":
            continue
        rows.append(r)
        _row(f"roofline_{r['cell']}", 0.0,
             f"dom={r['dominant']} frac={r['roofline_fraction']:.3f} "
             f"useful={r['useful_ratio']:.2f} "
             f"peakGiB={r['peak_bytes_per_device']/2**30:.1f}")
    if rows:
        fracs = [r["roofline_fraction"] for r in rows]
        _row("roofline_median_fraction", 0.0, f"{np.median(fracs):.3f}")


BENCHES = {
    "fig1": bench_fig1_bwa,
    "fig5": bench_fig5_overview,
    "fig6": bench_fig6_wastage,
    "fig7": bench_fig7_segments,
    "fig8": bench_fig8_per_task,
    "fleet_sim": bench_fleet_sim,
    "online_replay": bench_online_replay,
    "cluster_sim": bench_cluster_sim,
    "admission": bench_admission,
    "workload_replay": bench_workload_replay,
    "drain": bench_drain,
    "churn_replay": bench_churn_replay,
    "serve": bench_serve,
    "obs": bench_obs,
    "kernels": bench_kernels,
    "roofline": bench_roofline_summary,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(BENCHES))
    ap.add_argument("--json", default="BENCH_fleet.json",
                    help="machine-readable dump (name -> us_per_call)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    for n in names:
        if n not in BENCHES:
            ap.error(f"unknown benchmark {n!r} (choose from {','.join(BENCHES)})")
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n](args.full)
    # Merge into the existing dump so `--only` subset runs refresh their own
    # rows without clobbering the rest of the perf trajectory.
    dump = {}
    if os.path.exists(args.json):
        try:
            with open(args.json) as f:
                dump = json.load(f)
        except (OSError, json.JSONDecodeError):
            dump = {}
    dump.update({name: us for name, us, _ in RESULTS})
    dump["schema"] = 1
    with open(args.json, "w") as f:
        json.dump(dump, f, indent=1)


if __name__ == "__main__":
    main()
