"""``python -m repro.analysis [paths...]`` — run the lint gate."""

import sys

from .lint import main

if __name__ == "__main__":
    sys.exit(main())
