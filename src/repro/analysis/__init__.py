"""Static analysis + runtime contracts for the repro hot paths.

Two halves:

* :mod:`repro.analysis.lint` / :mod:`repro.analysis.rules` — the
  AST-based, JAX-aware checker (``python -m repro.analysis src/`` or the
  ``repro-lint`` entry point): use-after-donation, host-sync-in-hot-path,
  x64-scope, tracer-unsafe-control-flow, recompile-hazard, gated by an
  inline-allow + baseline ratchet.
* :mod:`repro.analysis.contracts` — ``dispatch_budget`` /
  ``record_dispatch``, the runtime assertions that pin one-program-per-
  drain, bounded compiled-shape counts, and zero-rebuild churn.

This package must stay import-light: ``contracts`` defers its jax
import to first use so instrumented hot-path modules can import
``record_dispatch`` without cycles or load-time cost.
"""

from .contracts import (DispatchBudgetError, dispatch_budget,
                        record_dispatch)
from .lint import Finding, LintConfig, run_lint

__all__ = [
    "DispatchBudgetError",
    "dispatch_budget",
    "record_dispatch",
    "Finding",
    "LintConfig",
    "run_lint",
]
