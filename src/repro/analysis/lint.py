"""JAX-aware lint driver: registry, suppressions, baseline ratchet.

Usage (also exposed as ``python -m repro.analysis`` / ``repro-lint``)::

    repro-lint src/                         # gate: fail on new findings
    repro-lint --strict src/                # also fail on stale baseline
    repro-lint --update-baseline src/       # rewrite the baseline counts

Two suppression mechanisms, both requiring a human-readable reason:

* inline — ``# lint: allow[rule] reason`` on the flagged line (or a
  standalone comment on the line above).  A reason is mandatory; a bare
  allow is itself reported as a ``bare-suppression`` finding.
* baseline — ``analysis_baseline.json`` maps ``"<path>::<rule>"`` to
  ``{"count": N, "why": "..."}``.  The gate fails when a file/rule pair
  exceeds its baselined count (the baseline can never grow silently);
  ``--strict`` additionally fails when the count *dropped*, forcing the
  baseline to be re-tightened — the ratchet only turns one way.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from collections import Counter

from .model import ModuleModel, build_model


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    @property
    def key(self) -> str:
        return f"{self.path}::{self.rule}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


_RULES: dict[str, "Rule"] = {}


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    doc: str
    fn: object  # callable(LintContext) -> Iterable[Finding]


def rule(name: str):
    """Register a rule function; its docstring is the ``--list-rules`` doc."""

    def deco(fn):
        _RULES[name] = Rule(name=name, doc=(fn.__doc__ or "").strip(), fn=fn)
        return fn

    return deco


def registered_rules() -> dict[str, Rule]:
    if not _RULES:
        from . import rules  # noqa: F401  (registers on import)
    return dict(_RULES)


@dataclasses.dataclass
class LintConfig:
    """Knobs the rules consult; tests override to point at fixtures."""

    # Hot-path roots for host-sync reachability: (class-or-None, function).
    entry_points: tuple = (
        ("ClusterSim", "run"),
        ("AdmissionState", "drain"),
        ("AdmissionState", "add_lanes"),
        ("AdmissionState", "mark_admitted"),
        ("ElasticPlanner", "drain"),
        (None, "simulate_fleet_many"),
        (None, "process_job_run"),
        ("MicroBatcher", "submit"),
        ("MicroBatcher", "_flush"),
    )
    # Path fragments exempt from hot-path rules (bench/warmup/tests).
    allow_paths: tuple = ("benchmarks/", "tests/", "launch/")
    # Function-name prefixes exempt from hot-path rules.
    allow_funcs: tuple = ("bench_", "warmup", "_warmup", "main")
    max_call_depth: int = 6


@dataclasses.dataclass
class LintContext:
    models: list[ModuleModel]
    config: LintConfig

    def model_for(self, path: str) -> ModuleModel | None:
        for m in self.models:
            if m.path == path:
                return m
        return None


def collect_files(paths: list[str]) -> list[str]:
    out = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(
                    d for d in dirs
                    if d not in {"__pycache__", ".git", ".ruff_cache"})
                out.extend(os.path.join(root, f)
                           for f in sorted(files) if f.endswith(".py"))
        else:
            raise SystemExit(f"lint: no such path: {p}")
    return out


def run_lint(paths: list[str],
             config: LintConfig | None = None,
             ) -> tuple[list[Finding], list[Finding], int]:
    """Lint ``paths``; return (active, inline_suppressed, n_files).

    ``active`` still includes baselined findings — the baseline is
    applied by :func:`apply_baseline` so callers can see both sides.
    """
    config = config or LintConfig()
    models, parse_failures = [], []
    files = collect_files(paths)
    for fpath in files:
        rel = os.path.relpath(fpath).replace(os.sep, "/")
        with open(fpath, encoding="utf-8") as f:
            src = f.read()
        try:
            models.append(build_model(rel, src))
        except SyntaxError as e:
            parse_failures.append(Finding(
                rule="parse-error", path=rel, line=e.lineno or 0,
                message=str(e.msg)))
    ctx = LintContext(models=models, config=config)

    raw: list[Finding] = list(parse_failures)
    for r in registered_rules().values():
        raw.extend(r.fn(ctx))

    active, suppressed = [], []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.rule)):
        m = ctx.model_for(f.path)
        sup = m.suppressions.get(f.line) if m else None
        if sup is not None and sup[0] == f.rule:
            suppressed.append(f)
        else:
            active.append(f)

    # A suppression without a reason is itself a finding.
    for m in models:
        for line, (rname, reason) in sorted(m.suppressions.items()):
            if not reason:
                active.append(Finding(
                    rule="bare-suppression", path=m.path, line=line,
                    message=f"allow[{rname}] needs a justification after "
                            f"the rule name"))
    return active, suppressed, len(files)


def load_baseline(path: str) -> dict:
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    return {k: v for k, v in data.items() if not k.startswith("_")}


def apply_baseline(active: list[Finding], baseline: dict,
                   ) -> tuple[list[Finding], list[str], list[str]]:
    """Split active findings into (new, baselined_keys, stale_notes)."""
    counts = Counter(f.key for f in active)
    new: list[Finding] = []
    for key, grp_count in sorted(counts.items()):
        allowed = int(baseline.get(key, {}).get("count", 0))
        if grp_count > allowed:
            group = [f for f in active if f.key == key]
            # Over budget: every finding in the group is reported so the
            # author can pick which to fix or justify.
            new.extend(group)
    stale = []
    for key, entry in sorted(baseline.items()):
        allowed = int(entry.get("count", 0))
        have = counts.get(key, 0)
        if have < allowed:
            stale.append(
                f"baseline stale: {key} allows {allowed}, found {have} — "
                f"shrink it (repro-lint --update-baseline)")
    baselined = [k for k in counts if counts[k] <= int(
        baseline.get(k, {}).get("count", 0))]
    return new, baselined, stale


def write_baseline(path: str, active: list[Finding],
                   old: dict | None = None) -> dict:
    counts = Counter(f.key for f in active)
    old = old or {}
    data = {
        "_comment": "repro-lint suppression baseline. Keys are "
                    "'<path>::<rule>'; 'count' is the allowed number of "
                    "findings, 'why' the standing justification. The CI "
                    "lint job fails when any count is exceeded, and "
                    "(--strict) when a count goes stale — the baseline "
                    "only shrinks.",
    }
    for key in sorted(counts):
        why = old.get(key, {}).get("why", "TODO: justify")
        data[key] = {"count": counts[key], "why": why}
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2, sort_keys=False)
        f.write("\n")
    return data


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-lint",
        description="JAX-aware static checks for the repro hot paths")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--baseline", default="analysis_baseline.json",
                    help="suppression baseline JSON "
                         "(default: analysis_baseline.json)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on stale baseline entries")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline from current findings")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in registered_rules().values():
            print(f"{r.name}\n    {r.doc}\n")
        return 0

    active, suppressed, n_files = run_lint(args.paths or ["src"])

    if args.update_baseline:
        old = load_baseline(args.baseline)
        data = write_baseline(args.baseline, active, old)
        n_todo = sum(1 for v in data.values()
                     if isinstance(v, dict) and v.get("why", "").startswith(
                         "TODO"))
        print(f"baseline rewritten: {len(data) - 1} keys "
              f"({n_todo} need a 'why')")
        return 0

    baseline = load_baseline(args.baseline)
    new, baselined, stale = apply_baseline(active, baseline)

    print(f"repro-lint: {n_files} files, "
          f"{len(active)} findings "
          f"({len(suppressed)} inline-suppressed, "
          f"{len(baselined)} file/rule groups baselined)")
    status = 0
    if new:
        print("NEW findings (fix, inline-allow with a reason, or baseline):")
        for f in new:
            print("  " + f.render())
        status = 1
    if stale:
        for note in stale:
            print(("  ! " if args.strict else "  note: ") + note)
        if args.strict:
            status = 1
    if status == 0:
        print("OK")
    return status


if __name__ == "__main__":
    sys.exit(main())
