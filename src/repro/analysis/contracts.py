"""Runtime dispatch/compile contracts: ``dispatch_budget``.

The static rules in :mod:`repro.analysis.rules` catch hazards in the
source; this module pins the *observed* behaviour.  Two signals:

* **compiles** — counted through jax's monitoring hooks: the
  ``/jax/core/compile/backend_compile_duration`` event fires exactly
  once per backend (XLA) compilation and never on a cache hit, so the
  delta across a scope is the number of new compiled programs.
* **dispatches** — jax has no cached-dispatch hook, so the repo's own
  device-program call sites self-report through
  :func:`record_dispatch` (``admission.drain``, ``admission.columns``,
  ``admission.scatter``, ``admission.dev_sync``, ``cluster.first_attempt``,
  ``fleet.probe``, ``fleet.retry``).  The counter is a plain dict
  increment — nanoseconds against the ~ms dispatches it counts.

Usage::

    with dispatch_budget(compiles=0, forbid=("admission.dev_sync",)) as b:
        sim.run()
    # raises DispatchBudgetError on exit if the scope compiled anything
    # or rebuilt device state; b.compiles / b.tag_counts stay readable.

``jax.monitoring`` has no per-listener unregister, so one module-global
listener is registered lazily on first use and feeds a counter for the
life of the process.
"""

from __future__ import annotations

import contextlib
from collections import Counter

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_compile_count = 0
_listener_registered = False
_dispatches: Counter = Counter()

# Optional observer installed by repro.obs.trace.enable(): called as
# hook(tag, n) after every record_dispatch.  None (one pointer check)
# whenever tracing is off; contracts never imports repro.obs.
_obs_dispatch_hook = None


def _ensure_listener() -> None:
    global _listener_registered
    if _listener_registered:
        return
    from jax import monitoring

    def _on_duration(event: str, duration: float, **kwargs) -> None:
        global _compile_count
        if event == _COMPILE_EVENT:
            _compile_count += 1

    monitoring.register_event_duration_secs_listener(_on_duration)
    _listener_registered = True


def record_dispatch(tag: str, n: int = 1) -> None:
    """Self-report ``n`` device-program executions under ``tag``.

    Called by the engine at every site that launches a compiled program
    (or, for ``*.dev_sync`` tags, re-uploads device state wholesale).
    Unconditional and cheap; budgets read the counter deltas.
    """
    _dispatches[tag] += n
    if _obs_dispatch_hook is not None:
        _obs_dispatch_hook(tag, n)


def compile_count() -> int:
    """Backend compiles observed so far (listener registers on first use)."""
    _ensure_listener()
    return _compile_count


def dispatch_counts() -> Counter:
    """Copy of the global per-tag dispatch counter."""
    return Counter(_dispatches)


class DispatchBudgetError(AssertionError):
    """A dispatch/compile contract was violated inside a budget scope."""


class Budget:
    """Live view of compile/dispatch activity since scope entry."""

    def __init__(self, compiles, dispatches, tags, forbid):
        self.max_compiles = compiles
        self.max_dispatches = dispatches
        self.tags = tuple(tags) if tags else None
        self.forbid = tuple(forbid)
        self._compiles0 = _compile_count
        self._dispatches0 = Counter(_dispatches)

    @property
    def compiles(self) -> int:
        return _compile_count - self._compiles0

    @property
    def tag_counts(self) -> Counter:
        now = Counter(_dispatches)
        now.subtract(self._dispatches0)
        return +now

    @property
    def dispatches(self) -> int:
        counts = self.tag_counts
        if self.tags is not None:
            return sum(counts[t] for t in self.tags)
        return sum(counts.values())

    def violations(self) -> list[str]:
        out = []
        if self.max_compiles is not None and self.compiles > self.max_compiles:
            out.append(
                f"compiled {self.compiles} new programs "
                f"(budget {self.max_compiles})")
        if (self.max_dispatches is not None
                and self.dispatches > self.max_dispatches):
            scope = f" across tags {list(self.tags)}" if self.tags else ""
            out.append(
                f"launched {self.dispatches} dispatches{scope} "
                f"(budget {self.max_dispatches})")
        counts = self.tag_counts
        for tag in self.forbid:
            if counts[tag]:
                out.append(
                    f"forbidden dispatch tag `{tag}` fired "
                    f"{counts[tag]}x")
        return out


@contextlib.contextmanager
def dispatch_budget(compiles: int | None = None,
                    dispatches: int | None = None,
                    tags=None,
                    forbid=()):
    """Assert compile/dispatch ceilings over a scope.

    ``compiles``   — max NEW backend compilations allowed (None: untracked).
    ``dispatches`` — max recorded dispatches, optionally restricted to
                     ``tags`` (None: untracked).
    ``forbid``     — dispatch tags that must not fire at all.

    Raises :class:`DispatchBudgetError` on scope exit listing every
    violated ceiling; yields a :class:`Budget` whose ``compiles`` /
    ``dispatches`` / ``tag_counts`` stay readable after exit.
    """
    _ensure_listener()
    budget = Budget(compiles, dispatches, tags, forbid)
    yield budget
    problems = budget.violations()
    if problems:
        raise DispatchBudgetError(
            "dispatch budget violated: " + "; ".join(problems))
