"""The JAX-aware rules.

Each rule is a function over a :class:`~repro.analysis.lint.LintContext`
registered with :func:`~repro.analysis.lint.rule`; it yields
:class:`~repro.analysis.lint.Finding` objects.  Rules are deliberately
syntactic — they know the repo's idioms (kernel factories, ``_KERNEL_CACHE``,
``pad_lane_axis`` bucketing, ``enable_x64`` scoping) and trade exhaustive
soundness for a low false-positive rate on exactly those idioms.
"""

from __future__ import annotations

import ast

from .lint import Finding, LintContext, rule
from .model import (FunctionInfo, JitDef, ModuleModel, dotted_name,
                    iter_scope, tail_name)

# Host-conversion callables: their result is a host value (rule 2 decides
# whether the *conversion itself* is a problem; rule 4 treats the result
# as safe to branch on).
_HOST_CONVERTERS = {"int", "float", "bool", "len"}
_NP_SYNC = {"np.asarray", "numpy.asarray", "np.array", "numpy.array"}
_EXPLICIT_SYNC = {"jax.device_get", "device_get"}
# Shape-bucketing helpers: a len()/shape value routed through one of
# these no longer recompiles per distinct size.
_BUCKETERS = {"_bucket", "_pow4", "pad_lane_axis", "group_lengths",
              "bit_length", "next_power_of_two"}
_RAW_ALLOC = {"np.zeros", "np.empty", "np.full", "np.ones",
              "jnp.zeros", "jnp.empty", "jnp.full", "jnp.ones",
              "numpy.zeros", "numpy.empty", "numpy.full", "numpy.ones"}


def _pos(node) -> tuple[int, int]:
    return (getattr(node, "lineno", 0), getattr(node, "col_offset", 0))


def _end_pos(node) -> tuple[int, int]:
    return (getattr(node, "end_lineno", getattr(node, "lineno", 0)),
            getattr(node, "end_col_offset", 0))


def _scope_sorted(fnode):
    return sorted(iter_scope(fnode), key=_pos)


def _jit_tables(ctx: LintContext):
    """(top-level jitted defs by bare name, factory name -> inner JitDef)."""
    jits: dict[str, JitDef] = {}
    factories: dict[str, JitDef] = {}
    for m in ctx.models:
        for fi in m.functions.values():
            if fi.jit is not None and "." not in fi.qualname:
                jits[fi.name] = fi.jit
        factories.update(m.factories)
        # `fn = jax.jit(...)` assignments are top-level callables too.
        for name, jd in m.jit_defs.items():
            if name not in jits and jd.factory is None and all(
                    f.name != name or f.jit is not jd
                    for f in m.functions.values()):
                jits[name] = jd
    return jits, factories


def _local_jit_map(fi: FunctionInfo, factories: dict) -> dict[str, JitDef]:
    """Names bound in this function from kernel-factory calls.

    Handles ``kernel = _drain_kernel(...)`` and the ternary form
    ``kernel = (_a(...) if cond else _b(...))``.
    """
    out: dict[str, JitDef] = {}
    for node in iter_scope(fi.node):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        val = node.value
        cands = [val.body, val.orelse] if isinstance(val, ast.IfExp) else [val]
        for c in cands:
            if isinstance(c, ast.Call):
                t = tail_name(c.func)
                if t in factories:
                    out[node.targets[0].id] = factories[t]
                    break
    return out


def _resolve_callee(call: ast.Call, local: dict, jits: dict
                    ) -> JitDef | None:
    t = tail_name(call.func)
    if t in local:
        return local[t]
    return jits.get(t)


def _store_names(target: ast.AST) -> set[str]:
    out = set()
    for node in ast.walk(target):
        if isinstance(node, (ast.Name, ast.Attribute)):
            d = dotted_name(node)
            if d:
                out.add(d)
    return out


# ---------------------------------------------------------------------------
# rule 1: use-after-donation


@rule("use-after-donation")
def use_after_donation(ctx: LintContext):
    """A buffer passed to a ``donate_argnums``/``donate_argnames`` call
    site is read again before being rebound.  Donated device buffers are
    invalidated by the call; any later read sees deleted memory."""
    jits, factories = _jit_tables(ctx)
    for m in ctx.models:
        for fi in m.functions.values():
            local = _local_jit_map(fi, factories)
            nodes = _scope_sorted(fi.node)
            stmts = [n for n in nodes if isinstance(n, ast.stmt)]
            for call in nodes:
                if not isinstance(call, ast.Call):
                    continue
                jd = _resolve_callee(call, local, jits)
                if jd is None or not jd.donated_params():
                    continue
                for expr in _donated_actuals(call, jd):
                    d = dotted_name(expr)
                    if d is None:
                        continue
                    yield from _check_read_after(
                        m, fi, call, d, nodes, stmts)


def _donated_actuals(call: ast.Call, jd: JitDef):
    params = jd.params
    for i in jd.donate_argnums:
        if i < len(call.args):
            yield call.args[i]
    for kw in call.keywords:
        if kw.arg is None:
            continue
        if kw.arg in jd.donate_argnames:
            yield kw.value
        elif kw.arg in params and params.index(kw.arg) in jd.donate_argnums:
            yield kw.value
    for name in jd.donate_argnames:
        if name in params and params.index(name) < len(call.args):
            yield call.args[params.index(name)]


def _enclosing_stmt(call, stmts):
    best = None
    for s in stmts:
        if (_pos(s) <= _pos(call) and _end_pos(s) >= _end_pos(call)
                and (best is None or _pos(s) >= _pos(best))):
            best = s
    return best


def _check_read_after(m: ModuleModel, fi: FunctionInfo, call: ast.Call,
                      donated: str, nodes, stmts):
    encl = _enclosing_stmt(call, stmts)
    if encl is not None and isinstance(
            encl, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
        targets = (encl.targets if isinstance(encl, ast.Assign)
                   else [encl.target])
        for t in targets:
            if donated in _store_names(t):
                return  # rebound by the very statement that donates
    boundary = _end_pos(encl if encl is not None else call)
    for node in nodes:
        if _pos(node) <= boundary:
            continue
        if isinstance(node, (ast.Name, ast.Attribute)):
            if dotted_name(node) != donated:
                continue
            if isinstance(node.ctx, ast.Store):
                return  # rebound before any read
            if isinstance(node.ctx, ast.Load):
                yield Finding(
                    rule="use-after-donation", path=m.path,
                    line=node.lineno,
                    message=f"`{donated}` was donated to "
                            f"`{tail_name(call.func)}` on line "
                            f"{call.lineno} and is read here before "
                            f"being rebound")
                return


# ---------------------------------------------------------------------------
# rule 2: host-sync-in-hot-path


@rule("host-sync-in-hot-path")
def host_sync_in_hot_path(ctx: LintContext):
    """``.item()``, ``float()``/``int()`` on device values,
    ``np.asarray``/``jax.device_get`` on jit results, or
    ``block_until_ready`` reachable from the event-loop entry points
    (``ClusterSim.run``, ``AdmissionState.drain``, fleet replay).  Each
    one stalls the dispatch pipeline for a device→host round trip."""
    jits, factories = _jit_tables(ctx)
    reachable = _reachable_functions(ctx)
    cfg = ctx.config
    for m in ctx.models:
        if any(frag in m.path for frag in cfg.allow_paths):
            continue
        for fi in m.functions.values():
            if fi.name not in reachable:
                continue
            if any(fi.name.startswith(p) for p in cfg.allow_funcs):
                continue
            local = _local_jit_map(fi, factories)
            tainted = _device_tainted(fi, local, jits)
            yield from _scan_syncs(m, fi, tainted, local, jits)


def _reachable_functions(ctx: LintContext) -> set[str]:
    """Bare function names reachable from the configured entry points."""
    graph: dict[str, set[str]] = {}
    roots: set[str] = set()
    known = {fi.name for m in ctx.models for fi in m.functions.values()}
    for m in ctx.models:
        for fi in m.functions.values():
            # calls, plus bound-method references to known functions
            # (``engine = self._run_fused; engine(...)``)
            graph.setdefault(fi.name, set()).update(
                fi.calls | (fi.refs & known))
            for klass, fname in ctx.config.entry_points:
                if fi.name == fname and (klass is None
                                         or fi.class_name == klass):
                    roots.add(fi.name)
    seen = set(roots)
    frontier = list(roots)
    for _ in range(ctx.config.max_call_depth):
        nxt = []
        for name in frontier:
            for callee in graph.get(name, ()):
                if callee in graph and callee not in seen:
                    seen.add(callee)
                    nxt.append(callee)
        if not nxt:
            break
        frontier = nxt
    return seen


def _device_tainted(fi: FunctionInfo, local: dict, jits: dict) -> set[str]:
    """Names holding values produced by jitted callables in this scope."""
    tainted: set[str] = set()
    for node in iter_scope(fi.node):
        if not isinstance(node, ast.Assign):
            continue
        if not (isinstance(node.value, ast.Call)
                and _resolve_callee(node.value, local, jits) is not None):
            continue
        for t in node.targets:
            if isinstance(t, ast.Name):
                tainted.add(t.id)
            elif isinstance(t, ast.Tuple):
                tainted.update(e.id for e in t.elts
                               if isinstance(e, ast.Name))
            elif isinstance(t, ast.Subscript) and isinstance(
                    t.value, ast.Name):
                tainted.add(t.value.id)
    return tainted


def _is_tainted_expr(expr, tainted, local, jits) -> bool:
    if isinstance(expr, ast.Name):
        return expr.id in tainted
    if isinstance(expr, ast.Subscript):
        return _is_tainted_expr(expr.value, tainted, local, jits)
    if isinstance(expr, ast.Call):
        return _resolve_callee(expr, local, jits) is not None
    return False


def _scan_syncs(m, fi, tainted, local, jits):
    for node in iter_scope(fi.node):
        if not isinstance(node, ast.Call):
            continue
        t = tail_name(node.func)
        dn = dotted_name(node.func)
        if t == "item" and not node.args:
            yield Finding(
                rule="host-sync-in-hot-path", path=m.path, line=node.lineno,
                message="`.item()` forces a device->host sync inside the "
                        "event loop")
        elif t == "block_until_ready":
            yield Finding(
                rule="host-sync-in-hot-path", path=m.path, line=node.lineno,
                message="`block_until_ready()` stalls the dispatch "
                        "pipeline in the hot path")
        elif dn in _EXPLICIT_SYNC:
            yield Finding(
                rule="host-sync-in-hot-path", path=m.path, line=node.lineno,
                message="`jax.device_get` is a device->host transfer in "
                        "the hot path")
        elif dn in _NP_SYNC and node.args and _is_tainted_expr(
                node.args[0], tainted, local, jits):
            yield Finding(
                rule="host-sync-in-hot-path", path=m.path, line=node.lineno,
                message=f"`{dn}` on a jit result blocks on the device "
                        f"in the hot path")
        elif (isinstance(node.func, ast.Name)
              and node.func.id in ("float", "int") and len(node.args) == 1
              and _is_tainted_expr(node.args[0], tainted, local, jits)):
            yield Finding(
                rule="host-sync-in-hot-path", path=m.path, line=node.lineno,
                message=f"`{node.func.id}()` on a jit result forces a "
                        f"device->host sync in the hot path")


# ---------------------------------------------------------------------------
# rule 3: x64-scope discipline


@rule("x64-scope")
def x64_scope(ctx: LintContext):
    """float64 dtypes or device literals constructed outside a
    ``with enable_x64():`` scope in jax-importing code.  Outside the
    scope jax silently truncates to float32, which breaks the
    float64-on-device precision contract bitwise."""
    for m in ctx.models:
        if not m.uses_jax:
            continue
        guarded = _x64_guarded_lines(m)
        for node in ast.walk(m.tree):
            line = getattr(node, "lineno", None)
            if line is None or line in m.x64_lines or line in guarded:
                continue
            dn = dotted_name(node) if isinstance(
                node, (ast.Name, ast.Attribute)) else None
            if dn in ("jnp.float64", "jax.numpy.float64"):
                yield Finding(
                    rule="x64-scope", path=m.path, line=line,
                    message="`jnp.float64` outside an `enable_x64()` "
                            "scope silently becomes float32")
            elif isinstance(node, ast.Call):
                fdn = dotted_name(node.func) or ""
                if not (fdn.startswith("jnp.")
                        or fdn.startswith("jax.numpy.")):
                    continue
                for kw in node.keywords:
                    if (kw.arg == "dtype"
                            and isinstance(kw.value, ast.Constant)
                            and kw.value.value == "float64"):
                        yield Finding(
                            rule="x64-scope", path=m.path, line=line,
                            message="dtype='float64' passed to a jnp "
                                    "constructor outside `enable_x64()`")


def _x64_guarded_lines(m: ModuleModel) -> set[int]:
    """Lines inside an explicit `jax_enable_x64`/x64 runtime guard."""
    guarded: set[int] = set()
    for node in ast.walk(m.tree):
        if isinstance(node, (ast.If, ast.IfExp)):
            test_names = {dotted_name(n) or "" for n in ast.walk(node.test)
                          if isinstance(n, (ast.Name, ast.Attribute))}
            if any("x64" in t for t in test_names):
                guarded.update(range(
                    node.lineno, (node.end_lineno or node.lineno) + 1))
    return guarded


# ---------------------------------------------------------------------------
# rule 4: tracer-unsafe control flow


@rule("tracer-unsafe-control-flow")
def tracer_unsafe_control_flow(ctx: LintContext):
    """Python ``if``/``while`` directly on a value returned by a jitted
    callable.  Under trace this raises ConcretizationTypeError; outside
    it is a hidden device sync.  Convert explicitly (``int()``/``bool``)
    or use ``lax.cond``/``jnp.where``."""
    jits, factories = _jit_tables(ctx)
    for m in ctx.models:
        for fi in m.functions.values():
            local = _local_jit_map(fi, factories)
            tainted = _device_tainted(fi, local, jits)
            if not tainted:
                continue
            for node in iter_scope(fi.node):
                if not isinstance(node, (ast.If, ast.While)):
                    continue
                name = _bare_tainted_in_test(node.test, tainted)
                if name:
                    kw = "if" if isinstance(node, ast.If) else "while"
                    yield Finding(
                        rule="tracer-unsafe-control-flow", path=m.path,
                        line=node.lineno,
                        message=f"Python `{kw}` branches on `{name}`, a "
                                f"jit result — tracer-unsafe and a "
                                f"hidden sync")


def _bare_tainted_in_test(test, tainted) -> str | None:
    """First tainted Name in the test not wrapped in a host converter."""
    stack = [test]
    while stack:
        node = stack.pop()
        if isinstance(node, ast.Call):
            t = tail_name(node.func)
            if t in _HOST_CONVERTERS or t in ("asarray", "array",
                                              "device_get"):
                continue  # explicit conversion: rule 2's territory
        if isinstance(node, ast.Name) and node.id in tainted:
            return node.id
        stack.extend(ast.iter_child_nodes(node))
    return None


# ---------------------------------------------------------------------------
# rule 5: recompile hazards


@rule("recompile-hazard")
def recompile_hazard(ctx: LintContext):
    """Jit signatures or call sites that recompile per event: float or
    unhashable static args, and operands shaped by a raw ``len()`` that
    skipped the pow2/pow4 bucketing helpers."""
    jits, factories = _jit_tables(ctx)
    for m in ctx.models:
        for fi in m.functions.values():
            if fi.jit is not None:
                yield from _static_arg_hazards(m, fi.jit)
            yield from _raw_shape_hazards(m, fi, factories, jits)


def _static_arg_hazards(m: ModuleModel, jd: JitDef):
    static = set(jd.static_argnames)
    params = jd.params
    for i in jd.static_argnums:
        if i < len(params):
            static.add(params[i])
    for pname in sorted(static):
        ann = jd.annotation_of(pname) or ""
        if "float" in ann:
            yield Finding(
                rule="recompile-hazard", path=m.path, line=jd.node.lineno,
                message=f"static arg `{pname}: {ann}` of `{jd.name}` "
                        f"recompiles per distinct float value")
        elif any(u in ann for u in ("list", "dict", "set", "ndarray")):
            yield Finding(
                rule="recompile-hazard", path=m.path, line=jd.node.lineno,
                message=f"static arg `{pname}: {ann}` of `{jd.name}` is "
                        f"unhashable — jit will reject or retrace it")


def _raw_shape_hazards(m: ModuleModel, fi: FunctionInfo, factories, jits):
    local = _local_jit_map(fi, factories)
    # Pass 1: names allocated with a len()-derived, unbucketed shape.
    raw: dict[str, int] = {}
    for node in iter_scope(fi.node):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        val = node.value
        if isinstance(val, ast.Call):
            dn = dotted_name(val.func)
            if dn in _RAW_ALLOC and _has_raw_len(val):
                raw[node.targets[0].id] = node.lineno
            # one aliasing hop: y = jnp.asarray(x) keeps x's shape
            elif (dn in _NP_SYNC or tail_name(val.func) == "asarray") \
                    and val.args and isinstance(val.args[0], ast.Name) \
                    and val.args[0].id in raw:
                raw[node.targets[0].id] = raw[val.args[0].id]
    if not raw:
        return
    # Pass 2: does a raw-shaped name feed a jitted call?
    for node in iter_scope(fi.node):
        if not isinstance(node, ast.Call):
            continue
        jd = _resolve_callee(node, local, jits)
        if jd is None:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            # unwrap an inline device upload: kernel(jnp.asarray(run_idx))
            if (isinstance(arg, ast.Call) and tail_name(arg.func) == "asarray"
                    and arg.args and isinstance(arg.args[0], ast.Name)):
                arg = arg.args[0]
            if isinstance(arg, ast.Name) and arg.id in raw:
                yield Finding(
                    rule="recompile-hazard", path=m.path, line=node.lineno,
                    message=f"`{arg.id}` (allocated with a raw len() "
                            f"shape on line {raw[arg.id]}) feeds jitted "
                            f"`{tail_name(node.func)}` — recompiles per "
                            f"distinct size; route through a bucketing "
                            f"helper")


def _has_raw_len(alloc_call: ast.Call) -> bool:
    """A len() call in the shape args not wrapped by a bucketing helper."""
    stack = [a for a in alloc_call.args] + [
        kw.value for kw in alloc_call.keywords if kw.arg != "dtype"]
    while stack:
        node = stack.pop()
        if isinstance(node, ast.Call):
            t = tail_name(node.func)
            if t in _BUCKETERS:
                continue
            if t == "len":
                return True
        stack.extend(ast.iter_child_nodes(node))
    return False


# ---------------------------------------------------------------------------
# rule 6: unguarded obs in hot path


# Module aliases the instrumentation convention imports observability
# under (``from repro.obs import trace as _obs`` / ``metrics as _met``)
# and the recording entry points that allocate when tracing is on.
_OBS_ROOTS = {"obs", "trace", "metrics", "_obs", "_met"}
_OBS_CALLS = {"span", "instant", "counter", "gauge", "hist", "series"}


@rule("unguarded-obs-in-hot-path")
def unguarded_obs_in_hot_path(ctx: LintContext):
    """A span/metric call reachable from the hot-path entry points that
    is not behind the module-level ``enabled`` guard.  The observability
    contract is that the disabled path is ONE attribute check — an
    unguarded ``_obs.span(...)`` or ``_met.counter(...)`` allocates and
    locks on every event even with tracing off."""
    reachable = _reachable_functions(ctx)
    cfg = ctx.config
    for m in ctx.models:
        if "repro/obs/" in m.path.replace("\\", "/"):
            continue  # the subsystem itself guards internally
        if any(frag in m.path for frag in cfg.allow_paths):
            continue
        guarded = _enabled_guarded_lines(m)
        for fi in m.functions.values():
            if fi.name not in reachable:
                continue
            if any(fi.name.startswith(p) for p in cfg.allow_funcs):
                continue
            for node in iter_scope(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                dn = dotted_name(node.func)
                if dn is None or "." not in dn:
                    continue
                if (dn.split(".")[0] not in _OBS_ROOTS
                        or tail_name(node.func) not in _OBS_CALLS):
                    continue
                if node.lineno in guarded:
                    continue
                yield Finding(
                    rule="unguarded-obs-in-hot-path", path=m.path,
                    line=node.lineno,
                    message=f"`{dn}(...)` in hot-path function "
                            f"`{fi.name}` is not behind the module-level "
                            f"enabled guard — wrap it in `if "
                            f"_obs.enabled:` so the disabled path stays "
                            f"a single attribute check")


def _enabled_guarded_lines(m: ModuleModel) -> set[int]:
    """Lines inside an ``if ...enabled...:`` guard (the obs convention:
    ``if _obs.enabled:`` around every hot-path span/metric call)."""
    guarded: set[int] = set()
    for node in ast.walk(m.tree):
        if isinstance(node, (ast.If, ast.IfExp)):
            test_names = {dotted_name(n) or "" for n in ast.walk(node.test)
                          if isinstance(n, (ast.Name, ast.Attribute))}
            if any(t.endswith("enabled") for t in test_names):
                guarded.update(range(
                    node.lineno, (node.end_lineno or node.lineno) + 1))
    return guarded
