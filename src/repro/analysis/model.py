"""AST fact extraction shared by the lint rules.

The rules in :mod:`repro.analysis.rules` never walk raw trees; they
query a :class:`ModuleModel` built here once per file.  The model knows
the JAX-specific shapes this repo actually uses:

* jitted defs — ``@jax.jit`` and
  ``@functools.partial(jax.jit, donate_argnums=..., static_argnames=...)``
  decorators, with the donate/static specs literal-evaluated;
* kernel factories — module functions that *return* an inner jitted def
  (the ``_fused_kernel(masked)`` / ``_KERNEL_CACHE`` pattern in
  ``sched.admission``), so a call site like ``kernel = _drain_kernel(...)``
  inherits the inner def's donation contract;
* ``with enable_x64():`` spans, for the x64-scope rule;
* per-function call edges (bare callee names), for hot-path
  reachability;
* inline ``# lint: allow[rule] reason`` suppressions.

Everything is a plain syntactic fact; no imports of the analysed code
are ever executed.
"""

from __future__ import annotations

import ast
import dataclasses
import re

JIT_NAMES = {"jax.jit", "jit", "jax.pjit", "pjit"}
PARTIAL_NAMES = {"functools.partial", "partial"}
X64_NAMES = {"enable_x64"}

_ALLOW_RE = re.compile(
    r"#\s*lint:\s*allow\[([a-z0-9-]+)\]\s*(.*?)\s*$")


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def tail_name(node: ast.AST) -> str | None:
    """Last component of a Name/Attribute chain (``c`` for ``a.b.c``)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def iter_scope(node: ast.AST):
    """Walk ``node`` without descending into nested function/class scopes.

    The root's own body is entered even when the root is itself a
    function; children that open a new scope (def/lambda/class) are
    yielded but not entered.
    """
    stack = list(ast.iter_child_nodes(node))
    while stack:
        child = stack.pop()
        yield child
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(child))


def _as_int_tuple(value) -> tuple[int, ...]:
    if isinstance(value, int):
        return (value,)
    if isinstance(value, (tuple, list)):
        return tuple(v for v in value if isinstance(v, int))
    return ()


def _as_str_tuple(value) -> tuple[str, ...]:
    if isinstance(value, str):
        return (value,)
    if isinstance(value, (tuple, list)):
        return tuple(v for v in value if isinstance(v, str))
    return ()


@dataclasses.dataclass
class JitDef:
    """A def compiled by ``jax.jit`` (directly or through ``partial``)."""

    name: str
    qualname: str
    node: ast.FunctionDef
    path: str
    donate_argnums: tuple[int, ...] = ()
    donate_argnames: tuple[str, ...] = ()
    static_argnums: tuple[int, ...] = ()
    static_argnames: tuple[str, ...] = ()
    factory: str | None = None  # enclosing factory function, if any

    @property
    def params(self) -> list[str]:
        a = self.node.args
        return [p.arg for p in a.posonlyargs + a.args]

    @property
    def kwonly_params(self) -> list[str]:
        return [p.arg for p in self.node.args.kwonlyargs]

    def annotation_of(self, pname: str) -> str | None:
        a = self.node.args
        for p in a.posonlyargs + a.args + a.kwonlyargs:
            if p.arg == pname and p.annotation is not None:
                return ast.unparse(p.annotation)
        return None

    def donated_params(self) -> set[str]:
        out = set(self.donate_argnames)
        params = self.params
        for i in self.donate_argnums:
            if 0 <= i < len(params):
                out.add(params[i])
        return out


def jit_spec(call_or_dec: ast.AST) -> dict | None:
    """Return the jit kwargs if the node is a jit expression, else None.

    Handles ``jax.jit``, ``jax.jit(...)``,
    ``functools.partial(jax.jit, ...)`` and ``jax.jit(fn, ...)``.
    An empty dict means "jitted, default options".
    """
    if dotted_name(call_or_dec) in JIT_NAMES:
        return {}
    if not isinstance(call_or_dec, ast.Call):
        return None
    fname = dotted_name(call_or_dec.func)
    if fname in JIT_NAMES:
        return _literal_kwargs(call_or_dec)
    if (fname in PARTIAL_NAMES and call_or_dec.args
            and dotted_name(call_or_dec.args[0]) in JIT_NAMES):
        return _literal_kwargs(call_or_dec)
    return None


def _literal_kwargs(call: ast.Call) -> dict:
    out = {}
    for kw in call.keywords:
        if kw.arg is None:
            continue
        try:
            out[kw.arg] = ast.literal_eval(kw.value)
        except (ValueError, SyntaxError):
            out[kw.arg] = None  # present but not a literal
    return out


def _make_jitdef(fnode, qualname, path, spec, factory=None) -> JitDef:
    return JitDef(
        name=fnode.name, qualname=qualname, node=fnode, path=path,
        donate_argnums=_as_int_tuple(spec.get("donate_argnums")),
        donate_argnames=_as_str_tuple(spec.get("donate_argnames")),
        static_argnums=_as_int_tuple(spec.get("static_argnums")),
        static_argnames=_as_str_tuple(spec.get("static_argnames")),
        factory=factory)


@dataclasses.dataclass
class FunctionInfo:
    """One def (module, method, or nested) plus its local facts."""

    name: str
    qualname: str
    class_name: str | None
    node: ast.FunctionDef
    path: str
    calls: set[str] = dataclasses.field(default_factory=set)
    # Name/Attribute loads that are not calls — bound-method dispatch
    # (``fn = self._run_fused; fn(...)``) shows up here, not in calls.
    refs: set[str] = dataclasses.field(default_factory=set)
    jit: JitDef | None = None

    @property
    def is_method(self) -> bool:
        return self.class_name is not None


@dataclasses.dataclass
class ModuleModel:
    """All syntactic facts the rules need for one source file."""

    path: str
    tree: ast.Module
    source_lines: list[str]
    functions: dict[str, FunctionInfo] = dataclasses.field(
        default_factory=dict)
    jit_defs: dict[str, JitDef] = dataclasses.field(default_factory=dict)
    factories: dict[str, JitDef] = dataclasses.field(default_factory=dict)
    x64_lines: set[int] = dataclasses.field(default_factory=set)
    uses_enable_x64: bool = False
    imports: set[str] = dataclasses.field(default_factory=set)
    suppressions: dict[int, tuple[str, str]] = dataclasses.field(
        default_factory=dict)

    @property
    def uses_jax(self) -> bool:
        return "jax" in self.imports

    def function_of(self, qualtail: str) -> FunctionInfo | None:
        """Look up by bare name or qualname suffix (first match)."""
        if qualtail in self.functions:
            return self.functions[qualtail]
        for q, fi in self.functions.items():
            if fi.name == qualtail:
                return fi
        return None


def build_model(path: str, source: str) -> ModuleModel:
    tree = ast.parse(source, filename=path)
    model = ModuleModel(path=path, tree=tree,
                        source_lines=source.splitlines())
    _collect_imports(model)
    _collect_functions(model)
    _collect_x64_spans(model)
    _collect_suppressions(model)
    return model


def _collect_imports(model: ModuleModel) -> None:
    for node in ast.walk(model.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                model.imports.add(alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            model.imports.add(node.module.split(".")[0])


def _collect_functions(model: ModuleModel) -> None:
    def visit(node, qualstack: list[str], class_name: str | None):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                visit(child, qualstack + [child.name], child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qualname = ".".join(qualstack + [child.name])
                info = FunctionInfo(
                    name=child.name, qualname=qualname,
                    class_name=class_name, node=child, path=model.path)
                for sub in iter_scope(child):
                    if isinstance(sub, ast.Call):
                        callee = tail_name(sub.func)
                        if callee:
                            info.calls.add(callee)
                    elif (isinstance(sub, (ast.Name, ast.Attribute))
                          and isinstance(getattr(sub, "ctx", None),
                                         ast.Load)):
                        ref = tail_name(sub)
                        if ref:
                            info.refs.add(ref)
                spec = _decorator_jit_spec(child)
                if spec is not None:
                    info.jit = _make_jitdef(
                        child, qualname, model.path, spec)
                    model.jit_defs[child.name] = info.jit
                model.functions[qualname] = info
                visit(child, qualstack + [child.name], None)
            else:
                visit(child, qualstack, class_name)

    visit(model.tree, [], None)
    _collect_factories(model)
    _collect_jit_assignments(model)


def _decorator_jit_spec(fnode) -> dict | None:
    for dec in fnode.decorator_list:
        spec = jit_spec(dec)
        if spec is not None:
            return spec
    return None


def _collect_factories(model: ModuleModel) -> None:
    """A function returning one of its own jitted inner defs is a factory."""
    for qualname, info in model.functions.items():
        inner = {
            fi.name: fi.jit for q, fi in model.functions.items()
            if fi.jit is not None and q.startswith(qualname + ".")}
        if not inner:
            continue
        for node in iter_scope(info.node):
            if (isinstance(node, ast.Return)
                    and isinstance(node.value, ast.Name)
                    and node.value.id in inner):
                jd = inner[node.value.id]
                jd.factory = info.name
                model.factories[info.name] = jd


def _collect_jit_assignments(model: ModuleModel) -> None:
    """``fn = jax.jit(helper, donate_argnums=...)`` at any scope."""
    for node in ast.walk(model.tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)
                and isinstance(node.value, ast.Call)):
            continue
        spec = jit_spec(node.value)
        if spec is None:
            continue
        name = node.targets[0].id
        # Prefer the wrapped def's signature when it is a local def.
        wrapped = None
        args = node.value.args
        base = args[1] if (dotted_name(node.value.func) in PARTIAL_NAMES
                           and len(args) > 1) else (
            args[0] if args else None)
        if base is not None and isinstance(base, ast.Name):
            fi = model.function_of(base.id)
            if fi is not None:
                wrapped = fi.node
        target = wrapped if wrapped is not None else ast.FunctionDef(
            name=name, args=ast.arguments(
                posonlyargs=[], args=[], kwonlyargs=[], kw_defaults=[],
                defaults=[]),
            body=[], decorator_list=[], lineno=node.lineno,
            col_offset=node.col_offset)
        model.jit_defs[name] = _make_jitdef(
            target, name, model.path, spec)


def _collect_x64_spans(model: ModuleModel) -> None:
    for node in ast.walk(model.tree):
        if not isinstance(node, (ast.With, ast.AsyncWith)):
            continue
        for item in node.items:
            expr = item.context_expr
            callee = expr.func if isinstance(expr, ast.Call) else expr
            if tail_name(callee) in X64_NAMES:
                model.uses_enable_x64 = True
                model.x64_lines.update(
                    range(node.lineno, (node.end_lineno or node.lineno) + 1))
                break


def _collect_suppressions(model: ModuleModel) -> None:
    """``# lint: allow[rule] reason`` — same line, or a standalone
    comment line applying to the next line."""
    for i, line in enumerate(model.source_lines, start=1):
        m = _ALLOW_RE.search(line)
        if not m:
            continue
        rule, reason = m.group(1), m.group(2)
        target = i
        if line.lstrip().startswith("#"):
            target = i + 1
        model.suppressions[target] = (rule, reason)
