"""Batched discrete-event cluster simulator with packed memory envelopes.

This is the paper's deployment context: a resource manager packs workflow
tasks onto nodes using each task's *memory envelope over time*.  KS+'s
envelopes free the unused head-room of early segments for other tasks —
the wastage reduction translates directly into throughput.

The simulator is discrete-event: nodes admit a queued job when the job's
allocation envelope fits under the node's *residual envelope* for the whole
projected runtime; the OOM killer fires when a job's hidden trace exceeds
its own allocation, triggering the method's retry strategy.

Three engines share the event semantics:

* ``engine="fused"`` (default) — the packed layout below, with the
  per-event hot path moved off the host: the admission check is ONE jitted
  XLA dispatch per event over every (node, queued job) pair at once
  (:class:`repro.sched.admission.AdmissionState` — device-resident packed
  state, donated-buffer updates, and an incremental fits-column
  invalidation mask instead of full per-admission recompute), and OOM
  retries that land at the same event time are compacted into one
  multi-row :func:`retry_packed` / re-probe slice (the fleet engine's
  compaction trick) instead of one Python round-trip per lane.
* ``engine="packed"`` — all job plans live in one packed
  ``(B, K)`` envelope batch (:mod:`repro.core.envelope`); the admission
  check is a single vectorized fits-under-residual reduction across every
  queued job per node, OOM times come from one batched
  :func:`repro.core.fleet.first_attempt` probe over the whole workload
  (device-resident traces), wastage is O(K) span arithmetic, and retry
  re-plans flow through :class:`RetrySpec` / :func:`retry_packed`.  Kept
  as the host-side float64 reference the fused engine is differentially
  pinned to (``tests/test_admission_fused.py``).
* ``engine="legacy"`` — the original per-job Python event loop, kept as the
  decision-for-decision oracle the packed engine is differentially tested
  against (``tests/test_cluster_packed.py``) and benchmarked against
  (``benchmarks/run.py --only cluster_sim``).

Precision contract: the packed engine's attempt-#1 OOM probe runs on the
device in float32 (that is what makes it one dispatch over the whole
workload); post-retry probes, admission residuals and wastage stay in
float64.  The two engines therefore agree bitwise whenever trace-vs-plan
margins exceed float32 resolution (~1e-7 relative) — true for the
differential workloads and for any real monitoring data, but a trace that
grazes its allocation within one float32 ulp may OOM under one engine and
not the other.

Fused-admission precision contract: the fused engine keeps the float32
attempt-#1 probe AND the float64 post-retry probes/wastage of the packed
engine; its admission residuals run in float64 *on the device*
(``jax.experimental.enable_x64`` scopes 64-bit semantics to those
dispatches) with the same elementwise operations as the host path.  The
only permitted divergence is the summation order over a node's resident
envelopes (numpy reduces linearly, XLA may tree-reduce) — last-ulp
(~1e-16 relative) residual differences, so an admission decision can only
flip when a job's need grazes the residual within one float64 ulp of the
1e-9 admission tolerance.  The differential suite pins the two engines'
placement logs bitwise on workloads with real margins.

``run(offsets=[...])`` sweeps peak/start safety offsets and
``last_peak_bump`` the way :class:`KSPlusAuto` sweeps k: plans are re-packed
per candidate (cheap) while the trace batch stays device-resident and the
per-candidate OOM probes hit the same jitted program.  Per-family
``offsets={family: OffsetCandidate}`` mappings may now disagree on *every*
field including ``last_peak_bump`` — bumps fold into a per-lane array that
rides :func:`repro.core.envelope.retry_packed`'s ``bump`` axis.

Workflow DAGs: jobs may carry ``parents`` (jids that must *finish* first).
All three engines drive the same dependency-release frontier
(:class:`_DagFrontier`): only released jobs enter the admission queue, a
``done`` event releases its children at that event time, and a permanent
failure (unsatisfiable / out of attempts) counts every not-yet-released
descendant as unschedulable.  Cycles, self-parents, duplicate and unknown
job ids are rejected loudly at submit time with the offending ids named.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import AllocationPlan, alloc_at, first_violation
from repro.core.envelope import (
    PAD_START,
    OffsetCandidate,
    PackedEnvelopes,
    RetrySpec,
    alloc_at_packed,
    apply_offsets,
    first_violation_packed,
    fits_under,
    residual_over,
    retry_packed,
    segment_sample_bounds,
    span_alloc_sum,
)
from repro.core.retry import apply_retry_spec

__all__ = ["Job", "Node", "ClusterSim", "ClusterResult", "OffsetCandidate"]

ADMIT_GRID = 64  # samples on the admission horizon (both engines)

RetryFn = Callable[[AllocationPlan, float, float], AllocationPlan]


@dataclasses.dataclass
class Job:
    jid: int
    family: str
    input_gb: float
    mem: np.ndarray          # hidden ground-truth trace (GB per dt)
    dt: float
    plan: AllocationPlan     # current allocation envelope
    est_runtime: float       # scheduler-facing runtime estimate
    attempts: int = 0
    wasted_gbs: float = 0.0
    # Workflow DAG edges: jids of jobs that must *finish* before this one
    # becomes admissible (empty = released at t=0, the historical behavior).
    parents: Tuple[int, ...] = ()

    @property
    def runtime(self) -> float:
        return len(self.mem) * self.dt


class _DagFrontier:
    """Dependency-release frontier shared by all three engines.

    Built (and validated — loudly) at submit time from each job's
    ``parents``; a job enters the admission queue only once every parent
    has *finished*.  An OOM kill re-queues the killed job itself (its
    parents already finished) but never re-blocks released children; a
    *permanent* failure (unsatisfiable / out of attempts) dooms every
    not-yet-released descendant — they are counted unschedulable and never
    placed.  All three engines drive the same object the same way, so the
    differential suites keep pinning their decision logs bitwise.
    """

    def __init__(self, jobs: List[Job]):
        # One validator for every DAG surface (duplicates, self-parents,
        # unknown parents, cycles — each named loudly); the wfcommons
        # importer runs the same code over string task ids.
        from repro.workloads.wfc import validate_dag_ids
        jids = [job.jid for job in jobs]
        validate_dag_ids(jids, [job.parents for job in jobs], kind="job")
        self.index: Dict[int, int] = {jid: i for i, jid in enumerate(jids)}
        B = len(jobs)
        self.pending = np.zeros((B,), np.int64)   # unfinished parent count
        self.children: List[List[int]] = [[] for _ in range(B)]
        self.dead = np.zeros((B,), bool)
        for i, job in enumerate(jobs):
            for p in dict.fromkeys(job.parents):  # dedupe, keep order
                self.children[self.index[p]].append(i)
                self.pending[i] += 1

    @classmethod
    def build(cls, jobs: List[Job]) -> Optional["_DagFrontier"]:
        """A fresh frontier, or ``None`` for dependency-free workloads."""
        if not any(job.parents for job in jobs):
            return None
        return cls(jobs)

    def roots(self) -> List[int]:
        return [i for i in range(len(self.pending)) if self.pending[i] == 0]

    def release(self, i: int) -> List[int]:
        """Job index ``i`` finished; returns newly admissible job indices
        (in the deterministic submission-order the engines share)."""
        out = []
        for c in self.children[i]:
            self.pending[c] -= 1
            if self.pending[c] == 0 and not self.dead[c]:
                out.append(c)
        return out

    def doom(self, i: int) -> int:
        """Job index ``i`` failed permanently: mark every not-yet-released
        descendant dead; returns how many (each counts unschedulable)."""
        count = 0
        stack = list(self.children[i])
        while stack:
            c = stack.pop()
            if self.dead[c]:
                continue
            self.dead[c] = True
            count += 1
            stack.extend(self.children[c])
        return count


@dataclasses.dataclass
class Node:
    nid: int
    capacity_gb: float
    running: List[Tuple[float, "Job"]] = dataclasses.field(default_factory=list)

    def residual_at(self, t_abs: float, horizon: np.ndarray) -> np.ndarray:
        """Residual capacity over ``horizon`` (absolute times)."""
        used = np.zeros_like(horizon)
        for start, job in self.running:
            rel = horizon - start
            active = (rel >= 0) & (rel < job.runtime + 1e-9)
            used += np.where(active, alloc_at(job.plan, np.maximum(rel, 0)), 0.0)
        return self.capacity_gb - used

    def fits(self, job: Job, t_abs: float) -> bool:
        horizon = t_abs + np.linspace(0, job.est_runtime, ADMIT_GRID)
        resid = self.residual_at(t_abs, horizon)
        need = alloc_at(job.plan, np.linspace(0, job.est_runtime, ADMIT_GRID))
        return bool(np.all(need <= resid + 1e-9))


@dataclasses.dataclass
class ClusterResult:
    makespan: float
    total_wastage_gbs: float
    retries: int
    unschedulable: int
    avg_utilization: float
    # Admission log: (t, nid, jid) per placement, in decision order.  The
    # differential test and the cluster_sim benchmark compare these bitwise.
    placements: Optional[List[Tuple[float, int, int]]] = None
    offset: Optional[OffsetCandidate] = None


def _as_spec(retry) -> Tuple[Optional[RetrySpec], Optional[RetryFn]]:
    """Normalize a retry argument into (spec, callable) — exactly one set.

    Accepts a :class:`RetrySpec`, a RetrySpec kind string, a registered
    method *name* (``"ks+"`` — resolved to that method's retry rule through
    :mod:`repro.core.registry`), a fitted method instance (its
    ``retry_spec`` is used), or a legacy ``(plan, t_fail, used)`` callable.
    """
    if isinstance(retry, RetrySpec):
        return retry, None
    if isinstance(retry, str):
        from repro.core import registry
        spec = registry.try_retry_spec(retry)
        return (spec if spec is not None else RetrySpec(retry)), None
    if hasattr(retry, "retry_spec"):  # a MemoryPredictor-like method object
        return retry.retry_spec, None
    return None, retry


class ClusterSim:
    """Packs jobs (method-agnostic) and replays hidden traces with OOM.

    ``retry`` (in :meth:`run`) is either a static :class:`RetrySpec` —
    the vectorized path, required for offset sweeps of ``last_peak_bump`` —
    or a legacy ``(plan, t_fail, used) -> plan`` callable.
    """

    def __init__(self, nodes: List[Node], max_attempts: int = 20,
                 engine: str = "fused"):
        if engine not in ("fused", "packed", "legacy"):
            raise ValueError(f"unknown engine: {engine!r}")
        self.nodes = nodes
        self.max_attempts = max_attempts
        self.engine = engine

    # ------------------------------------------------------------------ API
    def run(self, jobs: List[Job], retry,
            offsets: Union[None, str, Dict[str, OffsetCandidate],
                           Sequence[OffsetCandidate]] = None
            ) -> Union[ClusterResult, List[ClusterResult]]:
        """Replay ``jobs`` through the cluster; see the module docstring.

        Without ``offsets`` returns one :class:`ClusterResult` and mutates
        the ``Job`` objects (attempts / wasted_gbs / plan) like the legacy
        loop always did.  With a sequence of ``offsets`` returns one result
        per :class:`OffsetCandidate` — jobs are *not* mutated; each
        candidate replays the same workload with re-packed plans while the
        trace batch (and its device copy) is shared across the sweep.

        ``offsets="auto"`` sweeps the registry's default candidate grid
        (:data:`repro.core.registry.DEFAULT_OFFSET_GRID`) and returns only
        the lowest-wastage result; ``offsets={family: OffsetCandidate}``
        applies *per-task-family* candidates (e.g. the output of
        :func:`repro.core.registry.tune_offset` per family) in one replay —
        families absent from the mapping run at identity.
        """
        if self.engine == "legacy":
            if offsets is not None:
                raise ValueError("offset sweeps require a batched engine")
            return self._run_legacy(jobs, retry)
        run_one = (self._run_fused if self.engine == "fused"
                   else self._run_packed)
        if offsets is None:
            return run_one(jobs, retry, None, None, write_back=True)
        if isinstance(offsets, str):
            if offsets != "auto":
                raise ValueError(f"unknown offsets mode: {offsets!r}")
            from repro.core.registry import DEFAULT_OFFSET_GRID
            offsets = DEFAULT_OFFSET_GRID
            shared = self._pack_shared(jobs)
            sweep = [run_one(jobs, retry, cand, shared, write_back=False)
                     for cand in offsets]
            return min(sweep, key=lambda r: r.total_wastage_gbs)
        if isinstance(offsets, dict):
            cand = self._family_offsets(jobs, offsets)
            return run_one(jobs, retry, cand, None, write_back=False)
        shared = self._pack_shared(jobs)
        return [run_one(jobs, retry, cand, shared, write_back=False)
                for cand in offsets]

    @staticmethod
    def _family_offsets(jobs: List[Job],
                        mapping: Dict[str, OffsetCandidate]
                        ) -> OffsetCandidate:
        """Fold a per-family candidate mapping into one per-lane candidate.

        ``peak``/``start``/``last_peak_bump`` all become per-lane arrays
        (identity for families not in the mapping): per-family
        :func:`repro.core.registry.tune_offset` winners may disagree on
        every field, including the ksplus last-peak bump — unmapped lanes
        get NaN bumps, which fall back to the retry spec's static value
        inside :func:`repro.core.envelope.retry_packed`.
        """
        families = {job.family for job in jobs}
        unknown = set(mapping) - families
        if unknown:
            raise ValueError(
                f"offset mapping names unknown families: {sorted(unknown)} "
                f"(workload families: {sorted(families)})")
        peak = np.zeros((len(jobs),), np.float64)
        start = np.zeros((len(jobs),), np.float64)
        bump = np.full((len(jobs),), np.nan, np.float64)
        any_bump = False
        for i, job in enumerate(jobs):
            c = mapping.get(job.family)
            if c is not None:
                peak[i] = c.peak
                start[i] = c.start
                if c.last_peak_bump is not None:
                    bump[i] = c.last_peak_bump
                    any_bump = True
        return OffsetCandidate(peak=peak, start=start,
                               last_peak_bump=(bump if any_bump else None))

    # ---------------------------------------------------------- legacy loop
    def _run_legacy(self, jobs: List[Job], retry) -> ClusterResult:
        spec, retry_fn = _as_spec(retry)
        if retry_fn is None:
            # RetrySpec rules that reference "the machine" (max-machine,
            # double's cap) are bounded by the largest node in this cluster.
            cap_max = max(n.capacity_gb for n in self.nodes)

            def retry_fn(plan, t_fail, used, _spec=spec, _cap=cap_max):
                return apply_retry_spec(_spec, plan, t_fail, used,
                                        machine_memory=_cap)
        frontier = _DagFrontier.build(jobs)
        queue: List[Job] = (list(jobs) if frontier is None
                            else [jobs[i] for i in frontier.roots()])
        events: List[Tuple[float, int, str, int, Job]] = []
        seq = itertools.count()
        retries = 0
        unschedulable = 0
        area_used = 0.0
        done_at = 0.0
        placements: List[Tuple[float, int, int]] = []

        def try_admit(now: float):
            admitted = True
            while admitted and queue:
                admitted = False
                for job in list(queue):
                    for node in self.nodes:
                        if node.fits(job, now):
                            queue.remove(job)
                            node.running.append((now, job))
                            placements.append((now, node.nid, job.jid))
                            v = first_violation(job.plan, job.mem, job.dt)
                            if v < 0:
                                end = now + job.runtime
                                heapq.heappush(events, (end, next(seq), "done",
                                                        node.nid, job))
                            else:
                                heapq.heappush(events, (now + v * job.dt,
                                                        next(seq), "oom",
                                                        node.nid, job))
                            admitted = True
                            break

        try_admit(0.0)
        guard = 0
        while events:
            guard += 1
            if guard > 200_000:
                raise RuntimeError("cluster sim did not converge")
            t, _, kind, nid, job = heapq.heappop(events)
            node = self.nodes[nid]
            node.running = [(s, j) for s, j in node.running if j.jid != job.jid]
            if kind == "done":
                alloc = alloc_at(job.plan,
                                 np.arange(len(job.mem)) * job.dt)
                job.wasted_gbs += float(np.sum(alloc - job.mem) * job.dt)
                area_used += float(np.sum(job.mem) * job.dt)
                done_at = max(done_at, t)
                if frontier is not None:  # dependency-release
                    queue.extend(
                        jobs[c] for c in
                        frontier.release(frontier.index[job.jid]))
            else:  # OOM kill
                v = first_violation(job.plan, job.mem, job.dt)
                alloc = alloc_at(job.plan, np.arange(v + 1) * job.dt)
                job.wasted_gbs += float(np.sum(alloc) * job.dt)
                job.attempts += 1
                retries += 1
                if job.attempts >= self.max_attempts or \
                        float(np.max(job.mem)) > max(
                            n.capacity_gb for n in self.nodes):
                    unschedulable += 1
                    if frontier is not None:  # descendants can never run
                        unschedulable += frontier.doom(
                            frontier.index[job.jid])
                else:
                    job.plan = retry_fn(job.plan, v * job.dt,
                                        float(job.mem[v]))
                    queue.append(job)
            try_admit(t)

        total_cap_area = sum(n.capacity_gb for n in self.nodes) * max(done_at, 1e-9)
        return ClusterResult(
            makespan=done_at,
            total_wastage_gbs=sum(j.wasted_gbs for j in jobs),
            retries=retries,
            unschedulable=unschedulable,
            avg_utilization=area_used / total_cap_area,
            placements=placements,
        )

    # ---------------------------------------------------------- packed loop
    def _pack_shared(self, jobs: List[Job]):
        """Per-dt trace groups, uploaded to the device once per workload.

        Every offset candidate's attempt-#1 probe reuses these arrays — the
        (B, T) trace batch is by far the largest operand, so keeping it
        resident is what makes the sweep cheap.
        """
        import jax.numpy as jnp

        from repro.core.fleet import pack_traces

        by_dt: Dict[float, List[int]] = {}
        for i, job in enumerate(jobs):
            by_dt.setdefault(float(job.dt), []).append(i)
        groups = []
        for dtv in sorted(by_dt):
            idxs = np.asarray(by_dt[dtv], np.int64)
            pt = pack_traces([jobs[i].mem for i in idxs])
            groups.append((dtv, idxs, jnp.asarray(pt.mems),
                           jnp.asarray(pt.lengths)))
        return groups

    def _initial_viol(self, starts, peaks, groups, B: int) -> np.ndarray:
        """Attempt-#1 OOM probe for every lane: one jitted dispatch per dt
        group (:func:`repro.core.fleet.first_attempt`)."""
        import jax.numpy as jnp

        from repro.core.fleet import first_attempt

        viol = np.empty((B,), np.int64)
        for dtv, idxs, dmems, dlengths in groups:
            v, _ = first_attempt(
                jnp.asarray(starts[idxs].astype(np.float32)),
                jnp.asarray(peaks[idxs].astype(np.float32)),
                dmems, dlengths, jnp.float32(np.inf), dt=dtv)
            viol[idxs] = np.asarray(v, np.int64)
        return viol

    @staticmethod
    def _apply_offset(env: PackedEnvelopes, cand: OffsetCandidate):
        """Re-pack the plan batch under one offset candidate (cheap: O(BK));
        see :func:`repro.core.envelope.apply_offsets` — scalar (sweep) and
        per-lane (per-family mapping) candidates both land here."""
        return apply_offsets(env.starts, env.peaks, env.nseg, cand)

    def _prep_packed(self, jobs: List[Job], retry,
                     offset: Optional[OffsetCandidate], shared):
        """Shared packed-engine setup (plans, grids, probes) — used
        verbatim by both the host-side packed loop and the fused loop so
        the two engines start from identical state."""
        if any(node.running for node in self.nodes):
            # Resident jobs live outside the packed batch; admitting around
            # them silently would diverge from the legacy loop.
            raise ValueError(
                "batched engines require empty Node.running; submit "
                "resident jobs as part of `jobs` or use engine='legacy'")
        spec, retry_fn = _as_spec(retry)
        bump_lanes = None
        if offset is not None and offset.last_peak_bump is not None:
            if spec is None:
                raise ValueError(
                    "sweeping last_peak_bump requires a RetrySpec retry")
            lb = np.asarray(offset.last_peak_bump, np.float64)
            if lb.ndim == 0:
                spec = spec._replace(bump=float(lb))
            else:  # per-lane bumps; NaN = keep the spec's static value
                bump_lanes = np.where(np.isnan(lb), spec.bump, lb)

        B = len(jobs)
        env = PackedEnvelopes.from_plans([j.plan for j in jobs])
        if offset is None:
            starts, peaks = env.starts.copy(), env.peaks.copy()
        else:
            starts, peaks = self._apply_offset(env, offset)
        nseg = env.nseg
        K = starts.shape[1]

        # Per-job static state (float64 host arrays).
        dts = np.asarray([j.dt for j in jobs], np.float64)
        lengths = np.asarray([len(j.mem) for j in jobs], np.int64)
        runtimes = lengths * dts
        est = np.asarray([j.est_runtime for j in jobs], np.float64)
        summem = np.asarray(
            [j.mem.sum(dtype=np.float64) for j in jobs], np.float64)
        peak_demand = np.asarray(
            [float(np.max(j.mem)) for j in jobs], np.float64)
        caps = np.asarray([n.capacity_gb for n in self.nodes], np.float64)
        cap_max = float(caps.max())
        # Admission horizon grids (B, G) — the legacy per-job linspace,
        # evaluated for every job at once.
        grid_rel = np.linspace(0.0, est, ADMIT_GRID, axis=1)
        need = alloc_at_packed(starts, peaks, grid_rel)
        bounds = segment_sample_bounds(starts, dts[:, None])

        # Attempt-#1 OOM probe, one batched dispatch per dt group.
        shared = shared if shared is not None else self._pack_shared(jobs)
        viol = self._initial_viol(starts, peaks, shared, B)
        return (spec, retry_fn, bump_lanes, starts, peaks, nseg, K, dts,
                lengths, runtimes, summem, peak_demand, caps, cap_max,
                grid_rel, need, bounds, viol)

    def _run_packed(self, jobs: List[Job], retry,
                    offset: Optional[OffsetCandidate], shared,
                    write_back: bool) -> ClusterResult:
        if not jobs:
            return ClusterResult(0.0, 0.0, 0, 0, 0.0, placements=[],
                                 offset=offset)
        (spec, retry_fn, bump_lanes, starts, peaks, nseg, K, dts, lengths,
         runtimes, summem, peak_demand, caps, cap_max, grid_rel, need,
         bounds, viol) = self._prep_packed(jobs, retry, offset, shared)
        B = len(jobs)

        # Mutable replay state.  attempts/wastage continue from the Job
        # counters, exactly like the legacy loop's in-place accumulation.
        attempts0 = np.asarray([j.attempts for j in jobs], np.int64)
        attempts = attempts0.copy()
        wasted = np.asarray([j.wasted_gbs for j in jobs], np.float64)
        node_running: List[List[int]] = [[] for _ in self.nodes]
        admit_t = np.zeros((B,), np.float64)
        frontier = _DagFrontier.build(jobs)
        queue: List[int] = (list(range(B)) if frontier is None
                            else frontier.roots())
        events: List[Tuple[float, int, str, int, int]] = []
        seq = itertools.count()
        retries = 0
        unschedulable = 0
        area_used = 0.0
        done_at = 0.0
        placements: List[Tuple[float, int, int]] = []

        def fits_column(ni: int, q: List[int], now: float) -> Dict[int, bool]:
            """Admission predicate for every queued job vs node ``ni`` at
            ``now`` — one vectorized residual evaluation + reduction."""
            run = node_running[ni]
            grid_abs = now + grid_rel[q]
            resid = residual_over(
                caps[ni], starts[run], peaks[run], admit_t[run], grid_abs,
                dur=runtimes[run])
            ok = fits_under(need[q], resid)
            return dict(zip(q, ok.tolist()))

        def try_admit(now: float):
            cols: Dict[int, Dict[int, bool]] = {}
            admitted = True
            while admitted and queue:
                admitted = False
                for ji in list(queue):
                    for ni in range(len(self.nodes)):
                        col = cols.get(ni)
                        if col is None or ji not in col:
                            col = cols[ni] = fits_column(ni, list(queue), now)
                        if col[ji]:
                            queue.remove(ji)
                            node_running[ni].append(ji)
                            admit_t[ji] = now
                            cols.pop(ni, None)  # this node's residual changed
                            placements.append(
                                (float(now), self.nodes[ni].nid,
                                 jobs[ji].jid))
                            v = viol[ji]
                            if v < 0:
                                heapq.heappush(
                                    events, (now + runtimes[ji], next(seq),
                                             "done", ni, ji))
                            else:
                                heapq.heappush(
                                    events, (now + v * dts[ji], next(seq),
                                             "oom", ni, ji))
                            admitted = True
                            break

        try_admit(0.0)
        guard = 0
        while events:
            guard += 1
            if guard > 200_000:
                raise RuntimeError("cluster sim did not converge")
            t, _, kind, ni, ji = heapq.heappop(events)
            node_running[ni].remove(ji)
            row = slice(ji, ji + 1)
            if kind == "done":
                w = span_alloc_sum(peaks[row], bounds[row], lengths[row])[0]
                wasted[ji] += (w - summem[ji]) * dts[ji]
                area_used += summem[ji] * dts[ji]
                done_at = max(done_at, t)
                if frontier is not None:  # dependency-release
                    queue.extend(frontier.release(ji))
            else:  # OOM kill
                v = int(viol[ji])
                w = span_alloc_sum(peaks[row], bounds[row],
                                   np.asarray([v + 1]))[0]
                wasted[ji] += w * dts[ji]
                attempts[ji] += 1
                retries += 1
                if attempts[ji] >= self.max_attempts or \
                        peak_demand[ji] > cap_max:
                    unschedulable += 1
                    if frontier is not None:  # descendants can never run
                        unschedulable += frontier.doom(ji)
                else:
                    t_fail = v * dts[ji]
                    used = float(jobs[ji].mem[v])
                    if spec is not None:
                        ns, npk = retry_packed(
                            spec, starts[row], peaks[row], nseg[row],
                            np.asarray([t_fail]), np.asarray([used]),
                            machine_memory=cap_max,
                            bump=(None if bump_lanes is None
                                  else bump_lanes[row]))
                        starts[ji], peaks[ji] = ns[0], npk[0]
                    else:
                        s, p = PackedEnvelopes(
                            starts, peaks, nseg).row(ji)
                        new = retry_fn(AllocationPlan(s, p), t_fail, used)
                        starts[ji, :new.n] = new.starts
                        starts[ji, new.n:] = PAD_START
                        peaks[ji, :new.n] = new.peaks
                        peaks[ji, new.n:] = new.peaks[-1]
                        nseg[ji] = new.n
                    # Refresh the lane's derived state (plan changed).
                    need[ji] = alloc_at_packed(
                        starts[row], peaks[row], grid_rel[row])[0]
                    bounds[ji] = segment_sample_bounds(
                        starts[row], dts[ji])[0]
                    viol[ji] = first_violation_packed(
                        starts[row], peaks[row],
                        np.asarray(jobs[ji].mem, np.float64)[None, :],
                        lengths[row], float(dts[ji]))[0]
                    queue.append(ji)
            try_admit(t)

        if write_back:
            for i, job in enumerate(jobs):
                job.attempts = int(attempts[i])
                job.wasted_gbs = float(wasted[i])
                if attempts[i] > attempts0[i]:  # plan changed by retries
                    s, p = PackedEnvelopes(starts, peaks, nseg).row(i)
                    job.plan = AllocationPlan(starts=s, peaks=p)

        total_cap_area = float(caps.sum()) * max(done_at, 1e-9)
        return ClusterResult(
            makespan=done_at,
            total_wastage_gbs=float(wasted.sum()),
            retries=retries,
            unschedulable=unschedulable,
            avg_utilization=area_used / total_cap_area,
            placements=placements,
            offset=offset,
        )

    # ----------------------------------------------------------- fused loop
    def _run_fused(self, jobs: List[Job], retry,
                   offset: Optional[OffsetCandidate], shared,
                   write_back: bool,
                   admission_backend: str = "fused") -> ClusterResult:
        """Packed event loop with the per-event hot path fused into XLA.

        Decision-for-decision identical to :meth:`_run_packed` (the
        differential suite pins the placement logs bitwise); differs in
        *how* the work is done:

        * admission — :class:`repro.sched.admission.AdmissionState`: one
          jitted float64 dispatch per event over every (node, queued lane)
          pair, then incremental recomputes of only the invalidated
          entries after each placement, instead of full per-node numpy
          columns per admission;
        * retries — all OOMs that land at the same event time are
          compacted into one multi-row ``retry_packed`` re-plan, one
          batched ``need``/``bounds`` refresh and one batched float64
          re-probe per dt group, instead of one 1-row slice per event.
        """
        if not jobs:
            return ClusterResult(0.0, 0.0, 0, 0, 0.0, placements=[],
                                 offset=offset)
        from repro.sched.admission import AdmissionState

        (spec, retry_fn, bump_lanes, starts, peaks, nseg, K, dts, lengths,
         runtimes, summem, peak_demand, caps, cap_max, grid_rel, need,
         bounds, viol) = self._prep_packed(jobs, retry, offset, shared)
        B = len(jobs)

        attempts0 = np.asarray([j.attempts for j in jobs], np.int64)
        attempts = attempts0.copy()
        wasted = np.asarray([j.wasted_gbs for j in jobs], np.float64)
        adm = AdmissionState(caps, K=K, G=ADMIT_GRID,
                             backend=admission_backend, use_dur=True)
        adm.add_lanes(starts, peaks, need, grid_rel, dur=runtimes)
        frontier = _DagFrontier.build(jobs)
        queue: List[int] = (list(range(B)) if frontier is None
                            else frontier.roots())
        events: List[Tuple[float, int, str, int, int]] = []
        seq = itertools.count()
        retries = 0
        unschedulable = 0
        area_used = 0.0
        done_at = 0.0
        placements: List[Tuple[float, int, int]] = []

        def try_admit(now: float):
            """Greedy drain on the shared fits matrix.

            Decision-equivalent to the packed loop's job-by-job scan:
            admissions only shrink residuals, so an unfit job can never
            become fit within one drain — the first fitting job in queue
            order under the current state is exactly the next job the
            per-job scan would admit.  Each iteration refreshes the
            invalidated entries (one fused dispatch) and picks the first
            (job, node) pair in (queue, node) order from the matrix.
            """
            adm.sync_now(now)
            while queue:
                adm.columns(now, queue)  # one dispatch for invalid entries
                q = np.asarray(queue)
                M = adm.fits[:, q]       # (N, Q) — all entries now valid
                anyfit = M.any(axis=0)
                if not anyfit.any():
                    break
                col = int(np.argmax(anyfit))
                ni = int(np.argmax(M[:, col]))
                ji = int(q[col])
                queue.remove(ji)
                adm.place(ni, ji, now)
                placements.append(
                    (float(now), self.nodes[ni].nid, jobs[ji].jid))
                v = viol[ji]
                if v < 0:
                    heapq.heappush(events, (now + runtimes[ji], next(seq),
                                            "done", ni, ji))
                else:
                    heapq.heappush(events, (now + v * dts[ji], next(seq),
                                            "oom", ni, ji))

        try_admit(0.0)
        guard = 0
        while events:
            # Drain the maximal same-time prefix: events pushed *during*
            # this batch land behind it in (t, seq) order, exactly where
            # the one-at-a-time loop would pop them.
            t = events[0][0]
            batch: List[Tuple[float, int, str, int, int]] = []
            while events and events[0][0] == t:
                batch.append(heapq.heappop(events))
            guard += len(batch)
            if guard > 200_000:
                raise RuntimeError("cluster sim did not converge")

            # Stage wastage for the whole batch against the *pre-retry*
            # plans (compacted multi-row span arithmetic).
            done_idx = [ji for (_, _, k, _, ji) in batch if k == "done"]
            oom_idx = [ji for (_, _, k, _, ji) in batch if k == "oom"]
            w_done: Dict[int, float] = {}
            w_oom: Dict[int, float] = {}
            if done_idx:
                rows = np.asarray(done_idx)
                w = span_alloc_sum(peaks[rows], bounds[rows], lengths[rows])
                w_done = dict(zip(done_idx, w))
            if oom_idx:
                rows = np.asarray(oom_idx)
                w = span_alloc_sum(peaks[rows], bounds[rows],
                                   viol[rows] + 1)
                w_oom = dict(zip(oom_idx, w))

            # Event-batched retries: compact the retrying minority into one
            # multi-row re-plan + refresh (lane-local, so staging it before
            # the per-event processing below cannot change any decision —
            # a lane only becomes visible to admission once it is queued).
            retry_set = [
                ji for ji in oom_idx
                if attempts[ji] + 1 < self.max_attempts
                and peak_demand[ji] <= cap_max]
            if retry_set:
                rows = np.asarray(retry_set)
                if spec is not None:
                    ns, npk = retry_packed(
                        spec, starts[rows], peaks[rows], nseg[rows],
                        viol[rows] * dts[rows],
                        np.asarray([float(jobs[ji].mem[viol[ji]])
                                    for ji in retry_set]),
                        machine_memory=cap_max,
                        bump=(None if bump_lanes is None
                              else bump_lanes[rows]))
                    starts[rows], peaks[rows] = ns, npk
                else:
                    for ji in retry_set:
                        s, p = PackedEnvelopes(starts, peaks, nseg).row(ji)
                        new = retry_fn(AllocationPlan(s, p),
                                       float(viol[ji] * dts[ji]),
                                       float(jobs[ji].mem[viol[ji]]))
                        starts[ji, :new.n] = new.starts
                        starts[ji, new.n:] = PAD_START
                        peaks[ji, :new.n] = new.peaks
                        peaks[ji, new.n:] = new.peaks[-1]
                        nseg[ji] = new.n
                # Refresh derived state for all retried lanes at once;
                # post-retry probes stay float64 (precision contract), one
                # batched pass per dt group.
                need[rows] = alloc_at_packed(
                    starts[rows], peaks[rows], grid_rel[rows])
                bounds[rows] = segment_sample_bounds(
                    starts[rows], dts[rows][:, None])
                by_dt: Dict[float, List[int]] = {}
                for ji in retry_set:
                    by_dt.setdefault(float(dts[ji]), []).append(ji)
                for dtv, lanes in by_dt.items():
                    g = np.asarray(lanes)
                    tmax = int(lengths[g].max())
                    mems = np.zeros((len(lanes), tmax), np.float64)
                    for r, ji in enumerate(lanes):
                        mems[r, :lengths[ji]] = jobs[ji].mem
                    viol[g] = first_violation_packed(
                        starts[g], peaks[g], mems, lengths[g], dtv)
                # NOTE: the admission state keeps each lane's OLD plan
                # until that lane's kill event is processed below — while
                # an OOMing job is still resident, the node's residual
                # must be computed against the envelope it was admitted
                # with, not the staged re-plan.
            retryable = set(retry_set)

            # Process the batch one event at a time — identical admission
            # interleaving to the per-event loop.
            for (t_, _, kind, ni, ji) in batch:
                adm.release(ni, ji)
                if kind == "done":
                    wasted[ji] += (w_done[ji] - summem[ji]) * dts[ji]
                    area_used += summem[ji] * dts[ji]
                    done_at = max(done_at, t_)
                    if frontier is not None:  # dependency-release
                        queue.extend(frontier.release(ji))
                else:  # OOM kill
                    wasted[ji] += w_oom[ji] * dts[ji]
                    attempts[ji] += 1
                    retries += 1
                    if ji in retryable:
                        # The lane left its node: its staged re-plan may
                        # now become visible to admission.
                        adm.update_lane(ji, starts[ji], peaks[ji],
                                        need[ji])
                        queue.append(ji)
                    else:
                        unschedulable += 1
                        if frontier is not None:  # descendants blocked
                            unschedulable += frontier.doom(ji)
                try_admit(t_)

        if write_back:
            for i, job in enumerate(jobs):
                job.attempts = int(attempts[i])
                job.wasted_gbs = float(wasted[i])
                if attempts[i] > attempts0[i]:  # plan changed by retries
                    s, p = PackedEnvelopes(starts, peaks, nseg).row(i)
                    job.plan = AllocationPlan(starts=s, peaks=p)

        total_cap_area = float(caps.sum()) * max(done_at, 1e-9)
        return ClusterResult(
            makespan=done_at,
            total_wastage_gbs=float(wasted.sum()),
            retries=retries,
            unschedulable=unschedulable,
            avg_utilization=area_used / total_cap_area,
            placements=placements,
            offset=offset,
        )
