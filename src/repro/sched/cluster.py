"""Batched discrete-event cluster simulator with packed memory envelopes.

This is the paper's deployment context: a resource manager packs workflow
tasks onto nodes using each task's *memory envelope over time*.  KS+'s
envelopes free the unused head-room of early segments for other tasks —
the wastage reduction translates directly into throughput.

The simulator is discrete-event: nodes admit a queued job when the job's
allocation envelope fits under the node's *residual envelope* for the whole
projected runtime; the OOM killer fires when a job's hidden trace exceeds
its own allocation, triggering the method's retry strategy.

Three engines share the event semantics:

* ``engine="fused"`` (default) — the packed layout below, with the
  per-event hot path moved off the host: the admission check is ONE jitted
  XLA dispatch per event over every (node, queued job) pair at once
  (:class:`repro.sched.admission.AdmissionState` — device-resident packed
  state, donated-buffer updates, and an incremental fits-column
  invalidation mask instead of full per-admission recompute), and OOM
  retries that land at the same event time are compacted into one
  multi-row :func:`retry_packed` / re-probe slice (the fleet engine's
  compaction trick) instead of one Python round-trip per lane.
* ``engine="packed"`` — all job plans live in one packed
  ``(B, K)`` envelope batch (:mod:`repro.core.envelope`); the admission
  check is a single vectorized fits-under-residual reduction across every
  queued job per node, OOM times come from one batched
  :func:`repro.core.fleet.first_attempt` probe over the whole workload
  (device-resident traces), wastage is O(K) span arithmetic, and retry
  re-plans flow through :class:`RetrySpec` / :func:`retry_packed`.  Kept
  as the host-side float64 reference the fused engine is differentially
  pinned to (``tests/test_admission_fused.py``).
* ``engine="legacy"`` — the original per-job Python event loop, kept as the
  decision-for-decision oracle the packed engine is differentially tested
  against (``tests/test_cluster_packed.py``) and benchmarked against
  (``benchmarks/run.py --only cluster_sim``).

Precision contract: the packed engine's attempt-#1 OOM probe runs on the
device in float32 (that is what makes it one dispatch over the whole
workload); post-retry probes, admission residuals and wastage stay in
float64.  The two engines therefore agree bitwise whenever trace-vs-plan
margins exceed float32 resolution (~1e-7 relative) — true for the
differential workloads and for any real monitoring data, but a trace that
grazes its allocation within one float32 ulp may OOM under one engine and
not the other.

Fused-admission precision contract: the fused engine keeps the float32
attempt-#1 probe AND the float64 post-retry probes/wastage of the packed
engine; its admission residuals run in float64 *on the device*
(``jax.experimental.enable_x64`` scopes 64-bit semantics to those
dispatches) with the same elementwise operations as the host path.  The
only permitted divergence is the summation order over a node's resident
envelopes (numpy reduces linearly, XLA may tree-reduce) — last-ulp
(~1e-16 relative) residual differences, so an admission decision can only
flip when a job's need grazes the residual within one float64 ulp of the
1e-9 admission tolerance.  The differential suite pins the two engines'
placement logs bitwise on workloads with real margins.

``run(offsets=[...])`` sweeps peak/start safety offsets and
``last_peak_bump`` the way :class:`KSPlusAuto` sweeps k: plans are re-packed
per candidate (cheap) while the trace batch stays device-resident and the
per-candidate OOM probes hit the same jitted program.  Per-family
``offsets={family: OffsetCandidate}`` mappings may now disagree on *every*
field including ``last_peak_bump`` — bumps fold into a per-lane array that
rides :func:`repro.core.envelope.retry_packed`'s ``bump`` axis.

Workflow DAGs: jobs may carry ``parents`` (jids that must *finish* first).
All three engines drive the same dependency-release frontier
(:class:`_DagFrontier`): only released jobs enter the admission queue, a
``done`` event releases its children at that event time, and a permanent
failure (unsatisfiable / out of attempts) counts every not-yet-released
descendant as unschedulable.  Cycles, self-parents, duplicate and unknown
job ids are rejected loudly at submit time with the offending ids named.

Arrivals and faults: jobs may carry ``release_time`` (no engine admits a
job before it; a child released before its parents finish simply waits
for them), and ``run(faults=...)`` injects a
:class:`repro.sched.faults.FaultSchedule` of node leave/join events into
all three engines.  A leave evicts the node's residents in admission
order — each evicted job's allocated area up to the eviction time counts
as wastage, its attempt counter advances against the same
``max_attempts`` budget as OOM retries (``ClusterResult.evictions``
breaks the count out), and it requeues ahead of other waiters; running
out of attempts through evictions dooms DAG descendants exactly like an
OOM (``ClusterResult.doomed``).  Jobs the surviving fleet can never fit
park in a starvation-tracked side queue and re-enter on the next join
(``ClusterResult.starved`` / ``starvation_s``).  Unknown-node leaves
raise ``KeyError`` and joins of active nodes raise ``ValueError``, both
naming the node.  Oversized attempt-1 plans are rejected at submit time.

Eviction precision contract: eviction *decisions* (victim order, requeue
position, attempt/doom accounting, subsequent placements) are bitwise
across engines — they involve no new arithmetic, only the shared event
protocol.  Eviction *wastage* is the plan's area over the whole samples
elapsed since admission: the batched engines evaluate it with the same
O(K) span arithmetic as done/OOM wastage, the legacy loop with
per-sample float64 sums — within 1e-6 relative, the existing wastage
contract.  Under faults, ``avg_utilization``'s denominator becomes the
piecewise-constant capacity integral; without them it stays the
closed-form product, bit-for-bit the pre-fault result.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.analysis.contracts import record_dispatch
from repro.core import AllocationPlan, alloc_at, first_violation
from repro.obs import metrics as _met
from repro.obs import trace as _obs
from repro.core.envelope import (
    PAD_START,
    OffsetCandidate,
    PackedEnvelopes,
    RetrySpec,
    alloc_at_packed,
    apply_offsets,
    first_violation_packed,
    fits_under,
    residual_over,
    retry_packed,
    segment_sample_bounds,
    span_alloc_sum,
)
from repro.core.retry import apply_retry_spec
from repro.sched.faults import FaultEvent, FaultSchedule

__all__ = ["Job", "Node", "ClusterSim", "ClusterResult", "OffsetCandidate",
           "FaultEvent", "FaultSchedule"]

ADMIT_GRID = 64  # samples on the admission horizon (both engines)

RetryFn = Callable[[AllocationPlan, float, float], AllocationPlan]


def _norm_faults(faults) -> Tuple[FaultEvent, ...]:
    """Normalize a ``faults`` argument into a stably time-sorted tuple."""
    if faults is None:
        return ()
    if isinstance(faults, FaultSchedule):
        return faults.events
    events = tuple(faults)
    for e in events:
        if not isinstance(e, FaultEvent):
            raise TypeError(f"not a FaultEvent: {e!r}")
    return tuple(sorted(events, key=lambda e: e.t))


def _elapsed_samples(t: float, t0: float, dt: float, length: int) -> int:
    """Whole trace samples a job occupied between admission at ``t0`` and
    eviction at ``t`` — the span its eviction wastage covers.  Identical
    float arithmetic in every engine (the differential contract)."""
    return min(int(np.floor((float(t) - float(t0)) / float(dt) + 1e-9)),
               int(length))


@dataclasses.dataclass
class Job:
    jid: int
    family: str
    input_gb: float
    mem: np.ndarray          # hidden ground-truth trace (GB per dt)
    dt: float
    plan: AllocationPlan     # current allocation envelope
    est_runtime: float       # scheduler-facing runtime estimate
    attempts: int = 0
    wasted_gbs: float = 0.0
    # Workflow DAG edges: jids of jobs that must *finish* before this one
    # becomes admissible (empty = released at t=0, the historical behavior).
    parents: Tuple[int, ...] = ()
    # Absolute submission time: the job enters the admission queue at
    # max(release_time, all parents finished).  0.0 = the historical
    # released-at-start behavior; see repro.workloads.arrivals for seeded
    # arrival processes.
    release_time: float = 0.0

    @property
    def runtime(self) -> float:
        return len(self.mem) * self.dt


class _DagFrontier:
    """Dependency-release frontier shared by all three engines.

    Built (and validated — loudly) at submit time from each job's
    ``parents``; a job enters the admission queue only once every parent
    has *finished*.  An OOM kill re-queues the killed job itself (its
    parents already finished) but never re-blocks released children; a
    *permanent* failure (unsatisfiable / out of attempts) dooms every
    not-yet-released descendant — they are counted unschedulable and never
    placed.  All three engines drive the same object the same way, so the
    differential suites keep pinning their decision logs bitwise.
    """

    def __init__(self, jobs: List[Job]):
        # One validator for every DAG surface (duplicates, self-parents,
        # unknown parents, cycles — each named loudly); the wfcommons
        # importer runs the same code over string task ids.
        from repro.workloads.wfc import validate_dag_ids
        jids = [job.jid for job in jobs]
        validate_dag_ids(jids, [job.parents for job in jobs], kind="job")
        self.index: Dict[int, int] = {jid: i for i, jid in enumerate(jids)}
        B = len(jobs)
        self.pending = np.zeros((B,), np.int64)   # unfinished parent count
        self.children: List[List[int]] = [[] for _ in range(B)]
        self.dead = np.zeros((B,), bool)
        for i, job in enumerate(jobs):
            for p in dict.fromkeys(job.parents):  # dedupe, keep order
                self.children[self.index[p]].append(i)
                self.pending[i] += 1

    @classmethod
    def build(cls, jobs: List[Job]) -> Optional["_DagFrontier"]:
        """A fresh frontier, or ``None`` for dependency-free workloads."""
        if not any(job.parents for job in jobs):
            return None
        return cls(jobs)

    def roots(self) -> List[int]:
        return [i for i in range(len(self.pending)) if self.pending[i] == 0]

    def release(self, i: int) -> List[int]:
        """Job index ``i`` finished; returns newly admissible job indices
        (in the deterministic submission-order the engines share)."""
        out = []
        for c in self.children[i]:
            self.pending[c] -= 1
            if self.pending[c] == 0 and not self.dead[c]:
                out.append(c)
        return out

    def doom(self, i: int) -> int:
        """Job index ``i`` failed permanently: mark every not-yet-released
        descendant dead; returns how many (each counts unschedulable)."""
        count = 0
        stack = list(self.children[i])
        while stack:
            c = stack.pop()
            if self.dead[c]:
                continue
            self.dead[c] = True
            count += 1
            stack.extend(self.children[c])
        return count


class _LaneQueue:
    """Admission queue over lane indices with O(1) removal.

    Replaces the fused engine's plain Python list, whose per-placement
    ``queue.remove(ji)`` and per-event ``[q for q in queue ...]`` parking
    rescan made a busy drain O(Q²): membership lives in a numpy index
    mask, removals mark entries dead in O(1), and the order list compacts
    lazily on the next :meth:`ids` snapshot — amortized linear over a
    replay.  Order semantics match the list exactly (append at the back,
    evicted/unparked lanes pushed to the front in their given order,
    removals preserve the relative order of survivors), which is what
    keeps the placement logs bitwise against the oracles.
    """

    __slots__ = ("_order", "_in", "_tok", "_dead")

    def __init__(self, B: int):
        # Each (lane, token) entry is live iff the lane is queued AND the
        # token matches the lane's latest enqueue — a lane that is
        # admitted, OOMs, and re-queues must NOT resurrect its stale
        # (earlier) position in the order list.
        self._order: List[Tuple[int, int]] = []
        self._in = np.zeros(B, bool)
        self._tok = np.zeros(B, np.int64)
        self._dead = 0

    def __len__(self) -> int:
        return len(self._order) - self._dead

    def append(self, ji: int):
        self._tok[ji] += 1
        self._order.append((ji, int(self._tok[ji])))
        self._in[ji] = True

    def push_front(self, lanes: Sequence[int]):
        lanes = [int(ji) for ji in lanes]
        if not lanes:
            return
        self._compact()
        self._tok[lanes] += 1
        self._order[0:0] = [(ji, int(self._tok[ji])) for ji in lanes]
        self._in[lanes] = True

    def remove(self, ji: int):
        self._in[ji] = False
        self._dead += 1

    def remove_many(self, lanes) -> None:
        n = 0
        for ji in lanes:
            self._in[int(ji)] = False
            n += 1
        self._dead += n

    def ids(self) -> np.ndarray:
        """Current queue order as an index array (compacts if needed)."""
        self._compact()
        return np.asarray([ji for ji, _ in self._order], np.int64)

    def _compact(self):
        if self._dead:
            inq, tok = self._in, self._tok
            self._order = [(ji, tk) for ji, tk in self._order
                           if inq[ji] and tok[ji] == tk]
            self._dead = 0


@dataclasses.dataclass
class Node:
    nid: int
    capacity_gb: float
    running: List[Tuple[float, "Job"]] = dataclasses.field(default_factory=list)

    def residual_at(self, t_abs: float, horizon: np.ndarray) -> np.ndarray:
        """Residual capacity over ``horizon`` (absolute times)."""
        used = np.zeros_like(horizon)
        for start, job in self.running:
            rel = horizon - start
            active = (rel >= 0) & (rel < job.runtime + 1e-9)
            used += np.where(active, alloc_at(job.plan, np.maximum(rel, 0)), 0.0)
        return self.capacity_gb - used

    def fits(self, job: Job, t_abs: float) -> bool:
        horizon = t_abs + np.linspace(0, job.est_runtime, ADMIT_GRID)
        resid = self.residual_at(t_abs, horizon)
        need = alloc_at(job.plan, np.linspace(0, job.est_runtime, ADMIT_GRID))
        return bool(np.all(need <= resid + 1e-9))


@dataclasses.dataclass
class ClusterResult:
    makespan: float
    total_wastage_gbs: float
    retries: int
    unschedulable: int
    avg_utilization: float
    # Admission log: (t, nid, jid) per placement, in decision order.  The
    # differential test and the cluster_sim benchmark compare these bitwise.
    placements: Optional[List[Tuple[float, int, int]]] = None
    offset: Optional[OffsetCandidate] = None
    # Fault-injection accounting (all zero without a FaultSchedule):
    evictions: int = 0       # jobs killed by node departures
    doomed: int = 0          # DAG descendants of permanent failures
    #   (already included in ``unschedulable``; broken out for the suite)
    starved: int = 0         # jobs never finished nor failed (parked/queued)
    starvation_s: float = 0.0  # total time jobs spent parked (unfittable)
    finished: int = 0        # jobs that ran to completion


def _as_spec(retry) -> Tuple[Optional[RetrySpec], Optional[RetryFn]]:
    """Normalize a retry argument into (spec, callable) — exactly one set.

    Accepts a :class:`RetrySpec`, a RetrySpec kind string, a registered
    method *name* (``"ks+"`` — resolved to that method's retry rule through
    :mod:`repro.core.registry`), a fitted method instance (its
    ``retry_spec`` is used), or a legacy ``(plan, t_fail, used)`` callable.
    """
    if isinstance(retry, RetrySpec):
        return retry, None
    if isinstance(retry, str):
        from repro.core import registry
        spec = registry.try_retry_spec(retry)
        return (spec if spec is not None else RetrySpec(retry)), None
    if hasattr(retry, "retry_spec"):  # a MemoryPredictor-like method object
        return retry.retry_spec, None
    return None, retry


class ClusterSim:
    """Packs jobs (method-agnostic) and replays hidden traces with OOM.

    ``retry`` (in :meth:`run`) is either a static :class:`RetrySpec` —
    the vectorized path, required for offset sweeps of ``last_peak_bump`` —
    or a legacy ``(plan, t_fail, used) -> plan`` callable.
    """

    def __init__(self, nodes: List[Node], max_attempts: int = 20,
                 engine: str = "fused", drain: str = "device",
                 shard: Optional[int] = None):
        if engine not in ("fused", "packed", "legacy"):
            raise ValueError(f"unknown engine: {engine!r}")
        if drain not in ("device", "host"):
            raise ValueError(f"unknown drain mode: {drain!r}")
        if shard is not None and drain != "device":
            raise ValueError("shard= requires drain='device'")
        self.nodes = nodes
        self.max_attempts = max_attempts
        self.engine = engine
        # Fused-engine drain mode: "device" folds the whole greedy drain
        # into one jitted dispatch per event (AdmissionState.drain);
        # "host" keeps the per-placement columns/argmax loop as the
        # decision oracle.  ``shard`` shards the drain's node axis over
        # that many devices (shard_map).  Both are ignored by the packed
        # and legacy engines.
        self.drain = drain
        self.shard = shard

    # ------------------------------------------------------------------ API
    def _validate_submit(self, jobs: List[Job]) -> None:
        """Fail fast, loudly, at submit time.

        A job whose attempt-1 plan peak exceeds the largest node's
        capacity can never be placed — rejecting it here (naming the job
        ids) beats discovering a permanent failure mid-replay.  Release
        times must be finite and non-negative.
        """
        if not self.nodes:
            raise ValueError("cluster has no nodes")
        cap0 = max(n.capacity_gb for n in self.nodes)
        bad = [job.jid for job in jobs
               if float(np.max(job.plan.peaks)) > cap0 + 1e-9]
        if bad:
            raise ValueError(
                f"unschedulable at submit: attempt-1 plan peak exceeds the "
                f"largest node capacity ({cap0:g} GB) for job ids {bad}")
        bad = [job.jid for job in jobs
               if not np.isfinite(job.release_time)
               or job.release_time < 0.0]
        if bad:
            raise ValueError(
                f"release_time must be finite and >= 0 for job ids {bad}")

    def run(self, jobs: List[Job], retry,
            offsets: Union[None, str, Dict[str, OffsetCandidate],
                           Sequence[OffsetCandidate]] = None,
            faults: Union[None, FaultSchedule,
                          Sequence[FaultEvent]] = None,
            trace: bool = False
            ) -> Union[ClusterResult, List[ClusterResult]]:
        """Replay ``jobs`` through the cluster; see the module docstring.

        ``trace=True`` scope-enables :mod:`repro.obs` tracing for the
        replay (restoring the previous state afterwards); when tracing
        is already enabled the replay is spanned either way.  Tracing
        only observes — placements/retries/evictions are bitwise
        identical traced or untraced (``tests/test_obs.py``).

        Without ``offsets`` returns one :class:`ClusterResult` and mutates
        the ``Job`` objects (attempts / wasted_gbs / plan) like the legacy
        loop always did.  With a sequence of ``offsets`` returns one result
        per :class:`OffsetCandidate` — jobs are *not* mutated; each
        candidate replays the same workload with re-packed plans while the
        trace batch (and its device copy) is shared across the sweep.

        ``offsets="auto"`` sweeps the registry's default candidate grid
        (:data:`repro.core.registry.DEFAULT_OFFSET_GRID`) and returns only
        the lowest-wastage result; ``offsets={family: OffsetCandidate}``
        applies *per-task-family* candidates (e.g. the output of
        :func:`repro.core.registry.tune_offset` per family) in one replay —
        families absent from the mapping run at identity.

        ``faults`` injects a :class:`repro.sched.faults.FaultSchedule`
        (or a plain event sequence) of node leave/join events; all three
        engines replay it identically — evictions, requeue-with-backoff,
        doomed-descendant accounting and starvation parking included.
        """
        if trace and not _obs.enabled:
            with _obs.tracing():
                return self.run(jobs, retry, offsets, faults)
        if _obs.enabled:
            with _obs.span("cluster.run", engine=self.engine,
                           drain=self.drain, jobs=len(jobs)):
                return self._run_impl(jobs, retry, offsets, faults)
        return self._run_impl(jobs, retry, offsets, faults)

    def _run_impl(self, jobs: List[Job], retry, offsets, faults
                  ) -> Union[ClusterResult, List[ClusterResult]]:
        faults = _norm_faults(faults)
        self._validate_submit(jobs)
        if self.engine == "legacy":
            if offsets is not None:
                raise ValueError("offset sweeps require a batched engine")
            return self._run_legacy(jobs, retry, faults)
        run_one = (self._run_fused if self.engine == "fused"
                   else self._run_packed)
        if offsets is None:
            return run_one(jobs, retry, None, None, write_back=True,
                           faults=faults)
        if isinstance(offsets, str):
            if offsets != "auto":
                raise ValueError(f"unknown offsets mode: {offsets!r}")
            from repro.core.registry import DEFAULT_OFFSET_GRID
            offsets = DEFAULT_OFFSET_GRID
            shared = self._pack_shared(jobs)
            sweep = [run_one(jobs, retry, cand, shared, write_back=False,
                             faults=faults)
                     for cand in offsets]
            return min(sweep, key=lambda r: r.total_wastage_gbs)
        if isinstance(offsets, dict):
            cand = self._family_offsets(jobs, offsets)
            return run_one(jobs, retry, cand, None, write_back=False,
                           faults=faults)
        shared = self._pack_shared(jobs)
        return [run_one(jobs, retry, cand, shared, write_back=False,
                        faults=faults)
                for cand in offsets]

    @staticmethod
    def _family_offsets(jobs: List[Job],
                        mapping: Dict[str, OffsetCandidate]
                        ) -> OffsetCandidate:
        """Fold a per-family candidate mapping into one per-lane candidate.

        ``peak``/``start``/``last_peak_bump`` all become per-lane arrays
        (identity for families not in the mapping): per-family
        :func:`repro.core.registry.tune_offset` winners may disagree on
        every field, including the ksplus last-peak bump — unmapped lanes
        get NaN bumps, which fall back to the retry spec's static value
        inside :func:`repro.core.envelope.retry_packed`.
        """
        families = {job.family for job in jobs}
        unknown = set(mapping) - families
        if unknown:
            raise ValueError(
                f"offset mapping names unknown families: {sorted(unknown)} "
                f"(workload families: {sorted(families)})")
        peak = np.zeros((len(jobs),), np.float64)
        start = np.zeros((len(jobs),), np.float64)
        bump = np.full((len(jobs),), np.nan, np.float64)
        any_bump = False
        for i, job in enumerate(jobs):
            c = mapping.get(job.family)
            if c is not None:
                peak[i] = c.peak
                start[i] = c.start
                if c.last_peak_bump is not None:
                    bump[i] = c.last_peak_bump
                    any_bump = True
        return OffsetCandidate(peak=peak, start=start,
                               last_peak_bump=(bump if any_bump else None))

    # ---------------------------------------------------------- legacy loop
    def _run_legacy(self, jobs: List[Job], retry,
                    faults: Tuple[FaultEvent, ...] = ()) -> ClusterResult:
        spec, retry_fn = _as_spec(retry)
        if retry_fn is None:
            # RetrySpec rules that reference "the machine" (max-machine,
            # double's cap) are bounded by the largest node in this cluster.
            cap_max = max(n.capacity_gb for n in self.nodes)

            def retry_fn(plan, t_fail, used, _spec=spec, _cap=cap_max):
                return apply_retry_spec(_spec, plan, t_fail, used,
                                        machine_memory=_cap)
        frontier = _DagFrontier.build(jobs)
        active: List[Node] = list(self.nodes)
        by_nid: Dict[int, Node] = {n.nid: n for n in active}
        epoch: Dict[int, int] = {job.jid: 0 for job in jobs}
        queue: List[Job] = []
        parked: List[Job] = []
        park_t: Dict[int, float] = {}
        need_cache: Dict[int, float] = {}
        events: List[Tuple[float, int, str, int, object, int]] = []
        seq = itertools.count()
        retries = 0
        unschedulable = 0
        evictions = 0
        doomed = 0
        finished = 0
        starvation_s = 0.0
        area_used = 0.0
        done_at = 0.0
        last_t = 0.0
        placements: List[Tuple[float, int, int]] = []
        have_faults = bool(faults)
        cap_sum = float(sum(n.capacity_gb for n in active))
        cap_integral = 0.0
        cap_last = 0.0

        for i in (range(len(jobs)) if frontier is None
                  else frontier.roots()):
            job = jobs[i]
            if job.release_time > 0.0:
                heapq.heappush(events, (float(job.release_time), next(seq),
                                        "arrive", -1, job, 0))
            else:
                queue.append(job)
        for fe in faults:
            heapq.heappush(events, (float(fe.t), next(seq), fe.kind,
                                    int(fe.nid), fe, 0))

        def need_peak(job: Job) -> float:
            """Peak of the admission-need row (invalidated on re-plan) —
            the packed engines' ``need.max(axis=1)``, one job at a time."""
            v = need_cache.get(job.jid)
            if v is None:
                v = float(np.max(alloc_at(
                    job.plan,
                    np.linspace(0.0, job.est_runtime, ADMIT_GRID))))
                need_cache[job.jid] = v
            return v

        def try_admit(now: float):
            # Graceful degradation: a job no surviving node could *ever*
            # fit parks in a starvation-tracked side queue (it re-enters
            # on the next join) instead of spinning in the scan below.
            if queue:
                cap_hi = max((n.capacity_gb for n in active), default=0.0)
                for job in [j for j in queue
                            if need_peak(j) > cap_hi + 1e-9]:
                    queue.remove(job)
                    parked.append(job)
                    park_t[job.jid] = now
            admitted = True
            while admitted and queue:
                admitted = False
                for job in list(queue):
                    for node in active:
                        if node.fits(job, now):
                            queue.remove(job)
                            node.running.append((now, job))
                            placements.append((now, node.nid, job.jid))
                            v = first_violation(job.plan, job.mem, job.dt)
                            if v < 0:
                                end = now + job.runtime
                                heapq.heappush(
                                    events, (end, next(seq), "done",
                                             node.nid, job,
                                             epoch[job.jid]))
                            else:
                                heapq.heappush(
                                    events, (now + v * job.dt, next(seq),
                                             "oom", node.nid, job,
                                             epoch[job.jid]))
                            admitted = True
                            break

        def submit_child(c: int, now: float):
            child = jobs[c]
            if child.release_time > now:
                heapq.heappush(events, (float(child.release_time),
                                        next(seq), "arrive", -1, child, 0))
            else:
                queue.append(child)

        try_admit(0.0)
        guard = 0
        while events:
            guard += 1
            if guard > 200_000:
                raise RuntimeError("cluster sim did not converge")
            t, _, kind, nid, payload, ep = heapq.heappop(events)
            last_t = max(last_t, t)
            if kind in ("done", "oom"):
                job = payload
                if ep != epoch[job.jid]:
                    continue  # evicted since this event was scheduled
                node = by_nid[nid]
                node.running = [(s, j) for s, j in node.running
                                if j.jid != job.jid]
                if kind == "done":
                    alloc = alloc_at(job.plan,
                                     np.arange(len(job.mem)) * job.dt)
                    job.wasted_gbs += float(np.sum(alloc - job.mem) * job.dt)
                    area_used += float(np.sum(job.mem) * job.dt)
                    done_at = max(done_at, t)
                    finished += 1
                    if frontier is not None:  # dependency-release
                        for c in frontier.release(
                                frontier.index[job.jid]):
                            submit_child(c, t)
                else:  # OOM kill
                    v = first_violation(job.plan, job.mem, job.dt)
                    alloc = alloc_at(job.plan, np.arange(v + 1) * job.dt)
                    job.wasted_gbs += float(np.sum(alloc) * job.dt)
                    job.attempts += 1
                    retries += 1
                    if job.attempts >= self.max_attempts or \
                            float(np.max(job.mem)) > max(
                                n.capacity_gb for n in self.nodes):
                        unschedulable += 1
                        if frontier is not None:  # descendants blocked
                            d = frontier.doom(frontier.index[job.jid])
                            doomed += d
                            unschedulable += d
                    else:
                        job.plan = retry_fn(job.plan, v * job.dt,
                                            float(job.mem[v]))
                        need_cache.pop(job.jid, None)
                        queue.append(job)
                try_admit(t)
            elif kind == "arrive":
                job = payload
                if frontier is None or \
                        not frontier.dead[frontier.index[job.jid]]:
                    queue.append(job)
                try_admit(t)
            elif kind == "leave":
                pos = next((i for i, n in enumerate(active)
                            if n.nid == nid), -1)
                if pos < 0:
                    raise KeyError(
                        f"node_leave: unknown or inactive node {nid} "
                        f"at t={t:g}")
                cap_integral += cap_sum * (t - cap_last)
                cap_last = t
                node = active.pop(pos)
                cap_sum -= node.capacity_gb
                victims = list(node.running)
                node.running = []
                requeue: List[Job] = []
                for (s, job) in victims:
                    epoch[job.jid] += 1     # stale pending done/oom events
                    evictions += 1
                    e = _elapsed_samples(t, s, job.dt, len(job.mem))
                    alloc = alloc_at(job.plan, np.arange(e) * job.dt)
                    job.wasted_gbs += float(np.sum(alloc) * job.dt)
                    job.attempts += 1       # the RetrySpec attempt budget
                    if job.attempts >= self.max_attempts:
                        unschedulable += 1
                        if frontier is not None:
                            d = frontier.doom(frontier.index[job.jid])
                            doomed += d
                            unschedulable += d
                    else:
                        requeue.append(job)
                queue[0:0] = requeue  # evicted jobs go ahead of waiters
                try_admit(t)
            else:  # join
                if any(n.nid == nid for n in active):
                    raise ValueError(
                        f"node_join: node {nid} already active at t={t:g}")
                cap_integral += cap_sum * (t - cap_last)
                cap_last = t
                fe = payload
                node = Node(nid, float(fe.capacity_gb))
                by_nid[nid] = node
                active.append(node)
                cap_sum += node.capacity_gb
                if parked:  # unpark everything; the sweep re-parks misfits
                    for job in parked:
                        starvation_s += t - park_t.pop(job.jid)
                    queue[0:0] = parked
                    parked.clear()
                try_admit(t)

        for job in parked:
            starvation_s += last_t - park_t.pop(job.jid)
        if have_faults:
            end_t = max(done_at, cap_last)
            cap_integral += cap_sum * (end_t - cap_last)
            total_cap_area = max(cap_integral, 1e-9)
        else:
            total_cap_area = sum(
                n.capacity_gb for n in self.nodes) * max(done_at, 1e-9)
        return ClusterResult(
            makespan=done_at,
            total_wastage_gbs=sum(j.wasted_gbs for j in jobs),
            retries=retries,
            unschedulable=unschedulable,
            avg_utilization=area_used / total_cap_area,
            placements=placements,
            evictions=evictions,
            doomed=doomed,
            starved=len(jobs) - finished - unschedulable,
            starvation_s=starvation_s,
            finished=finished,
        )

    # ---------------------------------------------------------- packed loop
    def _pack_shared(self, jobs: List[Job]):
        """Per-dt trace groups, uploaded to the device once per workload.

        Every offset candidate's attempt-#1 probe reuses these arrays — the
        (B, T) trace batch is by far the largest operand, so keeping it
        resident is what makes the sweep cheap.
        """
        import jax.numpy as jnp

        from repro.core.fleet import pack_traces

        by_dt: Dict[float, List[int]] = {}
        for i, job in enumerate(jobs):
            by_dt.setdefault(float(job.dt), []).append(i)
        groups = []
        for dtv in sorted(by_dt):
            idxs = np.asarray(by_dt[dtv], np.int64)
            pt = pack_traces([jobs[i].mem for i in idxs])
            groups.append((dtv, idxs, jnp.asarray(pt.mems),
                           jnp.asarray(pt.lengths)))
        return groups

    def _initial_viol(self, starts, peaks, groups, B: int) -> np.ndarray:
        """Attempt-#1 OOM probe for every lane: one jitted dispatch per dt
        group (:func:`repro.core.fleet.first_attempt`)."""
        import jax.numpy as jnp

        from repro.core.fleet import first_attempt

        viol = np.empty((B,), np.int64)
        for dtv, idxs, dmems, dlengths in groups:
            record_dispatch("cluster.first_attempt")
            v, _ = first_attempt(
                jnp.asarray(starts[idxs].astype(np.float32)),
                jnp.asarray(peaks[idxs].astype(np.float32)),
                dmems, dlengths, jnp.float32(np.inf), dt=dtv)
            # lint: allow[host-sync-in-hot-path] one batched readback per dt group seeds the host event queue at replay setup
            viol[idxs] = np.asarray(v, np.int64)
        return viol

    @staticmethod
    def _apply_offset(env: PackedEnvelopes, cand: OffsetCandidate):
        """Re-pack the plan batch under one offset candidate (cheap: O(BK));
        see :func:`repro.core.envelope.apply_offsets` — scalar (sweep) and
        per-lane (per-family mapping) candidates both land here."""
        return apply_offsets(env.starts, env.peaks, env.nseg, cand)

    def _prep_packed(self, jobs: List[Job], retry,
                     offset: Optional[OffsetCandidate], shared):
        """Shared packed-engine setup (plans, grids, probes) — used
        verbatim by both the host-side packed loop and the fused loop so
        the two engines start from identical state."""
        if any(node.running for node in self.nodes):
            # Resident jobs live outside the packed batch; admitting around
            # them silently would diverge from the legacy loop.
            raise ValueError(
                "batched engines require empty Node.running; submit "
                "resident jobs as part of `jobs` or use engine='legacy'")
        spec, retry_fn = _as_spec(retry)
        bump_lanes = None
        if offset is not None and offset.last_peak_bump is not None:
            if spec is None:
                raise ValueError(
                    "sweeping last_peak_bump requires a RetrySpec retry")
            lb = np.asarray(offset.last_peak_bump, np.float64)
            if lb.ndim == 0:
                spec = spec._replace(bump=float(lb))
            else:  # per-lane bumps; NaN = keep the spec's static value
                bump_lanes = np.where(np.isnan(lb), spec.bump, lb)

        B = len(jobs)
        env = PackedEnvelopes.from_plans([j.plan for j in jobs])
        if offset is None:
            starts, peaks = env.starts.copy(), env.peaks.copy()
        else:
            starts, peaks = self._apply_offset(env, offset)
        nseg = env.nseg
        K = starts.shape[1]

        # Per-job static state (float64 host arrays).
        dts = np.asarray([j.dt for j in jobs], np.float64)
        lengths = np.asarray([len(j.mem) for j in jobs], np.int64)
        runtimes = lengths * dts
        est = np.asarray([j.est_runtime for j in jobs], np.float64)
        summem = np.asarray(
            [j.mem.sum(dtype=np.float64) for j in jobs], np.float64)
        peak_demand = np.asarray(
            [float(np.max(j.mem)) for j in jobs], np.float64)
        caps = np.asarray([n.capacity_gb for n in self.nodes], np.float64)
        cap_max = float(caps.max())
        # Admission horizon grids (B, G) — the legacy per-job linspace,
        # evaluated for every job at once.
        grid_rel = np.linspace(0.0, est, ADMIT_GRID, axis=1)
        need = alloc_at_packed(starts, peaks, grid_rel)
        bounds = segment_sample_bounds(starts, dts[:, None])

        # Attempt-#1 OOM probe, one batched dispatch per dt group.
        shared = shared if shared is not None else self._pack_shared(jobs)
        viol = self._initial_viol(starts, peaks, shared, B)
        return (spec, retry_fn, bump_lanes, starts, peaks, nseg, K, dts,
                lengths, runtimes, summem, peak_demand, caps, cap_max,
                grid_rel, need, bounds, viol)

    def _run_packed(self, jobs: List[Job], retry,
                    offset: Optional[OffsetCandidate], shared,
                    write_back: bool,
                    faults: Tuple[FaultEvent, ...] = ()) -> ClusterResult:
        if not jobs:
            return ClusterResult(0.0, 0.0, 0, 0, 0.0, placements=[],
                                 offset=offset)
        (spec, retry_fn, bump_lanes, starts, peaks, nseg, K, dts, lengths,
         runtimes, summem, peak_demand, caps, cap_max, grid_rel, need,
         bounds, viol) = self._prep_packed(jobs, retry, offset, shared)
        B = len(jobs)

        # Mutable replay state.  attempts/wastage continue from the Job
        # counters, exactly like the legacy loop's in-place accumulation.
        attempts0 = np.asarray([j.attempts for j in jobs], np.int64)
        attempts = attempts0.copy()
        wasted = np.asarray([j.wasted_gbs for j in jobs], np.float64)
        release = np.asarray([j.release_time for j in jobs], np.float64)
        need_max = need.max(axis=1)
        # Fleet membership: events carry the stable ``nid``; positions in
        # these parallel lists shift under churn (leaves splice, joins
        # append — the same order the legacy loop's ``active`` keeps).
        active_nids: List[int] = [n.nid for n in self.nodes]
        caps_act = caps.copy()
        node_running: List[List[int]] = [[] for _ in active_nids]
        admit_t = np.zeros((B,), np.float64)
        epoch = np.zeros((B,), np.int64)
        frontier = _DagFrontier.build(jobs)
        queue: List[int] = []
        parked: List[int] = []
        park_t: Dict[int, float] = {}
        events: List[Tuple[float, int, str, int, object, int]] = []
        seq = itertools.count()
        retries = 0
        unschedulable = 0
        evictions = 0
        doomed = 0
        finished = 0
        starvation_s = 0.0
        area_used = 0.0
        done_at = 0.0
        last_t = 0.0
        placements: List[Tuple[float, int, int]] = []
        have_faults = bool(faults)
        cap_sum = float(caps_act.sum())
        cap_integral = 0.0
        cap_last = 0.0

        for ji in (range(B) if frontier is None else frontier.roots()):
            if release[ji] > 0.0:
                heapq.heappush(events, (float(release[ji]), next(seq),
                                        "arrive", -1, ji, 0))
            else:
                queue.append(ji)
        for fe in faults:
            heapq.heappush(events, (float(fe.t), next(seq), fe.kind,
                                    int(fe.nid), fe, 0))

        def fits_column(ni: int, q: List[int], now: float) -> Dict[int, bool]:
            """Admission predicate for every queued job vs node ``ni`` at
            ``now`` — one vectorized residual evaluation + reduction."""
            run = node_running[ni]
            grid_abs = now + grid_rel[q]
            resid = residual_over(
                caps_act[ni], starts[run], peaks[run], admit_t[run],
                grid_abs, dur=runtimes[run])
            ok = fits_under(need[q], resid)
            return dict(zip(q, ok.tolist()))

        def try_admit(now: float):
            if queue:  # park jobs no surviving node could ever fit
                cap_hi = float(caps_act.max()) if active_nids else 0.0
                for ji in [q for q in queue if need_max[q] > cap_hi + 1e-9]:
                    queue.remove(ji)
                    parked.append(ji)
                    park_t[ji] = now
            cols: Dict[int, Dict[int, bool]] = {}
            admitted = True
            while admitted and queue:
                admitted = False
                for ji in list(queue):
                    for ni in range(len(active_nids)):
                        col = cols.get(ni)
                        if col is None or ji not in col:
                            col = cols[ni] = fits_column(ni, list(queue), now)
                        if col[ji]:
                            queue.remove(ji)
                            node_running[ni].append(ji)
                            admit_t[ji] = now
                            cols.pop(ni, None)  # this node's residual changed
                            placements.append(
                                (float(now), active_nids[ni], jobs[ji].jid))
                            v = viol[ji]
                            if v < 0:
                                heapq.heappush(
                                    events, (now + runtimes[ji], next(seq),
                                             "done", active_nids[ni], ji,
                                             int(epoch[ji])))
                            else:
                                heapq.heappush(
                                    events, (now + v * dts[ji], next(seq),
                                             "oom", active_nids[ni], ji,
                                             int(epoch[ji])))
                            admitted = True
                            break

        try_admit(0.0)
        guard = 0
        while events:
            guard += 1
            if guard > 200_000:
                raise RuntimeError("cluster sim did not converge")
            t, _, kind, nid, payload, ep = heapq.heappop(events)
            last_t = max(last_t, t)
            if kind in ("done", "oom"):
                ji = payload
                if ep != epoch[ji]:
                    continue  # evicted since this event was scheduled
                node_running[active_nids.index(nid)].remove(ji)
                row = slice(ji, ji + 1)
                if kind == "done":
                    w = span_alloc_sum(peaks[row], bounds[row],
                                       lengths[row])[0]
                    wasted[ji] += (w - summem[ji]) * dts[ji]
                    area_used += summem[ji] * dts[ji]
                    done_at = max(done_at, t)
                    finished += 1
                    if frontier is not None:  # dependency-release
                        for c in frontier.release(ji):
                            if release[c] > t:
                                heapq.heappush(
                                    events, (float(release[c]), next(seq),
                                             "arrive", -1, c, 0))
                            else:
                                queue.append(c)
                else:  # OOM kill
                    v = int(viol[ji])
                    w = span_alloc_sum(peaks[row], bounds[row],
                                       np.asarray([v + 1]))[0]
                    wasted[ji] += w * dts[ji]
                    attempts[ji] += 1
                    retries += 1
                    if attempts[ji] >= self.max_attempts or \
                            peak_demand[ji] > cap_max:
                        unschedulable += 1
                        if frontier is not None:  # descendants blocked
                            d = frontier.doom(ji)
                            doomed += d
                            unschedulable += d
                    else:
                        t_fail = v * dts[ji]
                        used = float(jobs[ji].mem[v])
                        if spec is not None:
                            ns, npk = retry_packed(
                                spec, starts[row], peaks[row], nseg[row],
                                np.asarray([t_fail]), np.asarray([used]),
                                machine_memory=cap_max,
                                bump=(None if bump_lanes is None
                                      else bump_lanes[row]))
                            starts[ji], peaks[ji] = ns[0], npk[0]
                        else:
                            s, p = PackedEnvelopes(
                                starts, peaks, nseg).row(ji)
                            new = retry_fn(AllocationPlan(s, p), t_fail,
                                           used)
                            starts[ji, :new.n] = new.starts
                            starts[ji, new.n:] = PAD_START
                            peaks[ji, :new.n] = new.peaks
                            peaks[ji, new.n:] = new.peaks[-1]
                            nseg[ji] = new.n
                        # Refresh the lane's derived state (plan changed).
                        need[ji] = alloc_at_packed(
                            starts[row], peaks[row], grid_rel[row])[0]
                        need_max[ji] = need[ji].max()
                        bounds[ji] = segment_sample_bounds(
                            starts[row], dts[ji])[0]
                        viol[ji] = first_violation_packed(
                            starts[row], peaks[row],
                            np.asarray(jobs[ji].mem, np.float64)[None, :],
                            lengths[row], float(dts[ji]))[0]
                        queue.append(ji)
                try_admit(t)
            elif kind == "arrive":
                ji = payload
                if frontier is None or not frontier.dead[ji]:
                    queue.append(ji)
                try_admit(t)
            elif kind == "leave":
                if nid not in active_nids:
                    raise KeyError(
                        f"node_leave: unknown or inactive node {nid} "
                        f"at t={t:g}")
                cap_integral += cap_sum * (t - cap_last)
                cap_last = t
                pos = active_nids.index(nid)
                cap_sum -= float(caps_act[pos])
                caps_act = np.delete(caps_act, pos)
                victims = node_running.pop(pos)
                active_nids.pop(pos)
                requeue: List[int] = []
                for ji in victims:
                    epoch[ji] += 1      # stale pending done/oom events
                    evictions += 1
                    e = _elapsed_samples(t, admit_t[ji], dts[ji],
                                         lengths[ji])
                    w = span_alloc_sum(peaks[ji:ji + 1], bounds[ji:ji + 1],
                                       np.asarray([e]))[0]
                    wasted[ji] += w * dts[ji]
                    attempts[ji] += 1   # the RetrySpec attempt budget
                    if attempts[ji] >= self.max_attempts:
                        unschedulable += 1
                        if frontier is not None:
                            d = frontier.doom(ji)
                            doomed += d
                            unschedulable += d
                    else:
                        requeue.append(ji)
                queue[0:0] = requeue  # evicted jobs go ahead of waiters
                try_admit(t)
            else:  # join
                if nid in active_nids:
                    raise ValueError(
                        f"node_join: node {nid} already active at t={t:g}")
                cap_integral += cap_sum * (t - cap_last)
                cap_last = t
                fe = payload
                active_nids.append(nid)
                node_running.append([])
                caps_act = np.append(caps_act, float(fe.capacity_gb))
                cap_sum += float(fe.capacity_gb)
                if parked:  # unpark; the sweep re-parks misfits
                    for ji in parked:
                        starvation_s += t - park_t.pop(ji)
                    queue[0:0] = parked
                    parked.clear()
                try_admit(t)

        for ji in parked:
            starvation_s += last_t - park_t.pop(ji)
        if write_back:
            for i, job in enumerate(jobs):
                job.attempts = int(attempts[i])
                job.wasted_gbs = float(wasted[i])
                if attempts[i] > attempts0[i]:  # plan changed by retries
                    s, p = PackedEnvelopes(starts, peaks, nseg).row(i)
                    job.plan = AllocationPlan(starts=s, peaks=p)

        if have_faults:
            end_t = max(done_at, cap_last)
            cap_integral += cap_sum * (end_t - cap_last)
            total_cap_area = max(cap_integral, 1e-9)
        else:
            total_cap_area = float(caps.sum()) * max(done_at, 1e-9)
        return ClusterResult(
            makespan=done_at,
            total_wastage_gbs=float(wasted.sum()),
            retries=retries,
            unschedulable=unschedulable,
            avg_utilization=area_used / total_cap_area,
            placements=placements,
            offset=offset,
            evictions=evictions,
            doomed=doomed,
            starved=B - finished - unschedulable,
            starvation_s=starvation_s,
            finished=finished,
        )

    # ----------------------------------------------------------- fused loop
    def _run_fused(self, jobs: List[Job], retry,
                   offset: Optional[OffsetCandidate], shared,
                   write_back: bool,
                   admission_backend: str = "fused",
                   faults: Tuple[FaultEvent, ...] = ()) -> ClusterResult:
        """Packed event loop with the per-event hot path fused into XLA.

        Decision-for-decision identical to :meth:`_run_packed` (the
        differential suite pins the placement logs bitwise); differs in
        *how* the work is done:

        * admission — :class:`repro.sched.admission.AdmissionState`: one
          jitted float64 dispatch per event over every (node, queued lane)
          pair, then incremental recomputes of only the invalidated
          entries after each placement, instead of full per-node numpy
          columns per admission;
        * retries — all OOMs that land at the same event time are
          compacted into one multi-row ``retry_packed`` re-plan, one
          batched ``need``/``bounds`` refresh and one batched float64
          re-probe per dt group, instead of one 1-row slice per event.
        """
        if not jobs:
            return ClusterResult(0.0, 0.0, 0, 0, 0.0, placements=[],
                                 offset=offset)
        from repro.sched.admission import AdmissionState

        (spec, retry_fn, bump_lanes, starts, peaks, nseg, K, dts, lengths,
         runtimes, summem, peak_demand, caps, cap_max, grid_rel, need,
         bounds, viol) = self._prep_packed(jobs, retry, offset, shared)
        B = len(jobs)

        attempts0 = np.asarray([j.attempts for j in jobs], np.int64)
        attempts = attempts0.copy()
        wasted = np.asarray([j.wasted_gbs for j in jobs], np.float64)
        release = np.asarray([j.release_time for j in jobs], np.float64)
        need_max = need.max(axis=1)
        adm = AdmissionState(caps, K=K, G=ADMIT_GRID,
                             backend=admission_backend, use_dur=True,
                             shard=self.shard)
        adm.add_lanes(starts, peaks, need, grid_rel, dur=runtimes)
        device_drain = self.drain == "device"
        # Node rows in ``adm`` are positional; events carry the stable
        # ``nid`` and map through this list (leaves splice, joins append —
        # AdmissionState's remove_node/add_node row protocol).
        active_nids: List[int] = [n.nid for n in self.nodes]
        epoch = np.zeros((B,), np.int64)
        frontier = _DagFrontier.build(jobs)
        queue = _LaneQueue(B)
        parked: List[int] = []
        park_t: Dict[int, float] = {}
        events: List[Tuple[float, int, str, int, object, int]] = []
        seq = itertools.count()
        retries = 0
        unschedulable = 0
        evictions = 0
        doomed = 0
        finished = 0
        starvation_s = 0.0
        area_used = 0.0
        done_at = 0.0
        last_t = 0.0
        placements: List[Tuple[float, int, int]] = []
        have_faults = bool(faults)
        cap_sum = float(caps.sum())
        cap_integral = 0.0
        cap_last = 0.0

        for ji in (range(B) if frontier is None else frontier.roots()):
            if release[ji] > 0.0:
                heapq.heappush(events, (float(release[ji]), next(seq),
                                        "arrive", -1, ji, 0))
            else:
                queue.append(ji)
        for fe in faults:
            heapq.heappush(events, (float(fe.t), next(seq), fe.kind,
                                    int(fe.nid), fe, 0))

        def place_record(now: float, ni: int, ji: int):
            placements.append(
                (float(now), active_nids[ni], jobs[ji].jid))
            v = viol[ji]
            if v < 0:
                heapq.heappush(events, (now + runtimes[ji], next(seq),
                                        "done", active_nids[ni], ji,
                                        int(epoch[ji])))
            else:
                heapq.heappush(events, (now + v * dts[ji], next(seq),
                                        "oom", active_nids[ni], ji,
                                        int(epoch[ji])))

        def try_admit(now: float):
            """Greedy drain on the shared fits matrix.

            Decision-equivalent to the packed loop's job-by-job scan:
            admissions only shrink residuals, so an unfit job can never
            become fit within one drain — the first fitting job in queue
            order under the current state is exactly the next job the
            per-job scan would admit.

            With ``drain="device"`` the whole greedy loop — fits
            refresh, (queue, node)-order argmax, residual scatter,
            repeat — runs inside :meth:`AdmissionState.drain`, ONE
            jitted dispatch returning the packed placement list.  The
            host fallback iterates here, one fused ``columns`` refresh
            per placement, and is pinned bitwise against the device
            path by the differential suite.
            """
            ids = queue.ids()
            if ids.size:  # park jobs no surviving node could ever fit
                cap_hi = float(adm.caps.max()) if adm.N else 0.0
                bad = need_max[ids] > cap_hi + 1e-9
                if bad.any():
                    drop = ids[bad]
                    queue.remove_many(drop)
                    for ji in drop.tolist():
                        parked.append(ji)
                        park_t[ji] = now
                    ids = ids[~bad]
            adm.sync_now(now)
            if device_drain:
                if ids.size == 0 or adm.N == 0:
                    return
                placed = adm.drain(now, ids)
                if placed:
                    queue.remove_many([ji for ji, _ in placed])
                    for ji, ni in placed:
                        place_record(now, ni, ji)
                return
            alive = np.ones(ids.size, bool)
            while alive.any():
                cur = ids[alive]
                adm.columns(now, cur)  # one dispatch for invalid entries
                M = adm.fits[:, cur]   # (N, Q) — all entries now valid
                anyfit = M.any(axis=0)
                if not anyfit.any():
                    break
                col = int(np.argmax(anyfit))
                ni = int(np.argmax(M[:, col]))
                ji = int(cur[col])
                alive[np.nonzero(alive)[0][col]] = False
                queue.remove(ji)
                adm.place(ni, ji, now)
                place_record(now, ni, ji)

        def process_job_run(run_events):
            """One contiguous run of *fresh* done/oom events inside a
            same-time batch: stage wastage and compacted retries exactly
            like the pre-churn whole-batch path (no membership change can
            occur inside a run, so the staging stays decision-safe), then
            process the events one at a time."""
            nonlocal retries, unschedulable, doomed, finished
            nonlocal area_used, done_at
            # Stage wastage for the run against the *pre-retry* plans
            # (compacted multi-row span arithmetic).
            done_idx = [ev[4] for ev in run_events if ev[2] == "done"]
            oom_idx = [ev[4] for ev in run_events if ev[2] == "oom"]
            w_done: Dict[int, float] = {}
            w_oom: Dict[int, float] = {}
            if done_idx:
                rows = np.asarray(done_idx)
                w = span_alloc_sum(peaks[rows], bounds[rows], lengths[rows])
                w_done = dict(zip(done_idx, w))
            if oom_idx:
                rows = np.asarray(oom_idx)
                w = span_alloc_sum(peaks[rows], bounds[rows],
                                   viol[rows] + 1)
                w_oom = dict(zip(oom_idx, w))

            # Event-batched retries: compact the retrying minority into one
            # multi-row re-plan + refresh (lane-local, so staging it before
            # the per-event processing below cannot change any decision —
            # a lane only becomes visible to admission once it is queued).
            retry_set = [
                ji for ji in oom_idx
                if attempts[ji] + 1 < self.max_attempts
                and peak_demand[ji] <= cap_max]
            if retry_set:
                rows = np.asarray(retry_set)
                if spec is not None:
                    ns, npk = retry_packed(
                        spec, starts[rows], peaks[rows], nseg[rows],
                        viol[rows] * dts[rows],
                        np.asarray([float(jobs[ji].mem[viol[ji]])
                                    for ji in retry_set]),
                        machine_memory=cap_max,
                        bump=(None if bump_lanes is None
                              else bump_lanes[rows]))
                    starts[rows], peaks[rows] = ns, npk
                else:
                    for ji in retry_set:
                        s, p = PackedEnvelopes(starts, peaks, nseg).row(ji)
                        new = retry_fn(AllocationPlan(s, p),
                                       float(viol[ji] * dts[ji]),
                                       float(jobs[ji].mem[viol[ji]]))
                        starts[ji, :new.n] = new.starts
                        starts[ji, new.n:] = PAD_START
                        peaks[ji, :new.n] = new.peaks
                        peaks[ji, new.n:] = new.peaks[-1]
                        nseg[ji] = new.n
                # Refresh derived state for all retried lanes at once;
                # post-retry probes stay float64 (precision contract), one
                # batched pass per dt group.
                need[rows] = alloc_at_packed(
                    starts[rows], peaks[rows], grid_rel[rows])
                need_max[rows] = need[rows].max(axis=1)
                bounds[rows] = segment_sample_bounds(
                    starts[rows], dts[rows][:, None])
                by_dt: Dict[float, List[int]] = {}
                for ji in retry_set:
                    by_dt.setdefault(float(dts[ji]), []).append(ji)
                for dtv, lanes in by_dt.items():
                    g = np.asarray(lanes)
                    tmax = int(lengths[g].max())
                    mems = np.zeros((len(lanes), tmax), np.float64)
                    for r, ji in enumerate(lanes):
                        mems[r, :lengths[ji]] = jobs[ji].mem
                    viol[g] = first_violation_packed(
                        starts[g], peaks[g], mems, lengths[g], dtv)
                # NOTE: the admission state keeps each lane's OLD plan
                # until that lane's kill event is processed below — while
                # an OOMing job is still resident, the node's residual
                # must be computed against the envelope it was admitted
                # with, not the staged re-plan.
            retryable = set(retry_set)

            # Process the run one event at a time — identical admission
            # interleaving to the per-event loop.
            for (t_, _, kind, nid, ji, _) in run_events:
                adm.release(active_nids.index(nid), ji)
                if kind == "done":
                    wasted[ji] += (w_done[ji] - summem[ji]) * dts[ji]
                    area_used += summem[ji] * dts[ji]
                    done_at = max(done_at, t_)
                    finished += 1
                    if frontier is not None:  # dependency-release
                        for c in frontier.release(ji):
                            if release[c] > t_:
                                heapq.heappush(
                                    events, (float(release[c]), next(seq),
                                             "arrive", -1, c, 0))
                            else:
                                queue.append(c)
                else:  # OOM kill
                    wasted[ji] += w_oom[ji] * dts[ji]
                    attempts[ji] += 1
                    retries += 1
                    if ji in retryable:
                        # The lane left its node: its staged re-plan may
                        # now become visible to admission.
                        adm.update_lane(ji, starts[ji], peaks[ji],
                                        need[ji])
                        queue.append(ji)
                    else:
                        unschedulable += 1
                        if frontier is not None:  # descendants blocked
                            d = frontier.doom(ji)
                            doomed += d
                            unschedulable += d
                try_admit(t_)

        def process_leave(t: float, nid: int):
            """Node death: drop the admission row (validity-mask entries
            for the dead node vanish with it; other nodes' cached fits
            stay valid — their residuals are unchanged), evict residents
            in admission order, and account the kill like an OOM whose
            wastage stops at the eviction time."""
            nonlocal evictions, unschedulable, doomed
            nonlocal cap_sum, cap_integral, cap_last
            if nid not in active_nids:
                raise KeyError(
                    f"node_leave: unknown or inactive node {nid} "
                    f"at t={t:g}")
            cap_integral += cap_sum * (t - cap_last)
            cap_last = t
            pos = active_nids.index(nid)
            cap_sum -= float(adm.caps[pos])
            evicted = adm.remove_node(pos)
            active_nids.pop(pos)
            requeue: List[int] = []
            for ji in evicted:
                epoch[ji] += 1      # stale pending done/oom events
                evictions += 1
                e = _elapsed_samples(t, adm.admit_t[ji], dts[ji],
                                     lengths[ji])
                w = span_alloc_sum(peaks[ji:ji + 1], bounds[ji:ji + 1],
                                   np.asarray([e]))[0]
                wasted[ji] += w * dts[ji]
                attempts[ji] += 1   # the RetrySpec attempt budget
                if attempts[ji] >= self.max_attempts:
                    unschedulable += 1
                    if frontier is not None:
                        d = frontier.doom(ji)
                        doomed += d
                        unschedulable += d
                else:
                    requeue.append(ji)
            queue.push_front(requeue)  # evicted jobs go ahead of waiters

        def process_join(t: float, nid: int, fe: FaultEvent):
            nonlocal cap_sum, cap_integral, cap_last, starvation_s
            if nid in active_nids:
                raise ValueError(
                    f"node_join: node {nid} already active at t={t:g}")
            cap_integral += cap_sum * (t - cap_last)
            cap_last = t
            adm.add_node(float(fe.capacity_gb))
            active_nids.append(nid)
            cap_sum += float(fe.capacity_gb)
            if parked:  # unpark; the sweep re-parks misfits
                for ji in parked:
                    starvation_s += t - park_t.pop(ji)
                queue.push_front(parked)
                parked.clear()

        if _obs.enabled:
            # Resolve the engine series once — the registry lookup (lock
            # + dict get) is too costly to repeat on every event batch.
            _s_wastage = _met.series("cluster.wastage_gbs")
            _s_util = _met.series("cluster.utilization")
            _s_starve = _met.series("cluster.starvation_s")

        try_admit(0.0)
        guard = 0
        while events:
            # Drain the maximal same-time prefix: events pushed *during*
            # this batch land behind it in (t, seq) order, exactly where
            # the one-at-a-time loop would pop them.
            t = events[0][0]
            batch: List[Tuple[float, int, str, int, object, int]] = []
            while events and events[0][0] == t:
                batch.append(heapq.heappop(events))
            guard += len(batch)
            if guard > 200_000:
                raise RuntimeError("cluster sim did not converge")
            last_t = max(last_t, t)

            # Segment the batch: contiguous runs of done/oom events keep
            # the compacted staging path (freshness-filtered — an earlier
            # leave in this batch may have evicted their lanes), while
            # membership/arrival events process individually so staged
            # state never straddles an eviction.
            i = 0
            while i < len(batch):
                kind_i = batch[i][2]
                if kind_i in ("done", "oom"):
                    run_events = []
                    while i < len(batch) and batch[i][2] in ("done", "oom"):
                        ev = batch[i]
                        if ev[5] == epoch[ev[4]]:
                            run_events.append(ev)
                        i += 1
                    if run_events:
                        process_job_run(run_events)
                elif kind_i == "arrive":
                    ji = batch[i][4]
                    i += 1
                    if frontier is None or not frontier.dead[ji]:
                        queue.append(ji)
                    try_admit(t)
                elif kind_i == "leave":
                    process_leave(t, batch[i][3])
                    i += 1
                    try_admit(t)
                else:  # join
                    process_join(t, batch[i][3], batch[i][4])
                    i += 1
                    try_admit(t)

            if _obs.enabled:
                # Per-event-batch engine series keyed by sim time — the
                # curves ROADMAP items 2/5 (online selection) read back.
                _s_wastage.append(t, float(wasted.sum()))
                _s_util.append(t, area_used / max(
                    cap_integral + cap_sum * (t - cap_last), 1e-9))
                _s_starve.append(t, starvation_s)
                _obs.instant("cluster.event_batch", t=t, n=len(batch))

        for ji in parked:
            starvation_s += last_t - park_t.pop(ji)
        if write_back:
            for i, job in enumerate(jobs):
                job.attempts = int(attempts[i])
                job.wasted_gbs = float(wasted[i])
                if attempts[i] > attempts0[i]:  # plan changed by retries
                    s, p = PackedEnvelopes(starts, peaks, nseg).row(i)
                    job.plan = AllocationPlan(starts=s, peaks=p)

        if have_faults:
            # Piecewise-constant capacity under churn; without faults the
            # pre-churn closed form is kept bit-for-bit.
            end_t = max(done_at, cap_last)
            cap_integral += cap_sum * (end_t - cap_last)
            total_cap_area = max(cap_integral, 1e-9)
        else:
            total_cap_area = float(caps.sum()) * max(done_at, 1e-9)
        return ClusterResult(
            makespan=done_at,
            total_wastage_gbs=float(wasted.sum()),
            retries=retries,
            unschedulable=unschedulable,
            avg_utilization=area_used / total_cap_area,
            placements=placements,
            offset=offset,
            evictions=evictions,
            doomed=doomed,
            starved=B - finished - unschedulable,
            starvation_s=starvation_s,
            finished=finished,
        )
