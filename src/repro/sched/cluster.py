"""Event-driven cluster simulator with time-varying memory allocations.

This is the paper's deployment context: a resource manager packs workflow
tasks onto nodes using each task's *memory envelope over time*.  KS+'s
envelopes free the unused head-room of early segments for other tasks —
the wastage reduction translates directly into throughput.

The simulator is discrete-event: nodes admit a queued job when the job's
allocation envelope fits under the node's *residual envelope* for the whole
projected runtime; the OOM killer fires when a job's hidden trace exceeds
its own allocation, triggering the method's retry strategy.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import AllocationPlan, alloc_at, first_violation

__all__ = ["Job", "Node", "ClusterSim", "ClusterResult"]


@dataclasses.dataclass
class Job:
    jid: int
    family: str
    input_gb: float
    mem: np.ndarray          # hidden ground-truth trace (GB per dt)
    dt: float
    plan: AllocationPlan     # current allocation envelope
    est_runtime: float       # scheduler-facing runtime estimate
    attempts: int = 0
    wasted_gbs: float = 0.0

    @property
    def runtime(self) -> float:
        return len(self.mem) * self.dt


@dataclasses.dataclass
class Node:
    nid: int
    capacity_gb: float
    running: List[Tuple[float, "Job"]] = dataclasses.field(default_factory=list)

    def residual_at(self, t_abs: float, horizon: np.ndarray) -> np.ndarray:
        """Residual capacity over ``horizon`` (absolute times)."""
        used = np.zeros_like(horizon)
        for start, job in self.running:
            rel = horizon - start
            active = (rel >= 0) & (rel < job.runtime + 1e-9)
            used += np.where(active, alloc_at(job.plan, np.maximum(rel, 0)), 0.0)
        return self.capacity_gb - used

    def fits(self, job: Job, t_abs: float) -> bool:
        horizon = t_abs + np.linspace(0, job.est_runtime, 64)
        resid = self.residual_at(t_abs, horizon)
        need = alloc_at(job.plan, np.linspace(0, job.est_runtime, 64))
        return bool(np.all(need <= resid + 1e-9))


@dataclasses.dataclass
class ClusterResult:
    makespan: float
    total_wastage_gbs: float
    retries: int
    unschedulable: int
    avg_utilization: float


class ClusterSim:
    """Packs jobs (method-agnostic) and replays hidden traces with OOM."""

    def __init__(self, nodes: List[Node], max_attempts: int = 20):
        self.nodes = nodes
        self.max_attempts = max_attempts

    def run(self, jobs: List[Job], retry_fn) -> ClusterResult:
        queue: List[Job] = list(jobs)
        events: List[Tuple[float, int, str, int, Job]] = []  # (t, seq, kind, nid, job)
        seq = itertools.count()
        t = 0.0
        retries = 0
        unschedulable = 0
        area_used = 0.0
        done_at = 0.0

        def try_admit(now: float):
            admitted = True
            while admitted and queue:
                admitted = False
                for job in list(queue):
                    for node in self.nodes:
                        if node.fits(job, now):
                            queue.remove(job)
                            node.running.append((now, job))
                            v = first_violation(job.plan, job.mem, job.dt)
                            if v < 0:
                                end = now + job.runtime
                                heapq.heappush(events, (end, next(seq), "done",
                                                        node.nid, job))
                            else:
                                heapq.heappush(events, (now + v * job.dt,
                                                        next(seq), "oom",
                                                        node.nid, job))
                            admitted = True
                            break

        try_admit(0.0)
        guard = 0
        while events:
            guard += 1
            if guard > 200_000:
                raise RuntimeError("cluster sim did not converge")
            t, _, kind, nid, job = heapq.heappop(events)
            node = self.nodes[nid]
            node.running = [(s, j) for s, j in node.running if j.jid != job.jid]
            if kind == "done":
                alloc = alloc_at(job.plan,
                                 np.arange(len(job.mem)) * job.dt)
                job.wasted_gbs += float(np.sum(alloc - job.mem) * job.dt)
                area_used += float(np.sum(job.mem) * job.dt)
                done_at = max(done_at, t)
            else:  # OOM kill
                v = first_violation(job.plan, job.mem, job.dt)
                alloc = alloc_at(job.plan, np.arange(v + 1) * job.dt)
                job.wasted_gbs += float(np.sum(alloc) * job.dt)
                job.attempts += 1
                retries += 1
                if job.attempts >= self.max_attempts or \
                        float(np.max(job.mem)) > max(
                            n.capacity_gb for n in self.nodes):
                    unschedulable += 1
                else:
                    job.plan = retry_fn(job.plan, v * job.dt,
                                        float(job.mem[v]))
                    queue.append(job)
            try_admit(t)

        total_cap_area = sum(n.capacity_gb for n in self.nodes) * max(done_at, 1e-9)
        return ClusterResult(
            makespan=done_at,
            total_wastage_gbs=sum(j.wasted_gbs for j in jobs),
            retries=retries,
            unschedulable=unschedulable,
            avg_utilization=area_used / total_cap_area,
        )
