"""Shared admission runtime state: one fits matrix, one invalidation protocol.

Both admission paths — :class:`repro.sched.cluster.ClusterSim`'s packed
event loop and :class:`repro.sched.elastic.ElasticPlanner`'s churn-driven
``drain`` — answer the same question at every decision point: *which queued
envelopes fit under which node's residual envelope right now?*  This module
owns that answer as explicit runtime state instead of a per-call
recomputation:

* a **fits matrix** ``(N nodes, B lanes)`` of admission predicates plus a
  per-entry **validity mask** — the single source of truth for "does lane b
  fit node n at the current time",
* one **invalidation protocol** (see :class:`AdmissionState`):

  - advancing ``now`` invalidates everything (residuals are functions of
    absolute time),
  - *placing* a lane on a node invalidates only the node's currently-True
    entries — adding an envelope can only shrink the residual, so False
    entries stay False without recomputation (monotonicity),
  - *releasing* a lane from a node invalidates the node's whole column
    (the residual grew; False entries may flip True),
  - a lane's plan change (retry re-plan) invalidates that lane everywhere,
  - node join/leave adds/drops a row,

* two interchangeable compute backends:

  - ``backend="numpy"`` — the float64 host reference: per-node
    :func:`repro.core.envelope.residual_over` + ``fits_under`` calls,
    exactly the arithmetic the packed ``ClusterSim`` engine inlines,
  - ``backend="fused"`` — ONE jitted XLA dispatch per refresh computing
    every invalid ``(node, lane)`` entry at once on device-resident
    float64 state (``jax.experimental.enable_x64`` scopes the 64-bit
    semantics to these calls).  The packed envelope/need/placement-time
    buffers live on the device and are updated in place through donated
    scatter programs, so the per-event hot path is one fused dispatch
    over the already-packed ``(B, K)`` layout — not a Python loop over
    nodes and queued jobs.

Precision contract (see also :mod:`repro.sched.cluster`): both backends
evaluate residuals and admission predicates in float64 with identical
elementwise operations; the only permitted divergence is the summation
order over a node's resident envelopes (numpy reduces linearly, XLA may
tree-reduce), i.e. last-ulp differences ~1e-16 relative.  A decision can
therefore only differ between backends when a lane's need grazes the
residual within one float64 ulp of the 1e-9 admission tolerance — orders
of magnitude below any real trace/plan margin.

Shapes are kept jit-stable by padding the queued-lane and resident-lane
axes to power-of-two buckets (:func:`repro.core.fleet.pad_lane_axis`, the
fleet engine's compaction trick), bounding compilation to log2-many shapes.

The state is *frontier-agnostic*: ``ClusterSim``'s DAG-aware replay adds
every lane up front but only passes *released* lanes (all parents
finished) to :meth:`AdmissionState.columns`, so dependency structure
costs nothing here — unreleased lanes simply never enter a refresh.  The
``workload_replay`` benchmark drives this path with a ≥5k-task DAG.

The join/leave row protocol (:meth:`AdmissionState.add_node` /
:meth:`remove_node`) is what both churn consumers share:
``ElasticPlanner`` drives it for slice membership, and ``ClusterSim``'s
fault path drives it for ``FaultSchedule`` leave/join events —
``remove_node`` returns the dead node's resident lanes *in admission
order*, which is the eviction order every engine pins bitwise.  Node
rows are positional (a leave splices, a join appends); callers keep
their own stable-id ↔ row mapping.  Because the fused dispatch takes
``caps`` and the resident-lane index per call, churn needs no
device-state rebuild: dropping a row just drops it from the next
dispatch's operands, keeping the engine one-dispatch-per-refresh under
faults (``churn_replay`` benchmark).
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import numpy as np

from repro.analysis.contracts import record_dispatch
from repro.core.envelope import fits_column
from repro.obs import metrics as _met
from repro.obs import trace as _obs

__all__ = ["AdmissionState"]

_KERNEL_CACHE = {}


def _pow4(n: int) -> int:
    """Round ``n`` up to a power of 4 (1, 4, 16, 64, ...).

    Run-axis bucket for the fused kernels: coarser than pow2 on
    purpose — halving the number of distinct compiled shapes costs at
    most 2x padding on an axis these kernels reduce over cheaply.
    """
    b = max(n - 1, 0).bit_length()
    return 1 << (b + (b & 1))


def _fused_kernel(masked: bool):
    """Build (once) the jitted fused fits-columns program.

    Computes, for every requested node and queued lane at once::

        resid[n, q, g] = cap[n] - sum_r alloc_r(now + grid[q, g] - t0[r])
        fits[n, q]     = all_g need[q, g] <= resid[n, q, g] + tol
        minresid[n, q] = min_g resid[n, q, g]

    mirroring ``residual_over`` / ``fits_under`` elementwise in float64.
    ``masked`` (static) selects the anticipating-residual semantics
    (resident envelopes only count inside ``[t0, t0 + dur)``, the cluster
    simulator's rule) vs. the conservative count-forever semantics (the
    elastic planner's rule, ``usage_over`` with ``dur=None``).
    """
    if masked in _KERNEL_CACHE:
        return _KERNEL_CACHE[masked]
    import jax
    import jax.numpy as jnp

    @jax.jit
    def kernel(starts, peaks, admit_t, dur, need, grid,
               caps, run_idx, run_valid, q_idx, now, tol):
        N, R = run_idx.shape
        K = starts.shape[1]
        G = grid.shape[1]
        flat = run_idx.reshape(-1)
        rs = starts[flat]                        # (N*R, K)
        rp = peaks[flat]
        rt0 = admit_t[flat]                      # (N*R,)
        t = (now + grid[q_idx]).reshape(-1)      # (Q*G,) absolute times
        rel = t[None, :] - rt0[:, None]          # (N*R, Q*G)
        relc = jnp.maximum(rel, 0.0)
        # Step-function evaluation as a K-step select chain: with ascending
        # starts, the last satisfied "starts_k <= t" wins — exactly
        # ``searchsorted(side='right') - 1`` clipped to [0, K-1], without
        # materializing the (lanes, times, K) one-hot tensor.
        alloc = jnp.broadcast_to(rp[:, 0:1], relc.shape)
        for k in range(1, K):
            alloc = jnp.where(rs[:, k:k + 1] <= relc, rp[:, k:k + 1], alloc)
        if masked:
            rdur = dur[flat]
            active = (rel >= 0.0) & (rel < rdur[:, None] + 1e-9)
            alloc = jnp.where(active, alloc, 0.0)
        alloc = jnp.where(run_valid.reshape(-1)[:, None], alloc, 0.0)
        usage = alloc.reshape(N, R, -1).sum(axis=1)          # (N, Q*G)
        resid = (caps[:, None] - usage).reshape(N, -1, G)    # (N, Q, G)
        fits = jnp.all(need[q_idx][None, :, :] <= resid + tol, axis=-1)
        minresid = jnp.min(resid, axis=-1)
        return fits, minresid

    _KERNEL_CACHE[masked] = kernel
    return kernel


def _drain_alloc_chain(rs, rp, relc):
    """Step-function evaluation as a K-step select chain (shared with the
    columns kernel: with ascending starts, the last satisfied
    ``starts_k <= t`` wins) — ``(L, K) x (L, M) -> (L, M)``."""
    import jax.numpy as jnp
    alloc = jnp.broadcast_to(rp[:, 0:1], relc.shape)
    for k in range(1, rs.shape[1]):
        alloc = jnp.where(rs[:, k:k + 1] <= relc, rp[:, k:k + 1], alloc)
    return alloc


def _drain_kernel(masked: bool, select: str):
    """Build (once) the jitted one-dispatch greedy drain program.

    A full event's admission — including multi-placement drains — is ONE
    dispatch: a ``lax.while_loop`` over the device-resident state whose
    carry holds the residual tensor ``resid[n, q, g]`` and the packed
    placement list.  Each iteration:

    1. recomputes ``fits[n, q]`` from the carried residuals (the in-loop
       equivalent of refreshing every invalidated fits entry),
    2. places a maximal *order-preserving independent prefix* of the
       queue in one step — the batched top-k fast path.  Residual
       monotonicity (placements only shrink residuals) proves the picks
       independent: walking lanes in queue order, every fitting lane
       whose fitting-node set is disjoint from the nodes already used
       *this iteration* would be chosen identically by the sequential
       greedy, because none of the entries its decision reads have
       changed.  The prefix stops at the first fitting lane whose fit
       set intersects a used node — its decision could differ after the
       update, so it is re-evaluated next iteration,
    3. scatter-subtracts each placed lane's windowed envelope from its
       node's residual rows and clears the lane's active bit,

    until no queued lane fits.  The placed lanes' admission times are
    scatter-written into the donated ``admit_t`` buffer in the same
    dispatch, so the host does zero follow-up device work per drain.

    Callers shrink the lane axis before dispatching: residual
    monotonicity means a lane that does not fit any node on the *base*
    residuals can never place within the drain, so
    :meth:`AdmissionState.drain` restricts the dispatch to the lanes the
    (incrementally refreshed) fits cache marks as fitting somewhere —
    the while-loop then runs over a handful of candidate lanes instead
    of the whole queue.  The restriction is exact, not approximate: unfit
    lanes contribute nothing to the independent-prefix bookkeeping (their
    ``onehot``/``conflict`` entries are identically False), so the placed
    set and order are bitwise those of the full-queue program.

    ``select`` (static) picks the node rule: ``"first"`` — first fitting
    node in row order (the ClusterSim greedy; device ``argmax`` over the
    boolean column, identical tie-break to ``np.argmax``) — or
    ``"headroom"`` — most post-placement head-room ``minresid - peak``,
    first on ties (the ElasticPlanner rule).
    """
    key = ("drain", masked, select)
    if key in _KERNEL_CACHE:
        return _KERNEL_CACHE[key]
    import jax
    import jax.numpy as jnp
    from jax import lax

    @functools.partial(jax.jit, donate_argnums=(2,))
    def kernel(starts, peaks, admit_t, dur, need, grid,
               caps, node_valid, run_idx, run_valid,
               q_idx, q_valid, now, tol):
        N, R = run_idx.shape
        Q = q_idx.shape[0]
        G = grid.shape[1]
        B = starts.shape[0]
        # Base residuals from the current residents — elementwise the
        # same float64 program as the columns kernel.
        flat = run_idx.reshape(-1)
        rs = starts[flat]
        rp = peaks[flat]
        rt0 = admit_t[flat]
        tabs = (now + grid[q_idx]).reshape(-1)        # (Q*G,) absolute
        rel = tabs[None, :] - rt0[:, None]
        alloc = _drain_alloc_chain(rs, rp, jnp.maximum(rel, 0.0))
        if masked:
            rdur = dur[flat]
            active0 = (rel >= 0.0) & (rel < rdur[:, None] + 1e-9)
            alloc = jnp.where(active0, alloc, 0.0)
        alloc = jnp.where(run_valid.reshape(-1)[:, None], alloc, 0.0)
        usage = alloc.reshape(N, R, -1).sum(axis=1)
        resid0 = (caps[:, None] - usage).reshape(N, Q, G)
        need_q = need[q_idx]                          # (Q, G)
        if select == "headroom":
            peak_q = jnp.max(peaks[q_idx], axis=1)    # (Q,)
        # A lane placed inside this drain has admit_t == now *exactly*,
        # so its contribution at grid point (q, g) is evaluated at
        # rel = (now + grid[q, g]) - now — kept in this form (not
        # simplified to grid[q, g]) so the arithmetic matches what the
        # columns kernel computes for that resident afterwards, bitwise.
        prel = tabs - now
        prelc = jnp.maximum(prel, 0.0)
        nrange = jnp.arange(N, dtype=jnp.int32)
        qrange = jnp.arange(Q, dtype=jnp.int32)

        def cond(st):
            return ~st[5]

        def body(st):
            resid, active, out_lane, out_node, count, _ = st
            fits = jnp.all(need_q[None, :, :] <= resid + tol, axis=-1)
            fits = fits & node_valid[:, None] & active[None, :]
            anyfit = fits.any(axis=0)                 # (Q,)
            done = ~anyfit.any()
            if select == "first":
                node_q = jnp.argmax(fits, axis=0).astype(jnp.int32)
            else:
                head = resid.min(axis=-1) - peak_q[None, :]
                node_q = jnp.argmax(
                    jnp.where(fits, head, -jnp.inf), axis=0
                ).astype(jnp.int32)
            # Order-preserving independent prefix: optimistically every
            # fitting lane before the first whose fit set touches an
            # already-used node.  Before that first conflict the
            # optimistic used-set equals the sequential one, so the cut
            # point (and every placement before it) is exact.
            onehot = (nrange[:, None] == node_q[None, :]) & anyfit[None, :]
            before = (jnp.cumsum(onehot, axis=1, dtype=jnp.int32)
                      - onehot.astype(jnp.int32)) > 0
            conflict = anyfit & (fits & before).any(axis=0)
            first_conf = jnp.where(conflict.any(),
                                   jnp.argmax(conflict).astype(jnp.int32),
                                   jnp.int32(Q))
            place = anyfit & (qrange < first_conf) & ~done
            pos = count + jnp.cumsum(place, dtype=jnp.int32) - 1
            slot = jnp.where(place, pos, Q)
            out_lane = out_lane.at[slot].set(q_idx, mode="drop")
            out_node = out_node.at[slot].set(node_q, mode="drop")
            count = count + place.sum(dtype=jnp.int32)
            # Scatter-subtract the placed envelopes: at most one lane per
            # node per iteration by construction (a second lane fitting a
            # used node is past the conflict cut), so a node -> queue-col
            # scatter is collision-free.
            col = jnp.full((N,), Q, jnp.int32).at[
                jnp.where(place, node_q, N)].set(qrange, mode="drop")
            hasl = col < Q
            gl = q_idx[jnp.where(hasl, col, 0)]
            pal = _drain_alloc_chain(
                starts[gl], peaks[gl],
                jnp.broadcast_to(prelc[None, :], (N, prelc.shape[0])))
            if masked:
                pact = (prel[None, :] >= 0.0) \
                    & (prel[None, :] < dur[gl][:, None] + 1e-9)
                pal = jnp.where(pact, pal, 0.0)
            pal = jnp.where(hasl[:, None], pal, 0.0)
            resid = resid - pal.reshape(N, Q, G)
            active = active & ~place
            return (resid, active, out_lane, out_node, count, done)

        init = (resid0, q_valid, jnp.full((Q,), B, jnp.int32),
                jnp.zeros((Q,), jnp.int32), jnp.int32(0), jnp.bool_(False))
        _, _, out_lane, out_node, count, _ = lax.while_loop(cond, body, init)
        # Same-dispatch admit-time scatter: unused slots keep the
        # out-of-range fill B and drop.
        admit_new = admit_t.at[out_lane].set(now, mode="drop")
        return out_lane, out_node, count, admit_new

    _KERNEL_CACHE[key] = kernel
    return kernel


def _drain_kernel_sharded(masked: bool, select: str, shard: int):
    """Node-sharded drain: ``shard_map`` over the node axis of the fits
    matrix — nodes sharded, queued lanes replicated.

    Each shard carries its local residual block ``(N/shard, Q, G)``; per
    iteration the global "first fitting (queue-order, node-order) pair"
    is found with two collectives: a vectorized ``psum`` OR-reduction
    over the node axis for per-lane any-fit, then a ``pmin`` min-index
    reduction for the winning node (for ``select="headroom"``: ``pmax``
    of the head-room then ``pmin`` of the indices attaining it —
    first-on-ties, matching ``np.argmax``).  The owning shard
    scatter-subtracts the placed envelope from its local block; the
    packed placement list is replicated.  One placement per iteration —
    selection is globally ordered, so the single-device batched-prefix
    fast path is not needed for correctness, and placements match the
    unsharded program bitwise (per-node arithmetic is identical; only
    node *selection* is distributed, and it reduces over exact indices).
    """
    key = ("drain_sharded", masked, select, shard)
    if key in _KERNEL_CACHE:
        return _KERNEL_CACHE[key]
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.asarray(jax.devices()[:shard]), ("nodes",))

    def core(starts, peaks, admit_t, dur, need, grid, caps, node_valid,
             run_idx, run_valid, q_idx, q_valid, now, tol):
        Nl, R = run_idx.shape
        Q = q_idx.shape[0]
        G = grid.shape[1]
        B = starts.shape[0]
        off = lax.axis_index("nodes").astype(jnp.int32) * Nl
        flat = run_idx.reshape(-1)
        rs = starts[flat]
        rp = peaks[flat]
        rt0 = admit_t[flat]
        tabs = (now + grid[q_idx]).reshape(-1)
        rel = tabs[None, :] - rt0[:, None]
        alloc = _drain_alloc_chain(rs, rp, jnp.maximum(rel, 0.0))
        if masked:
            rdur = dur[flat]
            active0 = (rel >= 0.0) & (rel < rdur[:, None] + 1e-9)
            alloc = jnp.where(active0, alloc, 0.0)
        alloc = jnp.where(run_valid.reshape(-1)[:, None], alloc, 0.0)
        usage = alloc.reshape(Nl, R, -1).sum(axis=1)
        resid0 = (caps[:, None] - usage).reshape(Nl, Q, G)
        need_q = need[q_idx]
        if select == "headroom":
            peak_q = jnp.max(peaks[q_idx], axis=1)
        prel = tabs - now
        prelc = jnp.maximum(prel, 0.0)
        big = jnp.int32(Nl * shard)
        gidx = off + jnp.arange(Nl, dtype=jnp.int32)

        def cond(st):
            return ~st[5]

        def body(st):
            resid, active, out_lane, out_node, count, _ = st
            fits = jnp.all(need_q[None, :, :] <= resid + tol, axis=-1)
            fits = fits & node_valid[:, None] & active[None, :]
            anyfit = lax.psum(fits.any(axis=0).astype(jnp.int32),
                              "nodes") > 0
            done = ~anyfit.any()
            qsel = jnp.argmax(anyfit).astype(jnp.int32)
            colf = fits[:, qsel]
            if select == "first":
                nsel = lax.pmin(jnp.where(colf, gidx, big).min(), "nodes")
            else:
                minres = resid[:, qsel, :].min(axis=-1)
                head = jnp.where(colf, minres - peak_q[qsel], -jnp.inf)
                best = lax.pmax(head.max(), "nodes")
                nsel = lax.pmin(
                    jnp.where(colf & (head == best), gidx, big).min(),
                    "nodes")
            place = ~done
            slot = jnp.where(place, count, Q)
            out_lane = out_lane.at[slot].set(q_idx[qsel], mode="drop")
            out_node = out_node.at[slot].set(nsel, mode="drop")
            gl = q_idx[qsel]
            pal = _drain_alloc_chain(starts[gl][None], peaks[gl][None],
                                     prelc[None, :])
            if masked:
                pact = (prel >= 0.0) & (prel < dur[gl] + 1e-9)
                pal = jnp.where(pact[None, :], pal, 0.0)
            lrow = nsel - off
            own = place & (lrow >= 0) & (lrow < Nl)
            resid = resid.at[jnp.where(own, lrow, Nl)].add(
                -pal.reshape(Q, G), mode="drop")
            active = active.at[jnp.where(place, qsel, Q)].set(
                False, mode="drop")
            count = count + place.astype(jnp.int32)
            return (resid, active, out_lane, out_node, count, done)

        init = (resid0, q_valid, jnp.full((Q,), B, jnp.int32),
                jnp.zeros((Q,), jnp.int32), jnp.int32(0), jnp.bool_(False))
        _, _, out_lane, out_node, count, _ = lax.while_loop(
            cond, body, init)
        return out_lane, out_node, count

    smapped = shard_map(
        core, mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P(), P("nodes"), P("nodes"),
                  P("nodes"), P("nodes"), P(), P(), P(), P()),
        out_specs=(P(), P(), P()), check_rep=False)

    @functools.partial(jax.jit, donate_argnums=(2,))
    def kernel(starts, peaks, admit_t, dur, need, grid, caps, node_valid,
               run_idx, run_valid, q_idx, q_valid, now, tol):
        out_lane, out_node, count = smapped(
            starts, peaks, admit_t, dur, need, grid, caps, node_valid,
            run_idx, run_valid, q_idx, q_valid, now, tol)
        admit_new = admit_t.at[out_lane].set(now, mode="drop")
        return out_lane, out_node, count, admit_new

    _KERNEL_CACHE[key] = kernel
    return kernel


def _scatter_rows_fn():
    """Donated-buffer row scatter: the in-place device update primitive."""
    if "scatter" in _KERNEL_CACHE:
        return _KERNEL_CACHE["scatter"]
    import jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def scatter(buf, rows, vals):
        return buf.at[rows].set(vals)

    _KERNEL_CACHE["scatter"] = scatter
    return scatter


class AdmissionState:
    """Fits matrix + invalidation protocol over packed ``(B, K)`` envelopes.

    Lanes (queued/resident jobs) carry a packed envelope, a relative
    admission grid with its precomputed ``need`` evaluation, a placement
    time and an active-window duration; nodes carry a capacity and the
    list of resident lanes.  ``columns()`` refreshes every invalid
    ``(node, lane)`` entry for the requested lanes — one fused dispatch on
    the jitted backend — and returns the fits matrix slice; ``place`` /
    ``release`` / ``update_lane`` / ``add_node`` / ``remove_node`` keep
    the validity mask honest (the churn test drives exactly this contract).

    ``use_dur=False`` selects the elastic planner's conservative
    count-forever residual (``usage_over`` with ``dur=None``).
    """

    # Max candidate lanes per drain dispatch.  Deep backlogs routinely
    # have hundreds of lanes that *fit somewhere* while capacity admits
    # only a few — capping the dispatch keeps the while-loop program's
    # queue axis (and its padded pow2 bucket) small; the exact
    # continuation loop in :meth:`drain` re-dispatches in the rare case
    # more than DRAIN_CAP lanes were simultaneously placeable.  Queues
    # at or below the cap skip the candidate pre-filter and go straight
    # into the program: one dispatch per drain, no refresh round-trip.
    DRAIN_CAP = 256

    def __init__(self, caps: Sequence[float], K: int, G: int,
                 backend: str = "fused", use_dur: bool = True,
                 tol: float = 1e-9, shard: Optional[int] = None):
        if backend not in ("fused", "numpy"):
            raise ValueError(f"unknown admission backend: {backend!r}")
        if shard is not None:
            if backend != "fused":
                raise ValueError("shard= requires backend='fused'")
            shard = int(shard)
            if shard < 1:
                raise ValueError(f"shard must be >= 1, got {shard}")
            import jax
            have = len(jax.devices())
            if have < shard:
                raise ValueError(
                    f"shard={shard} needs {shard} devices but only {have} "
                    f"are visible — set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count={shard} "
                    f"before jax initializes its backend")
        self.shard = shard
        self.stats = {"drains": 0, "drain_dispatches": 0}
        self.backend = backend
        self.use_dur = bool(use_dur)
        self.tol = float(tol)
        self.K = int(K)
        self.G = int(G)
        self.caps = np.asarray(caps, np.float64).copy()
        N = len(self.caps)
        self.running: List[List[int]] = [[] for _ in range(N)]
        # Lane state (grows via add_lanes).
        self.starts = np.zeros((0, self.K), np.float64)
        self.peaks = np.zeros((0, self.K), np.float64)
        self.need = np.zeros((0, self.G), np.float64)
        self.grid = np.zeros((0, self.G), np.float64)
        self.admit_t = np.zeros((0,), np.float64)
        self.dur = np.zeros((0,), np.float64)
        # The shared runtime state: fits matrix + validity mask.
        self.fits = np.zeros((N, 0), bool)
        self.minresid = np.zeros((N, 0), np.float64)
        self.valid = np.zeros((N, 0), bool)
        self._now: Optional[float] = None
        self._dirty_dev = True  # device mirrors need a (re)upload

    # ------------------------------------------------------------- lane mgmt
    @property
    def B(self) -> int:
        return int(self.starts.shape[0])

    @property
    def N(self) -> int:
        return int(self.caps.shape[0])

    def ensure_k(self, k: int):
        """Grow the packed segment axis (rare: a new lane with more
        segments than any seen).  Padding follows the PackedEnvelopes
        convention — sentinel starts, replicated last peak — so existing
        lanes evaluate identically."""
        if k <= self.K:
            return
        from repro.core.envelope import PAD_START
        pad = k - self.K
        B = self.B
        self.starts = np.concatenate(
            [self.starts, np.full((B, pad), PAD_START)], axis=1)
        last = (self.peaks[:, -1:] if self.K else np.zeros((B, 1)))
        self.peaks = np.concatenate(
            [self.peaks, np.repeat(last, pad, axis=1)], axis=1)
        self.K = k
        self._dirty_dev = True

    def add_lanes(self, starts, peaks, need, grid,
                  dur=None) -> np.ndarray:
        """Append lanes; returns their indices.  New entries are invalid."""
        starts = np.asarray(starts, np.float64).reshape(-1, self.K)
        n = starts.shape[0]
        self.starts = np.concatenate([self.starts, starts])
        self.peaks = np.concatenate(
            [self.peaks, np.asarray(peaks, np.float64).reshape(n, self.K)])
        self.need = np.concatenate(
            [self.need, np.asarray(need, np.float64).reshape(n, self.G)])
        self.grid = np.concatenate(
            [self.grid, np.asarray(grid, np.float64).reshape(n, self.G)])
        self.admit_t = np.concatenate([self.admit_t, np.zeros(n)])
        self.dur = np.concatenate(
            [self.dur,
             np.full(n, np.inf) if dur is None
             else np.asarray(dur, np.float64).reshape(n)])
        pad = np.zeros((self.N, n), bool)
        self.fits = np.concatenate([self.fits, pad], axis=1)
        self.valid = np.concatenate([self.valid, pad.copy()], axis=1)
        self.minresid = np.concatenate(
            [self.minresid, np.zeros((self.N, n))], axis=1)
        self._dirty_dev = True
        return np.arange(self.B - n, self.B)

    def update_lane(self, lane: int, starts, peaks, need):
        """Re-plan a lane; its column is invalid on every node.

        If the lane is currently *resident* somewhere (a live re-size
        rather than a queued retry), that node's residual changed for
        every queued lane — its whole row is invalidated too.
        """
        self.starts[lane] = starts
        self.peaks[lane] = peaks
        self.need[lane] = need
        self.valid[:, lane] = False
        for ni, run in enumerate(self.running):
            if lane in run:
                self.valid[ni] = False
        self._push_lane(lane)

    # ------------------------------------------------------------- node mgmt
    def add_node(self, cap: float) -> int:
        self.caps = np.concatenate([self.caps, [float(cap)]])
        self.running.append([])
        B = self.B
        self.fits = np.concatenate([self.fits, np.zeros((1, B), bool)])
        self.valid = np.concatenate([self.valid, np.zeros((1, B), bool)])
        self.minresid = np.concatenate([self.minresid, np.zeros((1, B))])
        return self.N - 1

    def remove_node(self, ni: int) -> List[int]:
        """Drop a node row; returns the lanes that were resident on it."""
        evicted = self.running[ni]
        self.caps = np.delete(self.caps, ni)
        del self.running[ni]
        self.fits = np.delete(self.fits, ni, axis=0)
        self.valid = np.delete(self.valid, ni, axis=0)
        self.minresid = np.delete(self.minresid, ni, axis=0)
        return evicted

    # ----------------------------------------------------------- invalidation
    def sync_now(self, now: float):
        """Advance the clock; residuals are time functions, so a new ``now``
        invalidates every cached entry."""
        if self._now is None or now != self._now:
            self.valid[:] = False
            self._now = float(now)

    def place(self, ni: int, lane: int, now: float):
        """Resident set grows: only the node's True entries can change
        (residual shrank monotonically), so False entries stay valid."""
        self.running[ni].append(lane)
        self.admit_t[lane] = now
        self.valid[ni] &= ~self.fits[ni]
        self._push_admit(lane)

    def release(self, ni: int, lane: int):
        """Resident set shrinks: the residual grew, False entries may flip
        True — the node's whole column is invalid."""
        self.running[ni].remove(lane)
        self.valid[ni] = False

    def is_valid(self, ni: int, lane: int) -> bool:
        return bool(self.valid[ni, lane])

    # ---------------------------------------------------------------- refresh
    def columns(self, now: float, lanes: Sequence[int],
                sub: int = 8) -> np.ndarray:
        """Fits matrix slice ``(N, len(lanes))``, refreshed where invalid.

        One fused dispatch per call on the jitted backend: every invalid
        ``(node, lane)`` entry across all nodes is recomputed at once.
        ``sub`` sets the lane-bucket subdivision (see
        :func:`repro.core.fleet.pad_lane_axis`).
        """
        self.sync_now(now)
        lanes = np.asarray(lanes, np.int64)
        stale = ~self.valid[:, lanes]
        if stale.any():
            todo = lanes[stale.any(axis=0)]
            nodes = np.nonzero(stale.any(axis=1))[0]
            if self.backend == "numpy":
                self._refresh_numpy(nodes, todo)
            else:
                self._refresh_fused(nodes, todo, sub)
            self.valid[np.ix_(nodes, todo)] = True
        return self.fits[:, lanes]

    def _refresh_numpy(self, nodes: np.ndarray, lanes: np.ndarray):
        """Float64 host reference: per-node :func:`fits_column` — the
        exact arithmetic of the packed ClusterSim engine."""
        grid_abs = self._now + self.grid[lanes]
        for ni in nodes:
            run = self.running[ni]
            ok, resid = fits_column(
                self.caps[ni], self.starts[run], self.peaks[run],
                self.admit_t[run], self.need[lanes], grid_abs,
                dur=self.dur[run] if self.use_dur else None, tol=self.tol)
            self.fits[ni, lanes] = ok
            self.minresid[ni, lanes] = resid.min(axis=-1)

    # ------------------------------------------------------------ fused path
    def _dev_sync(self):
        """(Re)upload the packed lane state to the device (bulk path; the
        incremental paths go through donated scatters).

        Contract: after the initial upload this must never fire again on
        node join/leave — churn only changes the *operands* of the next
        dispatch, never the device-resident lane state
        (``tests/test_contracts.py`` pins the tag at one per replay).
        """
        import jax.numpy as jnp
        record_dispatch("admission.dev_sync")
        self._dstarts = jnp.asarray(self.starts)
        self._dpeaks = jnp.asarray(self.peaks)
        self._dneed = jnp.asarray(self.need)
        self._dgrid = jnp.asarray(self.grid)
        self._dadmit = jnp.asarray(self.admit_t)
        self._ddur = jnp.asarray(self.dur)
        self._dirty_dev = False

    def _push_lane(self, lane: int):
        if self.backend == "numpy" or self._dirty_dev:
            return
        self._push_lanes(np.asarray([lane]))

    def _push_lanes(self, lanes: np.ndarray):
        """In-place device update of re-planned lanes (donated buffers)."""
        if self.backend == "numpy" or self._dirty_dev:
            return
        from jax.experimental import enable_x64
        scatter = _scatter_rows_fn()
        record_dispatch("admission.scatter", 3)
        with enable_x64():
            import jax.numpy as jnp
            rows = jnp.asarray(np.asarray(lanes, np.int32))
            self._dstarts = scatter(self._dstarts, rows,
                                    jnp.asarray(self.starts[lanes]))
            self._dpeaks = scatter(self._dpeaks, rows,
                                   jnp.asarray(self.peaks[lanes]))
            self._dneed = scatter(self._dneed, rows,
                                  jnp.asarray(self.need[lanes]))

    def _push_admit(self, lane: int):
        if self.backend == "numpy" or self._dirty_dev:
            return
        from jax.experimental import enable_x64
        scatter = _scatter_rows_fn()
        record_dispatch("admission.scatter")
        with enable_x64():
            import jax.numpy as jnp
            self._dadmit = scatter(
                self._dadmit, jnp.asarray(np.asarray([lane], np.int32)),
                jnp.asarray(self.admit_t[lane:lane + 1]))

    def _refresh_fused(self, nodes: np.ndarray, lanes: np.ndarray,
                       sub: int = 8):
        """One fused XLA dispatch for every invalid (node, lane) entry.

        Only the stale node rows enter the dispatch — after a placement,
        that is a single node over the previously-True lanes, not the
        whole matrix.
        """
        import jax
        from jax.experimental import enable_x64
        import jax.numpy as jnp

        from repro.core.fleet import pad_lane_axis

        kernel = _fused_kernel(self.use_dur)
        # Only wide (execution-bound) refreshes reach this kernel — the
        # narrow compile-bound ones route to the host oracle in
        # :meth:`columns` — so shapes stay exact: stale rows only, run
        # axis padded pow2.  The queue axis is already coarse by the
        # time a refresh is wide (pow2 buckets at >256 lanes), so the
        # compiled-shape count stays small without extra padding.
        sel = [self.running[ni] for ni in nodes]
        rmax = max(max((len(r) for r in sel), default=0), 1)
        rmax = 1 << (rmax - 1).bit_length()
        run_idx = np.zeros((len(nodes), rmax), np.int32)
        run_valid = np.zeros((len(nodes), rmax), bool)
        for i, run in enumerate(sel):
            run_idx[i, :len(run)] = run
            run_valid[i, :len(run)] = True
        (q_idx,) = pad_lane_axis(
            (np.asarray(lanes, np.int32),), (0,), lo=8, fine=True, sub=sub)
        nq = len(lanes)
        record_dispatch("admission.columns")
        with enable_x64():
            if self._dirty_dev:
                self._dev_sync()
            # lint: allow[recompile-hazard] stale-row refreshes are execution-bound by design (see comment above): rows stay exact, only the run axis is padded
            fits, minresid = kernel(
                self._dstarts, self._dpeaks, self._dadmit, self._ddur,
                self._dneed, self._dgrid,
                jnp.asarray(self.caps[nodes]), jnp.asarray(run_idx),
                jnp.asarray(run_valid), jnp.asarray(q_idx),
                jnp.float64(self._now), jnp.float64(self.tol))
        # lint: allow[host-sync-in-hot-path] one batched readback materializes the host fits cache the drain pre-filter reads
        fits_h, minresid_h = jax.device_get((fits, minresid))
        self.fits[np.ix_(nodes, lanes)] = fits_h[:, :nq]
        self.minresid[np.ix_(nodes, lanes)] = minresid_h[:, :nq]

    # ------------------------------------------------------------------ drain
    def drain(self, now: float, lanes: Sequence[int],
              select: str = "first") -> List[tuple]:
        """Greedy drain at ``now`` over ``lanes`` (queue order): place
        lanes until none fits, returning ``[(lane, node_row), ...]`` in
        decision order.

        On the fused backend this is ONE device dispatch for the whole
        drain — the jitted while-loop program of :func:`_drain_kernel`
        (node-sharded via :func:`_drain_kernel_sharded` when the state
        was built with ``shard=``), including the donated-buffer
        admit-time scatter for every placement.  On the numpy backend it
        is the host reference loop over :meth:`columns` — the oracle the
        device program is differentially pinned against.

        ``select="first"`` is the ClusterSim rule (first fitting node in
        row order); ``select="headroom"`` is the ElasticPlanner rule
        (most post-placement head-room, first on ties).  Decision
        equivalence with the sequential greedy holds because placements
        only shrink residuals: an unfit lane can never become fit within
        one drain, and a fitting lane whose fitting-node set is disjoint
        from the drain's earlier placements reads only unchanged state.

        Queue routing (fused, unsharded): a queue of at most
        ``DRAIN_CAP`` lanes — a DAG dependency frontier, an elastic
        re-admission batch — goes straight into the program, whole:
        exactly one dispatch per drain, no refresh round-trip, and the
        per-dispatch cost is bounded by the cap's pow2 bucket.  A wider
        backlog first runs the candidate pre-filter: base-residual fits
        of the whole queue from :meth:`columns` — the incremental,
        validity-cached refresh, which within a same-``now`` event batch
        recomputes only the released node's row instead of the full
        matrix — and the program dispatches over *just the lanes that
        fit somewhere*.  The restriction is exact by residual
        monotonicity (placements only shrink residuals, so a lane unfit
        on the base residuals can never place within the drain), and it
        collapses the dispatch's queue axis from the whole backlog to
        the handful of contenders: event-dense flat replays, where most
        drains place nothing or one lane out of hundreds queued, run at
        stale-row refresh cost instead of full-program cost.  The
        sharded program keeps the full queue — its point is scaling the
        (nodes x queue) matrix itself, and its fits stay inside the
        ``shard_map``.
        """
        if _obs.enabled:
            q = int(np.asarray(lanes).size)
            with _obs.span("admission.drain", backend=self.backend,
                           q=q) as sp:
                out = self._drain(now, lanes, select)
                sp.add(placed=len(out))
                _met.hist("admission.drain.lanes",
                          buckets=_met.COUNT_BUCKETS).observe(q)
                _met.hist("admission.drain.placed",
                          buckets=_met.COUNT_BUCKETS).observe(len(out))
            return out
        return self._drain(now, lanes, select)

    def _drain(self, now: float, lanes: Sequence[int],
               select: str) -> List[tuple]:
        if select not in ("first", "headroom"):
            raise ValueError(f"unknown drain select rule: {select!r}")
        self.sync_now(now)
        self.stats["drains"] += 1
        lanes = [int(x) for x in np.asarray(lanes, np.int64).reshape(-1)]
        if not lanes or self.N == 0:
            return []
        if self.backend == "numpy":
            return self._drain_host(now, lanes, select)
        if self.shard:
            return self._drain_fused(now, lanes, select)
        placed_all: List[tuple] = []
        remaining = lanes
        while True:
            if len(remaining) <= self.DRAIN_CAP:
                # Narrow queue: the whole thing is the dispatch.
                placed_all.extend(self._drain_fused(now, remaining, select))
                break
            idx = np.nonzero(
                self.columns(now, remaining).any(axis=0))[0]
            if idx.size == 0:
                break
            cand = [remaining[i] for i in idx[:self.DRAIN_CAP]]
            placed = self._drain_fused(now, cand, select)
            placed_all.extend(placed)
            if idx.size <= self.DRAIN_CAP or not placed:
                # A single chunk held every candidate — the kernel's own
                # termination condition verified exhaustion — or the
                # kernel disagreed with the cache inside the float64
                # grazing band (precision contract) and made no progress.
                break
            got = {ji for ji, _ in placed}
            remaining = [ji for ji in remaining if ji not in got]
        return placed_all

    def _drain_host(self, now: float, lanes: List[int],
                    select: str) -> List[tuple]:
        """Host reference drain: the exact per-placement columns/argmax
        loop the engines ran before the device program existed."""
        placed: List[tuple] = []
        if select == "first":
            remaining = list(lanes)
            while remaining:
                M = self.columns(now, remaining)
                anyfit = M.any(axis=0)
                if not anyfit.any():
                    break
                col = int(np.argmax(anyfit))
                ni = int(np.argmax(M[:, col]))
                lane = remaining.pop(col)
                self.place(ni, lane, now)
                placed.append((lane, ni))
        else:
            for lane in lanes:
                col = self.columns(now, [lane])[:, 0]
                if not col.any():
                    continue
                head = self.minresid[:, lane] - float(self.peaks[lane].max())
                ni = int(np.argmax(np.where(col, head, -np.inf)))
                self.place(ni, lane, now)
                placed.append((lane, ni))
        return placed

    def _drain_fused(self, now: float, lanes: List[int],
                     select: str) -> List[tuple]:
        """One-dispatch device drain (see :func:`_drain_kernel`).

        The node axis is padded to a power of two (and to a multiple of
        the shard count when sharding) with ``-1e30`` capacities and a
        validity mask, the queue axis through the coarse pow2 buckets of
        :func:`repro.core.fleet.pad_lane_axis` — compilation stays
        bounded to log2-many shapes, which matters: the while-loop
        program is the most expensive compile in the repo, and the DAG
        replay's queue (the dependency frontier) wanders over two orders
        of magnitude.

        The program recomputes base residuals from ``running``/``caps``
        inside the dispatch, so node churn between drains needs no
        device-side rebuild; the placed nodes' cached True entries are
        invalidated afterwards (monotonic rule) so the next refresh
        recomputes exactly what a placement can have changed.
        """
        import jax
        from jax.experimental import enable_x64
        import jax.numpy as jnp

        from repro.core.fleet import pad_lane_axis

        N = self.N
        npad = 1 << max(N - 1, 0).bit_length()
        if self.shard:
            npad = max(npad, self.shard)
            npad = -(-npad // self.shard) * self.shard
        rmax = max(max((len(r) for r in self.running), default=0), 1)
        rmax = _pow4(rmax)
        run_idx = np.zeros((npad, rmax), np.int32)
        run_valid = np.zeros((npad, rmax), bool)
        for i, run in enumerate(self.running):
            run_idx[i, :len(run)] = run
            run_valid[i, :len(run)] = True
        caps = np.full((npad,), -1e30)
        caps[:N] = self.caps
        node_valid = np.zeros((npad,), bool)
        node_valid[:N] = True
        q_idx, q_valid = pad_lane_axis(
            (np.asarray(lanes, np.int32), np.ones(len(lanes), bool)),
            (0, False), lo=8)
        kernel = (_drain_kernel_sharded(self.use_dur, select, self.shard)
                  if self.shard else _drain_kernel(self.use_dur, select))
        with enable_x64():
            if self._dirty_dev:
                self._dev_sync()
            out_lane, out_node, count, admit_new = kernel(
                self._dstarts, self._dpeaks, self._dadmit, self._ddur,
                self._dneed, self._dgrid,
                jnp.asarray(caps), jnp.asarray(node_valid),
                jnp.asarray(run_idx), jnp.asarray(run_valid),
                jnp.asarray(q_idx), jnp.asarray(q_valid),
                jnp.float64(now), jnp.float64(self.tol))
            self._dadmit = admit_new
        self.stats["drain_dispatches"] += 1
        record_dispatch("admission.drain")
        # The drain's placement decisions must reach the host loop below,
        # so one transfer is irreducible — but it is ONE: fetching the
        # three outputs together replaces the previous int(count) +
        # 2x np.asarray round trips with a single batched device_get.
        # lint: allow[host-sync-in-hot-path] single batched readback per drain; decisions feed host bookkeeping
        out_lane, out_node, n = jax.device_get((out_lane, out_node, count))
        out_lane = out_lane[:n]
        out_node = out_node[:n]
        placed: List[tuple] = []
        for lane, ni in zip(out_lane.tolist(), out_node.tolist()):
            # Host bookkeeping per placement; the device-side admit_t
            # scatter already happened inside the drain dispatch.
            self.running[ni].append(lane)
            self.admit_t[lane] = now
            if self.shard:
                self.valid[ni, :] = False
            else:
                # Monotonic rule (same as place()): the placement only
                # shrank node ni's residual, so the pre-filter's cached
                # False entries stay valid; only the Trues must be
                # recomputed on the next refresh.
                self.valid[ni] &= ~self.fits[ni]
            placed.append((lane, ni))
        return placed
