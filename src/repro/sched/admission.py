"""Shared admission runtime state: one fits matrix, one invalidation protocol.

Both admission paths — :class:`repro.sched.cluster.ClusterSim`'s packed
event loop and :class:`repro.sched.elastic.ElasticPlanner`'s churn-driven
``drain`` — answer the same question at every decision point: *which queued
envelopes fit under which node's residual envelope right now?*  This module
owns that answer as explicit runtime state instead of a per-call
recomputation:

* a **fits matrix** ``(N nodes, B lanes)`` of admission predicates plus a
  per-entry **validity mask** — the single source of truth for "does lane b
  fit node n at the current time",
* one **invalidation protocol** (see :class:`AdmissionState`):

  - advancing ``now`` invalidates everything (residuals are functions of
    absolute time),
  - *placing* a lane on a node invalidates only the node's currently-True
    entries — adding an envelope can only shrink the residual, so False
    entries stay False without recomputation (monotonicity),
  - *releasing* a lane from a node invalidates the node's whole column
    (the residual grew; False entries may flip True),
  - a lane's plan change (retry re-plan) invalidates that lane everywhere,
  - node join/leave adds/drops a row,

* two interchangeable compute backends:

  - ``backend="numpy"`` — the float64 host reference: per-node
    :func:`repro.core.envelope.residual_over` + ``fits_under`` calls,
    exactly the arithmetic the packed ``ClusterSim`` engine inlines,
  - ``backend="fused"`` — ONE jitted XLA dispatch per refresh computing
    every invalid ``(node, lane)`` entry at once on device-resident
    float64 state (``jax.experimental.enable_x64`` scopes the 64-bit
    semantics to these calls).  The packed envelope/need/placement-time
    buffers live on the device and are updated in place through donated
    scatter programs, so the per-event hot path is one fused dispatch
    over the already-packed ``(B, K)`` layout — not a Python loop over
    nodes and queued jobs.

Precision contract (see also :mod:`repro.sched.cluster`): both backends
evaluate residuals and admission predicates in float64 with identical
elementwise operations; the only permitted divergence is the summation
order over a node's resident envelopes (numpy reduces linearly, XLA may
tree-reduce), i.e. last-ulp differences ~1e-16 relative.  A decision can
therefore only differ between backends when a lane's need grazes the
residual within one float64 ulp of the 1e-9 admission tolerance — orders
of magnitude below any real trace/plan margin.

Shapes are kept jit-stable by padding the queued-lane and resident-lane
axes to power-of-two buckets (:func:`repro.core.fleet.pad_lane_axis`, the
fleet engine's compaction trick), bounding compilation to log2-many shapes.

The state is *frontier-agnostic*: ``ClusterSim``'s DAG-aware replay adds
every lane up front but only passes *released* lanes (all parents
finished) to :meth:`AdmissionState.columns`, so dependency structure
costs nothing here — unreleased lanes simply never enter a refresh.  The
``workload_replay`` benchmark drives this path with a ≥5k-task DAG.

The join/leave row protocol (:meth:`AdmissionState.add_node` /
:meth:`remove_node`) is what both churn consumers share:
``ElasticPlanner`` drives it for slice membership, and ``ClusterSim``'s
fault path drives it for ``FaultSchedule`` leave/join events —
``remove_node`` returns the dead node's resident lanes *in admission
order*, which is the eviction order every engine pins bitwise.  Node
rows are positional (a leave splices, a join appends); callers keep
their own stable-id ↔ row mapping.  Because the fused dispatch takes
``caps`` and the resident-lane index per call, churn needs no
device-state rebuild: dropping a row just drops it from the next
dispatch's operands, keeping the engine one-dispatch-per-refresh under
faults (``churn_replay`` benchmark).
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence

import numpy as np

from repro.core.envelope import fits_column

__all__ = ["AdmissionState"]

_KERNEL_CACHE = {}


def _fused_kernel(masked: bool):
    """Build (once) the jitted fused fits-columns program.

    Computes, for every requested node and queued lane at once::

        resid[n, q, g] = cap[n] - sum_r alloc_r(now + grid[q, g] - t0[r])
        fits[n, q]     = all_g need[q, g] <= resid[n, q, g] + tol
        minresid[n, q] = min_g resid[n, q, g]

    mirroring ``residual_over`` / ``fits_under`` elementwise in float64.
    ``masked`` (static) selects the anticipating-residual semantics
    (resident envelopes only count inside ``[t0, t0 + dur)``, the cluster
    simulator's rule) vs. the conservative count-forever semantics (the
    elastic planner's rule, ``usage_over`` with ``dur=None``).
    """
    if masked in _KERNEL_CACHE:
        return _KERNEL_CACHE[masked]
    import jax
    import jax.numpy as jnp

    @jax.jit
    def kernel(starts, peaks, admit_t, dur, need, grid,
               caps, run_idx, run_valid, q_idx, now, tol):
        N, R = run_idx.shape
        K = starts.shape[1]
        G = grid.shape[1]
        flat = run_idx.reshape(-1)
        rs = starts[flat]                        # (N*R, K)
        rp = peaks[flat]
        rt0 = admit_t[flat]                      # (N*R,)
        t = (now + grid[q_idx]).reshape(-1)      # (Q*G,) absolute times
        rel = t[None, :] - rt0[:, None]          # (N*R, Q*G)
        relc = jnp.maximum(rel, 0.0)
        # Step-function evaluation as a K-step select chain: with ascending
        # starts, the last satisfied "starts_k <= t" wins — exactly
        # ``searchsorted(side='right') - 1`` clipped to [0, K-1], without
        # materializing the (lanes, times, K) one-hot tensor.
        alloc = jnp.broadcast_to(rp[:, 0:1], relc.shape)
        for k in range(1, K):
            alloc = jnp.where(rs[:, k:k + 1] <= relc, rp[:, k:k + 1], alloc)
        if masked:
            rdur = dur[flat]
            active = (rel >= 0.0) & (rel < rdur[:, None] + 1e-9)
            alloc = jnp.where(active, alloc, 0.0)
        alloc = jnp.where(run_valid.reshape(-1)[:, None], alloc, 0.0)
        usage = alloc.reshape(N, R, -1).sum(axis=1)          # (N, Q*G)
        resid = (caps[:, None] - usage).reshape(N, -1, G)    # (N, Q, G)
        fits = jnp.all(need[q_idx][None, :, :] <= resid + tol, axis=-1)
        minresid = jnp.min(resid, axis=-1)
        return fits, minresid

    _KERNEL_CACHE[masked] = kernel
    return kernel


def _scatter_rows_fn():
    """Donated-buffer row scatter: the in-place device update primitive."""
    if "scatter" in _KERNEL_CACHE:
        return _KERNEL_CACHE["scatter"]
    import jax

    @functools.partial(jax.jit, donate_argnums=(0,))
    def scatter(buf, rows, vals):
        return buf.at[rows].set(vals)

    _KERNEL_CACHE["scatter"] = scatter
    return scatter


class AdmissionState:
    """Fits matrix + invalidation protocol over packed ``(B, K)`` envelopes.

    Lanes (queued/resident jobs) carry a packed envelope, a relative
    admission grid with its precomputed ``need`` evaluation, a placement
    time and an active-window duration; nodes carry a capacity and the
    list of resident lanes.  ``columns()`` refreshes every invalid
    ``(node, lane)`` entry for the requested lanes — one fused dispatch on
    the jitted backend — and returns the fits matrix slice; ``place`` /
    ``release`` / ``update_lane`` / ``add_node`` / ``remove_node`` keep
    the validity mask honest (the churn test drives exactly this contract).

    ``use_dur=False`` selects the elastic planner's conservative
    count-forever residual (``usage_over`` with ``dur=None``).
    """

    def __init__(self, caps: Sequence[float], K: int, G: int,
                 backend: str = "fused", use_dur: bool = True,
                 tol: float = 1e-9):
        if backend not in ("fused", "numpy"):
            raise ValueError(f"unknown admission backend: {backend!r}")
        self.backend = backend
        self.use_dur = bool(use_dur)
        self.tol = float(tol)
        self.K = int(K)
        self.G = int(G)
        self.caps = np.asarray(caps, np.float64).copy()
        N = len(self.caps)
        self.running: List[List[int]] = [[] for _ in range(N)]
        # Lane state (grows via add_lanes).
        self.starts = np.zeros((0, self.K), np.float64)
        self.peaks = np.zeros((0, self.K), np.float64)
        self.need = np.zeros((0, self.G), np.float64)
        self.grid = np.zeros((0, self.G), np.float64)
        self.admit_t = np.zeros((0,), np.float64)
        self.dur = np.zeros((0,), np.float64)
        # The shared runtime state: fits matrix + validity mask.
        self.fits = np.zeros((N, 0), bool)
        self.minresid = np.zeros((N, 0), np.float64)
        self.valid = np.zeros((N, 0), bool)
        self._now: Optional[float] = None
        self._dirty_dev = True  # device mirrors need a (re)upload

    # ------------------------------------------------------------- lane mgmt
    @property
    def B(self) -> int:
        return int(self.starts.shape[0])

    @property
    def N(self) -> int:
        return int(self.caps.shape[0])

    def ensure_k(self, k: int):
        """Grow the packed segment axis (rare: a new lane with more
        segments than any seen).  Padding follows the PackedEnvelopes
        convention — sentinel starts, replicated last peak — so existing
        lanes evaluate identically."""
        if k <= self.K:
            return
        from repro.core.envelope import PAD_START
        pad = k - self.K
        B = self.B
        self.starts = np.concatenate(
            [self.starts, np.full((B, pad), PAD_START)], axis=1)
        last = (self.peaks[:, -1:] if self.K else np.zeros((B, 1)))
        self.peaks = np.concatenate(
            [self.peaks, np.repeat(last, pad, axis=1)], axis=1)
        self.K = k
        self._dirty_dev = True

    def add_lanes(self, starts, peaks, need, grid,
                  dur=None) -> np.ndarray:
        """Append lanes; returns their indices.  New entries are invalid."""
        starts = np.asarray(starts, np.float64).reshape(-1, self.K)
        n = starts.shape[0]
        self.starts = np.concatenate([self.starts, starts])
        self.peaks = np.concatenate(
            [self.peaks, np.asarray(peaks, np.float64).reshape(n, self.K)])
        self.need = np.concatenate(
            [self.need, np.asarray(need, np.float64).reshape(n, self.G)])
        self.grid = np.concatenate(
            [self.grid, np.asarray(grid, np.float64).reshape(n, self.G)])
        self.admit_t = np.concatenate([self.admit_t, np.zeros(n)])
        self.dur = np.concatenate(
            [self.dur,
             np.full(n, np.inf) if dur is None
             else np.asarray(dur, np.float64).reshape(n)])
        pad = np.zeros((self.N, n), bool)
        self.fits = np.concatenate([self.fits, pad], axis=1)
        self.valid = np.concatenate([self.valid, pad.copy()], axis=1)
        self.minresid = np.concatenate(
            [self.minresid, np.zeros((self.N, n))], axis=1)
        self._dirty_dev = True
        return np.arange(self.B - n, self.B)

    def update_lane(self, lane: int, starts, peaks, need):
        """Re-plan a lane; its column is invalid on every node.

        If the lane is currently *resident* somewhere (a live re-size
        rather than a queued retry), that node's residual changed for
        every queued lane — its whole row is invalidated too.
        """
        self.starts[lane] = starts
        self.peaks[lane] = peaks
        self.need[lane] = need
        self.valid[:, lane] = False
        for ni, run in enumerate(self.running):
            if lane in run:
                self.valid[ni] = False
        self._push_lane(lane)

    # ------------------------------------------------------------- node mgmt
    def add_node(self, cap: float) -> int:
        self.caps = np.concatenate([self.caps, [float(cap)]])
        self.running.append([])
        B = self.B
        self.fits = np.concatenate([self.fits, np.zeros((1, B), bool)])
        self.valid = np.concatenate([self.valid, np.zeros((1, B), bool)])
        self.minresid = np.concatenate([self.minresid, np.zeros((1, B))])
        return self.N - 1

    def remove_node(self, ni: int) -> List[int]:
        """Drop a node row; returns the lanes that were resident on it."""
        evicted = self.running[ni]
        self.caps = np.delete(self.caps, ni)
        del self.running[ni]
        self.fits = np.delete(self.fits, ni, axis=0)
        self.valid = np.delete(self.valid, ni, axis=0)
        self.minresid = np.delete(self.minresid, ni, axis=0)
        return evicted

    # ----------------------------------------------------------- invalidation
    def sync_now(self, now: float):
        """Advance the clock; residuals are time functions, so a new ``now``
        invalidates every cached entry."""
        if self._now is None or now != self._now:
            self.valid[:] = False
            self._now = float(now)

    def place(self, ni: int, lane: int, now: float):
        """Resident set grows: only the node's True entries can change
        (residual shrank monotonically), so False entries stay valid."""
        self.running[ni].append(lane)
        self.admit_t[lane] = now
        self.valid[ni] &= ~self.fits[ni]
        self._push_admit(lane)

    def release(self, ni: int, lane: int):
        """Resident set shrinks: the residual grew, False entries may flip
        True — the node's whole column is invalid."""
        self.running[ni].remove(lane)
        self.valid[ni] = False

    def is_valid(self, ni: int, lane: int) -> bool:
        return bool(self.valid[ni, lane])

    # ---------------------------------------------------------------- refresh
    def columns(self, now: float, lanes: Sequence[int]) -> np.ndarray:
        """Fits matrix slice ``(N, len(lanes))``, refreshed where invalid.

        One fused dispatch per call on the jitted backend: every invalid
        ``(node, lane)`` entry across all nodes is recomputed at once.
        """
        self.sync_now(now)
        lanes = np.asarray(lanes, np.int64)
        stale = ~self.valid[:, lanes]
        if stale.any():
            todo = lanes[stale.any(axis=0)]
            nodes = np.nonzero(stale.any(axis=1))[0]
            if self.backend == "numpy":
                self._refresh_numpy(nodes, todo)
            else:
                self._refresh_fused(nodes, todo)
            self.valid[np.ix_(nodes, todo)] = True
        return self.fits[:, lanes]

    def _refresh_numpy(self, nodes: np.ndarray, lanes: np.ndarray):
        """Float64 host reference: per-node :func:`fits_column` — the
        exact arithmetic of the packed ClusterSim engine."""
        grid_abs = self._now + self.grid[lanes]
        for ni in nodes:
            run = self.running[ni]
            ok, resid = fits_column(
                self.caps[ni], self.starts[run], self.peaks[run],
                self.admit_t[run], self.need[lanes], grid_abs,
                dur=self.dur[run] if self.use_dur else None, tol=self.tol)
            self.fits[ni, lanes] = ok
            self.minresid[ni, lanes] = resid.min(axis=-1)

    # ------------------------------------------------------------ fused path
    def _dev_sync(self):
        """(Re)upload the packed lane state to the device (bulk path; the
        incremental paths go through donated scatters)."""
        import jax.numpy as jnp
        self._dstarts = jnp.asarray(self.starts)
        self._dpeaks = jnp.asarray(self.peaks)
        self._dneed = jnp.asarray(self.need)
        self._dgrid = jnp.asarray(self.grid)
        self._dadmit = jnp.asarray(self.admit_t)
        self._ddur = jnp.asarray(self.dur)
        self._dirty_dev = False

    def _push_lane(self, lane: int):
        if self.backend == "numpy" or self._dirty_dev:
            return
        self._push_lanes(np.asarray([lane]))

    def _push_lanes(self, lanes: np.ndarray):
        """In-place device update of re-planned lanes (donated buffers)."""
        if self.backend == "numpy" or self._dirty_dev:
            return
        from jax.experimental import enable_x64
        scatter = _scatter_rows_fn()
        with enable_x64():
            import jax.numpy as jnp
            rows = jnp.asarray(np.asarray(lanes, np.int32))
            self._dstarts = scatter(self._dstarts, rows,
                                    jnp.asarray(self.starts[lanes]))
            self._dpeaks = scatter(self._dpeaks, rows,
                                   jnp.asarray(self.peaks[lanes]))
            self._dneed = scatter(self._dneed, rows,
                                  jnp.asarray(self.need[lanes]))

    def _push_admit(self, lane: int):
        if self.backend == "numpy" or self._dirty_dev:
            return
        from jax.experimental import enable_x64
        scatter = _scatter_rows_fn()
        with enable_x64():
            import jax.numpy as jnp
            self._dadmit = scatter(
                self._dadmit, jnp.asarray(np.asarray([lane], np.int32)),
                jnp.asarray(self.admit_t[lane:lane + 1]))

    def _refresh_fused(self, nodes: np.ndarray, lanes: np.ndarray):
        """One fused XLA dispatch for every invalid (node, lane) entry.

        Only the stale node rows enter the dispatch — after a placement,
        that is a single node over the previously-True lanes, not the
        whole matrix.
        """
        from jax.experimental import enable_x64
        import jax.numpy as jnp

        from repro.core.fleet import pad_lane_axis

        kernel = _fused_kernel(self.use_dur)
        sel = [self.running[ni] for ni in nodes]
        rmax = max(max((len(r) for r in sel), default=0), 1)
        rmax = 1 << (rmax - 1).bit_length()
        run_idx = np.zeros((len(nodes), rmax), np.int32)
        run_valid = np.zeros((len(nodes), rmax), bool)
        for i, run in enumerate(sel):
            run_idx[i, :len(run)] = run
            run_valid[i, :len(run)] = True
        (q_idx,) = pad_lane_axis(
            (np.asarray(lanes, np.int32),), (0,), lo=8, fine=True)
        nq = len(lanes)
        with enable_x64():
            if self._dirty_dev:
                self._dev_sync()
            fits, minresid = kernel(
                self._dstarts, self._dpeaks, self._dadmit, self._ddur,
                self._dneed, self._dgrid,
                jnp.asarray(self.caps[nodes]), jnp.asarray(run_idx),
                jnp.asarray(run_valid), jnp.asarray(q_idx),
                jnp.float64(self._now), jnp.float64(self.tol))
        self.fits[np.ix_(nodes, lanes)] = np.asarray(fits)[:, :nq]
        self.minresid[np.ix_(nodes, lanes)] = np.asarray(minresid)[:, :nq]
