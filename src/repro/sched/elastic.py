"""Elastic scaling: node membership changes + mesh re-planning.

At 1000+-node scale, membership churn is routine.  This module keeps the
data plane restartable under churn:

* :func:`plan_mesh` — best (data, model) factorization for a surviving
  device count, honoring divisibility of the model's sharded dims.
* :class:`ElasticPlanner` — admission control for concurrent jobs using
  their KS+ memory envelopes (host- or HBM-side): on `node_join` /
  `node_leave` it recomputes which queued jobs fit *now* and which running
  jobs must be checkpointed and re-sharded.

Together with the deterministic data pipeline (batches are a pure function
of ``(seed, step, shard)``) and atomic checkpoints, a re-shard is: drain →
checkpoint → re-plan mesh → restore → continue at the same step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import AllocationPlan, alloc_at

__all__ = ["plan_mesh", "ElasticPlanner"]


def plan_mesh(n_devices: int, model_divisors: Tuple[int, ...],
              prefer_model: int = 16) -> Tuple[int, int]:
    """Pick (data, model) for ``n_devices`` so every dim in
    ``model_divisors`` stays divisible by the model axis."""
    best = (n_devices, 1)
    for model in range(min(prefer_model, n_devices), 0, -1):
        if n_devices % model:
            continue
        if all(d % model == 0 for d in model_divisors if d):
            best = (n_devices // model, model)
            break
    return best


@dataclasses.dataclass
class _Slice:
    name: str
    memory_gb: float
    jobs: List[Tuple[str, AllocationPlan, float]] = dataclasses.field(
        default_factory=list)  # (job id, envelope, started_at)

    def headroom(self, now: float, horizon_s: float = 600.0) -> float:
        grid = now + np.linspace(0, horizon_s, 32)
        used = np.zeros_like(grid)
        for _, plan, t0 in self.jobs:
            used += alloc_at(plan, np.maximum(grid - t0, 0.0))
        return float(self.memory_gb - used.max())


class ElasticPlanner:
    def __init__(self):
        self.slices: Dict[str, _Slice] = {}

    def node_join(self, name: str, memory_gb: float):
        self.slices[name] = _Slice(name, memory_gb)

    def node_leave(self, name: str) -> List[str]:
        """Returns job ids that must be checkpointed and requeued."""
        sl = self.slices.pop(name, None)
        return [jid for jid, _, _ in (sl.jobs if sl else [])]

    def admit(self, jid: str, envelope: AllocationPlan, now: float
              ) -> Optional[str]:
        """Place a job on the slice with the most post-placement headroom."""
        best, best_head = None, -np.inf
        for sl in self.slices.values():
            head = sl.headroom(now) - float(envelope.peaks.max())
            if head > best_head:
                best, best_head = sl, head
        if best is None or best_head < 0:
            return None
        best.jobs.append((jid, envelope, now))
        return best.name

    def finish(self, jid: str):
        for sl in self.slices.values():
            sl.jobs = [(j, p, t) for j, p, t in sl.jobs if j != jid]
