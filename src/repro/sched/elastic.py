"""Elastic scaling: node membership changes + mesh re-planning.

At 1000+-node scale, membership churn is routine.  This module keeps the
data plane restartable under churn:

* :func:`plan_mesh` — best (data, model) factorization for a surviving
  device count, honoring divisibility of the model's sharded dims.
* :class:`ElasticPlanner` — admission control for concurrent jobs using
  their KS+ memory envelopes (host- or HBM-side).  It shares the packed
  admission primitive with :class:`repro.sched.cluster.ClusterSim`: slice
  residual head-room is one vectorized
  :func:`repro.core.envelope.usage_over` evaluation over the slice's packed
  job envelopes, not a per-job Python loop.  ``node_leave`` evicts the
  victim slice's jobs into a checkpoint/requeue list, ``node_join`` (and
  :meth:`ElasticPlanner.drain`) re-admits queued jobs through the same
  packed check.

Together with the deterministic data pipeline (batches are a pure function
of ``(seed, step, shard)``) and atomic checkpoints, a re-shard is: drain →
checkpoint → re-plan mesh → restore → continue at the same step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import AllocationPlan
from repro.core.envelope import PackedEnvelopes, usage_over

__all__ = ["plan_mesh", "ElasticPlanner"]


def plan_mesh(n_devices: int, model_divisors: Tuple[int, ...],
              prefer_model: int = 16) -> Tuple[int, int]:
    """Pick (data, model) for ``n_devices`` so every dim in
    ``model_divisors`` stays divisible by the model axis."""
    best = (n_devices, 1)
    for model in range(min(prefer_model, n_devices), 0, -1):
        if n_devices % model:
            continue
        if all(d % model == 0 for d in model_divisors if d):
            best = (n_devices // model, model)
            break
    return best


@dataclasses.dataclass
class _Slice:
    name: str
    memory_gb: float
    jobs: List[Tuple[str, AllocationPlan, float]] = dataclasses.field(
        default_factory=list)  # (job id, envelope, started_at)

    def headroom(self, now: float, horizon_s: float = 600.0) -> float:
        """Worst-case free memory over the horizon — packed evaluation of
        every resident envelope at once (shared with the cluster sim)."""
        if not self.jobs:
            return float(self.memory_gb)
        grid = now + np.linspace(0, horizon_s, 32)
        env = PackedEnvelopes.from_plans([p for _, p, _ in self.jobs])
        t0 = np.asarray([t for _, _, t in self.jobs], np.float64)
        used = usage_over(env.starts, env.peaks, t0, grid)
        return float(self.memory_gb - used.max())


class ElasticPlanner:
    """Envelope-aware admission control under node churn.

    Jobs that cannot be placed (yet) wait in ``pending`` in submission
    order; every membership change re-runs the packed admission check over
    the queue.  ``node_leave`` returns the job ids that must checkpoint —
    they are simultaneously requeued, so the next ``node_join``/``drain``
    re-admits them automatically (the re-shard decision is: evicted job →
    checkpoint → requeue → restore wherever it fits next).
    """

    def __init__(self):
        self.slices: Dict[str, _Slice] = {}
        self.pending: List[Tuple[str, AllocationPlan]] = []

    # ------------------------------------------------------------ membership
    def node_join(self, name: str, memory_gb: float,
                  now: Optional[float] = None) -> Dict[str, str]:
        """Add a slice and (with ``now`` given) re-admit queued jobs onto
        the grown pool.

        ``now`` must be the *current* scheduler time — resident envelopes
        are evaluated relative to it, so draining at a stale time would
        overestimate headroom.  Without ``now`` the queue is left for an
        explicit :meth:`drain`.  Returns ``{job id: slice name}`` for every
        queued job placed by this join.
        """
        self.slices[name] = _Slice(name, memory_gb)
        return self.drain(now) if now is not None else {}

    def node_leave(self, name: str, now: Optional[float] = None) -> List[str]:
        """Remove a slice; returns job ids that must be checkpointed.

        The evicted jobs are requeued (ahead of other waiters — they hold
        checkpoints and were running first); with ``now`` given they are
        immediately re-admitted wherever they fit on the surviving slices.
        """
        sl = self.slices.pop(name, None)
        evicted = [(jid, plan) for jid, plan, _ in (sl.jobs if sl else [])]
        self.pending = evicted + self.pending
        if now is not None:
            self.drain(now)
        return [jid for jid, _ in evicted]

    # ------------------------------------------------------------- admission
    def admit(self, jid: str, envelope: AllocationPlan, now: float
              ) -> Optional[str]:
        """Place a job on the slice with the most post-placement headroom."""
        best, best_head = None, -np.inf
        for sl in self.slices.values():
            head = sl.headroom(now) - float(envelope.peaks.max())
            if head > best_head:
                best, best_head = sl, head
        if best is None or best_head < 0:
            return None
        best.jobs.append((jid, envelope, now))
        return best.name

    def submit(self, jid: str, envelope: AllocationPlan, now: float
               ) -> Optional[str]:
        """Admit now, or queue for the next membership change."""
        placed = self.admit(jid, envelope, now)
        if placed is None:
            self.pending.append((jid, envelope))
        return placed

    def drain(self, now: float) -> Dict[str, str]:
        """Re-run admission for every queued job, in queue order."""
        placed: Dict[str, str] = {}
        still: List[Tuple[str, AllocationPlan]] = []
        for jid, envelope in self.pending:
            name = self.admit(jid, envelope, now)
            if name is None:
                still.append((jid, envelope))
            else:
                placed[jid] = name
        self.pending = still
        return placed

    @property
    def queued(self) -> List[str]:
        return [jid for jid, _ in self.pending]

    def finish(self, jid: str):
        for sl in self.slices.values():
            sl.jobs = [(j, p, t) for j, p, t in sl.jobs if j != jid]
        self.pending = [(j, p) for j, p in self.pending if j != jid]
