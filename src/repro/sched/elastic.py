"""Elastic scaling: node membership changes + mesh re-planning.

At 1000+-node scale, membership churn is routine.  This module keeps the
data plane restartable under churn:

* :func:`plan_mesh` — best (data, model) factorization for a surviving
  device count, honoring divisibility of the model's sharded dims.
* :class:`ElasticPlanner` — admission control for concurrent jobs using
  their KS+ memory envelopes (host- or HBM-side).  It shares *runtime
  state* with :class:`repro.sched.cluster.ClusterSim`'s fused engine, not
  just the primitive: every decision — ``admit``, ``submit``, and the
  churn-driven ``drain`` — reads the same
  :class:`repro.sched.admission.AdmissionState` fits matrix under the same
  invalidation protocol (time advance, place, release, plan change, node
  join/leave).  Admission is the pointwise fits-under-residual check over
  the slice's packed resident envelopes — a multi-segment envelope can be
  admitted into head-room that only exists *over time* — with the slice
  residual evaluated conservatively (resident envelopes count forever:
  ``usage_over`` with ``dur=None``), and ties broken toward the slice with
  the most post-placement head-room, matching the historical behavior for
  flat envelopes.  ``node_leave`` evicts the victim slice's jobs into a
  checkpoint/requeue list, ``node_join`` (and
  :meth:`ElasticPlanner.drain`) re-admits queued jobs through the same
  fits columns.

Together with the deterministic data pipeline (batches are a pure function
of ``(seed, step, shard)``) and atomic checkpoints, a re-shard is: drain →
checkpoint → re-plan mesh → restore → continue at the same step.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import AllocationPlan
from repro.core.envelope import (
    PAD_START,
    PackedEnvelopes,
    alloc_at_packed,
    usage_over,
)
from repro.sched.admission import AdmissionState

__all__ = ["plan_mesh", "ElasticPlanner"]


def plan_mesh(n_devices: int, model_divisors: Tuple[int, ...],
              prefer_model: int = 16) -> Tuple[int, int]:
    """Pick (data, model) for ``n_devices`` so every dim in
    ``model_divisors`` stays divisible by the model axis."""
    best = (n_devices, 1)
    for model in range(min(prefer_model, n_devices), 0, -1):
        if n_devices % model:
            continue
        if all(d % model == 0 for d in model_divisors if d):
            best = (n_devices // model, model)
            break
    return best


HORIZON_S = 600.0
HORIZON_GRID = 32


@dataclasses.dataclass
class _Slice:
    """Public per-slice view (resident jobs, introspection helpers).

    Admission *decisions* do not run through this object — they read the
    planner's shared :class:`AdmissionState` fits matrix; ``headroom`` is
    kept as a standalone float64 view for monitoring/inspection (on the
    same default horizon grid the admission state uses).
    """

    name: str
    memory_gb: float
    jobs: List[Tuple[str, AllocationPlan, float]] = dataclasses.field(
        default_factory=list)  # (job id, envelope, started_at)

    def headroom(self, now: float, horizon_s: float = HORIZON_S) -> float:
        """Worst-case free memory over the horizon — packed evaluation of
        every resident envelope at once."""
        if not self.jobs:
            return float(self.memory_gb)
        grid = now + np.linspace(0, horizon_s, HORIZON_GRID)
        env = PackedEnvelopes.from_plans([p for _, p, _ in self.jobs])
        t0 = np.asarray([t for _, _, t in self.jobs], np.float64)
        used = usage_over(env.starts, env.peaks, t0, grid)
        return float(self.memory_gb - used.max())


class ElasticPlanner:
    """Envelope-aware admission control under node churn.

    Jobs that cannot be placed (yet) wait in ``pending`` in submission
    order; every membership change re-runs the shared fits-matrix check
    over the queue.  ``node_leave`` returns the job ids that must
    checkpoint — they are simultaneously requeued, so the next
    ``node_join``/``drain`` re-admits them automatically (the re-shard
    decision is: evicted job → checkpoint → requeue → restore wherever it
    fits next).

    ``backend="numpy"`` (default) runs the shared admission state on the
    float64 host path; ``backend="fused"`` runs the same protocol with the
    jitted one-dispatch-per-refresh columns (identical decisions — see the
    precision contract in :mod:`repro.sched.admission`).
    """

    def __init__(self, backend: str = "numpy",
                 shard: Optional[int] = None):
        self.slices: Dict[str, _Slice] = {}
        self.pending: List[Tuple[str, AllocationPlan]] = []
        self._adm = AdmissionState(
            [], K=1, G=HORIZON_GRID, backend=backend, use_dur=False,
            shard=shard)
        self._names: List[str] = []  # slice name per AdmissionState row
        self._grid = np.linspace(0.0, HORIZON_S, HORIZON_GRID)
        self._lane: Dict[str, int] = {}  # job id -> lane index
        self._free: List[int] = []       # recycled lanes of finished jobs

    # ------------------------------------------------------------ membership
    def node_join(self, name: str, memory_gb: float,
                  now: Optional[float] = None) -> Dict[str, str]:
        """Add a slice and (with ``now`` given) re-admit queued jobs onto
        the grown pool.

        ``now`` must be the *current* scheduler time — resident envelopes
        are evaluated relative to it, so draining at a stale time would
        overestimate headroom.  Without ``now`` the queue is left for an
        explicit :meth:`drain`.  Returns ``{job id: slice name}`` for every
        queued job placed by this join.
        """
        self.slices[name] = _Slice(name, memory_gb)
        self._adm.add_node(memory_gb)
        self._names.append(name)
        return self.drain(now) if now is not None else {}

    def node_leave(self, name: str, now: Optional[float] = None) -> List[str]:
        """Remove a slice; returns job ids that must be checkpointed.

        The evicted jobs are requeued (ahead of other waiters — they hold
        checkpoints and were running first); with ``now`` given they are
        immediately re-admitted wherever they fit on the surviving slices.

        Raises :class:`KeyError` naming the slice when ``name`` is not a
        current member — a silent no-op here would let a fleet-state
        mismatch (double leave, typoed name) go unnoticed while the
        planner keeps admitting against stale capacity.  The ClusterSim
        fault path applies the same check to ``leave`` events.
        """
        if name not in self.slices:
            raise KeyError(f"node_leave: unknown slice {name!r}")
        sl = self.slices.pop(name)
        self._adm.remove_node(self._names.index(name))
        self._names.remove(name)
        evicted = [(jid, plan) for jid, plan, _ in sl.jobs]
        self.pending = evicted + self.pending
        if now is not None:
            self.drain(now)
        return [jid for jid, _ in evicted]

    # ------------------------------------------------------------- admission
    @staticmethod
    def _as_plan(envelope, input_gb=None) -> AllocationPlan:
        """Normalize the admission argument into an allocation envelope.

        Accepts an :class:`AllocationPlan`, a fitted method instance, or a
        registered method *name* (:mod:`repro.core.registry` — names
        construct fresh instances, so they only work for fit-free methods
        like ``"default"``); methods predict with ``input_gb``.
        """
        if isinstance(envelope, AllocationPlan):
            return envelope
        from repro.core import registry
        method = registry.resolve(envelope)
        if input_gb is None:
            raise ValueError(
                "admitting via a method (or registry name) needs input_gb")
        return method.predict(float(input_gb))

    def _ensure_lane(self, jid: str, envelope: AllocationPlan) -> int:
        """Lane index for ``jid`` in the shared state (created on first
        sight; resubmission with a changed envelope re-plans the lane)."""
        n = len(envelope.starts)
        self._adm.ensure_k(n)
        K = self._adm.K
        starts = np.full((K,), PAD_START, np.float64)
        peaks = np.empty((K,), np.float64)
        starts[:n] = envelope.starts
        peaks[:n] = envelope.peaks
        peaks[n:] = envelope.peaks[-1]
        need = alloc_at_packed(starts[None], peaks[None], self._grid)[0]
        lane = self._lane.get(jid)
        if lane is None:
            if self._free:  # recycle a finished job's lane: state stays
                lane = self._free.pop()  # bounded by max *concurrent* jobs
                self._adm.update_lane(lane, starts, peaks, need)
            else:
                lane = int(self._adm.add_lanes(
                    starts[None], peaks[None], need[None],
                    self._grid[None])[0])
            self._lane[jid] = lane
        elif not (np.array_equal(self._adm.starts[lane], starts)
                  and np.array_equal(self._adm.peaks[lane], peaks)):
            self._adm.update_lane(lane, starts, peaks, need)
        return lane

    def admit(self, jid: str, envelope, now: float, *,
              input_gb: Optional[float] = None) -> Optional[str]:
        """Place a job via the shared fits matrix.

        ``envelope`` is an :class:`AllocationPlan`, a fitted method, or a
        registered method name (see :meth:`_as_plan`).  Among the slices
        whose residual envelope covers the job's need pointwise over the
        horizon, pick the one with the most post-placement head-room
        (``minresid - peak``, first on ties — identical to the historical
        scalar rule for flat envelopes).
        """
        envelope = self._as_plan(envelope, input_gb)
        if not self._names:
            return None
        lane = self._ensure_lane(jid, envelope)
        for ni, name in enumerate(self._names):
            if lane in self._adm.running[ni]:
                # Already resident: this was a live re-size (the lane's
                # reservation just changed in place), not a placement.
                sl = self.slices[name]
                sl.jobs = [(j, envelope if j == jid else p, t)
                           for j, p, t in sl.jobs]
                return name
        col = self._adm.columns(now, [lane])[:, 0]  # (N,) fits
        if not col.any():
            return None
        head = self._adm.minresid[:, lane] - float(envelope.peaks.max())
        ni = int(np.argmax(np.where(col, head, -np.inf)))
        self._adm.place(ni, lane, now)
        name = self._names[ni]
        self.slices[name].jobs.append((jid, envelope, now))
        return name

    def submit(self, jid: str, envelope, now: float, *,
               input_gb: Optional[float] = None) -> Optional[str]:
        """Admit now, or queue for the next membership change."""
        envelope = self._as_plan(envelope, input_gb)
        placed = self.admit(jid, envelope, now)
        if placed is None:
            self.pending.append((jid, envelope))
        return placed

    def drain(self, now: float) -> Dict[str, str]:
        """Re-run admission for every queued job, in queue order — each
        decision reads the shared fits matrix, refreshed only where the
        invalidation protocol says it is stale.

        On ``backend="fused"`` the whole queue drains in ONE jitted
        dispatch (:meth:`AdmissionState.drain` with the head-room node
        rule) — decision-identical to the per-job loop because
        placements only shrink residuals, so a job unfit at its queue
        position can never become fit later in the same drain.  Queues
        with duplicate job ids or resident (live re-size) resubmissions
        fall back to the per-job loop, whose ``admit`` handles those
        branches.
        """
        if self._adm.backend == "fused" and self._names and self.pending:
            jids = [j for j, _ in self.pending]
            resident = set()
            for lanes in self._adm.running:
                resident.update(lanes)
            if (len(set(jids)) == len(jids)
                    and all(j in self._lane
                            and self._lane[j] not in resident
                            for j in jids)):
                return self._drain_device(now)
        lanes = [self._lane[j] for j, _ in self.pending if j in self._lane]
        if lanes and self._names:
            # One batched refresh for the whole queue up front; the per-job
            # admissions below then only pay incremental invalidations.
            self._adm.columns(now, lanes)
        placed: Dict[str, str] = {}
        still: List[Tuple[str, AllocationPlan]] = []
        for jid, envelope in self.pending:
            name = self.admit(jid, envelope, now)
            if name is None:
                still.append((jid, envelope))
            else:
                placed[jid] = name
        self.pending = still
        return placed

    def _drain_device(self, now: float) -> Dict[str, str]:
        """Queue-order device drain: re-plan any changed envelopes (lane
        updates are queue-local, so order cannot matter), then place the
        whole queue in one dispatch and mirror the decisions into the
        slice rosters."""
        order: List[Tuple[str, AllocationPlan, int]] = []
        for jid, envelope in self.pending:
            self._ensure_lane(jid, envelope)
            order.append((jid, envelope, self._lane[jid]))
        got = dict(self._adm.drain(now, [ln for _, _, ln in order],
                                   select="headroom"))
        placed: Dict[str, str] = {}
        still: List[Tuple[str, AllocationPlan]] = []
        for jid, envelope, lane in order:
            ni = got.get(lane)
            if ni is None:
                still.append((jid, envelope))
            else:
                name = self._names[ni]
                self.slices[name].jobs.append((jid, envelope, now))
                placed[jid] = name
        self.pending = still
        return placed

    @property
    def queued(self) -> List[str]:
        return [jid for jid, _ in self.pending]

    def finish(self, jid: str):
        lane = self._lane.pop(jid, None)
        for ni, name in enumerate(self._names):
            sl = self.slices[name]
            if any(j == jid for j, _, _ in sl.jobs):
                sl.jobs = [(j, p, t) for j, p, t in sl.jobs if j != jid]
                self._adm.release(ni, lane)
        self.pending = [(j, p) for j, p in self.pending if j != jid]
        if lane is not None:
            self._free.append(lane)
