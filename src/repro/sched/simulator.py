"""Trace-driven evaluation harness (paper §III).

Fits every method per task family on the training split, replays the test
split through the OOM/retry simulator, and aggregates GB·s wastage —
reproducing the comparisons behind Figs. 6–8.

The replay runs on the batched fleet engine (:mod:`repro.core.fleet`) by
default: the entire workflow's test split becomes one ``(B, T)`` lane batch
per method and the whole OOM/retry protocol executes inside a single jitted
XLA program, instead of ``families × executions × attempts`` Python-level
numpy calls.  ``engine="oracle"`` keeps the original per-execution loop —
it is the ground truth the engine is differentially tested against.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import (
    DefaultMethod,
    KSegments,
    KSPlus,
    KSPlusAuto,
    PPMImproved,
    TovarPPM,
    WittPercentile,
    bucket_traces,
    concat_packed,
    packed_predict,
    simulate_execution,
    simulate_fleet_many,
)
from repro.traces.generator import Execution, Workflow

__all__ = ["MethodResult", "ExperimentResult", "default_methods", "evaluate_workflow"]


@dataclasses.dataclass
class MethodResult:
    name: str
    per_family_gbs: Dict[str, float]
    total_gbs: float
    retries: int
    failures: int  # executions that never succeeded (hit machine limits)


@dataclasses.dataclass
class ExperimentResult:
    workflow: str
    seed: int
    train_frac: float
    methods: Dict[str, MethodResult]

    def reduction_vs(self, method: str, baseline: str) -> float:
        """Fractional wastage reduction of ``method`` vs ``baseline``."""
        b = self.methods[baseline].total_gbs
        m = self.methods[method].total_gbs
        return (b - m) / b if b > 0 else 0.0


def default_methods(k: int, machine_memory: float,
                    default_limit: float) -> Dict[str, Callable[[], object]]:
    """The paper's method zoo (§III-B) plus the Witt et al. percentile
    baseline, freshly constructed per family."""
    return {
        "ks+": lambda: KSPlus(k=k),
        "ks+auto": lambda: KSPlusAuto(machine_memory=machine_memory),
        "k-segments-selective": lambda: KSegments(k=k, variant="selective"),
        "k-segments-partial": lambda: KSegments(k=k, variant="partial"),
        "tovar-ppm": lambda: TovarPPM(machine_memory=machine_memory),
        "ppm-improved": lambda: PPMImproved(machine_memory=machine_memory),
        "witt-p95": lambda: WittPercentile(percentile=95.0,
                                           machine_memory=machine_memory),
        "default": lambda: DefaultMethod(limit_gb=default_limit,
                                         machine_memory=machine_memory),
    }


def _fit_methods(wf: Workflow, train, names, k, machine_memory):
    """Fit every method on every family's training split."""
    fitted: Dict[str, Dict[str, object]] = {}
    for fname, train_execs in train.items():
        fam = wf.families[fname]
        zoo = default_methods(k, machine_memory, fam.default_limit_gb)
        mems = [e.mem for e in train_execs]
        dts = [e.dt for e in train_execs]
        inputs = [e.input_gb for e in train_execs]
        fitted[fname] = {}
        for mname in names:
            method = zoo[mname]()
            method.fit(mems, dts, inputs)
            fitted[fname][mname] = method
    return fitted


def evaluate_workflow(
    wf: Workflow,
    *,
    seed: int,
    train_frac: float,
    k: int = 4,
    machine_memory: float = 128.0,
    methods: Optional[List[str]] = None,
    dt: float = 1.0,
    engine: str = "fleet",
) -> ExperimentResult:
    """Fit + replay one (workflow, seed, train fraction) cell.

    ``engine="fleet"`` (default) runs the replay on the batched engine —
    one jitted OOM/retry program per method over the *whole* test split;
    ``engine="oracle"`` replays execution-by-execution through
    :func:`simulate_execution`.
    """
    if engine not in ("fleet", "oracle"):
        raise ValueError(f"unknown engine: {engine!r}")
    train, test = wf.split(seed, train_frac, dt)
    names = methods or list(default_methods(k, machine_memory, 8.0).keys())
    results: Dict[str, MethodResult] = {
        m: MethodResult(m, {}, 0.0, 0, 0) for m in names
    }
    fitted = _fit_methods(wf, train, names, k, machine_memory)

    if engine == "oracle":
        for fname in train:
            for mname in names:
                method = fitted[fname][mname]
                fam_gbs = 0.0
                for e in test[fname]:
                    plan = method.predict(e.input_gb)
                    res = simulate_execution(
                        plan, method.retry, e.mem, e.dt,
                        machine_memory=machine_memory,
                    )
                    fam_gbs += res.wastage_gbs
                    results[mname].retries += res.num_retries
                    results[mname].failures += 0 if res.succeeded else 1
                results[mname].per_family_gbs[fname] = fam_gbs
                results[mname].total_gbs += fam_gbs
        return ExperimentResult(wf.name, seed, train_frac, results)

    # Fleet path: flatten the whole test split into one lane batch, bucketed
    # once and shared across methods; ALL methods replay in two dispatches.
    flat = [(fname, e) for fname in train for e in test[fname]]
    for mname in names:
        for fname in train:
            results[mname].per_family_gbs[fname] = 0.0
    if not flat:
        return ExperimentResult(wf.name, seed, train_frac, results)
    assert len({e.dt for _, e in flat}) == 1, "fleet engine needs uniform dt"
    traces = bucket_traces([e.mem for _, e in flat])
    fam_idx = np.asarray(
        [list(train).index(fname) for fname, _ in flat], np.int64)

    jobs = []
    for mname in names:
        # Vectorized per-family prediction, concatenated in flat-lane order.
        parts = [
            packed_predict(fitted[fname][mname],
                           [e.input_gb for e in test[fname]])
            for fname in train if test[fname]
        ]
        specs = {fitted[fname][mname].retry_spec for fname in train}
        assert len(specs) == 1, f"{mname}: retry spec differs across families"
        jobs.append((concat_packed(parts), specs.pop()))
    fleet = simulate_fleet_many(
        jobs, traces, flat[0][1].dt, machine_memory=machine_memory)

    for mname, fr in zip(names, fleet):
        per_fam = np.zeros(len(train))
        np.add.at(per_fam, fam_idx, fr.wastage_gbs)
        for i, fname in enumerate(train):
            results[mname].per_family_gbs[fname] = float(per_fam[i])
        results[mname].total_gbs = float(fr.wastage_gbs.sum())
        results[mname].retries = int(fr.retries.sum())
        results[mname].failures = int((~fr.succeeded).sum())

    return ExperimentResult(wf.name, seed, train_frac, results)


def run_paper_experiment(
    wf: Workflow,
    *,
    seeds=range(10),
    train_fracs=(0.25, 0.50, 0.75),
    k: int = 4,
    machine_memory: float = 128.0,
    methods: Optional[List[str]] = None,
    dt: float = 1.0,
    engine: str = "fleet",
):
    """Fig. 6 protocol: 10 seeds × {25, 50, 75}% training data, averaged."""
    out: Dict[float, Dict[str, float]] = {}
    for frac in train_fracs:
        acc: Dict[str, List[float]] = {}
        for seed in seeds:
            res = evaluate_workflow(
                wf, seed=seed, train_frac=frac, k=k,
                machine_memory=machine_memory, methods=methods, dt=dt,
                engine=engine,
            )
            for name, mr in res.methods.items():
                acc.setdefault(name, []).append(mr.total_gbs)
        out[frac] = {name: float(np.mean(v)) for name, v in acc.items()}
    return out
