"""Trace-driven evaluation harness (paper §III) + online replay.

Fits every method per task family on the training split, replays the test
split through the OOM/retry simulator, and aggregates GB·s wastage —
reproducing the comparisons behind Figs. 6–8.

The replay runs on the batched fleet engine (:mod:`repro.core.fleet`) by
default: the entire workflow's test split becomes one ``(B, T)`` lane batch
per method and the whole OOM/retry protocol executes inside a single jitted
XLA program, instead of ``families × executions × attempts`` Python-level
numpy calls.  ``engine="oracle"`` keeps the original per-execution loop —
it is the ground truth the engine is differentially tested against.

``mode="online"`` streams the test split *in submission order* through the
predictor lifecycle (:class:`repro.core.predictor.MemoryPredictor`):
executions are grouped into rounds (the i-th ``round_size`` executions of
every family share an event time), each round replays as one compacted
fleet dispatch over a lane *subset* of the shared trace batch
(:func:`repro.core.fleet.subset_batch` — bucket widths are preserved, so
per-lane arithmetic stays bit-identical to the offline batch), and between
rounds every online-capable method ``observe``s its outcomes and ``refit``s
under the given policy — one compacted refit per (family, method) per event
time, mirroring the cluster engine's event-batched retries.  With
``refit="never"`` no model ever changes, so online replay reproduces the
offline :class:`ExperimentResult` bitwise (differentially pinned in
``tests/test_online.py``).

The method zoo lives in :mod:`repro.core.registry` — method *names*
(including aliases) are accepted everywhere method lists are, and each
family's methods are constructed from the registry with the family's real
``default_limit_gb``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Union

import numpy as np

from repro.core import (
    ExecutionOutcome,
    RefitPolicy,
    bucket_traces,
    concat_packed,
    packed_predict,
    refit_batched,
    registry,
    simulate_execution,
    simulate_fleet_many,
    subset_batch,
)
from repro.core.fleet import PAD_START, FleetResult
from repro.traces.generator import Workflow

__all__ = ["MethodResult", "ExperimentResult", "default_methods",
           "evaluate_workflow", "run_paper_experiment"]


@dataclasses.dataclass
class MethodResult:
    name: str
    per_family_gbs: Dict[str, float]
    total_gbs: float
    retries: int
    failures: int  # executions that never succeeded (hit machine limits)


@dataclasses.dataclass
class ExperimentResult:
    workflow: str
    seed: int
    train_frac: float
    methods: Dict[str, MethodResult]

    def reduction_vs(self, method: str, baseline: str) -> float:
        """Fractional wastage reduction of ``method`` vs ``baseline``."""
        b = self.methods[baseline].total_gbs
        m = self.methods[method].total_gbs
        return (b - m) / b if b > 0 else 0.0


def default_methods(k: int, machine_memory: float,
                    default_limit: float) -> Dict[str, Callable[[], object]]:
    """Compatibility shim: the method zoo as per-name constructors.

    The zoo itself lives in :mod:`repro.core.registry` now — prefer
    ``registry.method_names()`` / ``registry.make(name, ...)``.
    """
    return {
        name: (lambda name=name: registry.make(
            name, k=k, machine_memory=machine_memory,
            default_limit=default_limit))
        for name in registry.method_names()
    }


def _fit_methods(wf: Workflow, train, names, k, machine_memory):
    """Construct (from the registry, with each family's real default
    limit) and fit every method on every family's training split."""
    fitted: Dict[str, Dict[str, object]] = {}
    for fname, train_execs in train.items():
        fam = wf.families[fname]
        mems = [e.mem for e in train_execs]
        dts = [e.dt for e in train_execs]
        inputs = [e.input_gb for e in train_execs]
        fitted[fname] = {}
        for mname in names:
            method = registry.make(mname, k=k, machine_memory=machine_memory,
                                   default_limit=fam.default_limit_gb)
            method.fit(mems, dts, inputs)
            fitted[fname][mname] = method
    return fitted


def _method_jobs(fitted, train, test, names):
    """One packed-plan job per method over the whole flat test split,
    family-major — the offline fleet batch."""
    jobs = []
    for mname in names:
        parts = [
            packed_predict(fitted[fname][mname],
                           [e.input_gb for e in test[fname]])
            for fname in train if test[fname]
        ]
        specs = {fitted[fname][mname].retry_spec for fname in train}
        assert len(specs) == 1, f"{mname}: retry spec differs across families"
        jobs.append((concat_packed(parts), specs.pop()))
    return jobs


def _aggregate_fleet(results, fleet, names, train, fam_idx):
    """Fold per-lane fleet outcomes into MethodResults (shared by the
    offline and online paths — identical reduction order, so the online
    ``refit="never"`` replay matches offline bitwise)."""
    for mname, fr in zip(names, fleet):
        per_fam = np.zeros(len(train))
        np.add.at(per_fam, fam_idx, fr.wastage_gbs)
        for i, fname in enumerate(train):
            results[mname].per_family_gbs[fname] = float(per_fam[i])
        results[mname].total_gbs = float(fr.wastage_gbs.sum())
        results[mname].retries = int(fr.retries.sum())
        results[mname].failures = int((~fr.succeeded).sum())


def evaluate_workflow(
    wf: Union[Workflow, str, object],
    *,
    seed: int,
    train_frac: float,
    k: int = 4,
    machine_memory: float = 128.0,
    methods: Optional[List[str]] = None,
    dt: float = 1.0,
    engine: str = "fleet",
    mode: str = "offline",
    refit: Union[RefitPolicy, str] = "never",
    round_size: int = 1,
) -> ExperimentResult:
    """Fit + replay one (workflow, seed, train fraction) cell.

    ``wf`` may be a :class:`repro.traces.generator.Workflow`, a
    :class:`repro.workloads.WorkflowTrace` (adapted via ``to_workflow``),
    or a scenario *name* from the :mod:`repro.workloads.scenarios`
    catalog (``"heavy_tail"``, ``"burst_arrival"``, ...) — built at its
    default size with this cell's ``seed``.

    ``engine="fleet"`` (default) runs the replay on the batched engine —
    one jitted OOM/retry program per method over the *whole* test split;
    ``engine="oracle"`` replays execution-by-execution through
    :func:`simulate_execution`.

    ``mode="online"`` (fleet engine only) streams the test split through
    the predictor lifecycle: per round of ``round_size`` executions per
    family, replay → ``observe`` → ``refit(refit)``.  Methods whose
    registry spec says ``online=False`` (the frozen paper baselines) replay
    with their fit-once models.  ``refit="never"`` reproduces the offline
    result bitwise.
    """
    if isinstance(wf, str):  # scenario-catalog name
        from repro.workloads import scenarios
        wf = scenarios.get(wf, seed=seed).to_workflow()
    elif hasattr(wf, "to_workflow"):  # a workloads.WorkflowTrace
        wf = wf.to_workflow()
    if engine not in ("fleet", "oracle"):
        raise ValueError(f"unknown engine: {engine!r}")
    if mode not in ("offline", "online"):
        raise ValueError(f"unknown mode: {mode!r}")
    if mode == "online" and engine != "fleet":
        raise ValueError("mode='online' requires engine='fleet'")
    if round_size < 1:
        raise ValueError(f"round_size must be >= 1, got {round_size}")
    policy = RefitPolicy.parse(refit)
    train, test = wf.split(seed, train_frac, dt)
    names = [registry.canonical_name(m) for m in methods] if methods \
        else registry.method_names()
    results: Dict[str, MethodResult] = {
        m: MethodResult(m, {}, 0.0, 0, 0) for m in names
    }
    fitted = _fit_methods(wf, train, names, k, machine_memory)

    if engine == "oracle":
        for fname in train:
            for mname in names:
                method = fitted[fname][mname]
                fam_gbs = 0.0
                for e in test[fname]:
                    plan = method.predict(e.input_gb)
                    res = simulate_execution(
                        plan, method.retry, e.mem, e.dt,
                        machine_memory=machine_memory,
                    )
                    fam_gbs += res.wastage_gbs
                    results[mname].retries += res.num_retries
                    results[mname].failures += 0 if res.succeeded else 1
                results[mname].per_family_gbs[fname] = fam_gbs
                results[mname].total_gbs += fam_gbs
        return ExperimentResult(wf.name, seed, train_frac, results)

    # Fleet path: flatten the whole test split into one lane batch, bucketed
    # once and shared across methods (and, online, across rounds).
    flat = [(fname, e) for fname in train for e in test[fname]]
    for mname in names:
        for fname in train:
            results[mname].per_family_gbs[fname] = 0.0
    if not flat:
        return ExperimentResult(wf.name, seed, train_frac, results)
    assert len({e.dt for _, e in flat}) == 1, "fleet engine needs uniform dt"
    traces = bucket_traces([e.mem for _, e in flat])
    fam_idx = np.asarray(
        [list(train).index(fname) for fname, _ in flat], np.int64)

    if mode == "offline":
        jobs = _method_jobs(fitted, train, test, names)
        fleet = simulate_fleet_many(
            jobs, traces, flat[0][1].dt, machine_memory=machine_memory)
        _aggregate_fleet(results, fleet, names, train, fam_idx)
        return ExperimentResult(wf.name, seed, train_frac, results)

    # Online replay: the i-th `round_size` executions of every family share
    # an event time; ALL methods still replay each round in the usual two
    # compacted dispatches, then observations and refits are batched per
    # (family, method) at the round boundary.  Per-family packed
    # predictions are cached and invalidated only by an actual refit, so a
    # family whose model never changes predicts exactly once — with
    # `refit="never"` the prediction work equals the offline replay's.
    B = len(flat)
    within = np.zeros((B,), np.int64)  # index within its family
    seen: Dict[str, int] = {}
    for i, (fname, _) in enumerate(flat):
        within[i] = seen.get(fname, 0)
        seen[fname] = within[i] + 1
    n_rounds = int(within.max()) // round_size + 1
    online = {m: registry.get_spec(m).online for m in names}
    wastage = {m: np.zeros((B,), np.float64) for m in names}
    attempts = {m: np.ones((B,), np.int64) for m in names}
    succeeded = {m: np.zeros((B,), bool) for m in names}
    pred_cache: Dict[tuple, tuple] = {}  # (family, method) -> packed plans

    def family_plans(fname, mname):
        sp = pred_cache.get((fname, mname))
        if sp is None:
            sp = pred_cache[(fname, mname)] = packed_predict(
                fitted[fname][mname],
                [e.input_gb for e in test[fname]])
        return sp

    for r in range(n_rounds):
        lanes = np.nonzero(within // round_size == r)[0]
        by_fam: Dict[str, list] = {}
        for i in lanes:
            fname, e = flat[i]
            by_fam.setdefault(fname, []).append((int(i), e))
        jobs = []
        for mname in names:
            parts = []
            for fname in train:
                pairs = by_fam.get(fname)
                if not pairs:
                    continue
                sp = family_plans(fname, mname)
                sub = within[[i for i, _ in pairs]]
                parts.append((sp[0][sub], sp[1][sub], sp[2][sub]))
            specs = {fitted[fname][mname].retry_spec for fname in train}
            assert len(specs) == 1, \
                f"{mname}: retry spec differs across families"
            sp = concat_packed(parts)
            K = sp[0].shape[1]
            starts = np.full((B, K), PAD_START, np.float32)
            peaks = np.ones((B, K), np.float32)
            nseg = np.ones((B,), np.int32)
            starts[lanes], peaks[lanes], nseg[lanes] = sp
            jobs.append(((starts, peaks, nseg), specs.pop()))
        fleet = simulate_fleet_many(
            jobs, subset_batch(traces, lanes), flat[0][1].dt,
            machine_memory=machine_memory)
        for mname, fr in zip(names, fleet):
            wastage[mname][lanes] = fr.wastage_gbs[lanes]
            attempts[mname][lanes] = fr.attempts[lanes]
            succeeded[mname][lanes] = fr.succeeded[lanes]
        if policy.kind == "never" or r == n_rounds - 1:
            # "never": no refit can ever consume the observations; final
            # round: the refitted models would never predict again.
            continue
        keys = []
        for mname in names:
            if not online[mname]:
                continue
            for fname, pairs in by_fam.items():
                method = fitted[fname][mname]
                for i, e in pairs:
                    method.observe(ExecutionOutcome(
                        mem=e.mem, dt=e.dt, input_gb=e.input_gb,
                        succeeded=bool(succeeded[mname][i]),
                        retries=int(attempts[mname][i] - 1)))
                keys.append((fname, mname))
        # One compacted refit pass per event time: every due family's
        # tail segments in one dispatch per segment count.
        did = refit_batched([fitted[f][m] for f, m in keys], policy)
        for (fname, mname), flag in zip(keys, did):
            if flag:
                pred_cache.pop((fname, mname), None)

    fleet = [FleetResult(wastage_gbs=wastage[m], attempts=attempts[m],
                         succeeded=succeeded[m]) for m in names]
    _aggregate_fleet(results, fleet, names, train, fam_idx)
    return ExperimentResult(wf.name, seed, train_frac, results)


def run_paper_experiment(
    wf: Union[Workflow, str, object],
    *,
    seeds=range(10),
    train_fracs=(0.25, 0.50, 0.75),
    k: int = 4,
    machine_memory: float = 128.0,
    methods: Optional[List[str]] = None,
    dt: float = 1.0,
    engine: str = "fleet",
    mode: str = "offline",
    refit: Union[RefitPolicy, str] = "never",
    round_size: int = 1,
):
    """Fig. 6 protocol: 10 seeds × {25, 50, 75}% training data, averaged.

    Like :func:`evaluate_workflow`, ``wf`` may be a scenario name (built
    once per seed — the synthesis seed follows the cell seed) or a
    :class:`repro.workloads.WorkflowTrace` (adapted once, shared by every
    cell); the conversion is hoisted out of the (seed, frac) grid.
    """
    if isinstance(wf, str):  # one synthesis per seed, shared across fracs
        from repro.workloads import scenarios
        per_seed = {s: scenarios.get(wf, seed=s).to_workflow()
                    for s in seeds}
        wf_for = per_seed.__getitem__
    elif hasattr(wf, "to_workflow"):  # adapt a WorkflowTrace exactly once
        adapted = wf.to_workflow()
        wf_for = lambda s: adapted  # noqa: E731
    else:
        wf_for = lambda s: wf  # noqa: E731
    out: Dict[float, Dict[str, float]] = {}
    for frac in train_fracs:
        acc: Dict[str, List[float]] = {}
        for seed in seeds:
            res = evaluate_workflow(
                wf_for(seed), seed=seed, train_frac=frac, k=k,
                machine_memory=machine_memory, methods=methods, dt=dt,
                engine=engine, mode=mode, refit=refit, round_size=round_size,
            )
            for name, mr in res.methods.items():
                acc.setdefault(name, []).append(mr.total_gbs)
        out[frac] = {name: float(np.mean(v)) for name, v in acc.items()}
    return out
