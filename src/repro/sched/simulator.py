"""Trace-driven evaluation harness (paper §III).

Fits every method per task family on the training split, replays the test
split through the OOM/retry simulator, and aggregates GB·s wastage —
reproducing the comparisons behind Figs. 6–8.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import (
    DefaultMethod,
    KSegments,
    KSPlus,
    KSPlusAuto,
    PPMImproved,
    TovarPPM,
    simulate_execution,
)
from repro.traces.generator import Execution, Workflow

__all__ = ["MethodResult", "ExperimentResult", "default_methods", "evaluate_workflow"]


@dataclasses.dataclass
class MethodResult:
    name: str
    per_family_gbs: Dict[str, float]
    total_gbs: float
    retries: int
    failures: int  # executions that never succeeded (hit machine limits)


@dataclasses.dataclass
class ExperimentResult:
    workflow: str
    seed: int
    train_frac: float
    methods: Dict[str, MethodResult]

    def reduction_vs(self, method: str, baseline: str) -> float:
        """Fractional wastage reduction of ``method`` vs ``baseline``."""
        b = self.methods[baseline].total_gbs
        m = self.methods[method].total_gbs
        return (b - m) / b if b > 0 else 0.0


def default_methods(k: int, machine_memory: float,
                    default_limit: float) -> Dict[str, Callable[[], object]]:
    """The paper's method zoo (§III-B), freshly constructed per family."""
    return {
        "ks+": lambda: KSPlus(k=k),
        "ks+auto": lambda: KSPlusAuto(machine_memory=machine_memory),
        "k-segments-selective": lambda: KSegments(k=k, variant="selective"),
        "k-segments-partial": lambda: KSegments(k=k, variant="partial"),
        "tovar-ppm": lambda: TovarPPM(machine_memory=machine_memory),
        "ppm-improved": lambda: PPMImproved(machine_memory=machine_memory),
        "default": lambda: DefaultMethod(limit_gb=default_limit,
                                         machine_memory=machine_memory),
    }


def evaluate_workflow(
    wf: Workflow,
    *,
    seed: int,
    train_frac: float,
    k: int = 4,
    machine_memory: float = 128.0,
    methods: Optional[List[str]] = None,
    dt: float = 1.0,
) -> ExperimentResult:
    train, test = wf.split(seed, train_frac, dt)
    names = methods or list(default_methods(k, machine_memory, 8.0).keys())
    results: Dict[str, MethodResult] = {
        m: MethodResult(m, {}, 0.0, 0, 0) for m in names
    }

    for fname, train_execs in train.items():
        fam = wf.families[fname]
        zoo = default_methods(k, machine_memory, fam.default_limit_gb)
        mems = [e.mem for e in train_execs]
        dts = [e.dt for e in train_execs]
        inputs = [e.input_gb for e in train_execs]
        for mname in names:
            method = zoo[mname]()
            method.fit(mems, dts, inputs)
            fam_gbs = 0.0
            for e in test[fname]:
                plan = method.predict(e.input_gb)
                res = simulate_execution(
                    plan, method.retry, e.mem, e.dt,
                    machine_memory=machine_memory,
                )
                fam_gbs += res.wastage_gbs
                results[mname].retries += res.num_retries
                results[mname].failures += 0 if res.succeeded else 1
            results[mname].per_family_gbs[fname] = fam_gbs
            results[mname].total_gbs += fam_gbs

    return ExperimentResult(wf.name, seed, train_frac, results)


def run_paper_experiment(
    wf: Workflow,
    *,
    seeds=range(10),
    train_fracs=(0.25, 0.50, 0.75),
    k: int = 4,
    machine_memory: float = 128.0,
    methods: Optional[List[str]] = None,
    dt: float = 1.0,
):
    """Fig. 6 protocol: 10 seeds × {25, 50, 75}% training data, averaged."""
    out: Dict[float, Dict[str, float]] = {}
    for frac in train_fracs:
        acc: Dict[str, List[float]] = {}
        for seed in seeds:
            res = evaluate_workflow(
                wf, seed=seed, train_frac=frac, k=k,
                machine_memory=machine_memory, methods=methods, dt=dt,
            )
            for name, mr in res.methods.items():
                acc.setdefault(name, []).append(mr.total_gbs)
        out[frac] = {name: float(np.mean(v)) for name, v in acc.items()}
    return out
