"""Scheduler layer: trace-driven evaluation, cluster sim, monitoring, elastic."""

from repro.sched.admission import AdmissionState
from repro.sched.cluster import (
    ClusterResult,
    ClusterSim,
    Job,
    Node,
    OffsetCandidate,
)
from repro.sched.elastic import ElasticPlanner, plan_mesh
from repro.sched.faults import FaultEvent, FaultSchedule
from repro.sched.monitor import HBMFootprintModel, MemoryMonitor, read_rss_gb
from repro.sched.simulator import (
    ExperimentResult,
    MethodResult,
    default_methods,
    evaluate_workflow,
    run_paper_experiment,
)

__all__ = [
    "AdmissionState",
    "ClusterResult", "ClusterSim", "Job", "Node", "OffsetCandidate",
    "ElasticPlanner", "plan_mesh",
    "FaultEvent", "FaultSchedule",
    "HBMFootprintModel", "MemoryMonitor", "read_rss_gb",
    "ExperimentResult", "MethodResult", "default_methods",
    "evaluate_workflow", "run_paper_experiment",
]
