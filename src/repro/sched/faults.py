"""Seeded fault injection for the cluster simulator.

A :class:`FaultSchedule` is an immutable, time-sorted list of node
membership events — ``leave`` (the node dies / is preempted; resident
jobs are evicted) and ``join`` (a node enters the fleet with a given
capacity) — consumed by :meth:`repro.sched.cluster.ClusterSim.run` via
its ``faults=`` argument.  All three engines inject the same schedule at
the same event times, so the differential suites keep pinning their
decision logs bitwise under churn (``tests/test_faults.py``).

Eviction semantics (identical in every engine):

* each resident job of a leaving node is killed — its allocated area up
  to the eviction time counts as wastage, its attempt counter advances
  (the same :class:`repro.core.envelope.RetrySpec` attempt budget that
  bounds OOM retries), and it re-enters the admission queue *ahead* of
  other waiters, in admission order;
* a job that runs out of attempts through evictions fails permanently —
  DAG descendants are doomed exactly like an OOM permanent failure;
* a job the surviving fleet cannot fit at all (its admission-need peak
  exceeds every remaining node's capacity) parks in a starvation-tracked
  side queue and re-enters on the next ``join`` instead of spinning in
  the admission queue (graceful degradation; see
  ``ClusterResult.starved`` / ``starvation_s``).

Constructors are seeded and deterministic: the same ``(nodes, args,
seed)`` always yields the same event list (``numpy.random.Generator``
over a tagged ``SeedSequence``).  Schedules compose with ``+`` — the
merge re-sorts by time, stably, so equal-time events keep their operand
order.

Device-drain coherence: the fused engine's default ``drain="device"``
path (:meth:`repro.sched.admission.AdmissionState.drain`) does not read
the host-side fits cache at all — every drain recomputes fits from the
post-churn ``running``/``caps`` state inside the one jitted dispatch,
so ``leave``/``join`` row splices need no device-side mask rebuild;
only the *host* fallback path consumes the incremental invalidation
protocol.  The churn/storm differential suites pin both paths bitwise
(``tests/test_device_drain.py``).
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Iterable, Iterator, List, Mapping, Sequence, Tuple

import numpy as np

__all__ = ["FaultEvent", "FaultSchedule"]

_KINDS = ("leave", "join")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One membership event: node ``nid`` leaves or joins at time ``t``.

    ``capacity_gb`` is required (positive) for joins — a joining node
    may rejoin with a different capacity than it left with — and unused
    for leaves.
    """

    t: float
    kind: str
    nid: int
    capacity_gb: float = 0.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r} (choose from {_KINDS})")
        if not np.isfinite(self.t) or self.t < 0.0:
            raise ValueError(
                f"fault event time must be finite and >= 0, got {self.t!r}")
        if self.kind == "join" and not self.capacity_gb > 0.0:
            raise ValueError(
                f"join of node {self.nid} needs a positive capacity_gb, "
                f"got {self.capacity_gb!r}")


class FaultSchedule:
    """Immutable, stably time-sorted sequence of :class:`FaultEvent`s."""

    def __init__(self, events: Iterable[FaultEvent] = ()):
        events = list(events)
        for e in events:
            if not isinstance(e, FaultEvent):
                raise TypeError(f"not a FaultEvent: {e!r}")
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: e.t))  # stable: equal t keeps order

    # ------------------------------------------------------------- protocol
    def __iter__(self) -> Iterator[FaultEvent]:
        return iter(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    def __add__(self, other: "FaultSchedule") -> "FaultSchedule":
        if not isinstance(other, FaultSchedule):
            return NotImplemented
        return FaultSchedule(self.events + other.events)

    def __repr__(self) -> str:
        return f"FaultSchedule({len(self.events)} events)"

    def validate(self, nids: Iterable[int]) -> None:
        """Replay the membership protocol against an initial fleet; raise
        loudly (naming the node) on a leave of an absent node or a join of
        a present one — the same checks every engine applies at runtime."""
        active = set(int(n) for n in nids)
        for e in self.events:
            if e.kind == "leave":
                if e.nid not in active:
                    raise KeyError(
                        f"fault schedule: leave of unknown or inactive "
                        f"node {e.nid} at t={e.t:g}")
                active.discard(e.nid)
            else:
                if e.nid in active:
                    raise ValueError(
                        f"fault schedule: join of already-active node "
                        f"{e.nid} at t={e.t:g}")
                active.add(e.nid)

    # --------------------------------------------------------- constructors
    @classmethod
    def preemption_storm(cls, nodes: Sequence, t: float, frac: float = 0.5,
                         seed: int = 0, down_time: float = None,
                         window: float = 5.0) -> "FaultSchedule":
        """Spot-style preemption: ~``frac`` of the fleet receives a
        termination notice within ``window`` seconds after ``t``; with
        ``down_time`` each victim rejoins (same capacity) that long after
        its own departure.  Victims and jitter are seeded."""
        nodes = list(nodes)
        if not nodes:
            raise ValueError("preemption_storm needs a non-empty fleet")
        rng = np.random.default_rng(
            np.random.SeedSequence([int(seed), 0x570F]))
        k = min(max(int(round(frac * len(nodes))), 1), len(nodes))
        victims = sorted(
            int(v) for v in rng.choice(len(nodes), size=k, replace=False))
        events: List[FaultEvent] = []
        for vi in victims:
            node = nodes[vi]
            tl = float(t + rng.uniform(0.0, window))
            events.append(FaultEvent(tl, "leave", int(node.nid)))
            if down_time is not None:
                events.append(FaultEvent(tl + float(down_time), "join",
                                         int(node.nid),
                                         float(node.capacity_gb)))
        return cls(events)

    @classmethod
    def node_churn(cls, nodes: Sequence, rate: float, horizon: float,
                   seed: int = 0, mean_down: float = 60.0
                   ) -> "FaultSchedule":
        """Poisson node churn over ``[0, horizon)``: leave events arrive at
        ``rate`` per second, each taking down one uniformly-chosen up node,
        which rejoins after an Exp(``mean_down``) repair time.  Sequential
        seeded simulation — the down set evolves, so correlated multi-node
        outages emerge naturally at high rates."""
        if rate <= 0.0 or horizon <= 0.0:
            raise ValueError("node_churn needs rate > 0 and horizon > 0")
        rng = np.random.default_rng(
            np.random.SeedSequence([int(seed), 0xC4C4]))
        up = {int(n.nid): float(n.capacity_gb) for n in nodes}
        repairs: List[Tuple[float, int, float]] = []  # (t_join, nid, cap)
        events: List[FaultEvent] = []
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= horizon:
                break
            while repairs and repairs[0][0] <= t:
                _, nid, cap = heapq.heappop(repairs)
                up[nid] = cap
            if not up:
                continue
            nid = sorted(up)[int(rng.integers(len(up)))]
            cap = up.pop(nid)
            events.append(FaultEvent(t, "leave", nid))
            tj = t + float(rng.exponential(mean_down))
            heapq.heappush(repairs, (tj, nid, cap))
            events.append(FaultEvent(tj, "join", nid, cap))
        return cls(events)

    @classmethod
    def rack_failure(cls, nodes: Sequence, rack_of: Mapping[int, object],
                     rack, t: float, down_time: float = None
                     ) -> "FaultSchedule":
        """Correlated failure: every node of ``rack`` (one power/network
        domain, per the ``nid -> rack`` mapping) leaves at exactly ``t``;
        with ``down_time`` the whole rack rejoins together."""
        members = [n for n in nodes if rack_of.get(int(n.nid)) == rack]
        if not members:
            raise ValueError(f"rack_failure: no nodes in rack {rack!r}")
        events: List[FaultEvent] = []
        for node in members:
            events.append(FaultEvent(float(t), "leave", int(node.nid)))
        if down_time is not None:
            for node in members:
                events.append(FaultEvent(float(t) + float(down_time), "join",
                                         int(node.nid),
                                         float(node.capacity_gb)))
        return cls(events)
