"""Online memory monitoring: feeds live ML-job memory traces into KS+.

``MemoryMonitor`` samples the current process RSS (host-side job memory —
the quantity the paper's resource managers limit) during training/serving
steps; accumulated traces per job type become KS+ training data, closing
the loop: observe → segment → predict → allocate the next job.

``HBMFootprintModel`` provides the device-side analogue from dry-run
artifacts: predicted HBM envelope of a step as a function of the token
count (the ML-world 'input size'), so the elastic scheduler can bin-pack
jobs onto TPU slices before compiling anything.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core import KSPlus

__all__ = ["read_rss_gb", "MemoryMonitor", "HBMFootprintModel"]

_PAGE = os.sysconf("SC_PAGE_SIZE")


def read_rss_gb() -> float:
    with open("/proc/self/statm") as f:
        return int(f.read().split()[1]) * _PAGE / 2**30


@dataclasses.dataclass
class MemoryMonitor:
    """Collects (elapsed_s, rss_gb) samples for one logical job."""

    job_type: str
    input_size: float       # job 'input size' (e.g. tokens, GB of data)
    dt: float = 0.5
    _t0: float = dataclasses.field(default_factory=time.monotonic)
    _last: float = dataclasses.field(default=-1e9)
    samples: List[float] = dataclasses.field(default_factory=list)

    def sample(self, force: bool = False):
        now = time.monotonic()
        if force or now - self._last >= self.dt:
            self.samples.append(read_rss_gb())
            self._last = now

    def trace(self) -> np.ndarray:
        return np.asarray(self.samples if self.samples else [read_rss_gb()])


class HBMFootprintModel:
    """KS+ applied to device-memory envelopes of compiled jobs.

    Fit on (tokens, per-step HBM envelope) observations — e.g. from dry-run
    ``memory_analysis`` at several batch sizes — then predict the envelope
    for a new job size.  Architecture-agnostic (§Arch-applicability).
    """

    def __init__(self, k: int = 3):
        self.model = KSPlus(k=k)
        self._obs: List = []

    def observe(self, tokens: float, envelope_gb: np.ndarray, dt: float = 1.0):
        self._obs.append((tokens, np.asarray(envelope_gb, float), dt))

    def fit(self):
        mems = [o[1] for o in self._obs]
        dts = [o[2] for o in self._obs]
        inputs = [o[0] for o in self._obs]
        self.model.fit(mems, dts, inputs)
        return self

    def predict(self, tokens: float):
        return self.model.predict(tokens)
