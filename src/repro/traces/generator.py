"""Synthetic workflow-trace generator.

The paper evaluates on monitoring traces of two nf-core workflows (*eager*
and *sarek*).  Those traces are not redistributable here, so this module
synthesizes statistically faithful stand-ins:

* each *task family* is a sequence of phases whose durations scale
  differently with the aggregated input size (paper §II-B: "the execution
  time of the first process of a task might scale linearly with the input
  size, while the second process might always take a constant amount"),
* memory within a phase is flat or ramps linearly (data loading),
* timing noise is heteroscedastic — absolute deviation grows with runtime
  (paper Fig. 3),
* the *eager* family set reproduces the BWA profile of Fig. 1 (long ~5 GB
  phase, then a step to ~10.7 GB at ~80 % of the runtime; median peak
  ≈ 10.6 GB) and the workflow-level average peak ≈ 2.3 GB; *sarek* has more
  instances and a lower average peak ≈ 1.7 GB (Fig. 5).

Every execution is reproducible from ``(workflow seed, family, index)``.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["Phase", "TaskFamily", "Execution", "Workflow", "eager", "sarek"]


@dataclasses.dataclass(frozen=True)
class Phase:
    """One phase of a task's lifetime.

    duration = dur_base + dur_per_gb * I   (seconds, before timing noise)
    level    = mem_base + mem_per_gb * I   (GB, before memory noise)
    ramp:    'flat' holds the level; 'linear' ramps from the previous
             phase's level up to this one (e.g. loading an index).
    """

    dur_base: float
    dur_per_gb: float
    mem_base: float
    mem_per_gb: float
    ramp: str = "flat"


@dataclasses.dataclass(frozen=True)
class TaskFamily:
    name: str
    phases: Tuple[Phase, ...]
    input_median_gb: float
    input_sigma: float = 0.30       # lognormal shape of input sizes
    timing_sigma: float = 0.14      # base relative timing noise
    timing_growth: float = 0.010    # extra relative noise per sqrt(second)
    mem_sigma: float = 0.03         # per-execution multiplicative memory noise
    default_limit_gb: float = 8.0   # the workflow developers' static limit

    def sample_input(self, rng: np.random.Generator) -> float:
        return float(
            self.input_median_gb * np.exp(rng.normal(0.0, self.input_sigma))
        )

    def generate(self, input_gb: float, rng: np.random.Generator,
                 dt: float = 1.0) -> np.ndarray:
        """Memory trace (GB per ``dt`` sample) for one execution."""
        mem_factor = float(np.exp(rng.normal(0.0, self.mem_sigma)))
        samples: List[np.ndarray] = []
        prev_level = 0.05
        for ph in self.phases:
            dur = ph.dur_base + ph.dur_per_gb * input_gb
            # Heteroscedastic timing noise: grows with nominal duration.
            rel = self.timing_sigma + self.timing_growth * np.sqrt(max(dur, 0.0))
            dur *= float(np.exp(rng.normal(0.0, rel)))
            n = max(int(round(dur / dt)), 1)
            level = (ph.mem_base + ph.mem_per_gb * input_gb) * mem_factor
            if ph.ramp == "linear":
                seg = np.linspace(prev_level, level, n, endpoint=True)
            else:
                seg = np.full(n, level)
            samples.append(seg)
            prev_level = level
        mem = np.concatenate(samples)
        mem = mem * (1.0 + rng.normal(0.0, 0.004, mem.shape))  # sampling jitter
        return np.maximum(mem, 0.01)


@dataclasses.dataclass(frozen=True)
class Execution:
    family: str
    input_gb: float
    dt: float
    mem: np.ndarray  # (L,) GB

    @property
    def runtime(self) -> float:
        return len(self.mem) * self.dt

    @property
    def peak(self) -> float:
        return float(np.max(self.mem))


@dataclasses.dataclass
class Workflow:
    """A named set of task families with per-family instance counts."""

    name: str
    families: Dict[str, TaskFamily]
    instances: Dict[str, int]

    def generate(self, seed: int, dt: float = 1.0) -> Dict[str, List[Execution]]:
        out: Dict[str, List[Execution]] = {}
        for fname, fam in self.families.items():
            rng = np.random.default_rng(
                np.random.SeedSequence(
                    [seed, zlib.crc32(fname.encode()) % (2**31)])
            )
            execs = []
            for _ in range(self.instances[fname]):
                I = fam.sample_input(rng)
                execs.append(
                    Execution(fname, I, dt, fam.generate(I, rng, dt))
                )
            out[fname] = execs
        return out

    def split(self, seed: int, train_frac: float, dt: float = 1.0):
        """Seeded train/test split per family (paper: 10 seeds × 25/50/75 %)."""
        data = self.generate(seed, dt)
        rng = np.random.default_rng(np.random.SeedSequence([seed, 7]))
        train: Dict[str, List[Execution]] = {}
        test: Dict[str, List[Execution]] = {}
        for fname, execs in data.items():
            perm = rng.permutation(len(execs))
            n_train = max(int(round(train_frac * len(execs))), 2)
            idx_train = set(perm[:n_train].tolist())
            train[fname] = [e for i, e in enumerate(execs) if i in idx_train]
            test[fname] = [e for i, e in enumerate(execs) if i not in idx_train]
        return train, test


def _fam(name, phases, med, limit, **kw) -> TaskFamily:
    return TaskFamily(name=name, phases=tuple(phases), input_median_gb=med,
                      default_limit_gb=limit, **kw)


def eager(instances_per_family: int = 40) -> Workflow:
    """nf-core/eager-like workflow: 9 predicted task families (paper Fig. 8).

    BWA matches Fig. 1: ~80 % of the runtime at ≈5.1 GB, then a step to
    ≈10.7 GB; median input ≈4.7 GB gives a median peak ≈10.6 GB (Fig. 1a).
    """
    fams = [
        # Phase durations scale *differently* with input size (paper §II-B):
        # the alignment stream scales strongly, the merge/sort tail is nearly
        # constant — so the step position drifts across any fixed-fraction
        # segment grid as inputs vary.
        _fam("bwa", [
            Phase(25.0, 3.0, 2.40, 0.58, ramp="linear"),   # index load
            Phase(60.0, 65.0, 2.40, 0.58),                 # alignment (~I)
            Phase(140.0, 2.0, 5.05, 1.18),                 # merge (const)
            Phase(12.0, 1.0, 5.55, 1.32),                  # sort/flush spike
        ], med=4.7, limit=16.0),
        _fam("adapterremoval", [
            Phase(20.0, 2.0, 0.22, 0.030, ramp="linear"),
            Phase(30.0, 30.0, 0.30, 0.055),
        ], med=4.0, limit=4.0),
        _fam("samtools_filter", [
            Phase(20.0, 9.0, 0.18, 0.045),
        ], med=4.0, limit=4.0),
        _fam("samtools_flagstat", [
            Phase(12.0, 4.0, 0.10, 0.012),
        ], med=4.0, limit=2.0),
        _fam("mtnucratio", [
            Phase(8.0, 10.0, 0.12, 0.020),
            Phase(25.0, 0.5, 0.30, 0.060),                 # const-time tail
        ], med=3.0, limit=2.0),
        _fam("dedup", [
            Phase(15.0, 6.0, 0.60, 0.220, ramp="linear"),
            Phase(30.0, 1.0, 1.10, 0.360),                 # const-time hash
            Phase(8.0, 0.5, 1.45, 0.50),
        ], med=3.5, limit=8.0),
        _fam("damageprofiler", [
            Phase(18.0, 5.0, 0.90, 0.110),
        ], med=3.0, limit=4.0),
        _fam("preseq", [
            Phase(15.0, 5.0, 0.35, 0.070),
        ], med=3.0, limit=2.0),
        _fam("qualimap", [
            Phase(12.0, 12.0, 0.55, 0.100, ramp="linear"),
            Phase(45.0, 1.0, 1.25, 0.160),                 # const-time report
        ], med=3.5, limit=6.0),
    ]
    return Workflow("eager", {f.name: f for f in fams},
                    {f.name: instances_per_family for f in fams})


def sarek(instances_per_family: int = 70) -> Workflow:
    """nf-core/sarek-like workflow: more instances, lower avg peak (Fig. 5)."""
    fams = [
        _fam("fastqc", [Phase(20.0, 4.0, 0.30, 0.012)], med=3.0, limit=4.0),
        _fam("bwamem2", [
            Phase(20.0, 2.0, 1.80, 0.40, ramp="linear"),
            Phase(40.0, 55.0, 1.80, 0.40),                 # streaming (~I)
            Phase(110.0, 2.0, 3.40, 0.75),                 # merge (const)
            Phase(10.0, 0.5, 3.80, 0.85),
        ], med=3.2, limit=12.0),
        _fam("markduplicates", [
            Phase(12.0, 14.0, 0.80, 0.25, ramp="linear"),
            Phase(55.0, 1.0, 1.60, 0.45),                  # const-time dedup
        ], med=3.0, limit=8.0),
        _fam("baserecalibrator", [
            Phase(35.0, 9.0, 0.70, 0.16),
        ], med=3.0, limit=6.0),
        _fam("applybqsr", [
            Phase(28.0, 8.0, 0.55, 0.12),
        ], med=3.0, limit=4.0),
        _fam("haplotypecaller", [
            Phase(15.0, 22.0, 0.70, 0.14, ramp="linear"),  # scan (~I)
            Phase(70.0, 1.0, 1.05, 0.24),                  # assembly (const)
            Phase(9.0, 0.5, 1.40, 0.34),
        ], med=2.8, limit=8.0),
        _fam("strelka", [
            Phase(40.0, 12.0, 0.85, 0.18),
        ], med=2.8, limit=6.0),
        _fam("mosdepth", [
            Phase(15.0, 5.0, 0.25, 0.040),
        ], med=3.0, limit=2.0),
        _fam("vcftools", [
            Phase(12.0, 3.0, 0.15, 0.020),
        ], med=2.0, limit=2.0),
        _fam("snpeff", [
            Phase(10.0, 8.0, 0.90, 0.05, ramp="linear"),
            Phase(32.0, 1.0, 1.30, 0.10),                  # const-time annot
        ], med=2.5, limit=6.0),
    ]
    return Workflow("sarek", {f.name: f for f in fams},
                    {f.name: instances_per_family for f in fams})
