"""Workflow trace substrate: synthetic nf-core-like generators + ML job traces."""

from repro.traces.generator import (
    Execution,
    Phase,
    TaskFamily,
    Workflow,
    eager,
    sarek,
)

__all__ = ["Execution", "Phase", "TaskFamily", "Workflow", "eager", "sarek"]
