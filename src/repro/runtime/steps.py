"""jit-able train / serve step functions for every architecture.

``step_fn_for(cfg, kind)`` returns a pure function suitable for
``jax.jit(...).lower(**input_specs(...))`` — the single entry point used by
the trainer, the server, and the multi-pod dry-run.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step, forward_train, prefill
from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.model import _embed_inputs, _forward_seq, _head_logits
from repro.optim import adamw_update, cosine_schedule

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "make_encode_step", "step_fn_for"]


def make_train_step(cfg: ModelConfig, *, peak_lr: float = 3e-4,
                    warmup_steps: int = 100, total_steps: int = 10_000,
                    weight_decay: float = 0.1, clip_norm: float = 1.0):
    lr_fn = cosine_schedule(peak_lr=peak_lr, warmup_steps=warmup_steps,
                            total_steps=total_steps)

    def train_step(params, opt_state, batch, step):
        def loss_fn(p):
            return forward_train(p, cfg, batch)
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        lr = lr_fn(step)
        new_params, new_opt, stats = adamw_update(
            grads, opt_state, params, lr=lr,
            weight_decay=weight_decay, clip_norm=clip_norm)
        metrics = dict(metrics, lr=lr, **stats)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, capacity: Optional[int] = None):
    def prefill_step(params, batch):
        return prefill(params, cfg, batch, capacity=capacity)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, batch, cache, pos):
        return decode_step(params, cfg, batch, cache, pos)
    return serve_step


def make_encode_step(cfg: ModelConfig):
    """Encoder-only 'prefill': full forward to logits (e.g. HuBERT)."""
    def encode_step(params, batch):
        h = _embed_inputs(params, cfg, batch)
        positions = batch.get("positions")
        if positions is None:
            Bsz, S = h.shape[0], h.shape[1]
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (Bsz, S))
        h, _, _ = _forward_seq(params, cfg, h, positions, collect_cache=False)
        return _head_logits(params, cfg, h)
    return encode_step


def step_fn_for(cfg: ModelConfig, kind: str) -> Callable:
    if kind == "train":
        return make_train_step(cfg)
    if kind == "prefill":
        return make_prefill_step(cfg)
    if kind == "encode":
        return make_encode_step(cfg)
    if kind == "decode":
        return make_decode_step(cfg)
    raise ValueError(kind)
