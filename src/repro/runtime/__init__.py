"""Training / serving step construction."""

from repro.runtime.steps import (
    make_train_step,
    make_prefill_step,
    make_decode_step,
    make_encode_step,
    step_fn_for,
)

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "make_encode_step", "step_fn_for"]
