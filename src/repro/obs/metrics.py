"""Process-global metrics registry: counters, gauges, fixed-bucket
histograms, and sim-time-keyed series.

The registry is deliberately tiny — dict lookups and float adds under
one lock per metric (Series appends are lock-free: deque.append is
atomic under the GIL) — because its hot-path callers (the micro-batcher
flush, the admission drain, the fused event loop) record behind the same
``repro.obs.trace.enabled`` guard the tracer uses: with observability
off, no metric code runs at all.

Four metric kinds, all label-aware (labels are sorted kwarg tuples):

* :class:`Counter` — monotone ``inc``;
* :class:`Gauge` — last-write ``set``;
* :class:`Histogram` — **fixed buckets** chosen at creation (the
  cumulative-bucket layout Prometheus expects; no dynamic resizing on
  the hot path);
* :class:`Series` — bounded ``(t, value)`` append log keyed by *sim
  time*, for the per-engine-event wastage/utilization/starvation curves
  the online-selection work (ROADMAP items 2/5) reads back.

:func:`repro.obs.export.prometheus_text` renders the registry in
Prometheus text exposition format; :meth:`Registry.snapshot` gives the
JSON form the CI perf job uploads.
"""

from __future__ import annotations

import bisect
import math
import threading
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Series", "Registry",
           "REGISTRY", "counter", "gauge", "hist", "series",
           "LATENCY_BUCKETS_S", "COUNT_BUCKETS"]

# Default fixed buckets: request latencies (seconds, log-spaced) and
# batch/lane counts (pow2).
LATENCY_BUCKETS_S: Tuple[float, ...] = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0)
COUNT_BUCKETS: Tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 4096)


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()

    def snapshot(self) -> dict:
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._values: Dict[tuple, float] = {}

    def inc(self, n: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + n

    def value(self, **labels) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def snapshot(self) -> dict:
        with self._lock:
            return {"kind": self.kind,
                    "values": [{"labels": dict(k), "value": v}
                               for k, v in sorted(self._values.items())]}


class Gauge(_Metric):
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        super().__init__(name, help)
        self._values: Dict[tuple, float] = {}

    def set(self, v: float, **labels) -> None:
        with self._lock:
            self._values[_label_key(labels)] = float(v)

    def value(self, **labels) -> Optional[float]:
        return self._values.get(_label_key(labels))

    def snapshot(self) -> dict:
        with self._lock:
            return {"kind": self.kind,
                    "values": [{"labels": dict(k), "value": v}
                               for k, v in sorted(self._values.items())]}


class Histogram(_Metric):
    """Fixed upper-bound buckets (+inf implicit), cumulative on export."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = LATENCY_BUCKETS_S):
        super().__init__(name, help)
        ups = sorted(float(b) for b in buckets)
        if not ups or any(not math.isfinite(b) for b in ups):
            raise ValueError(f"histogram {name!r} needs finite fixed buckets")
        self.buckets: Tuple[float, ...] = tuple(ups)
        # per label-set: [bucket counts..., overflow], sum, count
        self._counts: Dict[tuple, List[int]] = {}
        self._sums: Dict[tuple, float] = {}

    def observe(self, v: float, **labels) -> None:
        key = _label_key(labels)
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            row = self._counts.get(key)
            if row is None:
                row = self._counts[key] = [0] * (len(self.buckets) + 1)
                self._sums[key] = 0.0
            row[i] += 1
            self._sums[key] += v

    def count(self, **labels) -> int:
        row = self._counts.get(_label_key(labels))
        return sum(row) if row else 0

    def snapshot(self) -> dict:
        with self._lock:
            out = []
            for key, row in sorted(self._counts.items()):
                cum, cums = 0, []
                for c in row:
                    cum += c
                    cums.append(cum)
                out.append({"labels": dict(key),
                            "buckets": list(self.buckets),
                            "cumulative": cums,  # last entry == count
                            "sum": self._sums[key],
                            "count": cum})
            return {"kind": self.kind, "values": out}


class Series(_Metric):
    """Bounded append-only ``(t, value)`` log keyed by sim time."""

    kind = "series"

    def __init__(self, name: str, help: str = "", maxlen: int = 65536):
        super().__init__(name, help)
        self._points: deque = deque(maxlen=int(maxlen))

    def append(self, t: float, v: float) -> None:
        # Lock-free: deque.append is atomic under the GIL, and this is
        # the one metric op hot enough (every fused event batch) for a
        # lock acquire/release to show up in the tracing-overhead gate.
        self._points.append((float(t), float(v)))

    def points(self) -> List[Tuple[float, float]]:
        return list(self._points)

    def __len__(self) -> int:
        return len(self._points)

    def snapshot(self) -> dict:
        return {"kind": self.kind, "points": self.points()}


class Registry:
    """Name -> metric, get-or-create with kind checking."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kw)
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help=help)

    def hist(self, name: str, help: str = "",
             buckets: Sequence[float] = LATENCY_BUCKETS_S) -> Histogram:
        return self._get(Histogram, name, help=help, buckets=buckets)

    def series(self, name: str, help: str = "",
               maxlen: int = 65536) -> Series:
        return self._get(Series, name, help=help, maxlen=maxlen)

    def metrics(self) -> Dict[str, _Metric]:
        with self._lock:
            return dict(self._metrics)

    def snapshot(self) -> dict:
        """JSON-able dump of every metric (the CI artifact payload)."""
        return {name: m.snapshot()
                for name, m in sorted(self.metrics().items())}

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()


# The process-global registry all hot-path instrumentation records into.
REGISTRY = Registry()

counter = REGISTRY.counter
gauge = REGISTRY.gauge
hist = REGISTRY.hist
series = REGISTRY.series
