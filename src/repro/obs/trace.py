"""Low-overhead span tracer built on the dispatch-tag seam.

One module-global tracer (mirroring :mod:`repro.analysis.contracts`'
module-global counters): :data:`enabled` is the master switch, and the
**disabled path is a single attribute check** — instrumented hot paths
are written as ::

    if _obs.enabled:
        with _obs.span("admission.drain") as sp:
            out = self._drain(now, lanes, select)
            sp.add(placed=len(out))
    ...

so a replay with tracing off allocates nothing and calls nothing (the
``unguarded-obs-in-hot-path`` lint rule enforces the guard).  Tracing
only ever *observes* — ``perf_counter_ns`` timestamps, counter reads —
so traced and untraced replays are bitwise-identical on placements,
retries and evictions (pinned by ``tests/test_obs.py``).

Three event sources feed one bounded ring buffer:

* **spans** — :func:`span` context managers on a thread-local stack;
  each close appends one complete ("X") event with its duration and
  whatever dispatch/compile activity it enclosed;
* **dispatch tags** — :func:`enable` installs a hook into
  :func:`repro.analysis.contracts.record_dispatch`, so every
  self-reported device-program launch (``admission.drain``,
  ``serve.batch``, ...) lands as an instant event *and* is attributed
  to the innermost open span on its thread;
* **compiles** — a lazily registered ``jax.monitoring`` listener (the
  same one-global-listener idiom as ``contracts``: jax has no
  per-listener unregister) turns backend-compile duration events into
  instant events and per-span compile counts.

Export/summary live in :mod:`repro.obs.export`.
"""

from __future__ import annotations

import contextlib
import threading
import time
from collections import deque
from typing import Deque, Dict, List, Optional

__all__ = ["enabled", "enable", "disable", "tracing", "span", "instant",
           "events", "clear", "Span", "DEFAULT_RING"]

DEFAULT_RING = 65536

# The master switch.  Hot paths read this ONE module attribute and do
# nothing else when it is False.
enabled: bool = False

_ring: Deque[dict] = deque(maxlen=DEFAULT_RING)
_tls = threading.local()
_compile_listener_registered = False
_epoch_ns = time.perf_counter_ns()  # trace-relative timestamp origin


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def _now_us() -> float:
    return (time.perf_counter_ns() - _epoch_ns) / 1e3


class Span:
    """One open span: name + start time + absorbed dispatch/compile
    activity.  Appended to the ring as a complete event on exit."""

    __slots__ = ("name", "args", "tid", "t0", "dispatches",
                 "compiles", "compile_us")

    def __init__(self, name: str, args: Optional[dict]):
        self.name = name
        self.args = args
        self.tid = threading.get_ident()
        self.t0 = 0.0
        self.dispatches: Optional[Dict[str, int]] = None
        self.compiles = 0
        self.compile_us = 0.0

    def add(self, **args) -> "Span":
        """Attach result-side attributes (e.g. ``placed=n``) post-entry."""
        if self.args is None:
            self.args = dict(args)
        else:
            self.args.update(args)
        return self

    def __enter__(self) -> "Span":
        _stack().append(self)
        self.t0 = _now_us()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = _now_us()
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        ev = {"ph": "X", "name": self.name, "ts": self.t0,
              "dur": t1 - self.t0, "tid": self.tid}
        if self.args:
            ev["args"] = self.args
        if self.dispatches:
            ev["dispatches"] = self.dispatches
        if self.compiles:
            ev["compiles"] = self.compiles
            ev["compile_us"] = self.compile_us
        _ring.append(ev)
        return False


class _NoopSpan:
    """Shared do-nothing span for defensive unguarded calls while
    tracing is off."""

    __slots__ = ()

    def add(self, **args) -> "_NoopSpan":
        return self

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NOOP = _NoopSpan()


def span(name: str, **args):
    """Open a span; use as a context manager.  No-op while disabled."""
    if not enabled:
        return _NOOP
    return Span(name, args or None)


def instant(name: str, **args) -> None:
    """Record one instant event.  No-op while disabled."""
    if not enabled:
        return
    ev = {"ph": "i", "name": name, "ts": _now_us(),
          "tid": threading.get_ident(), "s": "t"}
    if args:
        ev["args"] = args
    _ring.append(ev)


# ------------------------------------------------------------------ bridges
def _on_dispatch(tag: str, n: int) -> None:
    """contracts.record_dispatch hook: attribute to the innermost open
    span, or record a loose instant event when no span is open."""
    if not enabled:
        return
    st = _stack()
    if st:
        sp = st[-1]
        if sp.dispatches is None:
            sp.dispatches = {}
        sp.dispatches[tag] = sp.dispatches.get(tag, 0) + n
    else:
        _ring.append({"ph": "i", "name": f"dispatch:{tag}",
                      "ts": _now_us(), "tid": threading.get_ident(),
                      "s": "t"})


def _on_compile_duration(event: str, duration: float, **kw) -> None:
    if not enabled:
        return
    from repro.analysis.contracts import _COMPILE_EVENT
    if event != _COMPILE_EVENT:
        return
    us = duration * 1e6
    st = _stack()
    if st:
        sp = st[-1]
        sp.compiles += 1
        sp.compile_us += us
    else:
        _ring.append({"ph": "i", "name": "jax.compile", "ts": _now_us(),
                      "tid": threading.get_ident(), "s": "t",
                      "args": {"duration_us": us}})


def _ensure_compile_listener() -> None:
    global _compile_listener_registered
    if _compile_listener_registered:
        return
    from jax import monitoring
    monitoring.register_event_duration_secs_listener(_on_compile_duration)
    _compile_listener_registered = True


# ---------------------------------------------------------------- lifecycle
def enable(ring: Optional[int] = None) -> None:
    """Turn tracing on: install the dispatch hook and the compile
    listener, optionally resizing the ring (which clears it)."""
    global enabled, _ring
    from repro.analysis import contracts
    if ring is not None and ring != _ring.maxlen:
        _ring = deque(maxlen=int(ring))
    contracts._obs_dispatch_hook = _on_dispatch
    _ensure_compile_listener()
    enabled = True


def disable() -> None:
    """Turn tracing off (the ring's contents stay readable)."""
    global enabled
    from repro.analysis import contracts
    enabled = False
    contracts._obs_dispatch_hook = None


@contextlib.contextmanager
def tracing(ring: Optional[int] = None):
    """Scope-enable tracing; restores the previous on/off state on exit
    (events recorded inside stay in the ring for export)."""
    was = enabled
    enable(ring=ring)
    try:
        yield
    finally:
        if not was:
            disable()


def events() -> List[dict]:
    """Snapshot of the ring, oldest first."""
    return list(_ring)


def clear() -> None:
    _ring.clear()
