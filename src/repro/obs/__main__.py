"""CLI: ``python -m repro.obs summarize <trace>``.

Reads a trace exported by :mod:`repro.obs.export` (Chrome-trace JSON or
JSONL) and prints the per-tag time/dispatch/compile breakdown.
"""

from __future__ import annotations

import argparse
import sys

from repro.obs.export import read_events, summarize


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(prog="python -m repro.obs")
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_sum = sub.add_parser(
        "summarize",
        help="per-tag time/dispatch/compile breakdown of a trace file")
    p_sum.add_argument("trace",
                       help="Chrome-trace JSON or JSONL event log")
    args = parser.parse_args(argv)
    if args.cmd == "summarize":
        print(summarize(read_events(args.trace)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
