"""Timeline and metrics export: Chrome-trace JSON, JSONL, Prometheus.

Writers over the tracer ring (:func:`repro.obs.trace.events`) and the
metrics registry (:data:`repro.obs.metrics.REGISTRY`):

* :func:`chrome_trace` / :func:`write_chrome_trace` — the Chrome trace
  event format (JSON object with a ``traceEvents`` array), loadable by
  Perfetto / ``chrome://tracing``;
* :func:`write_jsonl` / :func:`read_events` — one event per line, the
  append-friendly log form; ``read_events`` round-trips both formats;
* :func:`prometheus_text` — text exposition of the metrics registry
  (counters, gauges, cumulative-bucket histograms; series are exported
  as their last point, full curves ride the JSON snapshot);
* :func:`summarize` — the per-tag time/dispatch/compile breakdown
  behind ``python -m repro.obs summarize <trace>``.
"""

from __future__ import annotations

import json
import os
from collections import defaultdict
from typing import Dict, List, Optional

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace

__all__ = ["chrome_trace", "write_chrome_trace", "write_jsonl",
           "read_events", "prometheus_text", "write_prometheus",
           "metrics_snapshot", "write_metrics_snapshot", "summarize"]


def _events_or_ring(events: Optional[List[dict]]) -> List[dict]:
    return _trace.events() if events is None else list(events)


# ------------------------------------------------------------- chrome trace
def chrome_trace(events: Optional[List[dict]] = None) -> dict:
    """Chrome trace event format: ``{"traceEvents": [...]}``.

    Span dicts already carry the Chrome keys (``ph``/``name``/``ts``/
    ``dur``/``tid``); this adds the ``pid`` and folds the absorbed
    dispatch/compile attribution into ``args`` so Perfetto shows it in
    the span detail pane.
    """
    pid = os.getpid()
    out = []
    for ev in _events_or_ring(events):
        ce = {"ph": ev["ph"], "name": ev["name"], "ts": ev["ts"],
              "pid": pid, "tid": ev.get("tid", 0), "cat": "repro"}
        if ev["ph"] == "X":
            ce["dur"] = ev.get("dur", 0.0)
        if ev["ph"] == "i":
            ce["s"] = ev.get("s", "t")
        args = dict(ev.get("args") or {})
        if ev.get("dispatches"):
            args["dispatches"] = ev["dispatches"]
        if ev.get("compiles"):
            args["compiles"] = ev["compiles"]
            args["compile_us"] = ev.get("compile_us", 0.0)
        if args:
            ce["args"] = args
        out.append(ce)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str,
                       events: Optional[List[dict]] = None) -> int:
    doc = chrome_trace(events)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])


# -------------------------------------------------------------------- jsonl
def write_jsonl(path: str, events: Optional[List[dict]] = None) -> int:
    evs = _events_or_ring(events)
    with open(path, "w", encoding="utf-8") as f:
        for ev in evs:
            f.write(json.dumps(ev) + "\n")
    return len(evs)


def read_events(path: str) -> List[dict]:
    """Load events back from either export format (the summarize CLI's
    round-trip): a Chrome-trace JSON object or a JSONL event log."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        # Multiple documents: a JSONL event log.
        return [json.loads(line) for line in text.splitlines() if line.strip()]
    if isinstance(doc, dict) and "traceEvents" in doc:
        # Chrome trace: fold args back into the ring shape.
        out = []
        for ce in doc["traceEvents"]:
            ev = dict(ce)
            args = dict(ev.pop("args", None) or {})
            if "dispatches" in args:
                ev["dispatches"] = args.pop("dispatches")
            if "compiles" in args:
                ev["compiles"] = args.pop("compiles")
                ev["compile_us"] = args.pop("compile_us", 0.0)
            if args:
                ev["args"] = args
            out.append(ev)
        return out
    # A one-line JSONL file parses as a single JSON object.
    return [doc] if isinstance(doc, dict) else list(doc)


# --------------------------------------------------------------- prometheus
def _prom_name(name: str) -> str:
    out = "".join(c if c.isalnum() or c == "_" else "_" for c in name)
    return out if not out[:1].isdigit() else "_" + out


def _prom_labels(labels: dict, extra: Optional[dict] = None) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    body = ",".join(f'{_prom_name(str(k))}="{v}"'
                    for k, v in sorted(items.items()))
    return "{" + body + "}"


def prometheus_text(registry: Optional[_metrics.Registry] = None) -> str:
    """Prometheus text exposition (version 0.0.4) of the registry."""
    registry = registry or _metrics.REGISTRY
    lines: List[str] = []
    for name, m in sorted(registry.metrics().items()):
        pname = _prom_name(name)
        snap = m.snapshot()
        if m.kind in ("counter", "gauge"):
            lines.append(f"# TYPE {pname} {m.kind}")
            if m.help:
                lines.append(f"# HELP {pname} {m.help}")
            for row in snap["values"]:
                lines.append(
                    f"{pname}{_prom_labels(row['labels'])} {row['value']:g}")
        elif m.kind == "histogram":
            lines.append(f"# TYPE {pname} histogram")
            if m.help:
                lines.append(f"# HELP {pname} {m.help}")
            for row in snap["values"]:
                for ub, c in zip(row["buckets"] + [float("inf")],
                                 row["cumulative"]):
                    le = "+Inf" if ub == float("inf") else f"{ub:g}"
                    lines.append(
                        f"{pname}_bucket"
                        f"{_prom_labels(row['labels'], {'le': le})} {c}")
                lines.append(
                    f"{pname}_sum{_prom_labels(row['labels'])} "
                    f"{row['sum']:g}")
                lines.append(
                    f"{pname}_count{_prom_labels(row['labels'])} "
                    f"{row['count']}")
        elif m.kind == "series":
            # Prometheus has no native series type; expose the last
            # point as a gauge (full curves live in the JSON snapshot).
            pts = snap["points"]
            if pts:
                lines.append(f"# TYPE {pname} gauge")
                t, v = pts[-1]
                lines.append(
                    f"{pname}{_prom_labels({'sim_t': f'{t:g}'})} {v:g}")
    return "\n".join(lines) + "\n"


def write_prometheus(path: str,
                     registry: Optional[_metrics.Registry] = None) -> None:
    with open(path, "w", encoding="utf-8") as f:
        f.write(prometheus_text(registry))


def metrics_snapshot(registry: Optional[_metrics.Registry] = None) -> dict:
    return (registry or _metrics.REGISTRY).snapshot()


def write_metrics_snapshot(path: str,
                           registry: Optional[_metrics.Registry] = None
                           ) -> None:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(metrics_snapshot(registry), f, indent=1)


# ---------------------------------------------------------------- summarize
def summarize(events: Optional[List[dict]] = None) -> str:
    """Per-tag breakdown: span time, dispatch counts, compiles.

    One row per span name (count / total / mean / max milliseconds plus
    the dispatch tags and compiles absorbed by those spans), then named
    instant events grouped by name, then one row per dispatch tag seen
    *outside* any span — the same accounting whether the events come
    from the live ring or a file round-trip.
    """
    evs = _events_or_ring(events)
    spans: Dict[str, dict] = defaultdict(
        lambda: {"n": 0, "total_us": 0.0, "max_us": 0.0,
                 "dispatches": defaultdict(int), "compiles": 0,
                 "compile_us": 0.0})
    loose: Dict[str, int] = defaultdict(int)
    instants: Dict[str, int] = defaultdict(int)
    compiles_loose = 0
    for ev in evs:
        if ev["ph"] == "X":
            row = spans[ev["name"]]
            row["n"] += 1
            dur = float(ev.get("dur", 0.0))
            row["total_us"] += dur
            row["max_us"] = max(row["max_us"], dur)
            for tag, n in (ev.get("dispatches") or {}).items():
                row["dispatches"][tag] += n
            row["compiles"] += int(ev.get("compiles", 0))
            row["compile_us"] += float(ev.get("compile_us", 0.0))
        elif ev["ph"] == "i":
            name = ev["name"]
            if name.startswith("dispatch:"):
                loose[name[len("dispatch:"):]] += 1
            elif name == "jax.compile":
                compiles_loose += 1
            else:
                instants[name] += 1

    head = (f"{'span':<28} {'count':>7} {'total_ms':>10} {'mean_ms':>9} "
            f"{'max_ms':>9} {'compiles':>8}  dispatches")
    lines = [head, "-" * len(head)]
    for name in sorted(spans, key=lambda n: -spans[n]["total_us"]):
        row = spans[name]
        disp = " ".join(f"{t}={c}" for t, c in sorted(
            row["dispatches"].items())) or "-"
        mean = row["total_us"] / row["n"] / 1e3
        lines.append(
            f"{name:<28} {row['n']:>7} {row['total_us'] / 1e3:>10.2f} "
            f"{mean:>9.3f} {row['max_us'] / 1e3:>9.2f} "
            f"{row['compiles']:>8}  {disp}")
    if not spans:
        lines.append("(no spans recorded)")
    if instants:
        lines.append("")
        lines.append("instants:")
        for name in sorted(instants):
            lines.append(f"  {name:<33} {instants[name]:>7}")
    if loose or compiles_loose:
        lines.append("")
        lines.append("outside any span:")
        for tag in sorted(loose):
            lines.append(f"  dispatch:{tag:<24} {loose[tag]:>7}")
        if compiles_loose:
            lines.append(f"  jax.compile{'':<22} {compiles_loose:>7}")
    n_instant = sum(1 for ev in evs if ev["ph"] == "i")
    lines.append("")
    lines.append(f"{len(evs)} events ({sum(r['n'] for r in spans.values())} "
                 f"spans, {n_instant} instants)")
    return "\n".join(lines)
