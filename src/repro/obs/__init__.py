"""repro.obs — engine-wide tracing, metrics, and timeline export.

Built on the dispatch-tag seam (:mod:`repro.analysis.contracts`): spans
absorb ``record_dispatch`` tags and ``jax.monitoring`` compile events,
the metrics registry collects serve/drain/engine counters, and
:mod:`repro.obs.export` writes Chrome-trace/Perfetto JSON, JSONL logs,
and Prometheus text.  Everything is off by default; the disabled hot
path is a single ``trace.enabled`` attribute check and tracing never
perturbs placements (see ``tests/test_obs.py``).

Usage::

    from repro import obs

    with obs.tracing():
        sim.run(jobs, retry, trace=True)
    obs.write_chrome_trace("trace.perfetto.json")
    print(obs.summarize())
"""

from repro.obs import export, metrics, trace
from repro.obs.export import (chrome_trace, metrics_snapshot,
                              prometheus_text, read_events, summarize,
                              write_chrome_trace, write_jsonl,
                              write_metrics_snapshot, write_prometheus)
from repro.obs.metrics import (REGISTRY, Counter, Gauge, Histogram,
                               Registry, Series, counter, gauge, hist,
                               series)
from repro.obs.trace import (Span, clear, disable, enable, events,
                             instant, span, tracing)

__all__ = [
    "trace", "metrics", "export",
    # trace
    "enable", "disable", "tracing", "span", "instant", "events", "clear",
    "Span",
    # metrics
    "REGISTRY", "Registry", "Counter", "Gauge", "Histogram", "Series",
    "counter", "gauge", "hist", "series",
    # export
    "chrome_trace", "write_chrome_trace", "write_jsonl", "read_events",
    "prometheus_text", "write_prometheus", "metrics_snapshot",
    "write_metrics_snapshot", "summarize",
]
