"""Mamba2 (state-space duality) mixer: chunked SSD + causal conv + decode.

The chunked SSD here is the pure-jnp reference form of the algorithm
(quadratic within chunks, decay-weighted state passing across chunks) and
doubles as the oracle for the Pallas kernel in ``repro.kernels.ssd``.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm

__all__ = ["segsum", "ssd_chunked", "ssd_decode_step", "causal_conv1d",
           "conv_decode_step", "mamba2_mixer", "mamba2_decode"]


def segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular segment sums: out[..., i, j] = sum_{j < s <= i} a_s."""
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    out = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    X: jnp.ndarray,   # (B, S, H, P)  — inputs pre-multiplied by dt
    A: jnp.ndarray,   # (B, S, H)     — log-decay increments (dt * A, A < 0)
    Bm: jnp.ndarray,  # (B, S, G, N)
    Cm: jnp.ndarray,  # (B, S, G, N)
    chunk: int,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, P, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (Y (B,S,H,P), final_state (B,H,P,N))."""
    b, l, h, p = X.shape
    g, n = Bm.shape[2], Bm.shape[3]
    pad = (-l) % chunk
    if pad:  # zero-pad: X=0 adds nothing, A=0 keeps the state (exp(0)=1)
        X = jnp.pad(X, ((0, 0), (0, pad), (0, 0), (0, 0)))
        A = jnp.pad(A, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        out_len = l
        l = l + pad
    else:
        out_len = l
    nc = l // chunk
    rep = h // g

    Xc = X.reshape(b, nc, chunk, h, p)
    Ac = A.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,l)
    Bc = jnp.repeat(Bm.reshape(b, nc, chunk, g, n), rep, axis=3)
    Cc = jnp.repeat(Cm.reshape(b, nc, chunk, g, n), rep, axis=3)

    A_cs = jnp.cumsum(Ac, axis=-1)                        # (b,h,c,l)
    L = jnp.exp(segsum(Ac))                               # (b,h,c,l,l)
    Y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp", Cc, Bc, L, Xc)

    decay_states = jnp.exp(A_cs[..., -1:] - A_cs)         # (b,h,c,l)
    states = jnp.einsum("bclhn,bhcl,bclhp->bchpn", Bc, decay_states, Xc)

    init = (jnp.zeros((b, 1, h, p, n), X.dtype) if initial_state is None
            else initial_state[:, None].astype(X.dtype))
    states = jnp.concatenate([init, states], axis=1)      # (b,c+1,h,p,n)
    chunk_decay = jnp.exp(segsum(jnp.pad(A_cs[..., -1], ((0, 0), (0, 0), (1, 0)))))
    new_states = jnp.einsum("bhzc,bchpn->bzhpn", chunk_decay, states)
    states, final = new_states[:, :-1], new_states[:, -1]

    state_decay_out = jnp.exp(A_cs)                       # (b,h,c,l)
    Y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp", Cc, states, state_decay_out)
    Y = (Y_diag + Y_off).reshape(b, l, h, p)[:, :out_len]
    return Y, final


def ssd_decode_step(
    state: jnp.ndarray,  # (B, H, P, N)
    x: jnp.ndarray,      # (B, H, P)   — NOT pre-multiplied by dt
    dt: jnp.ndarray,     # (B, H)
    A: jnp.ndarray,      # (H,)
    Bm: jnp.ndarray,     # (B, G, N)
    Cm: jnp.ndarray,     # (B, G, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One recurrent step. Returns (y (B,H,P), new_state)."""
    b, h, p, n = state.shape
    g = Bm.shape[1]
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B, H, N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dA = jnp.exp(dt * A[None, :])     # (B, H)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh, x)
    new_state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bhn,bhpn->bhp", Ch, new_state)
    return y, new_state


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  init_state: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv, kernel size KW. x: (B,S,C), w: (C,KW), b: (C,)."""
    kw = w.shape[1]
    if init_state is None:
        xp = jnp.pad(x, ((0, 0), (kw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    S = x.shape[1]
    y = sum(xp[:, i:i + S, :] * w[None, None, :, i].astype(x.dtype)
            for i in range(kw))
    return y + b.astype(x.dtype)[None, None, :]


def conv_decode_step(conv_state: jnp.ndarray, x_new: jnp.ndarray,
                     w: jnp.ndarray, b: jnp.ndarray):
    """conv_state: (B, KW-1, C); x_new: (B, C). Returns (y (B,C), new_state)."""
    kw = w.shape[1]
    full = jnp.concatenate([conv_state.astype(x_new.dtype),
                            x_new[:, None, :]], axis=1)  # (B, KW, C)
    y = jnp.einsum("bkc,ck->bc", full, w.astype(x_new.dtype)) \
        + b.astype(x_new.dtype)[None, :]
    return y, full[:, 1:, :]


def _split_zxbcdt(zxbcdt, d_inner, conv_dim, n_heads):
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_dim]
    dt = zxbcdt[..., d_inner + conv_dim:]
    assert dt.shape[-1] == n_heads
    return z, xBC, dt


def mamba2_mixer(p: Dict[str, jnp.ndarray], cfg, u: jnp.ndarray,
                 initial_state: Optional[jnp.ndarray] = None,
                 ssd_impl=ssd_chunked):
    """Full Mamba2 block mix for train/prefill.  u: (B, S, d_model).

    Returns (out, final_ssm_state, conv_tail) where conv_tail is the last
    KW-1 pre-conv inputs — the conv state needed to continue decoding.
    """
    B_, S, _ = u.shape
    din, H, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_headdim
    G, N = cfg.ssm_groups, cfg.ssm_state
    conv_dim = din + 2 * G * N
    dtype = u.dtype

    zxbcdt = jnp.einsum("bsd,dz->bsz", u, p["in_proj"].astype(dtype))
    z, xBC, dt_raw = _split_zxbcdt(zxbcdt, din, conv_dim, H)
    kw = p["conv_w"].shape[1]
    conv_tail = xBC[:, -(kw - 1):, :] if S >= kw - 1 else jnp.pad(
        xBC, ((0, 0), (kw - 1 - S, 0), (0, 0)))
    xBC = jax.nn.silu(causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    x = xBC[..., :din].reshape(B_, S, H, P)
    Bm = xBC[..., din:din + G * N].reshape(B_, S, G, N)
    Cm = xBC[..., din + G * N:].reshape(B_, S, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))              # (H,)

    X = (x.astype(jnp.float32) * dt[..., None]).astype(dtype)
    Adt = (dt * A[None, None, :]).astype(dtype)
    Y, final = ssd_impl(X, Adt, Bm, Cm, cfg.ssm_chunk,
                        initial_state=initial_state)
    Y = Y + p["D"].astype(dtype)[None, None, :, None] * x
    y = Y.reshape(B_, S, din)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(dtype))
    return out, final, conv_tail


def mamba2_decode(p: Dict[str, jnp.ndarray], cfg, u: jnp.ndarray,
                  conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """One-token decode.  u: (B, 1, d_model).

    Returns (out (B,1,d), new_conv_state, new_ssm_state).
    """
    B_ = u.shape[0]
    din, H, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_headdim
    G, N = cfg.ssm_groups, cfg.ssm_state
    dtype = u.dtype

    zxbcdt = jnp.einsum("bd,dz->bz", u[:, 0], p["in_proj"].astype(dtype))
    z, xBC, dt_raw = _split_zxbcdt(zxbcdt, din, din + 2 * G * N, H)
    xBC, new_conv = conv_decode_step(conv_state, xBC, p["conv_w"], p["conv_b"])
    xBC = jax.nn.silu(xBC)
    x = xBC[..., :din].reshape(B_, H, P)
    Bm = xBC[..., din:din + G * N].reshape(B_, G, N)
    Cm = xBC[..., din + G * N:].reshape(B_, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, new_state = ssd_decode_step(
        ssm_state.astype(jnp.float32), x.astype(jnp.float32), dt, A,
        Bm.astype(jnp.float32), Cm.astype(jnp.float32))
    y = y.astype(dtype) + p["D"].astype(dtype)[None, :, None] * x
    y = y.reshape(B_, din)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"].astype(dtype))
    return out[:, None, :], new_conv, new_state.astype(ssm_state.dtype)
