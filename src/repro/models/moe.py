"""Top-k Mixture-of-Experts with sort-based token dispatch.

Dispatch is O(T·k log T·k) sort + gathers — *not* the GShard one-hot einsum,
whose dispatch FLOPs (T·E·C·d) would dwarf the expert compute itself at our
shapes.  Tokens are routed to a capacity-bounded per-expert buffer
``(E, C, d)``; the batched expert matmuls are plain einsums so the lowered
FLOPs equal the *active* parameter count (top-k experts per token), which is
what the 6·N_active·D roofline accounting expects.

Expert weights carry the ``expert`` logical axis and are sharded over the
``model`` mesh axis (expert parallelism); GSPMD turns the data→expert
scatter/gather into all-to-alls on the token buffer.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.launch.partitioning import (
    current_batch_axes,
    current_batch_shards,
    current_mesh,
    logical_constraint,
)

__all__ = ["moe_block", "moe_block_local", "moe_capacity"]


def _local_dispatch(xl: jnp.ndarray, router_w, topk: int, C: int):
    """Per-device token routing (plain local ops; used under shard_map).

    xl: (Tl, d) local tokens.  Returns (buf (E,C,d), slot, rows, gate, keep,
    probs) — everything the combine step and aux losses need.
    """
    Tl, d = xl.shape
    E = router_w.shape[1]
    logits = jnp.einsum("td,de->te", xl, router_w.astype(xl.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, topk)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    flat_e = expert_idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    rows = order // topk
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(Tl * topk, dtype=jnp.int32) - starts[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + jnp.minimum(rank, C - 1), E * C)
    gathered = jnp.take(xl, rows, axis=0)
    buf = jnp.zeros((E * C, d), xl.dtype).at[slot].set(gathered, mode="drop")
    gate_sorted = gate_vals.reshape(-1)[order]
    return (buf.reshape(E, C, d), slot, rows, gate_sorted, keep,
            probs, counts)


def _local_combine(out_buf, slot, rows, gate_sorted, keep, Tl: int):
    """Per-device combine: scatter expert outputs back to local tokens."""
    E_C, d = out_buf.reshape(-1, out_buf.shape[-1]).shape
    out_flat = out_buf.reshape(E_C, d)
    picked = jnp.take(out_flat, jnp.minimum(slot, E_C - 1), axis=0)
    contrib = picked * (gate_sorted * keep).astype(out_flat.dtype)[:, None]
    return jnp.zeros((Tl, d), out_flat.dtype).at[rows].add(contrib)


def moe_capacity(num_tokens: int, n_experts: int, topk: int,
                 capacity_factor: float) -> int:
    c = int(num_tokens * topk / n_experts * capacity_factor)
    return max(-(-c // 8) * 8, 8)  # round up to 8 for tiling


def moe_block(
    x: jnp.ndarray,             # (B, S, d)
    router_w: jnp.ndarray,      # (d, E)
    w_gate: jnp.ndarray,        # (E, d, ff)
    w_up: jnp.ndarray,          # (E, d, ff)
    w_down: jnp.ndarray,        # (E, ff, d)
    *,
    topk: int,
    capacity_factor: float = 1.25,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Returns (output (B,S,d), aux dict with load-balance loss terms)."""
    B, S, d = x.shape
    E = router_w.shape[1]
    T = B * S
    C = moe_capacity(T, E, topk, capacity_factor)
    xf = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xf, router_w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)          # (T, E) f32
    gate_vals, expert_idx = jax.lax.top_k(probs, topk)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch -------------------------------------------
    flat_e = expert_idx.reshape(-1)                  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)         # (T*k,)
    sorted_e = flat_e[order]
    tok_of = order // topk                           # source token per slot
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts             # (E,)
    rank = jnp.arange(T * topk, dtype=jnp.int32) - starts[sorted_e]
    keep = rank < C                                  # capacity dropping
    slot = sorted_e * C + jnp.minimum(rank, C - 1)
    slot = jnp.where(keep, slot, E * C)              # OOB -> dropped

    gathered = jnp.take(xf, tok_of, axis=0)          # (T*k, d)
    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(gathered, mode="drop")
    buf = buf.reshape(E, C, d)
    buf = logical_constraint(buf, "expert", None, None)

    # ---- expert computation (active FLOPs only) ------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(x.dtype))
    act = jax.nn.silu(g) * u
    out_buf = jnp.einsum("ecf,efd->ecd", act, w_down.astype(x.dtype))
    out_flat = out_buf.reshape(E * C, d)

    # ---- combine back ---------------------------------------------------
    picked = jnp.take(out_flat, jnp.minimum(slot, E * C - 1), axis=0)
    gate_sorted = gate_vals.reshape(-1)[order]
    contrib = picked * (gate_sorted * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((T, d), x.dtype).at[tok_of].add(contrib)

    # Switch-style load-balance aux loss (computed in f32).
    frac_tokens = jnp.mean(
        (jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0)) / (T * topk))
    me = jnp.mean(probs, axis=0)                     # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(1.0) / (T * topk)
    aux_loss = E * jnp.sum(me * ce)
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (T * topk)
    aux = dict(moe_aux_loss=aux_loss, moe_dropped_frac=dropped,
               moe_frac_tokens=frac_tokens)
    return y.reshape(B, S, d), aux


def moe_block_local(
    x: jnp.ndarray,             # (B, S, d)
    router_w: jnp.ndarray,      # (d, E)
    w_gate: jnp.ndarray,        # (E, d, ff)
    w_up: jnp.ndarray,          # (E, d, ff)
    w_down: jnp.ndarray,        # (E, ff, d)
    *,
    topk: int,
    capacity_factor: float = 1.25,
    n_shards: int = 0,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Shard-local MoE dispatch (beyond-paper §Perf optimization).

    :func:`moe_block` sorts the *global* token stream, so under GSPMD every
    device materializes the full (T, d) activation — an all-gather whose
    traffic dwarfs the expert compute.  Here every data shard routes only
    its local tokens (leading ``n_shards`` axis stays sharded on the batch
    axes; per-shard expert capacity), and only the capacity-bounded expert
    buffer crosses the network: the resharding

        (shard, E, C_local, d): batch-sharded  →  expert-sharded

    lowers to the canonical MoE all-to-all, and back after the expert
    matmuls.  Collective volume per layer drops from O(T·d · L) gathers to
    2 × T·topk·d / #shards per chip — the textbook EP exchange.
    """
    B, S, d = x.shape
    E = router_w.shape[1]
    if n_shards <= 0:
        n_shards = current_batch_shards()
    T = B * S
    if T % n_shards:
        n_shards = 1
    Tl = T // n_shards
    C = moe_capacity(Tl, E, topk, capacity_factor)

    mesh = current_mesh()
    if mesh is not None and n_shards > 1:
        # GSPMD's gather/scatter partitioner cannot prove the dispatch
        # local (it all-gathers operand + broadcast u32 indices — measured
        # ~1 TiB/layer on olmoe); shard_map makes locality explicit.
        return _moe_shardmap(x, router_w, w_gate, w_up, w_down, mesh,
                             topk=topk, C=C, n_shards=n_shards)

    xs = x.reshape(n_shards, Tl, d)
    xs = logical_constraint(xs, "batch", None, None)
    s_idx = jnp.arange(n_shards)

    logits = jnp.einsum("std,de->ste", xs, router_w.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)           # (s, Tl, E) f32
    gate_vals, expert_idx = jax.lax.top_k(probs, topk)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    flat_e = expert_idx.reshape(n_shards, Tl * topk)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    tok_of = order // topk                            # (s, Tl*k)
    counts = jnp.zeros((n_shards, E), jnp.int32).at[
        s_idx[:, None], flat_e].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts     # (s, E)
    rank = jnp.arange(Tl * topk, dtype=jnp.int32)[None, :] - \
        jnp.take_along_axis(starts, sorted_e, axis=-1)
    keep = rank < C
    slot = sorted_e * C + jnp.minimum(rank, C - 1)
    slot = jnp.where(keep, slot, E * C)

    # Flat-row gather/scatter: take_along_axis with a trailing broadcast
    # materializes (s, Tl·k, d)-shaped u32 *index* tensors that GSPMD then
    # all-gathers (measured: 1 TiB/layer on olmoe).  Row-id forms keep the
    # indices (s·Tl·k,)-shaped.
    xf_flat = xs.reshape(n_shards * Tl, d)
    rows = (s_idx[:, None] * Tl + tok_of).reshape(-1)
    gathered = jnp.take(xf_flat, rows, axis=0)        # (s*Tl*k, d)
    stride = E * C + 1                                # +1 = per-shard drop slot
    flat_slot = (s_idx[:, None] * stride + slot).reshape(-1)
    buf = jnp.zeros((n_shards * stride, d), x.dtype).at[
        flat_slot].set(gathered, mode="drop")
    buf = buf.reshape(n_shards, stride, d)[:, :E * C]
    buf = buf.reshape(n_shards, E, C, d)
    # Keep the buffer batch-sharded (and replicated over the model axis):
    # the expert einsums below contract with E-sharded weights, so GSPMD
    # partitions them over E by *slicing* the locally-replicated buffer
    # (free) and the combine becomes a partial-sum all-reduce of (Tl, d) —
    # no token gathers.
    buf = logical_constraint(buf, "batch", None, None, None)

    g = jnp.einsum("secd,edf->secf", buf, w_gate.astype(x.dtype))
    u = jnp.einsum("secd,edf->secf", buf, w_up.astype(x.dtype))
    act = jax.nn.silu(g) * u
    out_buf = jnp.einsum("secf,efd->secd", act, w_down.astype(x.dtype))
    out_flat = out_buf.reshape(n_shards * E * C, d)

    pick_rows = (s_idx[:, None] * (E * C)
                 + jnp.minimum(slot, E * C - 1)).reshape(-1)
    picked = jnp.take(out_flat, pick_rows, axis=0)    # (s*Tl*k, d)
    gate_sorted = jnp.take_along_axis(
        gate_vals.reshape(n_shards, Tl * topk), order, axis=-1)
    contrib = picked * (gate_sorted * keep).astype(
        x.dtype).reshape(-1)[:, None]
    y = jnp.zeros((n_shards * Tl, d), x.dtype).at[rows].add(contrib)
    y = y.reshape(n_shards, Tl, d)
    y = logical_constraint(y, "batch", None, None)

    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.sum(counts, axis=0).astype(jnp.float32) / (T * topk)
    aux_loss = E * jnp.sum(me * ce)
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (T * topk)
    aux = dict(moe_aux_loss=aux_loss, moe_dropped_frac=dropped,
               moe_frac_tokens=jnp.mean(ce))
    return y.reshape(B, S, d), aux


def _moe_shardmap(x, router_w, w_gate, w_up, w_down, mesh, *,
                  topk: int, C: int, n_shards: int):
    """shard_map dispatch/combine + GSPMD expert compute.

    Dispatch and combine run as explicitly-local per-device programs over
    the batch axes (replicated over ``model``); only the capacity-bounded
    expert buffer participates in cross-device communication, via the
    E-sharded expert einsums whose partial results reduce over ``model``.
    """
    B, S, d = x.shape
    E = router_w.shape[1]
    batch_axes = current_batch_axes() or tuple(
        a for a in ("pod", "data") if a in mesh.axis_names)
    xs = x.reshape(n_shards, (B * S) // n_shards, d)
    Tl = xs.shape[1]

    disp = shard_map(
        lambda xl, rw: jax.tree.map(
            lambda a: a[None], _local_dispatch(xl[0], rw, topk, C)),
        mesh=mesh,
        in_specs=(P(batch_axes, None, None), P(None, None)),
        out_specs=P(batch_axes),
        check_rep=False,
    )
    buf, slot, rows, gate_sorted, keep, probs, counts = disp(xs, router_w)
    # buf: (n_shards, E, C, d) batch-sharded, replicated over model.
    buf = logical_constraint(buf, "batch", None, None, None)

    g = jnp.einsum("secd,edf->secf", buf, w_gate.astype(x.dtype))
    u = jnp.einsum("secd,edf->secf", buf, w_up.astype(x.dtype))
    act = jax.nn.silu(g) * u
    out_buf = jnp.einsum("secf,efd->secd", act, w_down.astype(x.dtype))
    out_buf = logical_constraint(out_buf, "batch", None, None, None)

    comb = shard_map(
        lambda ob, sl, rw, gs, kp: _local_combine(
            ob[0], sl[0], rw[0], gs[0], kp[0], Tl)[None],
        mesh=mesh,
        in_specs=(P(batch_axes, None, None, None), P(batch_axes, None),
                  P(batch_axes, None), P(batch_axes, None),
                  P(batch_axes, None)),
        out_specs=P(batch_axes, None, None),
        check_rep=False,
    )
    y = comb(out_buf, slot, rows, gate_sorted, keep)
    y = logical_constraint(y, "batch", None, None)

    T = B * S
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.sum(counts, axis=0).astype(jnp.float32) / (T * topk)
    aux_loss = E * jnp.sum(me * ce)
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / (T * topk)
    aux = dict(moe_aux_loss=aux_loss, moe_dropped_frac=dropped,
               moe_frac_tokens=jnp.mean(ce))
    return y.reshape(B, S, d), aux
