"""GQA attention: chunked online-softmax implementation + KV cache ops.

The XLA implementation here is flash-structured — an unrolled loop over
query chunks (so each chunk's KV extent is a *static* slice ending at the
causal frontier: exact causal FLOPs, no wasted upper triangle) with a
``lax.scan`` over KV chunks carrying the online-softmax state (running max,
normalizer, accumulator).  Peak live memory is O(chunk_q × chunk_kv) per
score block instead of O(S²), which keeps the dry-run memory analysis
faithful to what the Pallas kernel (``repro.kernels.flash_attention``) does
on TPU.  The same function doubles as the oracle for that kernel.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "chunked_gqa_attention", "decode_gqa_attention",
    "init_kv_cache", "append_kv", "update_positions",
]

_NEG_INF = -1e30


def _attend_q_chunk(
    qc: jnp.ndarray,        # (B, Cq, K, G, hd) — compute dtype
    k: jnp.ndarray,         # (B, Skv, K, hd)
    v: jnp.ndarray,         # (B, Skv, K, hd)
    q_positions: jnp.ndarray,   # (B, Cq) int32 global positions
    kv_positions: jnp.ndarray,  # (B, Skv) int32 global positions
    kv_valid: jnp.ndarray,      # (B, Skv) bool
    *,
    causal: bool,
    window: Optional[int],
    chunk_kv: int,
) -> jnp.ndarray:
    """One query chunk against all supplied KV, scanning KV chunks."""
    B, Cq, K, G, hd = qc.shape
    Skv = k.shape[1]
    pad = (-Skv) % chunk_kv
    if pad:  # partial trailing chunk: pad and mark invalid
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=-1)
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))
        Skv += pad
    nkv = Skv // chunk_kv
    scale = 1.0 / (hd ** 0.5)
    qf = qc * jnp.asarray(scale, qc.dtype)

    m = jnp.full((B, K, G, Cq), _NEG_INF, jnp.float32)
    l = jnp.zeros((B, K, G, Cq), jnp.float32)
    acc = jnp.zeros((B, K, G, Cq, hd), jnp.float32)

    # Static unroll over KV chunks (not lax.scan): the online-softmax chain
    # is identical, but every chunk's FLOPs appear in the lowered HLO — XLA
    # cost analysis counts a while-loop body once, which would undercount
    # attention by the KV-chunk count.
    for j in range(nkv):
        sl = slice(j * chunk_kv, (j + 1) * chunk_kv)
        kc, vc = k[:, sl], v[:, sl]
        kp, kvalid = kv_positions[:, sl], kv_valid[:, sl]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qf, kc,
                       preferred_element_type=jnp.float32)
        mask = kvalid[:, None, None, None, :]
        if causal:
            mask = mask & (kp[:, None, None, None, :]
                           <= q_positions[:, None, None, :, None])
        if window is not None:
            mask = mask & (kp[:, None, None, None, :]
                           > q_positions[:, None, None, :, None] - window)
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(qc.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        m = m_new
    out = acc / jnp.maximum(l[..., None], 1e-30)
    # (B, K, G, Cq, hd) -> (B, Cq, K*G, hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Cq, K * G, hd)
    return out.astype(qc.dtype)


def chunked_gqa_attention(
    q: jnp.ndarray,          # (B, Sq, H, hd)
    k: jnp.ndarray,          # (B, Skv, K, hd)
    v: jnp.ndarray,          # (B, Skv, K, hd)
    *,
    causal: bool = True,
    q_offset: int = 0,
    window: Optional[int] = None,
    chunk_q: int = 512,
    chunk_kv: int = 1024,
) -> jnp.ndarray:
    """Memory-efficient GQA attention with exact causal FLOPs.

    The query axis is split into static chunks (unrolled); chunk ``i`` only
    sees KV up to its causal frontier — a static slice, so the lowered HLO
    contains no masked-away dead compute.
    """
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    K = k.shape[2]
    assert H % K == 0, (H, K)
    G = H // K
    # Adaptive tiling: cap the unrolled chunk count for long sequences
    # (<= ~16 query tiles x ~8 KV tiles regardless of S).
    cq = min(max(chunk_q, Sq // 16), Sq)
    ckv = min(max(chunk_kv, Skv // 8), Skv)

    qg = q.reshape(B, Sq, K, G, hd)
    kv_pos_full = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
    kv_valid_full = jnp.ones((B, Skv), bool)

    outs = []
    for start in range(0, Sq, cq):
        stop = min(start + cq, Sq)
        qc = qg[:, start:stop]
        q_pos = jnp.broadcast_to(
            (q_offset + jnp.arange(start, stop, dtype=jnp.int32))[None],
            (B, stop - start))
        if causal:
            frontier = q_offset + stop  # exclusive causal frontier
            kv_hi = min(-(-min(frontier, Skv) // ckv) * ckv, Skv)
        else:
            kv_hi = Skv
        kv_lo = 0
        if window is not None:
            kv_lo = max(0, (q_offset + start - window + 1) // ckv * ckv)
        outs.append(_attend_q_chunk(
            qc, k[:, kv_lo:kv_hi], v[:, kv_lo:kv_hi],
            q_pos, kv_pos_full[:, kv_lo:kv_hi], kv_valid_full[:, kv_lo:kv_hi],
            causal=causal, window=window, chunk_kv=ckv,
        ))
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def decode_gqa_attention(
    q: jnp.ndarray,            # (B, 1, H, hd)
    cache_k: jnp.ndarray,      # (B, cap, K, hd)
    cache_v: jnp.ndarray,      # (B, cap, K, hd)
    kv_positions: jnp.ndarray,  # (B, cap) int32, -1 for empty slots
    pos: jnp.ndarray,          # (B,) int32 current decode position
    *,
    window: Optional[int] = None,
    chunk_kv: int = 0,         # unused; kept for call compatibility
) -> jnp.ndarray:
    """Single-token decode against a (possibly ring) KV cache.

    Unlike prefill, this is one fused einsum-softmax-einsum: with Sq == 1
    the score tensor is only (B, H, cap), and keeping the cache's sequence
    axis in a single contraction lets GSPMD shard it over the ``model``
    axis (flash-decoding-style sequence parallelism) — the reductions over
    the sharded axis lower to small all-reduces of (B, H)-sized tensors.
    """
    B, _, H, hd = q.shape
    cap, K = cache_k.shape[1], cache_k.shape[2]
    G = H // K
    scale = 1.0 / (hd ** 0.5)
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32)
    mask = (kv_positions >= 0) & (kv_positions <= pos[:, None])
    if window is not None:
        mask = mask & (kv_positions > pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, _NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskh->bkgh", (p / l).astype(q.dtype), cache_v)
    return out.reshape(B, 1, H, hd)


def init_kv_cache(batch: int, capacity: int, n_kv_heads: int, head_dim: int,
                  dtype) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (k, v, positions); positions is shared across layers."""
    return (
        jnp.zeros((batch, capacity, n_kv_heads, head_dim), dtype),
        jnp.zeros((batch, capacity, n_kv_heads, head_dim), dtype),
        jnp.full((batch, capacity), -1, jnp.int32),
    )


def append_kv(cache_k: jnp.ndarray, cache_v: jnp.ndarray,
              k_new: jnp.ndarray, v_new: jnp.ndarray,
              pos: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Write one token's K/V at ``pos % capacity`` (ring indexing)."""
    cap = cache_k.shape[1]
    slot = (pos % cap).astype(jnp.int32)  # (B,)
    b_idx = jnp.arange(cache_k.shape[0])
    k = cache_k.at[b_idx, slot].set(k_new[:, 0].astype(cache_k.dtype))
    v = cache_v.at[b_idx, slot].set(v_new[:, 0].astype(cache_v.dtype))
    return k, v


def update_positions(positions: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Record the newly appended token's absolute position (once per step)."""
    cap = positions.shape[1]
    slot = (pos % cap).astype(jnp.int32)
    b_idx = jnp.arange(positions.shape[0])
    return positions.at[b_idx, slot].set(pos.astype(jnp.int32))
