"""Per-family block definitions: parameter defs + apply functions.

Block kinds:
  * dense  — pre-norm GQA attention + SwiGLU MLP (optional qk-norm, M-RoPE)
  * moe    — pre-norm GQA attention + top-k MoE MLP
  * mamba2 — pre-norm Mamba2 (SSD) mixer
Hybrid models (Zamba2) compose scanned mamba2 blocks with one weight-shared
dense block applied every ``shared_attn_every`` layers (see model.py).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    append_kv,
    chunked_gqa_attention,
    decode_gqa_attention,
)
from repro.models.layers import apply_mrope, apply_rope, rmsnorm, swiglu
from repro.models.mamba2 import mamba2_decode, mamba2_mixer
from repro.models.moe import moe_block, moe_block_local
from repro.models.params import ParamDef
from repro.launch.partitioning import logical_constraint

__all__ = [
    "attn_param_defs", "mlp_param_defs", "moe_param_defs", "mamba2_param_defs",
    "dense_block_defs", "moe_block_defs", "mamba2_block_defs",
    "apply_attn", "apply_attn_decode",
    "apply_dense_block", "apply_dense_block_decode",
    "apply_moe_block", "apply_moe_block_decode",
    "apply_mamba2_block", "apply_mamba2_block_decode",
    "CONV_KW",
]

CONV_KW = 4  # Mamba2 depthwise conv kernel width


# ---------------------------------------------------------------------------
# parameter definitions
# ---------------------------------------------------------------------------


def attn_param_defs(cfg) -> Dict[str, ParamDef]:
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    p = {
        "ln": ParamDef((D,), (None,), init="ones"),
        "wq": ParamDef((D, H * hd), ("embed_fsdp", "heads")),
        "wk": ParamDef((D, K * hd), ("embed_fsdp", "heads")),
        "wv": ParamDef((D, K * hd), ("embed_fsdp", "heads")),
        "wo": ParamDef((H * hd, D), ("heads", "embed_fsdp"),
                       init_scale=out_scale),
    }
    if cfg.qk_norm:
        p["q_norm"] = ParamDef((hd,), (None,), init="ones")
        p["k_norm"] = ParamDef((hd,), (None,), init="ones")
    return p


def mlp_param_defs(cfg) -> Dict[str, ParamDef]:
    D, F = cfg.d_model, cfg.d_ff
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    return {
        "ln": ParamDef((D,), (None,), init="ones"),
        "w_gate": ParamDef((D, F), ("embed_fsdp", "ff")),
        "w_up": ParamDef((D, F), ("embed_fsdp", "ff")),
        "w_down": ParamDef((F, D), ("ff", "embed_fsdp"), init_scale=out_scale),
    }


def moe_param_defs(cfg) -> Dict[str, ParamDef]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    return {
        "ln": ParamDef((D,), (None,), init="ones"),
        "router": ParamDef((D, E), ("embed_fsdp", None)),
        "w_gate": ParamDef((E, D, F), ("expert", "embed_fsdp", None)),
        "w_up": ParamDef((E, D, F), ("expert", "embed_fsdp", None)),
        "w_down": ParamDef((E, F, D), ("expert", None, "embed_fsdp"),
                           init_scale=out_scale),
    }


def mamba2_param_defs(cfg) -> Dict[str, ParamDef]:
    D, din = cfg.d_model, cfg.d_inner
    H, P, G, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_groups, cfg.ssm_state
    conv_dim = din + 2 * G * N
    zdim = 2 * din + 2 * G * N + H
    out_scale = 0.02 / math.sqrt(2 * cfg.n_layers)

    def a_log_init(key):
        return jnp.log(jnp.linspace(1.0, 16.0, H))

    def dt_bias_init(key):
        dt = jnp.exp(jax.random.uniform(
            key, (H,), minval=math.log(1e-3), maxval=math.log(1e-1)))
        return dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus

    return {
        "ln": ParamDef((D,), (None,), init="ones"),
        "in_proj": ParamDef((D, zdim), ("embed_fsdp", "ssm_inner")),
        "conv_w": ParamDef((conv_dim, CONV_KW), ("ssm_inner", None),
                           init_scale=0.1),
        "conv_b": ParamDef((conv_dim,), ("ssm_inner",), init="zeros"),
        "dt_bias": ParamDef((H,), (None,), custom_init=dt_bias_init),
        "A_log": ParamDef((H,), (None,), custom_init=a_log_init),
        "D": ParamDef((H,), (None,), init="ones"),
        "norm_scale": ParamDef((din,), ("ssm_inner",), init="ones"),
        "out_proj": ParamDef((din, D), ("ssm_inner", "embed_fsdp"),
                             init_scale=out_scale),
    }


def dense_block_defs(cfg) -> Dict[str, Dict[str, ParamDef]]:
    return {"attn": attn_param_defs(cfg), "mlp": mlp_param_defs(cfg)}


def moe_block_defs(cfg) -> Dict[str, Dict[str, ParamDef]]:
    return {"attn": attn_param_defs(cfg), "moe": moe_param_defs(cfg)}


def mamba2_block_defs(cfg) -> Dict[str, Dict[str, ParamDef]]:
    return {"mamba": mamba2_param_defs(cfg)}


# ---------------------------------------------------------------------------
# apply functions
# ---------------------------------------------------------------------------


def _project_qkv(p, cfg, h):
    B, S, _ = h.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dtype = h.dtype
    q = jnp.einsum("bsd,dh->bsh", h, p["wq"].astype(dtype)).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", h, p["wk"].astype(dtype)).reshape(B, S, K, hd)
    v = jnp.einsum("bsd,dh->bsh", h, p["wv"].astype(dtype)).reshape(B, S, K, hd)
    q = logical_constraint(q, "batch", None, "q_heads", None)
    k = logical_constraint(k, "batch", None, "kv_heads", None)
    v = logical_constraint(v, "batch", None, "kv_heads", None)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _rope(cfg, x, positions):
    if cfg.mrope_sections is not None:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def apply_attn(
    p: Dict, cfg, h: jnp.ndarray, positions: jnp.ndarray,
    *, window: Optional[int] = None, return_kv: bool = False,
):
    """Attention sublayer (pre-norm, residual) for train/prefill.

    Set ``return_kv`` to also get (k, v) back for KV-cache construction.
    """
    resid = h
    h = rmsnorm(h, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(p, cfg, h)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    out = chunked_gqa_attention(
        q, k, v, causal=cfg.causal, window=window,
        chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv)
    B, S, _, _ = out.shape
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(h.dtype))
    return resid + out, ((k, v) if return_kv else None)


def apply_attn_decode(
    p: Dict, cfg, h: jnp.ndarray, pos: jnp.ndarray,
    cache_k: jnp.ndarray, cache_v: jnp.ndarray, kv_positions: jnp.ndarray,
    *, window: Optional[int] = None,
):
    """Decode attention sublayer.  ``kv_positions`` must already include the
    current token (updated once per step outside the layer scan)."""
    resid = h
    h = rmsnorm(h, p["ln"], cfg.norm_eps)
    q, k, v = _project_qkv(p, cfg, h)
    positions = pos[:, None]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(pos[:, None, None], (pos.shape[0], 1, 3))
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    cache_k, cache_v = append_kv(cache_k, cache_v, k, v, pos)
    out = decode_gqa_attention(
        q, cache_k, cache_v, kv_positions, pos,
        window=window, chunk_kv=cfg.attn_chunk_kv)
    B = out.shape[0]
    out = out.reshape(B, 1, cfg.n_heads * cfg.hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"].astype(h.dtype))
    return resid + out, cache_k, cache_v


def apply_dense_block(p, cfg, h, positions, window=None, return_kv=False):
    h, kv = apply_attn(p["attn"], cfg, h, positions,
                       window=window, return_kv=return_kv)
    resid = h
    hn = rmsnorm(h, p["mlp"]["ln"], cfg.norm_eps)
    h = resid + swiglu(hn, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    return h, kv


def apply_dense_block_decode(p, cfg, h, pos, cache_k, cache_v, kv_positions,
                             window=None):
    h, cache_k, cache_v = apply_attn_decode(
        p["attn"], cfg, h, pos, cache_k, cache_v, kv_positions, window=window)
    resid = h
    hn = rmsnorm(h, p["mlp"]["ln"], cfg.norm_eps)
    h = resid + swiglu(hn, p["mlp"]["w_gate"], p["mlp"]["w_up"],
                       p["mlp"]["w_down"])
    return h, cache_k, cache_v


def apply_moe_block(p, cfg, h, positions, window=None, return_kv=False):
    h, kv = apply_attn(p["attn"], cfg, h, positions,
                       window=window, return_kv=return_kv)
    resid = h
    hn = rmsnorm(h, p["moe"]["ln"], cfg.norm_eps)
    moe_fn = moe_block_local if cfg.moe_local_dispatch else moe_block
    out, aux = moe_fn(
        hn, p["moe"]["router"], p["moe"]["w_gate"], p["moe"]["w_up"],
        p["moe"]["w_down"], topk=cfg.topk,
        capacity_factor=cfg.capacity_factor)
    return resid + out, kv, aux


def apply_moe_block_decode(p, cfg, h, pos, cache_k, cache_v, kv_positions,
                           window=None):
    h, cache_k, cache_v = apply_attn_decode(
        p["attn"], cfg, h, pos, cache_k, cache_v, kv_positions, window=window)
    resid = h
    hn = rmsnorm(h, p["moe"]["ln"], cfg.norm_eps)
    moe_fn = moe_block_local if cfg.moe_local_dispatch else moe_block
    out, _ = moe_fn(
        hn, p["moe"]["router"], p["moe"]["w_gate"], p["moe"]["w_up"],
        p["moe"]["w_down"], topk=cfg.topk,
        capacity_factor=cfg.capacity_factor)
    return resid + out, cache_k, cache_v


def apply_mamba2_block(p, cfg, h, initial_state=None, ssd_impl=None):
    """Train/prefill Mamba2 block. Returns (h, final_ssm_state, conv_tail)."""
    resid = h
    hn = rmsnorm(h, p["mamba"]["ln"], cfg.norm_eps)
    kwargs = {} if ssd_impl is None else {"ssd_impl": ssd_impl}
    out, final_state, conv_tail = mamba2_mixer(
        p["mamba"], cfg, hn, initial_state=initial_state, **kwargs)
    return resid + out, final_state, conv_tail


def apply_mamba2_block_decode(p, cfg, h, conv_state, ssm_state):
    resid = h
    hn = rmsnorm(h, p["mamba"]["ln"], cfg.norm_eps)
    out, new_conv, new_ssm = mamba2_decode(
        p["mamba"], cfg, hn, conv_state, ssm_state)
    return resid + out, new_conv, new_ssm
