"""Parameter definition / init / shape machinery.

Each parameter is declared once as a :class:`ParamDef` (shape, dtype,
logical sharding axes, initializer).  From the same declaration we derive:

* ``jax.ShapeDtypeStruct`` trees for the multi-pod dry-run (no allocation),
* real initialized arrays for smoke tests / the e2e training example,
* ``NamedSharding`` trees via the logical-axis rules in ``repro.launch.mesh``.

Logical axes used by the zoo:
  "layer"      — scanned layer axis (never sharded)
  "vocab"      — vocabulary dim            -> "model"
  "embed_fsdp" — weight d_model dims       -> "data"  (FSDP/ZeRO-3 style)
  "heads"      — attention head*head_dim   -> "model" (tensor parallel)
  "ff"         — MLP hidden dim            -> "model"
  "expert"     — MoE expert dim            -> "model" (expert parallel)
  "ssm_inner"  — Mamba2 inner/conv dims    -> "model"
  None         — replicated
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamDef", "build_shapes", "build_specs", "init_tree", "stack_defs"]

Axes = Tuple[Optional[str], ...]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Axes
    dtype: str = "float32"
    init: str = "normal"      # normal | zeros | ones | custom
    init_scale: float = 0.02
    custom_init: Optional[Callable] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def build_shapes(defs) -> Dict:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


def build_specs(defs) -> Dict:
    """Logical-axis PartitionSpec-precursors (tuples of axis names)."""
    return jax.tree.map(lambda d: d.axes, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def _init_one(d: ParamDef, key) -> jnp.ndarray:
    if d.custom_init is not None:
        return d.custom_init(key).astype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    return (jax.random.normal(key, d.shape, jnp.float32)
            * d.init_scale).astype(d.dtype)


def init_tree(defs, key) -> Dict:
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(d, k) for d, k in zip(leaves, keys)])


def stack_defs(defs, n_layers: int) -> Dict:
    """Prepend a scanned 'layer' axis to every ParamDef in the tree."""
    def stack(d: ParamDef) -> ParamDef:
        custom = None
        if d.custom_init is not None:
            base = d.custom_init

            def custom(key, _base=base, _n=n_layers, _d=d):
                ks = jax.random.split(key, _n)
                return jnp.stack([_base(k) for k in ks])
        return ParamDef(
            shape=(n_layers,) + d.shape,
            axes=("layer",) + d.axes,
            dtype=d.dtype, init=d.init, init_scale=d.init_scale,
            custom_init=custom)
    return jax.tree.map(stack, defs, is_leaf=lambda x: isinstance(x, ParamDef))
