"""Shared layers: norms, rotary embeddings (RoPE / M-RoPE), MLP, embeddings."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "rmsnorm", "swiglu", "rope_frequencies", "apply_rope", "apply_mrope",
    "embed_lookup",
]


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm in f32 with cast back to the input dtype."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray,
           w_down: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    dtype = x.dtype
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down.astype(dtype))


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies (head_dim/2,)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def _rotate(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding.  x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Multimodal RoPE (Qwen2-VL): the rotary dimensions are partitioned into
    (temporal, height, width) sections, each rotated by its own position id.

    x: (B, S, H, hd); positions3: (B, S, 3) int32; sum(sections) == hd // 2.
    """
    hd = x.shape[-1]
    if sum(sections) != hd // 2:
        raise ValueError(f"M-RoPE sections {sections} must sum to {hd // 2}")
    inv = rope_frequencies(hd, theta)  # (hd/2,)
    # section id per rotary dim
    sec_id = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)
    ])
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),  # (B, S, 3)
        jnp.broadcast_to(sec_id[None, None, :],
                         positions3.shape[:2] + (hd // 2,)).astype(jnp.int32),
        axis=-1,
    )  # (B, S, hd/2): position id per rotary dim
    ang = pos * inv
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def embed_lookup(table: jnp.ndarray, tokens: jnp.ndarray,
                 dtype: jnp.dtype) -> jnp.ndarray:
    """Embedding gather with compute-dtype cast."""
    return jnp.take(table, tokens, axis=0).astype(dtype)
