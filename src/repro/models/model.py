"""Model assembly: embed → lax.scan(blocks) → norm → head, for all families.

Layers are stacked and scanned (MaxText-style) so the lowered HLO is O(1) in
depth — essential for compiling 88-layer dry-runs on a CPU host.  Hybrid
models scan *super-layers* (``shared_attn_every`` Mamba2 blocks + one
weight-shared attention block); the shared block's parameters live outside
the scan and are closed over.

Three entry points per model:
  * :func:`forward_train`   — full-sequence logits + CE loss path.
  * :func:`prefill`         — full-sequence forward that also returns the
                              serving cache (KV / SSM+conv states).
  * :func:`decode_step`     — one-token step against the cache.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.attention import update_positions
from repro.models.config import ModelConfig
from repro.models.layers import embed_lookup, rmsnorm
from repro.launch.partitioning import logical_constraint
from repro.models.params import (
    ParamDef,
    build_shapes,
    build_specs,
    init_tree,
    stack_defs,
)

__all__ = ["param_defs", "param_shapes", "param_specs", "init_params",
           "forward_train", "prefill", "decode_step", "init_cache",
           "cache_shapes"]


# ---------------------------------------------------------------------------
# parameter tree
# ---------------------------------------------------------------------------


def _block_defs(cfg: ModelConfig):
    if cfg.family in ("dense", "vlm", "audio"):
        return B.dense_block_defs(cfg)
    if cfg.family == "moe":
        return B.moe_block_defs(cfg)
    if cfg.family in ("ssm", "hybrid"):
        return B.mamba2_block_defs(cfg)
    raise ValueError(cfg.family)


def _n_scan(cfg: ModelConfig) -> int:
    """Number of scan steps (super-layers for hybrid)."""
    if cfg.family == "hybrid":
        assert cfg.n_layers % cfg.shared_attn_every == 0, \
            (cfg.n_layers, cfg.shared_attn_every)
        return cfg.n_layers // cfg.shared_attn_every
    return cfg.n_layers


def param_defs(cfg: ModelConfig) -> Dict:
    D, V = cfg.d_model, cfg.vocab
    blk = _block_defs(cfg)
    if cfg.family == "hybrid":
        blk = stack_defs(blk, cfg.shared_attn_every)   # inner unrolled axis
    tree = {
        "embed": ParamDef((V, D), ("vocab", "embed_fsdp")),
        "blocks": stack_defs(blk, _n_scan(cfg)),
        "final_ln": ParamDef((D,), (None,), init="ones"),
        "head": ParamDef((D, V), ("embed_fsdp", "vocab")),
    }
    if cfg.family == "hybrid":
        tree["shared"] = B.dense_block_defs(cfg)
    return tree


def param_shapes(cfg: ModelConfig) -> Dict:
    return build_shapes(param_defs(cfg))


def param_specs(cfg: ModelConfig) -> Dict:
    return build_specs(param_defs(cfg))


def init_params(cfg: ModelConfig, key) -> Dict:
    return init_tree(param_defs(cfg), key)


# ---------------------------------------------------------------------------
# serving-cache trees
# ---------------------------------------------------------------------------


def cache_shapes(cfg: ModelConfig, batch: int, capacity: int) -> Dict:
    """ShapeDtypeStructs of the serving cache (for dry-run input_specs)."""
    dt = jnp.dtype(cfg.dtype)
    L = _n_scan(cfg)
    out: Dict = {}
    if cfg.family in ("dense", "moe", "vlm"):
        K, hd = cfg.n_kv_heads, cfg.hd
        out["k"] = jax.ShapeDtypeStruct((L, batch, capacity, K, hd), dt)
        out["v"] = jax.ShapeDtypeStruct((L, batch, capacity, K, hd), dt)
        out["kv_positions"] = jax.ShapeDtypeStruct((batch, capacity), jnp.int32)
    if cfg.family in ("ssm", "hybrid"):
        H, P, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
        conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * N
        nl = (L, cfg.shared_attn_every) if cfg.family == "hybrid" else (L,)
        out["ssm"] = jax.ShapeDtypeStruct(
            nl + (batch, H, P, N), jnp.float32)
        out["conv"] = jax.ShapeDtypeStruct(
            nl + (batch, B.CONV_KW - 1, conv_dim), dt)
    if cfg.family == "hybrid":
        K, hd = cfg.n_kv_heads, cfg.hd
        cap = capacity if cfg.sliding_window is None else min(
            capacity, cfg.sliding_window)
        out["k"] = jax.ShapeDtypeStruct((L, batch, cap, K, hd), dt)
        out["v"] = jax.ShapeDtypeStruct((L, batch, cap, K, hd), dt)
        out["kv_positions"] = jax.ShapeDtypeStruct((batch, cap), jnp.int32)
    return out


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> Dict:
    shapes = cache_shapes(cfg, batch, capacity)
    out = {k: jnp.zeros(v.shape, v.dtype) for k, v in shapes.items()}
    if "kv_positions" in out:
        out["kv_positions"] = jnp.full(
            shapes["kv_positions"].shape, -1, jnp.int32)
    return out


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "full":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def _scan_or_unroll(body, cfg: ModelConfig, carry, xs):
    """lax.scan over layers, or a static unroll with identical semantics.

    The unrolled form is used by the dry-run: XLA's cost analysis counts a
    ``while`` body once regardless of trip count, so scanned models report
    ~1/L of their true FLOPs; unrolling makes cost_analysis exact while
    keeping shapes, shardings and math identical.
    """
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    L = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(L):
        xs_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xs_i)
        ys.append(y)
    ys_stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *ys)
    return carry, ys_stacked


def _embed_inputs(params, cfg: ModelConfig, batch: Dict) -> jnp.ndarray:
    dtype = jnp.dtype(cfg.dtype)
    if "embeds" in batch:  # stubbed modality frontend (vlm / audio)
        h = batch["embeds"].astype(dtype)
    else:
        h = embed_lookup(params["embed"], batch["tokens"], dtype)
    return logical_constraint(h, "batch", None, None)


def _default_positions(cfg: ModelConfig, Bsz: int, S: int):
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (Bsz, S))
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(pos[..., None], (Bsz, S, 3))
    return pos


def _forward_seq(params, cfg: ModelConfig, h, positions, collect_cache: bool):
    """Shared train/prefill body.  Returns (h, cache_ys, aux)."""
    aux = {}

    def _sp(x):
        """Sequence-parallel carry sharding (Megatron-SP analogue): the
        tensor SAVED between blocks (and for the backward pass) lives
        seq-sharded over the model axis; GSPMD inserts the all-gather
        before the column-parallel matmuls and the reduce-scatter after
        the row-parallel ones."""
        if cfg.seq_parallel:
            return logical_constraint(x, "batch", "seq_sp", None)
        return x

    h = _sp(h)

    if cfg.family in ("dense", "vlm", "audio"):
        def body(carry, xs):
            hh, kv = B.apply_dense_block(
                xs, cfg, carry, positions,
                window=cfg.sliding_window, return_kv=collect_cache)
            return _sp(hh), kv
        h, kvs = _scan_or_unroll(_maybe_remat(body, cfg), cfg, h,
                                 params["blocks"])
        cache_ys = {"kv": kvs} if collect_cache else None

    elif cfg.family == "moe":
        def body(carry, xs):
            hh, kv, aux_l = B.apply_moe_block(
                xs, cfg, carry, positions,
                window=cfg.sliding_window, return_kv=collect_cache)
            return _sp(hh), (kv, aux_l)
        h, (kvs, aux_layers) = _scan_or_unroll(
            _maybe_remat(body, cfg), cfg, h, params["blocks"])
        aux = {k: jnp.mean(v) for k, v in aux_layers.items()}
        cache_ys = {"kv": kvs} if collect_cache else None

    elif cfg.family == "ssm":
        def body(carry, xs):
            hh, ssm, conv = B.apply_mamba2_block(xs, cfg, carry)
            return _sp(hh), (ssm, conv)
        h, (ssms, convs) = _scan_or_unroll(
            _maybe_remat(body, cfg), cfg, h, params["blocks"])
        cache_ys = {"ssm": ssms, "conv": convs} if collect_cache else None

    elif cfg.family == "hybrid":
        shared = params["shared"]
        every = cfg.shared_attn_every

        def body(carry, xs):
            hh = carry
            ssm_l, conv_l = [], []
            for j in range(every):  # static unroll inside the scan step
                p_j = jax.tree.map(lambda a: a[j], xs)
                hh, ssm, conv = B.apply_mamba2_block(p_j, cfg, hh)
                ssm_l.append(ssm)
                conv_l.append(conv)
            hh, kv = B.apply_dense_block(
                shared, cfg, hh, positions,
                window=cfg.sliding_window, return_kv=collect_cache)
            return hh, (jnp.stack(ssm_l), jnp.stack(conv_l), kv)
        h, (ssms, convs, kvs) = _scan_or_unroll(
            _maybe_remat(body, cfg), cfg, h, params["blocks"])
        cache_ys = ({"ssm": ssms, "conv": convs, "kv": kvs}
                    if collect_cache else None)
    else:
        raise ValueError(cfg.family)

    return h, cache_ys, aux


def _head_logits(params, cfg: ModelConfig, h: jnp.ndarray) -> jnp.ndarray:
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    return jnp.einsum("bsd,dv->bsv", h, params["head"].astype(h.dtype),
                      preferred_element_type=jnp.float32)


def _fused_head_ce(params, cfg: ModelConfig, h: jnp.ndarray,
                   labels: jnp.ndarray) -> jnp.ndarray:
    """Tensor-parallel-aware fused LM head + cross entropy.

    The naive ``take_along_axis(logits, labels)`` forces GSPMD to all-gather
    the vocab-sharded (B, S, V) logits onto every device.  Instead:

    * logits stay bf16 and vocab-sharded; logsumexp reduces over the sharded
      axis, lowering to partial reductions + a tiny (B, S) all-reduce;
    * the gold logit is recomputed as ``h · head[:, label]`` — a gather of
      head *columns* (D-sized) instead of a gather from the logits cube.
    """
    h = rmsnorm(h, params["final_ln"], cfg.norm_eps)
    head = params["head"].astype(h.dtype)
    logits = jnp.einsum("bsd,dv->bsv", h, head,
                        preferred_element_type=jnp.float32).astype(h.dtype)
    logits = logical_constraint(logits, "batch", None, "vocab")
    m = jnp.max(logits, axis=-1)
    ex = jnp.exp((logits - m[..., None]).astype(jnp.float32))
    lse = m.astype(jnp.float32) + jnp.log(jnp.sum(ex, axis=-1))

    Bsz, S = labels.shape
    gold_cols = jnp.take(head, labels.reshape(-1), axis=1)  # (D, B*S)
    # (D, B*S) -> (B, S, D) then a cheap row-wise dot with h.
    gold_cols = gold_cols.T.reshape(Bsz, S, head.shape[0])
    gold = jnp.sum(h.astype(jnp.float32) * gold_cols.astype(jnp.float32),
                   axis=-1)
    return jnp.mean(lse - gold)


def forward_train(params, cfg: ModelConfig, batch: Dict):
    """Returns (loss, metrics).  batch: tokens|embeds, labels[, positions]."""
    h = _embed_inputs(params, cfg, batch)
    Bsz, S = h.shape[0], h.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, Bsz, S)
    h, _, aux = _forward_seq(params, cfg, h, positions, collect_cache=False)

    labels = batch["labels"]
    if cfg.logits_chunk and S > cfg.logits_chunk:
        # Beyond-paper option: chunked fused head+CE so even the sharded
        # (B, S, V) logits buffer never fully materializes.
        n = S // cfg.logits_chunk
        total = jnp.zeros((), jnp.float32)
        for i in range(n):  # static unroll: exact HLO cost accounting
            sl = slice(i * cfg.logits_chunk, (i + 1) * cfg.logits_chunk)
            total = total + _fused_head_ce(params, cfg, h[:, sl], labels[:, sl])
        loss = total / n
    else:
        loss = _fused_head_ce(params, cfg, h, labels)

    metrics = dict(ce_loss=loss, **aux)
    if "moe_aux_loss" in aux:
        loss = loss + 0.01 * aux["moe_aux_loss"]
    metrics["loss"] = loss
    return loss, metrics


def prefill(params, cfg: ModelConfig, batch: Dict, capacity: Optional[int] = None):
    """Full-sequence forward; returns (last-token logits, serving cache)."""
    if cfg.is_encoder_only:
        raise ValueError("encoder-only models have no decode/prefill cache")
    h = _embed_inputs(params, cfg, batch)
    Bsz, S = h.shape[0], h.shape[1]
    capacity = capacity or S
    positions = batch.get("positions")
    if positions is None:
        positions = _default_positions(cfg, Bsz, S)
    h, cache_ys, _ = _forward_seq(params, cfg, h, positions, collect_cache=True)
    logits = _head_logits(params, cfg, h[:, -1:, :])

    cache: Dict = {}
    if cache_ys and "kv" in cache_ys and cache_ys["kv"] is not None:
        k, v = cache_ys["kv"]  # (L, B, S', K, hd) where S' = S (full) for attn
        cap = capacity
        if cfg.family == "hybrid" and cfg.sliding_window is not None:
            cap = min(capacity, cfg.sliding_window)
            k, v = k[:, :, -cap:], v[:, :, -cap:]
        pad = cap - k.shape[2]
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache["k"], cache["v"] = k, v
        kv_pos = jnp.broadcast_to(
            jnp.arange(k.shape[2], dtype=jnp.int32)[None], (Bsz, k.shape[2]))
        if cfg.family == "hybrid" and cfg.sliding_window is not None:
            kv_pos = kv_pos + max(S - cap, 0)
        cache["kv_positions"] = jnp.where(kv_pos < S, kv_pos, -1)
    if cache_ys and "ssm" in cache_ys:
        cache["ssm"] = cache_ys["ssm"].astype(jnp.float32)
        cache["conv"] = cache_ys["conv"]
    return logits, cache


def decode_step(params, cfg: ModelConfig, batch: Dict, cache: Dict,
                pos: jnp.ndarray):
    """One-token decode.  batch: token (B,) or embed (B,1,D); pos: (B,).

    Returns (logits (B,1,V), new cache).
    """
    if cfg.is_encoder_only:
        raise ValueError("encoder-only models have no decode step")
    dtype = jnp.dtype(cfg.dtype)
    if "embeds" in batch:
        h = batch["embeds"].astype(dtype)
    else:
        h = embed_lookup(params["embed"], batch["tokens"][:, None], dtype)

    new_cache = dict(cache)
    if "kv_positions" in cache:
        kv_positions = update_positions(cache["kv_positions"], pos)
        new_cache["kv_positions"] = kv_positions

    if cfg.family in ("dense", "moe", "vlm"):
        apply = (B.apply_moe_block_decode if cfg.family == "moe"
                 else B.apply_dense_block_decode)

        def body(carry, xs):
            p_l, ck, cv = xs
            hh, ck, cv = apply(p_l, cfg, carry, pos, ck, cv, kv_positions,
                               window=cfg.sliding_window)
            return hh, (ck, cv)
        h, (ks, vs) = _scan_or_unroll(
            body, cfg, h, (params["blocks"], cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs

    elif cfg.family == "ssm":
        def body(carry, xs):
            p_l, conv, ssm = xs
            hh, conv, ssm = B.apply_mamba2_block_decode(
                p_l, cfg, carry, conv, ssm)
            return hh, (conv, ssm)
        h, (convs, ssms) = _scan_or_unroll(
            body, cfg, h, (params["blocks"], cache["conv"], cache["ssm"]))
        new_cache["conv"], new_cache["ssm"] = convs, ssms

    elif cfg.family == "hybrid":
        shared = params["shared"]
        every = cfg.shared_attn_every

        def body(carry, xs):
            p_s, conv_s, ssm_s, ck, cv = xs
            hh = carry
            convs, ssms = [], []
            for j in range(every):
                p_j = jax.tree.map(lambda a: a[j], p_s)
                hh, conv, ssm = B.apply_mamba2_block_decode(
                    p_j, cfg, hh, conv_s[j], ssm_s[j])
                convs.append(conv)
                ssms.append(ssm)
            hh, ck, cv = B.apply_dense_block_decode(
                shared, cfg, hh, pos, ck, cv, kv_positions,
                window=cfg.sliding_window)
            return hh, (jnp.stack(convs), jnp.stack(ssms), ck, cv)
        h, (convs, ssms, ks, vs) = _scan_or_unroll(
            body, cfg, h,
            (params["blocks"], cache["conv"], cache["ssm"],
             cache["k"], cache["v"]))
        new_cache.update(conv=convs, ssm=ssms, k=ks, v=vs)
    else:
        raise ValueError(cfg.family)

    logits = _head_logits(params, cfg, h)
    return logits, new_cache
