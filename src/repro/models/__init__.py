"""Model zoo: dense/MoE/SSM/hybrid/VLM/audio transformer families."""

from repro.models.config import ModelConfig, SMOKE_OVERRIDES
from repro.models.model import (
    cache_shapes,
    decode_step,
    forward_train,
    init_cache,
    init_params,
    param_defs,
    param_shapes,
    param_specs,
    prefill,
)

__all__ = [
    "ModelConfig", "SMOKE_OVERRIDES",
    "cache_shapes", "decode_step", "forward_train", "init_cache",
    "init_params", "param_defs", "param_shapes", "param_specs", "prefill",
]
