"""Unified model configuration covering all assigned architecture families.

One :class:`ModelConfig` drives the whole zoo: dense GQA transformers
(optionally qk-norm / M-RoPE / encoder-only), MoE transformers, Mamba2 (SSD)
stacks, and Zamba2-style hybrids (scanned Mamba2 blocks + one weight-shared
attention block applied periodically).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "SMOKE_OVERRIDES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attn-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # attention variants
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    causal: bool = True              # False for encoder-only (hubert)
    sliding_window: Optional[int] = None  # used by hybrid long-context cells

    # MoE
    n_experts: int = 0
    topk: int = 0
    capacity_factor: float = 1.25
    moe_local_dispatch: bool = False  # beyond-paper: shard-local dispatch

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    ssm_expand: int = 2

    # hybrid (Zamba2): apply the weight-shared attention block after every
    # `shared_attn_every`-th scanned Mamba2 block.
    shared_attn_every: int = 0

    # numerics / execution
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"          # compute dtype
    param_dtype: str = "float32"     # master weights
    remat: str = "full"              # none | full
    scan_layers: bool = True         # False: unroll (exact HLO cost analysis)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024
    attn_impl: str = "xla"           # xla (chunked online-softmax) | pallas
    fused_decode_gqa: bool = False   # beyond-paper: fused q@K/softmax/@V layout
    logits_chunk: int = 0            # beyond-paper: chunked LM head + CE (0 = off)
    seq_parallel: bool = False       # beyond-paper: shard saved activations
                                     # (scan carries) over the model axis

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def params_count(self) -> int:
        """Approximate parameter count (used for 6·N·D roofline accounting)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        emb = V * D * 2  # embed + untied head
        if self.family == "ssm":
            din, N, G, H = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
            per = D * (2 * din + 2 * G * N + H) + din * D  # in_proj + out_proj
            per += (din + 2 * G * N) * 4 + 2 * H + 2 * D + din  # conv/dt/A/D/norms
            return emb + L * per
        att = D * self.n_heads * self.hd + 2 * D * self.n_kv_heads * self.hd \
            + self.n_heads * self.hd * D
        if self.family == "moe":
            mlp = self.n_experts * 3 * D * F + D * self.n_experts  # experts + router
        else:
            mlp = 3 * D * F
        per = att + mlp + 2 * D
        total = emb + L * per
        if self.family == "hybrid":
            din, N, G, H = self.d_inner, self.ssm_state, self.ssm_groups, self.ssm_heads
            per_m = D * (2 * din + 2 * G * N + H) + din * D + \
                (din + 2 * G * N) * 4 + 2 * H + 2 * D + din
            total = emb + L * per_m + (att + 3 * D * F + 2 * D)  # one shared blk
        return total

    def active_params_count(self) -> int:
        """Active parameters per token (MoE counts only routed experts)."""
        if self.family != "moe":
            return self.params_count()
        D, F, L = self.d_model, self.d_ff, self.n_layers
        att = D * self.n_heads * self.hd + 2 * D * self.n_kv_heads * self.hd \
            + self.n_heads * self.hd * D
        mlp_active = self.topk * 3 * D * F + D * self.n_experts
        return self.vocab * D * 2 + L * (att + mlp_active + 2 * D)


# Reduced-config overrides for CPU smoke tests: same family/topology, tiny.
SMOKE_OVERRIDES = dict(
    n_layers=2,
    d_model=64,
    d_ff=128,
    vocab=256,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=16,
    n_experts=4,
    topk=2,
    shared_attn_every=2,
    sliding_window=None,
    attn_chunk_q=32,
    attn_chunk_kv=32,
    remat="none",
)
