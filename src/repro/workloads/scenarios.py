"""Named scenario catalog — stress workloads beyond the paper's two.

Each scenario is a seeded factory ``(n_tasks, seed) -> WorkflowTrace``
registered under a stable name, so tests, benchmarks and harness code all
pull the same workloads by name:

=================  =========================================================
``burst_arrival``  Barrier-wave DAG: whole waves release at once, slamming
                   the admission queue in bursts instead of a trickle.
``heavy_tail``     Heavy-tailed (lognormal, large sigma) memory and
                   duration — a few elephants among many mice; no DAG.
``deep_chain``     Interleaved deep dependency chains: release order is
                   serial per chain, parallel across chains.
``wide_fanout``    8-ary fan-out tree from one root: near-total
                   parallelism one hop after the root finishes.
``hetero_dt``      Families with different sampling periods, including one
                   family whose *own* history mixes dts (exercises
                   ``KSPlusAuto``'s hetero-dt policy once per process).
=================  =========================================================

``evaluate_workflow`` accepts these names directly (they adapt through
:meth:`WorkflowTrace.to_workflow`); ClusterSim replays come from
:meth:`WorkflowTrace.to_jobs`, DAG edges included.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.workloads.generate import (
    FamilyRecipe,
    WorkflowTrace,
    barrier_parents,
    chain_parents,
    fanout_parents,
    layered_parents,
    synthesize,
)

__all__ = ["ScenarioSpec", "SCENARIOS", "register_scenario",
           "scenario_names", "get"]


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str
    description: str
    factory: Callable[[int, int], WorkflowTrace]
    default_n: int = 512


SCENARIOS: Dict[str, ScenarioSpec] = {}


def register_scenario(name: str, description: str, default_n: int = 512):
    """Decorator: register ``factory(n_tasks, seed)`` as scenario ``name``."""
    def deco(factory):
        if name in SCENARIOS:
            raise ValueError(f"scenario already registered: {name!r}")
        SCENARIOS[name] = ScenarioSpec(
            name=name, description=description, factory=factory,
            default_n=default_n)
        return factory
    return deco


def scenario_names() -> List[str]:
    return list(SCENARIOS)


def get(name: str, *, n_tasks: Optional[int] = None,
        seed: int = 0) -> WorkflowTrace:
    """Build a catalog scenario by name."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario: {name!r} "
                       f"(registered: {', '.join(SCENARIOS)})")
    spec = SCENARIOS[name]
    return spec.factory(n_tasks if n_tasks is not None else spec.default_n,
                        seed)


def _split_counts(n: int, weights) -> List[int]:
    """Split ``n`` tasks across families by weight: every family gets at
    least one task (so tiny ``n`` is clamped to the family count) and the
    rounding drift is absorbed by the largest families, never below 1."""
    n = max(n, len(weights))
    total = sum(weights)
    counts = [max(int(round(n * w / total)), 1) for w in weights]
    while sum(counts) != n:
        i = counts.index(max(counts))
        counts[i] = max(counts[i] + (1 if sum(counts) < n else -1), 1)
    return counts


@register_scenario(
    "burst_arrival",
    "barrier-wave DAG: whole waves of mixed-shape tasks release at once",
    default_n=512)
def _burst_arrival(n_tasks: int, seed: int) -> WorkflowTrace:
    recipes = [
        FamilyRecipe("pilot", shape="plateau", dur_base=20.0, dur_per_gb=2.0,
                     mem_base=0.4, mem_per_gb=0.05, default_limit_gb=2.0),
        FamilyRecipe("burst_ramp", shape="ramp", dur_base=40.0,
                     dur_per_gb=12.0, mem_base=1.2, mem_per_gb=0.5,
                     ramp_frac=0.5, default_limit_gb=8.0),
        FamilyRecipe("burst_spike", shape="spike", dur_base=35.0,
                     dur_per_gb=8.0, mem_base=0.9, mem_per_gb=0.35,
                     spike_gain=2.4, default_limit_gb=8.0),
    ]
    counts = _split_counts(n_tasks, (1, 3, 3))
    wf = synthesize(recipes, counts, seed, name="burst_arrival")
    return dataclasses.replace(
        wf, parents=barrier_parents(wf.B, waves=max(n_tasks // 64, 4)))


@register_scenario(
    "heavy_tail",
    "heavy-tailed memory/runtime mix (elephants among mice), no DAG",
    default_n=512)
def _heavy_tail(n_tasks: int, seed: int) -> WorkflowTrace:
    recipes = [
        FamilyRecipe("mice", shape="plateau", dur_base=15.0, dur_per_gb=4.0,
                     mem_base=0.2, mem_per_gb=0.08, input_sigma=0.4,
                     mem_sigma=0.25, default_limit_gb=2.0),
        FamilyRecipe("elephants", shape="phases", dur_base=90.0,
                     dur_per_gb=40.0, mem_base=2.0, mem_per_gb=1.4,
                     input_sigma=0.9, mem_sigma=0.8, dur_sigma=0.5,
                     n_phases=4.0, default_limit_gb=24.0),
        FamilyRecipe("saw_io", shape="sawtooth", dur_base=45.0,
                     dur_per_gb=10.0, mem_base=0.8, mem_per_gb=0.4,
                     mem_sigma=0.5, cycles=6.0, default_limit_gb=8.0),
    ]
    counts = _split_counts(n_tasks, (8, 1, 3))
    return synthesize(recipes, counts, seed, name="heavy_tail")


@register_scenario(
    "deep_chain",
    "interleaved deep dependency chains (serial release per chain)",
    default_n=512)
def _deep_chain(n_tasks: int, seed: int) -> WorkflowTrace:
    recipes = [
        FamilyRecipe("stage", shape="ramp", dur_base=25.0, dur_per_gb=6.0,
                     mem_base=0.8, mem_per_gb=0.3, ramp_frac=0.4,
                     default_limit_gb=6.0),
        FamilyRecipe("checkpoint", shape="spike", dur_base=18.0,
                     dur_per_gb=3.0, mem_base=0.5, mem_per_gb=0.2,
                     spike_pos=0.9, spike_gain=1.8, default_limit_gb=4.0),
    ]
    counts = _split_counts(n_tasks, (3, 1))
    wf = synthesize(recipes, counts, seed, name="deep_chain")
    return dataclasses.replace(
        wf, parents=chain_parents(wf.B, chains=max(n_tasks // 64, 4)))


@register_scenario(
    "wide_fanout",
    "8-ary fan-out tree from one root (mass release after one task)",
    default_n=512)
def _wide_fanout(n_tasks: int, seed: int) -> WorkflowTrace:
    recipes = [
        FamilyRecipe("scatter", shape="plateau", dur_base=20.0,
                     dur_per_gb=5.0, mem_base=0.4, mem_per_gb=0.15,
                     default_limit_gb=4.0),
        FamilyRecipe("leafwork", shape="ramp", dur_base=30.0,
                     dur_per_gb=9.0, mem_base=0.9, mem_per_gb=0.4,
                     default_limit_gb=8.0),
    ]
    counts = _split_counts(n_tasks, (1, 3))
    wf = synthesize(recipes, counts, seed, name="wide_fanout")
    return dataclasses.replace(wf, parents=fanout_parents(wf.B, fanout=8))


@register_scenario(
    "hetero_dt",
    "families sampled at different dts, one family internally mixed",
    default_n=384)
def _hetero_dt(n_tasks: int, seed: int) -> WorkflowTrace:
    recipes = [
        FamilyRecipe("fast_probe", shape="spike", dur_base=30.0,
                     dur_per_gb=6.0, mem_base=0.6, mem_per_gb=0.25,
                     dt=0.5, default_limit_gb=4.0),
        FamilyRecipe("slow_batch", shape="phases", dur_base=80.0,
                     dur_per_gb=20.0, mem_base=1.2, mem_per_gb=0.5,
                     dt=2.0, n_phases=3.0, default_limit_gb=8.0),
        # One *family* with two sampling periods: its fit history is
        # heterogeneous, exercising KSPlusAuto's hetero_dt policy.
        FamilyRecipe("mixed", shape="ramp", dur_base=40.0, dur_per_gb=10.0,
                     mem_base=0.9, mem_per_gb=0.35, dt=1.0,
                     default_limit_gb=6.0),
        FamilyRecipe("mixed", shape="ramp", dur_base=40.0, dur_per_gb=10.0,
                     mem_base=0.9, mem_per_gb=0.35, dt=0.5,
                     default_limit_gb=6.0),
    ]
    counts = _split_counts(n_tasks, (1, 1, 1, 1))
    return synthesize(recipes, counts, seed, name="hetero_dt")


@register_scenario(
    "workload_replay",
    "layered random DAG at fleet scale — the workload_replay benchmark",
    default_n=5120)
def _workload_replay(n_tasks: int, seed: int) -> WorkflowTrace:
    recipes = [
        FamilyRecipe("etl", shape="ramp", dur_base=24.0, dur_per_gb=6.0,
                     mem_base=1.0, mem_per_gb=0.4, ramp_frac=0.5,
                     default_limit_gb=8.0),
        FamilyRecipe("train", shape="phases", dur_base=40.0,
                     dur_per_gb=10.0, mem_base=1.6, mem_per_gb=0.6,
                     n_phases=3.0, default_limit_gb=12.0),
        FamilyRecipe("score", shape="plateau", dur_base=16.0,
                     dur_per_gb=4.0, mem_base=0.5, mem_per_gb=0.2,
                     default_limit_gb=4.0),
        FamilyRecipe("compact", shape="sawtooth", dur_base=30.0,
                     dur_per_gb=5.0, mem_base=0.8, mem_per_gb=0.3,
                     cycles=5.0, default_limit_gb=6.0),
    ]
    counts = _split_counts(n_tasks, (3, 2, 4, 1))
    wf = synthesize(recipes, counts, seed, name="workload_replay")
    return dataclasses.replace(
        wf, parents=layered_parents(wf.B, seed=seed, layer_width=128,
                                    max_parents=2))
