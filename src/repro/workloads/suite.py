"""Robustness suite: scenario catalog x arrival process x fault schedule.

:func:`make_suite` enumerates a seeded grid of stress cases and
:func:`run_suite` replays each one through :class:`repro.sched.ClusterSim`,
producing one wastage / failure / doomed-work table
(:func:`suite_table`).  The default grid deliberately excludes
``heavy_tail`` — its elephants can exceed every node's capacity at
attempt 1, which the simulator now rejects at submit (fail-fast) — and
``workload_replay`` (fleet-scale; it has its own benchmark).

Every case is reproducible from its ``(scenario, arrival, fault, seed)``
tuple alone: arrivals and faults are seeded per-case, so the fused
engine's rows can be re-checked bitwise against the legacy oracle
(``check_oracle=True``, used by the CI smoke grid and
``benchmarks/run.py::bench_churn_replay``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import trace as _obs
from repro.workloads import scenarios as _scen
from repro.workloads.arrivals import diurnal_arrivals, poisson_arrivals

__all__ = ["SuiteCase", "make_suite", "run_suite", "suite_table",
           "DEFAULT_SCENARIOS", "DEFAULT_ARRIVALS", "DEFAULT_FAULTS"]

DEFAULT_SCENARIOS = ("burst_arrival", "deep_chain", "wide_fanout")
DEFAULT_ARRIVALS = ("none", "poisson", "diurnal")
DEFAULT_FAULTS = ("none", "storm", "churn")


@dataclasses.dataclass(frozen=True)
class SuiteCase:
    """One grid point; fully determines a replay given a fleet."""

    scenario: str
    arrival: str                 # "none" | "poisson" | "diurnal"
    fault: str                   # "none" | "storm" | "churn" | "rack"
    seed: int = 0
    n_tasks: Optional[int] = None

    @property
    def name(self) -> str:
        return f"{self.scenario}/{self.arrival}/{self.fault}/s{self.seed}"


def make_suite(scenarios: Sequence[str] = DEFAULT_SCENARIOS,
               arrivals: Sequence[str] = DEFAULT_ARRIVALS,
               faults: Sequence[str] = DEFAULT_FAULTS,
               seeds: Sequence[int] = (0,),
               n_tasks: Optional[int] = None) -> List[SuiteCase]:
    """The full seeded grid, scenario-major (stable, documented order)."""
    for s in scenarios:
        if s not in _scen.SCENARIOS:
            raise KeyError(f"unknown scenario: {s!r}")
    bad_a = set(arrivals) - set(DEFAULT_ARRIVALS)
    if bad_a:
        raise ValueError(f"unknown arrival kinds: {sorted(bad_a)}")
    bad_f = set(faults) - {"none", "storm", "churn", "rack"}
    if bad_f:
        raise ValueError(f"unknown fault kinds: {sorted(bad_f)}")
    return [SuiteCase(s, a, f, seed=sd, n_tasks=n_tasks)
            for s in scenarios for a in arrivals for f in faults
            for sd in seeds]


def _case_jobs(case: SuiteCase, n_tasks: int):
    wf = _scen.get(case.scenario, n_tasks=n_tasks, seed=case.seed)
    if case.arrival == "poisson":
        rel = poisson_arrivals(wf.B, rate=0.5, seed=case.seed,
                               parents=wf.parents)
        wf = dataclasses.replace(wf, release_times=rel)
    elif case.arrival == "diurnal":
        rel = diurnal_arrivals(wf.B, base_rate=0.5, period=600.0,
                               depth=0.8, seed=case.seed,
                               parents=wf.parents)
        wf = dataclasses.replace(wf, release_times=rel)
    return wf.to_jobs(seed=case.seed, under_frac=0.15)


def _case_faults(case: SuiteCase, nodes):
    from repro.sched.faults import FaultSchedule
    if case.fault == "none":
        return None
    if case.fault == "storm":
        return FaultSchedule.preemption_storm(
            nodes, t=60.0, frac=0.5, seed=case.seed, down_time=120.0)
    if case.fault == "churn":
        return FaultSchedule.node_churn(
            nodes, rate=1.0 / 120.0, horizon=900.0, seed=case.seed,
            mean_down=90.0)
    # "rack": the odd-numbered nodes share one failure domain
    rack_of = {int(n.nid): int(n.nid) % 2 for n in nodes}
    return FaultSchedule.rack_failure(nodes, rack_of, rack=1, t=90.0,
                                      down_time=180.0)


def _default_nodes():
    from repro.sched import Node
    return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0)]


def run_suite(cases: Sequence[SuiteCase], nodes=None, retry=None,
              engine: str = "fused", n_tasks: int = 96,
              check_oracle: bool = False) -> List[Dict[str, object]]:
    """Replay each case; one metrics row per case.

    With ``check_oracle`` every case is replayed twice and the fused (or
    packed) placement log is asserted bitwise-identical to the legacy
    per-job oracle — the robustness suite's differential guarantee.
    """
    from repro.core import RetrySpec, ksplus_retry
    from repro.sched import ClusterSim

    from repro.sched import Node

    if retry is None:
        retry = RetrySpec("ksplus")

    def fresh_fleet():
        base = nodes() if callable(nodes) else nodes
        if base is None:
            return _default_nodes()
        return [Node(n.nid, n.capacity_gb) for n in base]

    rows: List[Dict[str, object]] = []
    for case in cases:
        nt = case.n_tasks or n_tasks
        fleet = fresh_fleet()
        jobs = _case_jobs(case, nt)
        faults = _case_faults(case, fleet)
        if _obs.enabled:
            with _obs.span("suite.case", case=case.name, jobs=len(jobs)):
                res = ClusterSim(fleet, engine=engine).run(jobs, retry,
                                                           faults=faults)
        else:
            res = ClusterSim(fleet, engine=engine).run(jobs, retry,
                                                       faults=faults)
        if check_oracle:
            oracle = ClusterSim(fresh_fleet(), engine="legacy").run(
                _case_jobs(case, nt), ksplus_retry, faults=faults)
            if oracle.placements != res.placements:
                raise AssertionError(
                    f"{case.name}: {engine} placements diverge from the "
                    f"legacy oracle")
            np.testing.assert_allclose(
                res.total_wastage_gbs, oracle.total_wastage_gbs, rtol=1e-6)
        rows.append({
            "case": case.name,
            "jobs": len(jobs),
            "makespan": float(res.makespan),
            "wastage_gbs": float(res.total_wastage_gbs),
            "utilization": float(res.avg_utilization),
            "retries": int(res.retries),
            "evictions": int(res.evictions),
            "unschedulable": int(res.unschedulable),
            "doomed": int(res.doomed),
            "starved": int(res.starved),
            "starvation_s": float(res.starvation_s),
            "finished": int(res.finished),
        })
    return rows


_COLS: Tuple[Tuple[str, int], ...] = (
    ("case", 34), ("jobs", 6), ("makespan", 10), ("wastage_gbs", 12),
    ("utilization", 6), ("retries", 7), ("evictions", 6),
    ("unschedulable", 7), ("doomed", 6), ("starved", 7),
    ("starvation_s", 12),
)


def suite_table(rows: Sequence[Dict[str, object]]) -> str:
    """Fixed-width text table of :func:`run_suite` rows."""
    head = "  ".join(f"{name:>{w}}" if name != "case" else f"{name:<{w}}"
                     for name, w in _COLS)
    lines = [head, "-" * len(head)]
    for r in rows:
        cells = []
        for name, w in _COLS:
            v = r[name]
            if isinstance(v, float):
                cells.append(f"{v:>{w}.2f}")
            elif name == "case":
                cells.append(f"{v:<{w}}")
            else:
                cells.append(f"{v:>{w}}")
        lines.append("  ".join(cells))
    return "\n".join(lines)
