"""wfcommons / WorkflowHub instance import (and export).

`wfcommons <https://wfcommons.org>`_ publishes real workflow executions as
JSON *instances* (the WfFormat): a task graph plus per-task measurements.
This module turns such an instance into the same :class:`WorkflowTrace` +
DAG representation the synthetic generator emits, so imported workloads
flow through every consumer unchanged — DAG-aware :class:`ClusterSim`
replay, ``evaluate_workflow``, offset tuning, the fleet engine.

Two layouts are understood:

* **WfFormat >= 1.4** — tasks under ``workflow.specification.tasks``
  (``id``, ``name``, ``parents`` as id lists), measurements under
  ``workflow.execution.tasks`` (``runtimeInSeconds``,
  ``memoryInBytes``);
* **legacy (<= 1.3)** — tasks inline under ``workflow.tasks`` (or
  ``workflow.jobs``) with ``runtime`` seconds, ``memory`` bytes and
  ``parents`` as name lists.

wfcommons instances carry *peak* memory only, so each imported task gets a
noise-free plateau trace at its peak over its measured runtime (the
honest reconstruction — any richer time structure would be invented),
materialized through the generator's packed-lane kernel
(:func:`repro.workloads.generate.materialize_traces`).

Schema validation is loud: missing sections, duplicate ids, unknown
parent references, self-parents and dependency cycles all raise
``ValueError`` naming the offending task ids.  ``export_instance`` writes
a WfFormat-1.4-shaped document back out; import(export(x)) round-trips
the task graph and measurements exactly (pinned in
``tests/test_workloads.py``).
"""

from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.workloads.generate import (
    _SHAPE_ID,
    WorkflowTrace,
    materialize_traces,
)

__all__ = ["load_instance", "import_instance", "export_instance",
           "validate_dag_ids"]

_GIB = float(2 ** 30)


def validate_dag_ids(ids: Sequence, parents: Sequence[Sequence],
                     kind: str = "task") -> None:
    """Validate a task graph given as (id, parent-ids) lists — loudly.

    Raises ``ValueError`` naming the offending ids for duplicates,
    unknown parent references, self-parents, and dependency cycles
    (Kahn's algorithm residue).  The single validator behind both the
    wfcommons importer (string ids) and :class:`ClusterSim`'s submit-time
    DAG check (integer jids, ``kind="job"``).
    """
    seen, dups = set(), set()
    for i in ids:
        (dups if i in seen else seen).add(i)
    if dups:
        raise ValueError(f"duplicate {kind} ids: {sorted(dups)}")
    index = {tid: k for k, tid in enumerate(ids)}
    selfdep = sorted(tid for tid, ps in zip(ids, parents) if tid in ps)
    if selfdep:
        raise ValueError(f"{kind}s cannot be their own parent: {selfdep}")
    unknown = {tid: sorted(p for p in ps if p not in index)
               for tid, ps in zip(ids, parents)}
    unknown = {t: m for t, m in unknown.items() if m}
    if unknown:
        first = next(iter(unknown))
        raise ValueError(
            f"{kind} {first!r} references unknown parent ids: "
            f"{unknown[first]} ({len(unknown)} {kind}(s) affected)")
    # Kahn: whatever never reaches in-degree 0 sits on a cycle.
    pending = np.zeros(len(ids), np.int64)
    children: List[List[int]] = [[] for _ in ids]
    for k, ps in enumerate(parents):
        for p in dict.fromkeys(ps):
            children[index[p]].append(k)
            pending[k] += 1
    stack = [k for k in range(len(ids)) if pending[k] == 0]
    reached = 0
    while stack:
        k = stack.pop()
        reached += 1
        for c in children[k]:
            pending[c] -= 1
            if pending[c] == 0:
                stack.append(c)
    if reached != len(ids):
        cyc = sorted(ids[k] for k in range(len(ids)) if pending[k] > 0)
        raise ValueError(f"dependency cycle among task ids: {cyc}")


_TRAIL = re.compile(r"[_\-.]?\d+$")


def _category(name: str) -> str:
    """Task family from a task name: strip the trailing instance number
    (``blast_00000042`` -> ``blast``), the wfcommons naming convention."""
    return _TRAIL.sub("", name) or name


def _parse_tasks(doc: dict) -> List[dict]:
    """Normalize either WfFormat layout into
    ``{id, name, parents, runtime, memory_gb}`` records."""
    wf = doc.get("workflow")
    if not isinstance(wf, dict):
        raise ValueError(
            "not a wfcommons instance: missing 'workflow' object")
    out = []
    spec = wf.get("specification")
    if isinstance(spec, dict) and "tasks" in spec:
        execs = {t.get("id"): t
                 for t in wf.get("execution", {}).get("tasks", [])}
        missing = []
        for t in spec["tasks"]:
            tid = t.get("id")
            if tid is None:
                raise ValueError(
                    f"specification task without an 'id': {t.get('name')!r}")
            ex = execs.get(tid, {})
            if "runtimeInSeconds" not in ex or "memoryInBytes" not in ex:
                missing.append(str(tid))
                continue
            out.append(dict(
                id=str(tid), name=str(t.get("name", tid)),
                parents=[str(p) for p in t.get("parents", [])],
                runtime=float(ex["runtimeInSeconds"]),
                memory_gb=float(ex["memoryInBytes"]) / _GIB))
        if missing:
            raise ValueError(
                "tasks without runtime/memory measurements in "
                f"'workflow.execution.tasks': {sorted(missing)} — traces "
                "cannot be reconstructed from the specification alone")
        return out
    tasks = wf.get("tasks", wf.get("jobs"))
    if not isinstance(tasks, list):
        raise ValueError(
            "not a wfcommons instance: expected 'workflow.specification."
            "tasks' (WfFormat >= 1.4) or 'workflow.tasks' (legacy)")
    missing = []
    for t in tasks:
        tid = t.get("id", t.get("name"))
        if tid is None:
            raise ValueError(f"task without an 'id' or 'name': {t!r}")
        if "runtime" not in t or "memory" not in t:
            missing.append(str(tid))
            continue
        out.append(dict(
            id=str(tid), name=str(t.get("name", tid)),
            parents=[str(p) for p in t.get("parents", [])],
            runtime=float(t["runtime"]),
            memory_gb=float(t["memory"]) / _GIB))
    if missing:
        raise ValueError(
            f"tasks without 'runtime'/'memory' fields: {sorted(missing)}")
    # Legacy parents reference task *names*; translate names -> ids where
    # the parent is not already a known id (id == name is the common case).
    ids = {t["id"] for t in out}
    by_name = {t["name"]: t["id"] for t in out}
    for t in out:
        t["parents"] = [p if p in ids else by_name.get(p, p)
                        for p in t["parents"]]
    return out


def import_instance(doc: dict, *, dt: float = 1.0,
                    name: Optional[str] = None) -> WorkflowTrace:
    """A validated :class:`WorkflowTrace` from a wfcommons instance dict.

    Peak-only measurements become noise-free plateau traces at
    ``memoryInBytes`` over ``runtimeInSeconds`` (sampled every ``dt``
    seconds), packed straight into fleet lanes; families come from the
    task-name category (trailing instance numbers stripped).
    """
    tasks = _parse_tasks(doc)
    ids = [t["id"] for t in tasks]
    validate_dag_ids(ids, [t["parents"] for t in tasks])
    index = {tid: k for k, tid in enumerate(ids)}
    B = len(tasks)
    if B == 0:
        raise ValueError("instance contains no tasks")
    lengths = np.maximum(
        np.ceil(np.asarray([t["runtime"] for t in tasks]) / dt - 1e-9),
        1.0).astype(np.int64)
    level = np.maximum(
        np.asarray([t["memory_gb"] for t in tasks], np.float64), 1e-3)
    batch = materialize_traces(
        np.full((B,), _SHAPE_ID["plateau"], np.float32),
        level.astype(np.float32), lengths,
        np.zeros((B, 3), np.float32), np.zeros((B,), np.float32), seed=0)
    families = [_category(t["name"]) for t in tasks]
    return WorkflowTrace(
        name=(name if name is not None
              else str(doc.get("name", "wfcommons"))),
        task_ids=ids, families=families,
        input_gb=level.copy(),     # proxy: peak memory tracks input size
        dts=np.full((B,), float(dt)),
        lengths=lengths,
        parents=tuple(tuple(index[p] for p in t["parents"])
                      for t in tasks),
        batch=batch,
        default_limits={f: 8.0 for f in families})


def load_instance(path, *, dt: float = 1.0,
                  name: Optional[str] = None) -> WorkflowTrace:
    """:func:`import_instance` on a JSON file path."""
    with open(path) as f:
        return import_instance(json.load(f), dt=dt, name=name)


def export_instance(trace: WorkflowTrace) -> dict:
    """A WfFormat-1.4-shaped instance dict for ``trace``.

    Emits the task graph (specification) and per-task runtime / peak
    memory (execution); time structure beyond the peak is not part of the
    format, so ``import_instance(export_instance(t))`` reconstructs
    plateau traces — graph, runtimes and peaks round-trip exactly.
    """
    children: Dict[int, List[int]] = {i: [] for i in range(trace.B)}
    for i, ps in enumerate(trace.parents):
        for p in ps:
            children[p].append(i)
    peaks = trace.peaks()
    spec_tasks, exec_tasks = [], []
    for i in range(trace.B):
        tid = trace.task_ids[i]
        spec_tasks.append({
            "id": tid,
            # wfcommons naming convention: category + instance number —
            # re-import recovers the task family from it.
            "name": f"{trace.families[i]}_{i:08d}",
            "parents": [trace.task_ids[p] for p in trace.parents[i]],
            "children": [trace.task_ids[c] for c in children[i]],
        })
        exec_tasks.append({
            "id": tid,
            "runtimeInSeconds": float(trace.lengths[i] * trace.dts[i]),
            "memoryInBytes": float(peaks[i] * _GIB),
        })
    return {
        "name": trace.name,
        "schemaVersion": "1.4",
        "workflow": {
            "specification": {"tasks": spec_tasks},
            "execution": {"tasks": exec_tasks},
        },
    }
