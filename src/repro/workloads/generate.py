"""Seeded, jax-vectorized synthetic workload generation.

The paper evaluates on two nf-core workflows; :mod:`repro.traces.generator`
reproduces those two faithfully but builds every execution in a Python
loop.  This module is the *scale* path: task-family recipes are synthesized
**directly into the fleet engine's packed ``(B, T)`` lane layout** — per
length bucket, one jitted XLA dispatch materializes the whole ``(B, T)``
memory-over-time matrix from per-lane shape parameters, so a 10k-task
fleet costs a handful of batched dispatches instead of 10k Python-level
trace constructions.

Recipes compose three ingredients:

* a **parametric shape** (:data:`SHAPES`): ``plateau`` (flat), ``ramp``
  (load then hold), ``spike`` (flat with a short high excursion),
  ``sawtooth`` (periodic fill/flush cycles), ``phases`` (ascending step
  levels — the multi-phase profile KS+ segments),
* **input-size scaling laws**: durations and memory levels are affine in
  the task's (lognormal) input size, mirroring the paper's §II-B
  observation that phases scale differently with input size,
* **noise**: lognormal per-task duration/memory factors plus per-sample
  multiplicative jitter.

Everything is reproducible bit for bit from ``(recipes, counts, seed)`` —
the generator threads one ``jax.random`` key tree through every dispatch
(`tests/test_workloads.py` pins bitwise identity across calls).

The output :class:`WorkflowTrace` carries the packed
:class:`repro.core.fleet.FleetBatch`, per-task metadata and **DAG edges**
(``parents``), and adapts into every consumer: ``to_jobs`` for
:class:`repro.sched.cluster.ClusterSim` (dependency-aware replay),
``to_workflow`` for :func:`repro.sched.simulator.evaluate_workflow`, raw
``mems()`` for :func:`repro.core.registry.tune_offset` and the fleet
engine.  DAG shapes (chains, fan-out trees, random layered DAGs, barrier
waves) are built by the ``*_parents`` helpers; the wfcommons importer
(:mod:`repro.workloads.wfc`) produces the same representation.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fleet import FleetBatch, TraceBucket, _bucket, group_lengths

__all__ = [
    "SHAPES",
    "FamilyRecipe",
    "WorkflowTrace",
    "ScenarioWorkflow",
    "synthesize",
    "materialize_traces",
    "chain_parents",
    "fanout_parents",
    "layered_parents",
    "barrier_parents",
    "assert_release_order",
]

SHAPES = ("plateau", "ramp", "spike", "sawtooth", "phases")


@dataclasses.dataclass(frozen=True)
class FamilyRecipe:
    """One task family: a shape plus input-size scaling laws and noise.

    ``duration = (dur_base + dur_per_gb * I) * lognormal(dur_sigma)`` and
    ``level = (mem_base + mem_per_gb * I) * lognormal(mem_sigma)`` with
    ``I ~ input_median_gb * lognormal(input_sigma)``; the shape modulates
    ``level`` over normalized time.  Two recipes may share a ``name`` —
    their tasks then belong to one task family (the hetero-dt scenario
    mixes sampling periods inside a family this way).
    """

    name: str
    shape: str = "plateau"
    dur_base: float = 30.0
    dur_per_gb: float = 10.0
    mem_base: float = 0.5
    mem_per_gb: float = 0.25
    input_median_gb: float = 3.0
    input_sigma: float = 0.30
    dur_sigma: float = 0.10
    mem_sigma: float = 0.05
    noise: float = 0.01          # per-sample multiplicative jitter
    dt: float = 1.0
    default_limit_gb: float = 8.0
    # Shape parameters (meaning depends on ``shape``):
    ramp_frac: float = 0.6       # ramp: fraction of runtime spent ramping
    spike_pos: float = 0.8       # spike: center (fraction of runtime)
    spike_frac: float = 0.08     # spike: width (fraction of runtime)
    spike_gain: float = 2.0      # spike: height multiplier on the plateau
    cycles: float = 4.0          # sawtooth: fill/flush cycles
    n_phases: float = 3.0        # phases: number of ascending steps

    def __post_init__(self):
        if self.shape not in SHAPES:
            raise ValueError(
                f"unknown shape {self.shape!r} (choose from {SHAPES})")


# One packed parameter triple per lane; meaning depends on the shape id.
_SHAPE_ID = {s: i for i, s in enumerate(SHAPES)}


def _recipe_params(r: FamilyRecipe) -> Tuple[float, float, float]:
    if r.shape == "ramp":
        return (r.ramp_frac, 0.0, 0.0)
    if r.shape == "spike":
        return (r.spike_frac, r.spike_pos, r.spike_gain)
    if r.shape == "sawtooth":
        return (0.0, 0.0, r.cycles)
    if r.shape == "phases":
        return (0.0, 0.0, r.n_phases)
    return (0.0, 0.0, 0.0)  # plateau


@functools.lru_cache(maxsize=None)
def _kernels():
    """Build (once, lazily) the jitted generation kernels."""
    import jax
    import jax.numpy as jnp

    @functools.partial(jax.jit, static_argnames=("n",))
    def scalars(key, median, in_sigma, dur_base, dur_per_gb, dur_sigma,
                mem_base, mem_per_gb, mem_sigma, *, n):
        """Per-task input sizes, durations and memory levels — one family,
        one dispatch."""
        k1, k2, k3 = jax.random.split(key, 3)
        I = median * jnp.exp(in_sigma * jax.random.normal(k1, (n,)))
        dur = (dur_base + dur_per_gb * I) \
            * jnp.exp(dur_sigma * jax.random.normal(k2, (n,)))
        level = (mem_base + mem_per_gb * I) \
            * jnp.exp(mem_sigma * jax.random.normal(k3, (n,)))
        return I, dur, level

    @functools.partial(jax.jit, static_argnames=("T",))
    def traces(key, shape_id, level, lengths, p1, p2, p3, noise, *, T):
        """The whole ``(B, T)`` memory matrix of one length bucket in one
        dispatch: evaluate every lane's shape on the shared sample grid,
        then apply per-sample jitter.  Lanes with ``lengths == 0`` (lane
        padding) come out all-zero."""
        t = jnp.arange(T, dtype=jnp.float32)[None, :]
        Lr = lengths.astype(jnp.float32)[:, None]
        L = jnp.maximum(Lr, 1.0)
        u = t / L                                   # normalized time [0, 1)
        lev = level[:, None]
        a, c, g = p1[:, None], p2[:, None], p3[:, None]
        sid = shape_id[:, None]
        plateau = lev
        ramp = lev * (0.15 + 0.85 * jnp.minimum(
            u / jnp.maximum(a, 1e-6), 1.0))
        spike = lev * jnp.where(jnp.abs(u - c) <= a * 0.5, g, 1.0)
        saw = lev * (0.30 + 0.70 * jnp.mod(u * jnp.maximum(g, 1.0), 1.0))
        phases = lev * (0.30 + 0.70
                        * (jnp.floor(u * jnp.maximum(g, 1.0)) + 1.0)
                        / jnp.maximum(g, 1.0))
        mem = jnp.select([sid == 0, sid == 1, sid == 2, sid == 3, sid == 4],
                         [plateau, ramp, spike, saw, phases], lev)
        jitter = 1.0 + noise[:, None] * jax.random.normal(
            key, mem.shape, dtype=jnp.float32)
        mem = jnp.maximum(mem * jitter, 0.01)
        return jnp.where(t < Lr, mem, 0.0).astype(jnp.float32)

    return scalars, traces


def materialize_traces(shape_id: np.ndarray, level: np.ndarray,
                       lengths: np.ndarray, params: np.ndarray,
                       noise: np.ndarray, seed: int) -> FleetBatch:
    """Packed ``(B, T)`` lane traces from per-task shape parameters.

    The shared device path of the generator and the wfcommons importer:
    length-buckets the lanes (:func:`repro.core.fleet.group_lengths`, the
    same policy the fleet's own ``bucket_traces`` uses), pads each
    bucket's lane axis to a power of two, and materializes each bucket
    with ONE jitted dispatch.  Returns a ready-to-probe
    :class:`FleetBatch` whose bucket ``idx`` is the task index space.
    """
    import jax
    import jax.numpy as jnp

    _, traces_fn = _kernels()
    B = int(len(lengths))
    lengths = np.asarray(lengths, np.int64)
    key = jax.random.PRNGKey(np.uint32(seed))
    buckets = []
    for bi, (T, idx) in enumerate(group_lengths(lengths)):
        b = len(idx)
        Bp = _bucket(b)
        pad = Bp - b

        def lane(a, fill=0.0):
            a = np.asarray(a, np.float32)[idx]
            return jnp.asarray(np.concatenate(
                [a, np.full((pad,), fill, np.float32)]))

        bkey = jax.random.fold_in(key, bi)
        mems = np.asarray(traces_fn(
            bkey, lane(shape_id), lane(level),
            jnp.asarray(np.concatenate(
                [lengths[idx], np.zeros((pad,), np.int64)])),
            lane(params[:, 0]), lane(params[:, 1]), lane(params[:, 2]),
            lane(noise), T=T))
        plen = np.concatenate(
            [lengths[idx], np.zeros((pad,), np.int64)]).astype(np.int32)
        summem = mems.sum(axis=1, dtype=np.float64).astype(np.float32)
        memsneg = np.where(
            np.arange(T)[None, :] < plen[:, None], mems, -np.inf
        ).astype(np.float32)
        buckets.append(TraceBucket(
            idx=idx, mems=mems[:b], lengths=plen[:b],
            dmems=jnp.asarray(mems), dmemsneg=jnp.asarray(memsneg),
            dlengths=jnp.asarray(plen), dsummem=jnp.asarray(summem)))
    return FleetBatch(n=B, buckets=tuple(buckets))


# --------------------------------------------------------------- DAG shapes
def chain_parents(B: int, chains: int = 1) -> Tuple[Tuple[int, ...], ...]:
    """``chains`` interleaved deep chains: task i depends on i - chains."""
    return tuple(() if i < chains else (i - chains,) for i in range(B))


def fanout_parents(B: int, fanout: int = 8) -> Tuple[Tuple[int, ...], ...]:
    """A ``fanout``-ary tree rooted at task 0 (wide fan-out release)."""
    return tuple(() if i == 0 else ((i - 1) // fanout,) for i in range(B))


def layered_parents(B: int, seed: int = 0, layer_width: int = 64,
                    max_parents: int = 3) -> Tuple[Tuple[int, ...], ...]:
    """Random layered DAG: tasks in layer L draw 1..max_parents parents
    uniformly from layer L-1 (seeded, deterministic)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xDA6]))
    parents: List[Tuple[int, ...]] = []
    for i in range(B):
        layer = i // layer_width
        if layer == 0:
            parents.append(())
            continue
        lo, hi = (layer - 1) * layer_width, min(layer * layer_width, B)
        k = int(rng.integers(1, max_parents + 1))
        ps = rng.choice(np.arange(lo, hi), size=min(k, hi - lo),
                        replace=False)
        parents.append(tuple(int(p) for p in sorted(ps)))
    return tuple(parents)


def barrier_parents(B: int, waves: int = 8) -> Tuple[Tuple[int, ...], ...]:
    """Burst-arrival structure: tasks split into ``waves``; every task of
    wave w depends on wave w-1's *pilot* (its first task), so whole waves
    release at once — the cluster sees bursts, not a steady trickle."""
    per = max(B // waves, 1)
    parents: List[Tuple[int, ...]] = []
    for i in range(B):
        wave = min(i // per, waves - 1)
        if wave == 0:
            parents.append(())
        else:
            parents.append(((wave - 1) * per,))
    return tuple(parents)


# ------------------------------------------------------------ WorkflowTrace
@dataclasses.dataclass
class WorkflowTrace:
    """A workload: packed lane traces + per-task metadata + DAG edges.

    Lane ``i`` of ``batch`` is task ``i``; ``parents[i]`` are task indices
    that must finish before task ``i`` may start (empty tuple = root).
    The wfcommons importer and the synthetic generator both produce this.
    """

    name: str
    task_ids: List[str]
    families: List[str]
    input_gb: np.ndarray                 # (B,) float64
    dts: np.ndarray                      # (B,) float64
    lengths: np.ndarray                  # (B,) int64
    parents: Tuple[Tuple[int, ...], ...]
    batch: FleetBatch
    default_limits: Dict[str, float]
    release_times: Optional[np.ndarray] = None  # (B,) float64, roots only
    _loc: Optional[np.ndarray] = None    # (B, 2): bucket #, row #

    def __post_init__(self):
        loc = np.zeros((self.B, 2), np.int64)
        for bi, bucket in enumerate(self.batch.buckets):
            loc[bucket.idx, 0] = bi
            loc[bucket.idx, 1] = np.arange(len(bucket.idx))
        self._loc = loc

    @property
    def B(self) -> int:
        return int(self.batch.n)

    def mem(self, i: int) -> np.ndarray:
        """Task ``i``'s memory trace (float64 copy of its packed lane)."""
        bi, row = self._loc[i]
        bucket = self.batch.buckets[bi]
        return np.asarray(bucket.mems[row, : self.lengths[i]], np.float64)

    def mems(self) -> List[np.ndarray]:
        return [self.mem(i) for i in range(self.B)]

    def peaks(self) -> np.ndarray:
        """Per-task peak memory (GB), straight from the packed lanes."""
        out = np.zeros((self.B,), np.float64)
        for bucket in self.batch.buckets:
            valid = (np.arange(bucket.mems.shape[1])[None, :]
                     < bucket.lengths[:, None])
            out[bucket.idx] = np.max(
                np.where(valid, bucket.mems, 0.0), axis=1)
        return out

    def runtimes(self) -> np.ndarray:
        return self.lengths * self.dts

    # ------------------------------------------------------------- adapters
    def to_jobs(self, plans=None, *, margin: float = 1.12,
                under_frac: float = 0.0, seed: int = 0):
        """ClusterSim jobs (with DAG edges) for this workload.

        ``plans`` may be per-task :class:`AllocationPlan`s (e.g. from a
        fitted method); without them, 2-segment oracle-with-margin plans
        are derived from the hidden traces — ``under_frac`` of the tasks
        get an under-allocated second segment so the OOM/retry path is
        exercised (seeded, deterministic).
        """
        from repro.core.allocation import AllocationPlan
        from repro.sched.cluster import Job

        rng = np.random.default_rng(np.random.SeedSequence([seed, 0x70B5]))
        under = rng.uniform(size=self.B) < under_frac
        jobs = []
        for i in range(self.B):
            mem = self.mem(i)
            if plans is not None:
                plan = plans[i]
            else:
                L = len(mem)
                split = max(int(0.5 * L), 1)
                head = float(mem[:split].max())
                peak = float(mem.max())
                scale = 0.93 if under[i] else margin
                plan = AllocationPlan(
                    starts=np.asarray([0.0, max((split - 2) * self.dts[i],
                                                self.dts[i])]),
                    peaks=np.asarray([head * margin,
                                      max(peak * scale, head * margin)]))
            jobs.append(Job(
                jid=i, family=self.families[i],
                input_gb=float(self.input_gb[i]), mem=mem,
                dt=float(self.dts[i]), plan=plan,
                est_runtime=float(self.lengths[i] * self.dts[i]),
                parents=tuple(self.parents[i]),
                release_time=(0.0 if self.release_times is None
                              else float(self.release_times[i]))))
        return jobs

    def to_workflow(self) -> "ScenarioWorkflow":
        """Adapter for :func:`repro.sched.simulator.evaluate_workflow`."""
        from repro.traces.generator import Execution

        execs: Dict[str, List] = {}
        for i in range(self.B):
            execs.setdefault(self.families[i], []).append(Execution(
                self.families[i], float(self.input_gb[i]),
                float(self.dts[i]), self.mem(i)))
        fams = {f: _FamilyView(f, self.default_limits.get(f, 8.0))
                for f in execs}
        return ScenarioWorkflow(name=self.name, families=fams, _execs=execs)


@dataclasses.dataclass(frozen=True)
class _FamilyView:
    name: str
    default_limit_gb: float


@dataclasses.dataclass
class ScenarioWorkflow:
    """Duck-typed :class:`repro.traces.generator.Workflow` over a
    materialized :class:`WorkflowTrace` — ``evaluate_workflow`` and
    ``run_paper_experiment`` consume it unchanged.  The executions are
    fixed (the trace's own seed governs them); ``split`` seeds only the
    train/test permutation, exactly like ``Workflow.split``.
    """

    name: str
    families: Dict[str, _FamilyView]
    _execs: Dict[str, List]

    def generate(self, seed: int = 0, dt: float = 1.0):
        return self._execs

    def split(self, seed: int, train_frac: float, dt: float = 1.0):
        rng = np.random.default_rng(np.random.SeedSequence([seed, 7]))
        train: Dict[str, List] = {}
        test: Dict[str, List] = {}
        for fname, execs in self._execs.items():
            perm = rng.permutation(len(execs))
            n_train = max(int(round(train_frac * len(execs))), 2)
            idx_train = set(perm[:n_train].tolist())
            train[fname] = [e for i, e in enumerate(execs) if i in idx_train]
            test[fname] = [e for i, e in enumerate(execs)
                           if i not in idx_train]
        return train, test


# ---------------------------------------------------------------- generator
def synthesize(recipes: Sequence[FamilyRecipe], counts,
               seed: int = 0, *, name: str = "synthetic",
               parents: Optional[Sequence[Sequence[int]]] = None
               ) -> WorkflowTrace:
    """Generate a workload straight into packed lanes.

    ``counts`` is per-recipe instance counts (an int applies to every
    recipe).  Tasks are laid out recipe-major (recipe 0's tasks first), so
    ``parents`` — per-task parent indices, e.g. from
    :func:`layered_parents` — refers to that order.  One jitted scalar
    dispatch per recipe plus one trace dispatch per length bucket: a
    10k-task fleet materializes in a handful of XLA calls.
    """
    import jax

    if isinstance(counts, int):
        counts = [counts] * len(recipes)
    if len(counts) != len(recipes):
        raise ValueError(f"{len(counts)} counts vs {len(recipes)} recipes")
    scalars_fn, _ = _kernels()
    key = jax.random.PRNGKey(np.uint32(seed))

    shape_id, level, lengths, params, noise = [], [], [], [], []
    families: List[str] = []
    task_ids: List[str] = []
    input_gb, dts = [], []
    limits: Dict[str, float] = {}
    for ri, (r, n) in enumerate(zip(recipes, counts)):
        if n <= 0:
            continue
        # Fold in the recipe *position* as well as its identity: two
        # recipes that happen to share (name, shape, dt) must still draw
        # independent task populations.
        fkey = jax.random.fold_in(
            jax.random.fold_in(key, ri),
            zlib.crc32(f"{r.name}/{r.shape}/{r.dt}".encode()) % (2 ** 31))
        I, dur, lev = scalars_fn(
            fkey, r.input_median_gb, r.input_sigma, r.dur_base,
            r.dur_per_gb, r.dur_sigma, r.mem_base, r.mem_per_gb,
            r.mem_sigma, n=int(n))
        I = np.asarray(I, np.float64)
        L = np.maximum(np.round(np.asarray(dur, np.float64) / r.dt), 2.0)
        base = len(families)
        families.extend([r.name] * n)
        task_ids.extend(f"{r.name}_{base + j:08d}" for j in range(n))
        input_gb.append(I)
        dts.append(np.full((n,), float(r.dt)))
        lengths.append(L.astype(np.int64))
        shape_id.append(np.full((n,), _SHAPE_ID[r.shape], np.float32))
        level.append(np.asarray(lev, np.float32))
        params.append(np.tile(np.asarray(_recipe_params(r), np.float32),
                              (n, 1)))
        noise.append(np.full((n,), r.noise, np.float32))
        limits.setdefault(r.name, r.default_limit_gb)

    lengths = np.concatenate(lengths)
    batch = materialize_traces(
        np.concatenate(shape_id), np.concatenate(level), lengths,
        np.concatenate(params), np.concatenate(noise), seed)
    B = batch.n
    if parents is None:
        parents = tuple(() for _ in range(B))
    else:
        if len(parents) != B:
            raise ValueError(f"{len(parents)} parent lists vs {B} tasks")
        parents = tuple(tuple(int(p) for p in ps) for ps in parents)
    return WorkflowTrace(
        name=name, task_ids=task_ids, families=families,
        input_gb=np.concatenate(input_gb), dts=np.concatenate(dts),
        lengths=lengths, parents=parents, batch=batch,
        default_limits=limits)


# ----------------------------------------------------------- DAG validation
def assert_release_order(jobs, placements) -> None:
    """Check a ClusterSim placement log against the jobs' DAG.

    For every placed job, its *first* placement must come at or after every
    parent's finish time (last placement + runtime), and no job may be
    placed while a parent was never placed.  Exact for workloads without
    permanent failures (every placed job eventually finishes); the
    dependency-correctness assertion behind the ``workload_replay``
    benchmark and the DAG tests.
    """
    first: Dict[int, float] = {}
    last: Dict[int, float] = {}
    for t, _, jid in placements:
        first.setdefault(jid, t)
        last[jid] = t
    by_jid = {job.jid: job for job in jobs}
    for job in jobs:
        if job.jid not in first:
            continue
        for p in job.parents:
            if p not in last:
                raise AssertionError(
                    f"job {job.jid} was placed but its parent {p} never was")
            parent_end = last[p] + by_jid[p].runtime
            if first[job.jid] < parent_end - 1e-9:
                raise AssertionError(
                    f"job {job.jid} placed at t={first[job.jid]:.3f} before "
                    f"parent {p} finished at t={parent_end:.3f}")
