"""Seeded arrival processes: per-job release times, decoupled from DAG
structure.

A workload's *structure* (which tasks depend on which) and its *timing*
(when root work shows up at the cluster) are independent axes; these
helpers generate the timing.  Each returns a ``(B,)`` float64 array of
release times suitable for :func:`with_arrivals` /
``WorkflowTrace.release_times`` — non-root tasks keep 0.0, since a
child's effective release is gated by its parents finishing (the
simulator takes ``max`` implicitly: a child released before its parents
finish simply queues at the parent-finish event).

Generators are seeded and deterministic (``numpy.random.Generator`` over
tagged ``SeedSequence``s), so the robustness suite's differential runs
see identical timelines in every engine.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

__all__ = ["poisson_arrivals", "diurnal_arrivals", "trace_arrivals",
           "with_arrivals"]


def _roots_mask(B: int, parents) -> np.ndarray:
    if parents is None:
        return np.ones(B, bool)
    return np.asarray([len(p) == 0 for p in parents], bool)


def poisson_arrivals(B: int, rate: float, seed: int = 0,
                     parents=None) -> np.ndarray:
    """Homogeneous Poisson arrivals at ``rate`` jobs/second.

    Root tasks receive the cumulative-exponential arrival times in task
    order; non-root tasks stay at 0.0 (DAG-gated).
    """
    if rate <= 0.0:
        raise ValueError(f"poisson_arrivals needs rate > 0, got {rate!r}")
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0xA221]))
    roots = _roots_mask(B, parents)
    out = np.zeros(B, np.float64)
    out[roots] = np.cumsum(rng.exponential(1.0 / rate, int(roots.sum())))
    return out


def diurnal_arrivals(B: int, base_rate: float, period: float = 86_400.0,
                     depth: float = 0.8, seed: int = 0,
                     parents=None) -> np.ndarray:
    """Non-homogeneous Poisson with a sinusoidal day/night intensity.

    Intensity ``lam(t) = base_rate * (1 + depth * sin(2 pi t / period))``
    sampled by thinning: candidates arrive at the peak rate
    ``base_rate * (1 + depth)`` and are accepted with probability
    ``lam(t) / peak`` — the standard exact construction, so the accepted
    stream is the true inhomogeneous process.
    """
    if base_rate <= 0.0 or not (0.0 <= depth < 1.0):
        raise ValueError("diurnal_arrivals needs base_rate > 0 and "
                         "0 <= depth < 1")
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), 0xD1C4]))
    peak = base_rate * (1.0 + depth)
    roots = _roots_mask(B, parents)
    n = int(roots.sum())
    times = np.zeros(n, np.float64)
    t = 0.0
    for i in range(n):
        while True:
            t += float(rng.exponential(1.0 / peak))
            lam = base_rate * (1.0 + depth * np.sin(2.0 * np.pi * t / period))
            if rng.uniform() * peak <= lam:
                break
        times[i] = t
    out = np.zeros(B, np.float64)
    out[roots] = times
    return out


def trace_arrivals(B: int, times: Sequence[float],
                   parents=None) -> np.ndarray:
    """Trace-driven arrivals: replay recorded submit times.

    ``times`` must cover the workload's root tasks (extra entries are
    ignored; too few is an error — silently recycling a short trace would
    fabricate burst structure that was never measured).  Times are
    normalized so the earliest root releases at 0.0.
    """
    roots = _roots_mask(B, parents)
    n = int(roots.sum())
    times = np.asarray(list(times), np.float64)
    if len(times) < n:
        raise ValueError(
            f"trace_arrivals: trace has {len(times)} times but the "
            f"workload has {n} root tasks")
    if not np.isfinite(times[:n]).all() or (times[:n] < 0.0).any():
        raise ValueError("trace_arrivals: times must be finite and >= 0")
    sel = np.sort(times[:n])
    out = np.zeros(B, np.float64)
    out[roots] = sel - sel[0]
    return out


def with_arrivals(trace, release_times: Optional[np.ndarray]):
    """A copy of ``trace`` (a :class:`~repro.workloads.WorkflowTrace`)
    carrying ``release_times``; ``None`` clears them (everything at 0)."""
    if release_times is not None:
        release_times = np.asarray(release_times, np.float64)
        if release_times.shape != (trace.B,):
            raise ValueError(
                f"release_times shape {release_times.shape} != ({trace.B},)")
    return dataclasses.replace(trace, release_times=release_times)
