"""Workload subsystem: synthetic generation, wfcommons import, scenarios.

Three layers over one representation (:class:`WorkflowTrace` — packed
``(B, T)`` fleet lanes + per-task metadata + DAG edges):

* :mod:`repro.workloads.generate` — seeded, jax-vectorized task-family
  recipes synthesized straight into the fleet engine's lane layout, plus
  DAG shape builders (chains, fan-out, layered, barrier waves);
* :mod:`repro.workloads.wfc` — wfcommons/WorkflowHub JSON instance import
  and export with loud schema/cycle validation;
* :mod:`repro.workloads.scenarios` — the named scenario catalog
  (``burst_arrival``, ``heavy_tail``, ``deep_chain``, ``wide_fanout``,
  ``hetero_dt``, ``workload_replay``) consumed by ``evaluate_workflow``,
  the benchmarks and the tests.

Two timing layers ride on top: :mod:`repro.workloads.arrivals` (seeded
Poisson / diurnal / trace-driven release times, decoupled from DAG
structure) and :mod:`repro.workloads.suite` (the scenario x arrival x
fault robustness grid — ``make_suite`` / ``run_suite``).
"""

from repro.workloads import scenarios, wfc
from repro.workloads.arrivals import (
    diurnal_arrivals,
    poisson_arrivals,
    trace_arrivals,
    with_arrivals,
)
from repro.workloads.generate import (
    SHAPES,
    FamilyRecipe,
    ScenarioWorkflow,
    WorkflowTrace,
    assert_release_order,
    barrier_parents,
    chain_parents,
    fanout_parents,
    layered_parents,
    materialize_traces,
    synthesize,
)
from repro.workloads.scenarios import SCENARIOS, register_scenario, scenario_names
from repro.workloads.suite import SuiteCase, make_suite, run_suite, suite_table
from repro.workloads.wfc import (
    export_instance,
    import_instance,
    load_instance,
    validate_dag_ids,
)

__all__ = [
    "SHAPES", "FamilyRecipe", "WorkflowTrace", "ScenarioWorkflow",
    "synthesize", "materialize_traces", "assert_release_order",
    "chain_parents", "fanout_parents", "layered_parents", "barrier_parents",
    "scenarios", "SCENARIOS", "register_scenario", "scenario_names",
    "poisson_arrivals", "diurnal_arrivals", "trace_arrivals",
    "with_arrivals",
    "SuiteCase", "make_suite", "run_suite", "suite_table",
    "wfc", "load_instance", "import_instance", "export_instance",
    "validate_dag_ids",
]
