"""jit'd wrapper for the batched wastage kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wastage.kernel import oom_probe_call, wastage_call

__all__ = ["wastage_eval", "oom_probe"]


@functools.partial(jax.jit, static_argnames=("dt", "block_t", "interpret"))
def wastage_eval(starts, peaks, mems, lengths, dt: float = 1.0,
                 block_t: int = 512, interpret=None):
    """Batched successful-attempt wastage in GB·s.

    starts/peaks: (B, k) float; mems: (B, T) float; lengths: (B,) int32.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T = mems.shape
    bt = min(block_t, T)
    pad = (-T) % bt
    if pad:
        mems = jnp.pad(mems, ((0, 0), (0, pad)))
    return wastage_call(
        jnp.asarray(starts, jnp.float32), jnp.asarray(peaks, jnp.float32),
        jnp.asarray(mems, jnp.float32), jnp.asarray(lengths, jnp.int32),
        dt=dt, block_t=bt, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("dt", "block_t", "interpret"))
def oom_probe(starts, peaks, mems, lengths, dt: float = 1.0,
              block_t: int = 512, interpret=None):
    """Fused single-attempt OOM probe (fleet-engine inner step).

    starts/peaks: (B, k) float; mems: (B, T) float; lengths: (B,) int32.
    Returns ``(viol, w_succ, w_kill)`` — first violating sample index (or
    -1), successful-attempt wastage, and killed-attempt wastage, each (B,).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, T = mems.shape
    bt = min(block_t, T)
    pad = (-T) % bt
    if pad:
        mems = jnp.pad(mems, ((0, 0), (0, pad)))
    return oom_probe_call(
        jnp.asarray(starts, jnp.float32), jnp.asarray(peaks, jnp.float32),
        jnp.asarray(mems, jnp.float32), jnp.asarray(lengths, jnp.int32),
        dt=dt, block_t=bt, interpret=interpret)
