"""Oracles for the wastage kernels: the core's numpy implementations."""

from repro.core.wastage import oom_probe_ref, wastage_eval_ref

__all__ = ["wastage_eval_ref", "oom_probe_ref"]
