"""Oracle for the wastage kernel: the core's numpy implementation."""

from repro.core.wastage import wastage_eval_ref

__all__ = ["wastage_eval_ref"]
