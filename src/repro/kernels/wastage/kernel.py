"""Batched wastage-evaluation Pallas TPU kernel.

The fleet-scale evaluation hot loop of KS+: for thousands of (execution
trace × allocation plan) pairs, integrate ``allocated − used`` over time.
Each grid point evaluates one execution block: the step-function allocation
is reconstructed in VMEM from the (k,) segment starts/peaks via a one-hot
interval comparison (k ≤ 16, so the (T_block, k) compare/select stays in
registers), clamped from below by the trace (successful-attempt contract),
masked by validity, and reduced.

Grid: (num_execs, num_time_blocks); the scalar accumulator per execution
lives in VMEM scratch and is flushed on the last time block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wastage_kernel", "wastage_call", "oom_probe_kernel", "oom_probe_call"]


def wastage_kernel(starts_ref, peaks_ref, mem_ref, len_ref, out_ref, acc_scr,
                   *, block_t: int, dt: float):
    tb = pl.program_id(1)
    ntb = pl.num_programs(1)

    @pl.when(tb == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    starts = starts_ref[0].astype(jnp.float32)      # (k,)
    peaks = peaks_ref[0].astype(jnp.float32)        # (k,)
    mem = mem_ref[0].astype(jnp.float32)            # (block_t,)
    length = len_ref[0]                             # scalar int32

    t_idx = tb * block_t + jax.lax.iota(jnp.int32, block_t)
    t = t_idx.astype(jnp.float32) * dt
    alloc = _alloc_block(starts, peaks, t)
    alloc = jnp.maximum(alloc, mem)                 # successful attempt
    valid = (t_idx < length).astype(jnp.float32)
    acc_scr[...] = acc_scr[...] + jnp.sum((alloc - mem) * valid) * dt

    @pl.when(tb == ntb - 1)
    def _flush():
        out_ref[0] = acc_scr[...].astype(out_ref.dtype)


def _alloc_block(starts, peaks, t):
    """Step-function allocation on a time block via one-hot interval select.

    Duplicate starts yield empty intervals, so the *last* segment with
    ``start <= t`` wins — matching ``np.searchsorted(side='right') - 1``.
    Padded plan slots carry a huge sentinel start and are never active.
    """
    active = starts[None, :] <= t[:, None]           # (block_t, k)
    nxt = jnp.concatenate([starts[1:], jnp.full((1,), jnp.inf)])
    in_seg = active & (t[:, None] < nxt[None, :])
    alloc = jnp.sum(jnp.where(in_seg, peaks[None, :], 0.0), axis=1)
    return jnp.where(jnp.any(in_seg, axis=1), alloc, peaks[0])


def oom_probe_kernel(starts_ref, peaks_ref, mem_ref, len_ref,
                     viol_ref, wsucc_ref, wkill_ref,
                     acc_scr, viol_scr, *, block_t: int, dt: float):
    """One OOM/retry attempt, fused: first violation + both wastage modes.

    Per execution lane emits the first sample index where demand exceeds the
    allocation (-1 if none), the successful-attempt wastage
    (``max(alloc, mem) − mem`` integrated over valid samples) and the
    killed-attempt wastage (all allocation up to and including the kill
    sample).  The fleet engine's retry loop consumes all three, so one kernel
    pass replaces the per-execution ``first_violation`` + ``alloc_series``
    pair of the Python oracle.

    acc_scr: (3,) f32 scratch = [succ wastage, cumulative alloc, kill wastage]
    viol_scr: () i32 scratch  = first violation index so far (-1 = none)
    """
    tb = pl.program_id(1)
    ntb = pl.num_programs(1)

    @pl.when(tb == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)
        viol_scr[...] = jnp.full((), -1, jnp.int32)

    starts = starts_ref[0].astype(jnp.float32)      # (k,)
    peaks = peaks_ref[0].astype(jnp.float32)        # (k,)
    mem = mem_ref[0].astype(jnp.float32)            # (block_t,)
    length = len_ref[0]                             # scalar int32

    t_idx = tb * block_t + jax.lax.iota(jnp.int32, block_t)
    t = t_idx.astype(jnp.float32) * dt
    alloc = _alloc_block(starts, peaks, t)
    validb = t_idx < length
    valid = validb.astype(jnp.float32)

    bad = (mem > alloc) & validb
    any_v = jnp.any(bad)
    idx_in = jnp.argmax(bad)                        # first True in block
    local = jax.lax.iota(jnp.int32, block_t)
    # inclusive prefix of allocation up to the in-block kill sample, as a
    # masked sum (dynamic vector gather is not TPU-friendly)
    upto = jnp.sum(alloc * valid * (local <= idx_in).astype(jnp.float32))
    fresh = (viol_scr[...] < 0) & any_v
    viol_scr[...] = jnp.where(fresh, tb * block_t + idx_in, viol_scr[...])
    acc_scr[2] = jnp.where(fresh, acc_scr[1] + upto, acc_scr[2])
    acc_scr[1] = acc_scr[1] + jnp.sum(alloc * valid)
    acc_scr[0] = acc_scr[0] + jnp.sum((jnp.maximum(alloc, mem) - mem) * valid)

    @pl.when(tb == ntb - 1)
    def _flush():
        viol_ref[0] = viol_scr[...]
        wsucc_ref[0] = (acc_scr[0] * dt).astype(wsucc_ref.dtype)
        wkill_ref[0] = (acc_scr[2] * dt).astype(wkill_ref.dtype)


def oom_probe_call(starts, peaks, mems, lengths, *, dt: float,
                   block_t: int = 512, interpret: bool = False):
    """starts/peaks: (B, k); mems: (B, T); lengths: (B,).

    Returns ``(viol, w_succ, w_kill)``, each (B,).
    """
    B, k = starts.shape
    T = mems.shape[1]
    assert T % block_t == 0, (T, block_t)
    grid = (B, T // block_t)
    kernel = functools.partial(oom_probe_kernel, block_t=block_t, dt=dt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, block_t), lambda b, t: (b, t)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda b, t: (b,)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
            jax.ShapeDtypeStruct((B,), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((3,), jnp.float32),
                        pltpu.VMEM((), jnp.int32)],
        interpret=interpret,
    )(starts, peaks, mems, lengths)


def wastage_call(starts, peaks, mems, lengths, *, dt: float,
                 block_t: int = 512, interpret: bool = False):
    """starts/peaks: (B, k); mems: (B, T); lengths: (B,).  Returns (B,)."""
    B, k = starts.shape
    T = mems.shape[1]
    assert T % block_t == 0, (T, block_t)
    grid = (B, T // block_t)
    kernel = functools.partial(wastage_kernel, block_t=block_t, dt=dt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, block_t), lambda b, t: (b, t)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, t: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((), jnp.float32)],
        interpret=interpret,
    )(starts, peaks, mems, lengths)
