"""Batched wastage-evaluation Pallas TPU kernel.

The fleet-scale evaluation hot loop of KS+: for thousands of (execution
trace × allocation plan) pairs, integrate ``allocated − used`` over time.
Each grid point evaluates one execution block: the step-function allocation
is reconstructed in VMEM from the (k,) segment starts/peaks via a one-hot
interval comparison (k ≤ 16, so the (T_block, k) compare/select stays in
registers), clamped from below by the trace (successful-attempt contract),
masked by validity, and reduced.

Grid: (num_execs, num_time_blocks); the scalar accumulator per execution
lives in VMEM scratch and is flushed on the last time block.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wastage_kernel", "wastage_call"]


def wastage_kernel(starts_ref, peaks_ref, mem_ref, len_ref, out_ref, acc_scr,
                   *, block_t: int, dt: float):
    tb = pl.program_id(1)
    ntb = pl.num_programs(1)

    @pl.when(tb == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    starts = starts_ref[0].astype(jnp.float32)      # (k,)
    peaks = peaks_ref[0].astype(jnp.float32)        # (k,)
    mem = mem_ref[0].astype(jnp.float32)            # (block_t,)
    length = len_ref[0]                             # scalar int32

    t_idx = tb * block_t + jax.lax.iota(jnp.int32, block_t)
    t = t_idx.astype(jnp.float32) * dt
    # alloc(t) = peaks[max { i : starts_i <= t }] — one-hot interval select.
    active = starts[None, :] <= t[:, None]          # (block_t, k)
    # last active index == argmax of cumulative count; peaks are monotone
    # for KS+ but NOT for k-Segments, so select by interval, not by max.
    nxt = jnp.concatenate([starts[1:], jnp.full((1,), jnp.inf)])
    in_seg = active & (t[:, None] < nxt[None, :])
    alloc = jnp.sum(jnp.where(in_seg, peaks[None, :], 0.0), axis=1)
    alloc = jnp.where(jnp.any(in_seg, axis=1), alloc, peaks[0])
    alloc = jnp.maximum(alloc, mem)                 # successful attempt
    valid = (t_idx < length).astype(jnp.float32)
    acc_scr[...] = acc_scr[...] + jnp.sum((alloc - mem) * valid) * dt

    @pl.when(tb == ntb - 1)
    def _flush():
        out_ref[0] = acc_scr[...].astype(out_ref.dtype)


def wastage_call(starts, peaks, mems, lengths, *, dt: float,
                 block_t: int = 512, interpret: bool = False):
    """starts/peaks: (B, k); mems: (B, T); lengths: (B,).  Returns (B,)."""
    B, k = starts.shape
    T = mems.shape[1]
    assert T % block_t == 0, (T, block_t)
    grid = (B, T // block_t)
    kernel = functools.partial(wastage_kernel, block_t=block_t, dt=dt)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, k), lambda b, t: (b, 0)),
            pl.BlockSpec((1, block_t), lambda b, t: (b, t)),
            pl.BlockSpec((1,), lambda b, t: (b,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda b, t: (b,)),
        out_shape=jax.ShapeDtypeStruct((B,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((), jnp.float32)],
        interpret=interpret,
    )(starts, peaks, mems, lengths)
