"""jit'd public wrapper for the flash-attention kernel.

Handles padding to MXU-aligned tiles (sequence to block multiples, head_dim
to a lane multiple of 128), layout conversion from the model's
(B, S, H, hd), and CPU fallback to interpret mode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_call

__all__ = ["flash_attention"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jnp.ndarray,   # (B, Sq, H, hd) — model layout
    k: jnp.ndarray,   # (B, Skv, K, hd)
    v: jnp.ndarray,
    *, causal: bool = True, window: Optional[int] = None,
    block_q: int = 128, block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    if interpret is None:
        interpret = not _on_tpu()
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    sm_scale = 1.0 / hd ** 0.5

    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Skv, 8))
    pad_q = (-Sq) % bq
    pad_kv = (-Skv) % bk
    pad_hd = (-hd) % 128 if not interpret else 0  # lane alignment on TPU

    qt = jnp.moveaxis(q, 2, 1)  # (B, H, Sq, hd)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q or pad_hd:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, pad_hd)))
    if pad_kv or pad_hd:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_kv), (0, pad_hd)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_kv), (0, pad_hd)))

    out = flash_attention_call(
        qt, kt, vt, causal=causal, window=window, sm_scale=sm_scale,
        block_q=bq, block_k=bk, seq_q=Sq, seq_kv=Skv, interpret=interpret)
    out = out[:, :, :Sq, :hd]
    return jnp.moveaxis(out, 1, 2)  # back to (B, Sq, H, hd)
