"""Flash-attention forward Pallas TPU kernel (GQA, causal / windowed).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) with the KV axis
innermost ("arbitrary" semantics — sequential accumulation).  Online-softmax
state (running max, normalizer, f32 accumulator) lives in VMEM scratch and
is carried across KV blocks; the normalized output is written on the last
visited KV block.

BlockSpecs tile Q/K/V/O along the sequence axes only: each invocation sees
``(block_q, head_dim)`` of Q and ``(block_k, head_dim)`` of K/V in VMEM.
MXU alignment: block_q/block_k default to 128 and head_dim is padded to a
multiple of 128 by ``ops.flash_attention`` when needed.

Validated on CPU in interpret mode against ``ref.mha_reference``; on real
TPU hardware the same ``pallas_call`` lowers to Mosaic.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_call"]

_NEG_INF = -1e30


def flash_attention_kernel(
    q_ref, k_ref, v_ref, o_ref,          # VMEM block refs
    m_scr, l_scr, acc_scr,               # VMEM scratch
    *, sm_scale: float, causal: bool, window: Optional[int],
    block_q: int, block_k: int, seq_q: int, seq_kv: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nkv = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale   # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)           # (bq, bk)
        mask = (k_pos < seq_kv) & (q_pos < seq_q)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # Skip fully-masked blocks above the causal frontier.
        pl.when(kj * block_k <= qi * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(kj == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_call(
    q: jnp.ndarray,   # (B, H, Sq, hd)
    k: jnp.ndarray,   # (B, K, Skv, hd)
    v: jnp.ndarray,   # (B, K, Skv, hd)
    *, causal: bool, window: Optional[int], sm_scale: float,
    block_q: int = 128, block_k: int = 128,
    seq_q: int, seq_kv: int, interpret: bool = False,
) -> jnp.ndarray:
    B, H, Sq, hd = q.shape
    K = k.shape[1]
    G = H // K
    nq = Sq // block_q
    nk = k.shape[2] // block_k
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        flash_attention_kernel, sm_scale=sm_scale, causal=causal,
        window=window, block_q=block_q, block_k=block_k,
        seq_q=seq_q, seq_kv=seq_kv)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),       # running max
            pltpu.VMEM((block_q,), jnp.float32),       # normalizer
            pltpu.VMEM((block_q, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
