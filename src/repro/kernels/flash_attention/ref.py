"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

__all__ = ["mha_reference"]


def mha_reference(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None,
                  sm_scale: Optional[float] = None) -> jnp.ndarray:
    """Dense-softmax GQA attention.  q: (B,H,Sq,hd); k/v: (B,K,Skv,hd)."""
    B, H, Sq, hd = q.shape
    K, Skv = k.shape[1], k.shape[2]
    G = H // K
    scale = sm_scale if sm_scale is not None else 1.0 / hd ** 0.5
    kx = jnp.repeat(k, G, axis=1)
    vx = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      vx.astype(jnp.float32)).astype(q.dtype)
