"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

Grid: (batch, heads, num_chunks) with the chunk axis innermost and
sequential — the recurrent state (head_dim × state) lives in VMEM scratch
and is carried across chunk invocations, exactly the chunked dual form:
quadratic attention-like compute inside a chunk, linear state passing
between chunks.

Per invocation the VMEM working set is
``(chunk × P) x + (chunk × N) B,C + (chunk × chunk) decay + (P × N) state``
— e.g. chunk=128, P=64, N=128 → ~200 KB, comfortably inside VMEM, with the
two inner matmuls ((chunk×N)@(N×chunk) and (chunk×chunk)@(chunk×P)) shaped
for the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_kernel", "ssd_call"]


def ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, st_ref, state_scr,
               *, chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0].astype(jnp.float32)          # (chunk, P)
    a = a_ref[0, 0].astype(jnp.float32)          # (chunk,)
    b = b_ref[0, 0].astype(jnp.float32)          # (chunk, N)
    c = c_ref[0, 0].astype(jnp.float32)          # (chunk, N)

    cum = jnp.cumsum(a)                          # (chunk,)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)

    s = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (chunk,chunk)
    y_diag = jax.lax.dot_general(s * L, x, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    state = state_scr[...]                       # (P, N)
    y_off = jax.lax.dot_general(c * jnp.exp(cum)[:, None], state,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (chunk,P)
    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cum[-1] - cum)        # (chunk,)
    inc = jax.lax.dot_general(x, b * decay_to_end[:, None],
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = state * jnp.exp(cum[-1]) + inc
    st_ref[0, 0] = state_scr[...].astype(st_ref.dtype)


def ssd_call(x, a, b, c, *, chunk: int, n_groups: int,
             interpret: bool = False):
    """x: (B,H,S,P); a: (B,H,S); b,c: (B,G,S,N).  Returns (y, final_state)."""
    B, H, S, P = x.shape
    N = b.shape[-1]
    rep = H // n_groups
    nc = S // chunk
    grid = (B, H, nc)

    kernel = functools.partial(ssd_kernel, chunk=chunk)
    y, st = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, h, j: (bi, h, j, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bi, h, j: (bi, h, j)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bi, h, j, rep=rep: (bi, h // rep, j, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bi, h, j, rep=rep: (bi, h // rep, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bi, h, j: (bi, h, j, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, j: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, a, b, c)
    return y, st
