"""Oracle for the SSD kernel: the model's own chunked-jnp implementation."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba2 import ssd_chunked

__all__ = ["ssd_reference"]


def ssd_reference(x, a, b, c, *, chunk: int):
    """Kernel-layout wrapper.  x: (B,H,S,P); a: (B,H,S); b,c: (B,G,S,N)."""
    X = jnp.moveaxis(x, 1, 2)           # (B,S,H,P)
    A = jnp.moveaxis(a, 1, 2)           # (B,S,H)
    Bm = jnp.moveaxis(b, 1, 2)          # (B,S,G,N)
    Cm = jnp.moveaxis(c, 1, 2)
    Y, final = ssd_chunked(X, A, Bm, Cm, chunk)
    return jnp.moveaxis(Y, 1, 2), final  # (B,H,S,P), (B,H,P,N)
