"""jit'd public wrapper for the SSD kernel (model layout, padding, fallback)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_call

__all__ = ["ssd_pallas"]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(X, A, Bm, Cm, chunk: int = 128, interpret=None,
               initial_state=None):
    """Drop-in for ``repro.models.mamba2.ssd_chunked`` (model layout).

    X: (B,S,H,P) — inputs pre-multiplied by dt; A: (B,S,H) log-decays;
    Bm/Cm: (B,S,G,N).  Returns (Y (B,S,H,P), final_state (B,H,P,N)).
    ``initial_state`` is folded in as a virtual prefix via the state
    linearity (state' = decay * init + contribution).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, S, H, P = X.shape
    G = Bm.shape[2]
    pad = (-S) % chunk
    x = jnp.moveaxis(X, 1, 2)          # (B,H,S,P)
    a = jnp.moveaxis(A, 1, 2)          # (B,H,S)
    b = jnp.moveaxis(Bm, 1, 2)         # (B,G,S,N)
    c = jnp.moveaxis(Cm, 1, 2)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
        b = jnp.pad(b, ((0, 0), (0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, 0), (0, pad), (0, 0)))
    y, st = ssd_call(x, a, b, c, chunk=chunk, n_groups=G,
                     interpret=interpret)
    y = jnp.moveaxis(y[:, :, :S], 1, 2)
    if initial_state is not None:
        # linearity: y += C_t * exp(cumsum A) * init ; final += decay * init
        cum = jnp.cumsum(jnp.moveaxis(A, 1, 2).astype(jnp.float32), axis=-1)
        rep = H // G
        Ch = jnp.repeat(Cm, rep, axis=2)  # (B,S,H,N)
        w = jnp.exp(cum)                  # (B,H,S)
        extra = jnp.einsum("bshn,bhpn,bhs->bshp", Ch.astype(jnp.float32),
                           initial_state.astype(jnp.float32),
                           jnp.moveaxis(w, 1, 1))
        y = y + extra.astype(y.dtype)
        st = st + initial_state.astype(st.dtype) * jnp.exp(
            cum[..., -1])[..., None, None]
    return y, st
