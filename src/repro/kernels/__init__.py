"""Pallas TPU kernels for the framework's compute hot spots.

* ``flash_attention`` — GQA flash attention (causal / windowed) forward.
* ``ssd``             — Mamba2 chunked state-space-duality scan.
* ``wastage``         — KS+ fleet-scale wastage evaluation.

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling),
``ops.py`` (jit'd wrapper with CPU interpret-mode fallback) and ``ref.py``
(pure-jnp oracle used by the allclose test sweeps).
"""

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.ssd.ops import ssd_pallas
from repro.kernels.wastage.ops import wastage_eval

__all__ = ["flash_attention", "ssd_pallas", "wastage_eval"]
