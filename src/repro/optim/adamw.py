"""AdamW with decoupled weight decay + global-norm clipping (pure pytrees)."""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw_init", "adamw_update", "cosine_schedule"]


def adamw_init(params) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(
    grads, opt_state: Dict, params, *, lr,
    b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
    weight_decay: float = 0.1, clip_norm: float = 1.0,
) -> Tuple[Dict, Dict, Dict]:
    """Returns (new_params, new_opt_state, stats)."""
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-12))
    count = opt_state["count"] + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        step = (m / c1) / (jnp.sqrt(v / c2) + eps)
        # decay only matrices (norm scales / biases are 1-D)
        wd = weight_decay if p.ndim > 1 else 0.0
        newp = p.astype(jnp.float32) - lr * (step + wd * p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = dict(grad_norm=gnorm, clip_scale=scale)
    return new_p, {"m": new_m, "v": new_v, "count": count}, stats


def cosine_schedule(*, peak_lr: float, warmup_steps: int, total_steps: int,
                    min_ratio: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return peak_lr * jnp.where(step < warmup_steps, warm, cos)
    return lr
