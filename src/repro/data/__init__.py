"""Data pipeline substrate."""

from repro.data.pipeline import SyntheticLMDataset, host_batch

__all__ = ["SyntheticLMDataset", "host_batch"]
