"""Deterministic synthetic data pipeline.

Batches are a pure function of ``(seed, step, shard)`` so every host in a
multi-host deployment generates exactly its own shard with no coordination,
and a restarted / resharded job (elastic scaling, failure recovery) resumes
bit-identically from the step counter alone — the data-side half of the
fault-tolerance story.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

__all__ = ["SyntheticLMDataset", "host_batch"]


@dataclasses.dataclass(frozen=True)
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embed_dim: Optional[int] = None   # set for stubbed-frontend families
    mrope: bool = False

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> Dict:
        """One data-parallel shard of the global batch for ``step``."""
        assert self.global_batch % num_shards == 0
        b = self.global_batch // num_shards
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, shard]))
        out: Dict[str, np.ndarray] = {}
        # Markov token stream: with p=0.8 the next token is (prev + 7) mod V,
        # so even tiny smoke models visibly learn within tens of steps while
        # ~100M models keep improving for a few hundred.
        toks = rng.integers(0, self.vocab, (b, self.seq_len + 1), dtype=np.int32)
        mask = rng.random((b, self.seq_len)) < 0.8
        nxt = (toks[:, :-1] + 7) % self.vocab
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
        if self.embed_dim is not None:
            out["embeds"] = rng.standard_normal(
                (b, self.seq_len, self.embed_dim)).astype(np.float32)
            out["labels"] = toks[:, 1:]
        else:
            out["tokens"] = toks[:, :-1]
            out["labels"] = toks[:, 1:]
        if self.mrope:
            pos = np.arange(self.seq_len, dtype=np.int32)
            out["positions"] = np.broadcast_to(
                pos[None, :, None], (b, self.seq_len, 3)).copy()
        return out


def host_batch(cfg, seq_len: int, global_batch: int, step: int,
               seed: int = 0, shard: int = 0, num_shards: int = 1) -> Dict:
    ds = SyntheticLMDataset(
        vocab=cfg.vocab, seq_len=seq_len, global_batch=global_batch,
        seed=seed,
        embed_dim=cfg.d_model if cfg.family in ("vlm", "audio") else None,
        mrope=cfg.mrope_sections is not None)
    return ds.batch(step, shard, num_shards)
