"""``python -m repro.serve`` — run the saturation harness and print JSON.

A quick operator smoke test of the serving stack: seeded multi-tenant
traffic through batched and unbatched servers, the virtual-clock latency
loop, and the cache/compile discipline checks (see
:mod:`repro.serve.bench`).
"""

from __future__ import annotations

import argparse
import json

from repro.serve.bench import run_saturation


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        "python -m repro.serve",
        description="serve_saturation: multi-tenant micro-batched "
                    "prediction service benchmark")
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--requests", type=int, default=2048,
                    help="throughput-phase request count")
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="latency-phase open-loop arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    out = run_saturation(tenants=args.tenants, n_requests=args.requests,
                         rate_rps=args.rate, seed=args.seed)
    print(json.dumps(out, indent=2, default=str))
    thr = out["throughput"]
    ok = bool(thr["bitwise"]) and bool(
        out["discipline"]["warm_zero_compiles"])
    print(f"# speedup {thr['speedup_x']:.1f}x, "
          f"p99 {out['latency']['p99_ms']:.3f} ms, "
          f"bitwise={thr['bitwise']}, "
          f"warm_zero_compiles={out['discipline']['warm_zero_compiles']}")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
