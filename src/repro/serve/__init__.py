"""Multi-tenant prediction-as-a-service (ROADMAP item 3).

The request path::

    client.predict ──► PredictionServer.submit ──► PredictionCache hit?
            │                                         │ yes: resolve now
            ▼ no                                      ▼
    MicroBatcher (≤ max_wait_s) ──► buckets ──► one batched dispatch per
    bucket (gathered SegmentModel eval / predict_packed /
    simulate_fleet_many) ──► scatter to ServeFutures

Layers: :mod:`~repro.serve.batcher` (coalescing queue),
:mod:`~repro.serve.tenants` (copy-on-refit snapshot state),
:mod:`~repro.serve.cache` (prediction + program/trace caches),
:mod:`~repro.serve.server` (dispatch + the synchronous client),
:mod:`~repro.serve.bench` (the ``serve_saturation`` harness behind
``python -m repro.serve``).
"""

from repro.serve.batcher import (Backpressure, MicroBatcher, ServeFuture,
                                 ServeRequest, ServerClosed)
from repro.serve.cache import CacheStats, PredictionCache, ProgramCache
from repro.serve.server import (EvaluateResult, PredictionServer,
                                ServeClient, TuneResult)
from repro.serve.tenants import (ModelSnapshot, TenantRegistry,
                                 UnknownFamilyError, UnknownTenantError)

__all__ = [
    "Backpressure",
    "ServerClosed",
    "MicroBatcher",
    "ServeFuture",
    "ServeRequest",
    "CacheStats",
    "PredictionCache",
    "ProgramCache",
    "EvaluateResult",
    "TuneResult",
    "PredictionServer",
    "ServeClient",
    "ModelSnapshot",
    "TenantRegistry",
    "UnknownFamilyError",
    "UnknownTenantError",
]
