"""Per-tenant fitted-model state with copy-on-refit snapshot sharing.

Many tenants serving the same task family usually start from the same
fitted model (the operator seeds one fit per family and shares it).
Snapshots make that cheap and safe:

* a :class:`ModelSnapshot` is **frozen**: once published it is never
  mutated — every reader (predict dispatch, caches, in-flight batches)
  can hold it without locks;
* ``observe`` appends to *tenant-local* pending state only (one small
  per-tenant lock); the shared snapshot is untouched, so one tenant's
  feedback never perturbs another tenant's predictions;
* ``refit`` is **copy-on-refit**: when a tenant's :class:`RefitPolicy`
  comes due, the snapshot's method is deep-copied *off to the side*, the
  tenant's pending outcomes are replayed into the clone (the methods'
  own incremental-refit machinery — segmentation-tail caches etc. —
  rides along), and only then is the tenant's pointer swapped to a new
  snapshot with a bumped ``version``.  Other tenants keep the old
  snapshot; a reader that raced the swap sees a consistent (old) model.

Snapshot identity (``sid``) is process-unique and is the cache
generation: :mod:`repro.serve.cache` keys prediction entries and
device-resident trace batches by it, so a refit invalidates exactly the
forked tenant's entries and nothing else.
"""

from __future__ import annotations

import copy
import dataclasses
import itertools
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import registry
from repro.core.predictor import (ExecutionOutcome, MemoryPredictor,
                                  RefitPolicy)

__all__ = [
    "UnknownTenantError",
    "UnknownFamilyError",
    "ModelSnapshot",
    "TenantRegistry",
]

_SID = itertools.count(1)


class UnknownTenantError(KeyError):
    """The named tenant was never created on this server."""


class UnknownFamilyError(KeyError):
    """The tenant has no fitted model for the named task family."""


@dataclasses.dataclass(frozen=True)
class ModelSnapshot:
    """One published, immutable fitted model (+ its training data).

    ``sid`` is globally unique across all snapshots; ``version`` counts
    refits along one tenant's lineage (the seed is version 0).  The
    training arrays ride along so ``tune_offset`` / ``evaluate``
    dispatches replay the exact data the model was fitted on.
    """

    method: MemoryPredictor
    method_name: str
    family: str
    version: int
    sid: int
    dt: float
    machine_memory: float
    train_mems: Tuple[np.ndarray, ...]
    train_dts: Tuple[float, ...]
    train_inputs: Tuple[float, ...]

    def fork(self, method: MemoryPredictor,
             extra: Sequence[ExecutionOutcome]) -> "ModelSnapshot":
        """A refitted successor: version+1, fresh sid, history extended
        by the outcomes that drove the refit."""
        return dataclasses.replace(
            self, method=method, version=self.version + 1, sid=next(_SID),
            train_mems=self.train_mems + tuple(
                np.asarray(o.mem) for o in extra),
            train_dts=self.train_dts + tuple(float(o.dt) for o in extra),
            train_inputs=self.train_inputs + tuple(
                float(o.input_gb) for o in extra))


class _TenantState:
    """One tenant: snapshot pointers + pending (not-yet-refitted) outcomes."""

    def __init__(self, name: str):
        self.name = name
        self.lock = threading.Lock()   # guards writes; reads are lock-free
        self.families: Dict[str, ModelSnapshot] = {}
        self.pending: Dict[str, List[ExecutionOutcome]] = {}
        self.failures: Dict[str, int] = {}
        self.refits = 0


class TenantRegistry:
    """All tenants of one :class:`repro.serve.PredictionServer`."""

    def __init__(self, *, machine_memory: float = 128.0):
        self.machine_memory = float(machine_memory)
        self._tenants: Dict[str, _TenantState] = {}
        self._lock = threading.Lock()
        # Refit listeners (the server hooks cache invalidation in here).
        self._on_refit = []

    # ------------------------------------------------------------ tenants
    def add_tenant(self, name: str) -> None:
        with self._lock:
            if name in self._tenants:
                raise ValueError(f"tenant already exists: {name!r}")
            self._tenants[name] = _TenantState(name)

    def tenant_names(self) -> List[str]:
        return list(self._tenants)

    def _state(self, tenant: str) -> _TenantState:
        try:
            return self._tenants[tenant]
        except KeyError:
            raise UnknownTenantError(
                f"unknown tenant: {tenant!r} "
                f"(known: {', '.join(self._tenants) or 'none'})") from None

    def on_refit(self, fn) -> None:
        """Register ``fn(tenant, family, old_snapshot, new_snapshot)`` to
        run after every published refit (cache invalidation hook)."""
        self._on_refit.append(fn)

    # ------------------------------------------------------------ seeding
    def seed(self, family: str, method: Union[str, MemoryPredictor],
             mems: Sequence[np.ndarray], dts: Sequence[float],
             inputs: Sequence[float], *, k: int = 4,
             default_limit: float = 8.0,
             tenants: Optional[Sequence[str]] = None) -> ModelSnapshot:
        """Fit ``method`` once on the family's training executions and
        share the frozen snapshot across ``tenants`` (default: all).

        ``method`` resolves through :mod:`repro.core.registry` and must
        carry the ``packed`` capability — the batched dispatch path is
        built on ``predict_packed`` (`require=("packed",)` raises the
        registry's named :class:`~repro.core.registry.MissingCapabilityError`
        otherwise, at seed time rather than deep inside a flush).
        """
        if len(set(float(d) for d in dts)) != 1:
            raise ValueError(
                f"serve family {family!r} needs a uniform training dt "
                "(the batched evaluate/tune dispatches share one sampling "
                "period per family)")
        inst = registry.resolve(method, k=k,
                                machine_memory=self.machine_memory,
                                default_limit=default_limit,
                                require=("packed",))
        inst.fit(list(mems), list(dts), list(inputs))
        snap = ModelSnapshot(
            method=inst, method_name=registry.name_of(inst), family=family,
            version=0, sid=next(_SID), dt=float(dts[0]),
            machine_memory=self.machine_memory,
            train_mems=tuple(np.asarray(m) for m in mems),
            train_dts=tuple(float(d) for d in dts),
            train_inputs=tuple(float(i) for i in inputs))
        names = self.tenant_names() if tenants is None else tenants
        for t in names:
            st = self._state(t)
            with st.lock:
                st.families[family] = snap
                st.pending[family] = []
                st.failures[family] = 0
        return snap

    # ------------------------------------------------------------- reads
    def snapshot(self, tenant: str, family: str) -> ModelSnapshot:
        """The tenant's current snapshot — a lock-free pointer read (the
        dict value is swapped atomically by refit, never mutated)."""
        st = self._state(tenant)
        try:
            return st.families[family]
        except KeyError:
            raise UnknownFamilyError(
                f"tenant {tenant!r} has no fitted family {family!r} "
                f"(fitted: {', '.join(st.families) or 'none'})") from None

    def families(self, tenant: str) -> List[str]:
        return list(self._state(tenant).families)

    def evaluate_data(self, tenant: str, family: str):
        """``(mems, dts, inputs)`` the tenant's ``evaluate`` replays: the
        snapshot's fitted history plus any still-pending observations."""
        st = self._state(tenant)
        snap = self.snapshot(tenant, family)
        with st.lock:
            pend = list(st.pending.get(family, ()))
        return (list(snap.train_mems) + [np.asarray(o.mem) for o in pend],
                list(snap.train_dts) + [float(o.dt) for o in pend],
                list(snap.train_inputs) + [float(o.input_gb) for o in pend])

    # ------------------------------------------------------------- writes
    def observe(self, tenant: str, family: str,
                outcome: ExecutionOutcome) -> int:
        """Append one finished execution to the tenant's pending state.

        Touches only tenant-local lists under the tenant's own lock — the
        shared snapshot (and with it every other tenant's reads) is
        untouched.  Returns the pending count.
        """
        st = self._state(tenant)
        self.snapshot(tenant, family)  # loud on unknown family
        with st.lock:
            st.pending[family].append(outcome)
            if outcome.oomed:
                st.failures[family] += 1
            return len(st.pending[family])

    def refit(self, tenant: str, family: str,
              policy: Union[RefitPolicy, str] = "every_1") -> bool:
        """Copy-on-refit: maybe fork the tenant's snapshot for ``family``.

        Evaluates ``policy`` against the tenant's pending outcomes; when
        due, clones the (possibly shared) method, replays the pending
        outcomes through the clone's own ``observe``/``refit`` lifecycle
        (incremental refits included), publishes the fork as a new
        snapshot and clears the pending state.  Other tenants sharing the
        old snapshot are unaffected.  Returns True iff a refit happened.

        Raises the registry's named capability error for methods
        registered with ``online=False`` — a frozen baseline has no
        online state to refit.
        """
        st = self._state(tenant)
        old = self.snapshot(tenant, family)
        registry.check_capabilities(old.method, require=("online",))
        pol = RefitPolicy.parse(policy)
        with st.lock:
            pend = list(st.pending[family])
            fails = st.failures[family]
        if not pol.due(len(pend), fails):
            return False
        # The expensive part — clone + replay + refit — runs outside the
        # tenant lock: concurrent reads (and other tenants) never wait on it.
        clone = copy.deepcopy(old.method)
        for o in pend:
            clone.observe(o)
        clone.refit(RefitPolicy("every_n", 1))
        new = old.fork(clone, pend)
        with st.lock:
            st.families[family] = new
            # Keep observations that raced in during the refit pending.
            st.pending[family] = st.pending[family][len(pend):]
            st.failures[family] = sum(
                1 for o in st.pending[family] if o.oomed)
            st.refits += 1
        for fn in self._on_refit:
            fn(tenant, family, old, new)
        return True
