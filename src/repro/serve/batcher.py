"""Micro-batch queue: arrival → bucket → dispatch → scatter.

The request front of :mod:`repro.serve`: concurrent ``predict`` /
``tune_offset`` / ``evaluate`` calls land here as :class:`ServeRequest`
records and wait — at most ``max_wait_s`` — to be coalesced with other
requests into *buckets* (requests whose dispatch can share one batched
program, as decided by the server's ``key_fn``).  A flush fires when

* the oldest queued request has waited ``max_wait_s`` (the latency
  ceiling the operator buys batching with), or
* the queue reaches ``max_batch`` (saturation: arrivals outpace
  dispatch, so batches fill before the deadline — the regime the
  ``serve_saturation`` benchmark measures), or
* a caller forces it (``flush()`` / ``drain()``).

Backpressure is explicit: once ``max_queue`` requests are pending,
``submit`` raises :class:`Backpressure` instead of growing an unbounded
queue — the caller sheds load where it can still be cheap.

The batcher is **clock-injectable** (``clock=`` any monotonic float
source): tests and the saturation benchmark drive it on a virtual clock
(deterministic deadlines), while :meth:`MicroBatcher.start` runs the
same flush logic on a background thread against wall time for the live
``python -m repro.serve`` front.  All shared state sits behind one lock;
dispatch itself runs *outside* the lock so slow programs never block
arrivals.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional

from repro.obs import metrics as _met
from repro.obs import trace as _obs

__all__ = ["Backpressure", "ServerClosed", "ServeFuture", "ServeRequest",
           "MicroBatcher"]


class Backpressure(RuntimeError):
    """The service queue is saturated (``max_queue`` pending requests);
    the request was rejected, not queued."""


class ServerClosed(RuntimeError):
    """The batcher/server was closed: the request was not (and will
    never be) dispatched.  Raised by ``submit`` after ``close()`` and
    set on every future still queued at close time — callers blocked in
    ``result()`` fail fast instead of hanging."""


class ServeFuture:
    """Minimal completion slot a request's response is scattered into.

    Cheaper than ``concurrent.futures.Future`` on the hot path: the
    waiter ``threading.Event`` is allocated lazily, so the common
    synchronous flows (manual pumping in tests/benchmarks, the
    ``batching=False`` per-request path) never touch thread machinery.
    """

    __slots__ = ("_value", "_exc", "_done", "_event")

    def __init__(self):
        self._value = None
        self._exc: Optional[BaseException] = None
        self._done = False
        self._event: Optional[threading.Event] = None

    @property
    def done(self) -> bool:
        return self._done

    def set_result(self, value) -> None:
        self._value = value
        self._done = True  # after _value: readers gate on _done
        if self._event is not None:
            self._event.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._done = True
        if self._event is not None:
            self._event.set()

    def result(self, timeout: Optional[float] = None):
        if not self._done:
            if self._event is None:
                self._event = threading.Event()
            if self._done:  # resolved between the check and the alloc
                self._event.set()
            if not self._event.wait(timeout):
                raise TimeoutError("serve request not completed in time")
        if self._exc is not None:
            raise self._exc
        return self._value


@dataclasses.dataclass(slots=True)
class ServeRequest:
    """One queued call: ``kind`` ∈ {predict, tune_offset, evaluate}."""

    kind: str
    tenant: str
    family: str
    payload: Any
    arrival: float
    future: ServeFuture = dataclasses.field(default_factory=ServeFuture)
    # Filled by the server's key_fn at submit time (snapshot resolution
    # happens once, not per flush) and read by the dispatch scatter.
    key: Any = None
    snapshot: Any = None


class MicroBatcher:
    """Bounded-wait coalescing queue in front of the dispatch layer.

    ``key_fn(request)`` assigns each request its bucket key (requests
    sharing a key are dispatched by ONE ``dispatch_fn(key, requests)``
    call); ``dispatch_fn`` must resolve every request's future.
    """

    def __init__(self, dispatch_fn: Callable[[Any, List[ServeRequest]], None],
                 key_fn: Callable[[ServeRequest], Any], *,
                 max_wait_s: float = 0.002, max_batch: int = 256,
                 max_queue: int = 4096,
                 clock: Callable[[], float] = None):
        import time
        if max_batch < 1 or max_queue < max_batch:
            raise ValueError("need max_batch >= 1 and max_queue >= max_batch")
        self._dispatch = dispatch_fn
        self._key = key_fn
        self.max_wait_s = float(max_wait_s)
        self.max_batch = int(max_batch)
        self.max_queue = int(max_queue)
        self.clock = clock if clock is not None else time.monotonic
        self._lock = threading.Lock()
        self._wake = threading.Condition(self._lock)
        self._queue: List[ServeRequest] = []
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._closed = False
        self.stats: Dict[str, int] = {
            "submitted": 0, "rejected": 0, "flushes": 0,
            "deadline_flushes": 0, "full_flushes": 0,
            "batches": 0, "dispatched": 0, "max_depth": 0,
        }

    # ------------------------------------------------------------- arrival
    def submit(self, req: ServeRequest) -> ServeFuture:
        """Queue one request; raises :class:`Backpressure` at saturation.

        Returns the request's future.  When the queue hits ``max_batch``
        the submitting caller flushes inline (saturation flush) — under a
        threaded front that keeps the worker a pure deadline timer.
        """
        req.key = self._key(req)
        with self._lock:
            if self._closed:
                raise ServerClosed(
                    f"serve front closed; request "
                    f"{req.kind}/{req.tenant}/{req.family} rejected")
            if len(self._queue) >= self.max_queue:
                self.stats["rejected"] += 1
                raise Backpressure(
                    f"serve queue saturated ({self.max_queue} pending); "
                    f"request {req.kind}/{req.tenant}/{req.family} rejected")
            self._queue.append(req)
            self.stats["submitted"] += 1
            depth = len(self._queue)
            if depth > self.stats["max_depth"]:
                self.stats["max_depth"] = depth
            full = depth >= self.max_batch
            if full or self._thread is not None:
                self._wake.notify()
            if _obs.enabled:
                _met.gauge("serve.queue_depth").set(depth)
        if full:
            self._flush(kind="full_flushes")
        return req.future

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._queue)

    def oldest_deadline(self) -> Optional[float]:
        """Clock time at which the oldest pending request must flush."""
        with self._lock:
            if not self._queue:
                return None
            return self._queue[0].arrival + self.max_wait_s

    # ------------------------------------------------------------ flushing
    def pump(self, now: Optional[float] = None) -> int:
        """Flush iff the deadline passed or the queue is full (manual
        clock driving).  Returns the number of requests dispatched."""
        now = self.clock() if now is None else now
        with self._lock:
            if not self._queue:
                return 0
            due = (now >= self._queue[0].arrival + self.max_wait_s
                   or len(self._queue) >= self.max_batch)
        return self._flush(kind="deadline_flushes") if due else 0

    def flush(self) -> int:
        """Force-dispatch everything pending (end-of-stream drain)."""
        return self._flush(kind="deadline_flushes")

    def _flush(self, kind: str) -> int:
        with self._lock:
            batch, self._queue = self._queue, []
            if not batch:
                return 0
            self.stats["flushes"] += 1
            self.stats[kind] += 1
        if _obs.enabled:
            _met.counter("serve.flushes").inc(cause=kind)
            _met.hist("serve.batch_size",
                      buckets=_met.COUNT_BUCKETS).observe(len(batch))
            now = self.clock()
            wait_h = _met.hist("serve.wait_s")
            for req in batch:
                wait_h.observe(now - req.arrival)
        buckets: Dict[Any, List[ServeRequest]] = {}
        for req in batch:  # insertion order: FIFO within a bucket
            buckets.setdefault(req.key, []).append(req)
        for key, reqs in buckets.items():
            if _obs.enabled:
                with _obs.span("serve.dispatch", n=len(reqs)):
                    self._dispatch_bucket(key, reqs)
            else:
                self._dispatch_bucket(key, reqs)
            self.stats["batches"] += 1
            self.stats["dispatched"] += len(reqs)
        return len(batch)

    def _dispatch_bucket(self, key, reqs: List[ServeRequest]) -> None:
        try:
            self._dispatch(key, reqs)
        except BaseException as exc:  # scatter failures, keep serving
            for r in reqs:
                if not r.future.done:
                    r.future.set_exception(exc)

    # ------------------------------------------------------- threaded front
    def start(self) -> None:
        """Run the deadline loop on a background thread (wall clock)."""
        if self._thread is not None:
            return
        self._running = True
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-serve-batcher")
        self._thread.start()

    def stop(self) -> None:
        """Stop the background thread, flushing whatever is pending."""
        if self._thread is None:
            return
        with self._lock:
            self._running = False
            self._wake.notify()
        self._thread.join()
        self._thread = None
        self.flush()

    def close(self) -> None:
        """Shut down without dispatching: stop the pump thread and fail
        every still-queued request with :class:`ServerClosed`.

        The counterpart to :meth:`stop` (which drains): ``close`` is the
        abandon-ship path — callers blocked in ``result()`` get the
        error immediately instead of hanging on a future no thread will
        ever resolve, and later ``submit`` calls are rejected.
        Idempotent.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._running = False
            self._wake.notify()
            thread, self._thread = self._thread, None
        if thread is not None:
            thread.join()
        with self._lock:
            pending, self._queue = self._queue, []
        for r in pending:
            if not r.future.done:
                r.future.set_exception(ServerClosed(
                    f"serve front closed with request "
                    f"{r.kind}/{r.tenant}/{r.family} still queued"))

    def _run(self) -> None:
        while True:
            with self._lock:
                while self._running and not self._queue:
                    self._wake.wait()
                if not self._running:
                    return
                deadline = self._queue[0].arrival + self.max_wait_s
                wait = deadline - self.clock()
                if wait > 0:
                    self._wake.wait(wait)
                    continue  # re-evaluate: queue may have flushed/grown
            self._flush(kind="deadline_flushes")
