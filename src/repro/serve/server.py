"""The serving loop: tenants + micro-batcher + caches, one dispatch path.

:class:`PredictionServer` wires the three serve layers together and owns
the only code that actually runs models:

* ``predict`` requests whose snapshot bottoms out in a fitted
  :class:`repro.core.predictor.SegmentModel` (ks+, ks+auto) are
  **gathered across snapshots**: the bucket stacks every lane's
  regression coefficients and evaluates the whole batch with the exact
  elementwise recipe of
  :func:`repro.core.predictor.predict_plans_packed` — per-row ops only,
  offsets cast to the regression dtype — so the batched plans are
  *bit-identical* to per-request calls.  One bucket per
  ``(k, dtype)`` regardless of tenant, family or method: eight tenants'
  ks+ traffic shares one program.
* other ``predict`` requests bucket per snapshot and go through the
  method's own ``predict_packed`` (every registered method has one —
  seeding requires the ``packed`` capability).
* ``evaluate`` / ``tune_offset`` bucket per ``(tenant, family, sid)``
  and replay the snapshot's fitted history through
  :func:`repro.core.fleet.simulate_fleet_many` against a
  **device-resident** trace batch cached per snapshot
  (``serve.dev_sync`` fires only when it is first built).

Lane counts are padded with :func:`repro.core.fleet.pad_lane_axis`
(pow2, ``lo=1``), so the set of dispatched shapes is bounded and warm
traffic never compiles — ``tests/test_contracts.py`` pins the serving
path under ``dispatch_budget(compiles=0)``.  Every bucket dispatch fires
exactly one ``serve.batch`` tag.

:class:`ServeClient` is the synchronous in-process client: ``*_async``
returns a :class:`repro.serve.batcher.ServeFuture`; the plain calls
resolve it by draining the batcher (manual-clock servers) or waiting on
the background thread (:meth:`PredictionServer.start`).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.analysis.contracts import record_dispatch
from repro.core import registry
from repro.obs import metrics as _met
from repro.obs import trace as _obs
from repro.core.allocation import AllocationPlan
from repro.core.envelope import OffsetCandidate, apply_offsets
from repro.core.fleet import (bucket_traces, packed_predict, pad_lane_axis,
                              simulate_fleet_many)
from repro.core.predictor import (ExecutionOutcome, MemoryPredictor,
                                  RefitPolicy, SegmentModel)
from repro.serve.batcher import MicroBatcher, ServeFuture, ServeRequest
from repro.serve.cache import PredictionCache, ProgramCache
from repro.serve.tenants import ModelSnapshot, TenantRegistry

__all__ = ["EvaluateResult", "TuneResult", "PredictionServer", "ServeClient"]


@dataclasses.dataclass(frozen=True)
class EvaluateResult:
    """One ``evaluate`` response: the snapshot replayed on its own
    fitted history through the OOM/retry fleet engine."""

    total_gbs: float     # total wastage (GB*s) over the fitted executions
    n: int               # executions replayed
    succeeded: int       # lanes that finished within max_attempts
    mean_attempts: float
    sid: int             # snapshot that produced this result
    version: int


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """One ``tune_offset`` response (see :func:`repro.core.registry.tune_offset`)."""

    best: OffsetCandidate
    totals: np.ndarray   # per-candidate training wastage (GB*s)
    sid: int


def _plan_from_rows(starts: np.ndarray, peaks: np.ndarray) -> AllocationPlan:
    """Hot-path :class:`AllocationPlan` construction.

    The scatter loop hands this already-normalized rows (1-D float64,
    pinned/monotone by the batched evaluation), so the dataclass
    ``__post_init__`` re-validation is skipped — at thousands of plans
    per flush it is a measurable share of the serving floor.
    """
    plan = AllocationPlan.__new__(AllocationPlan)
    object.__setattr__(plan, "starts", starts)
    object.__setattr__(plan, "peaks", peaks)
    return plan


def _segment_model(method: MemoryPredictor) -> Optional[SegmentModel]:
    """The fitted SegmentModel a method bottoms out in, or None.

    Unwraps ``.model`` chains (KSPlusAuto -> KSPlus -> SegmentModel);
    anything else (baselines, k-Segments' own regressions) dispatches
    through its ``predict_packed`` instead of the gathered path.
    """
    m = method
    for _ in range(3):
        try:
            m = m.model
        except (AttributeError, RuntimeError):
            return None
        if isinstance(m, SegmentModel):
            return m
    return None


class PredictionServer:
    """Multi-tenant prediction-as-a-service front (in-process).

    ``batching=False`` degrades the SAME machinery to per-request
    dispatch (``max_batch=1``: every submit flushes itself) — the
    unbatched baseline the saturation benchmark and the bitwise tests
    compare against runs the identical dispatch code on 1-lane buckets.

    ``clock`` injects a monotonic float source (virtual clocks in tests
    and the benchmark); :meth:`start` runs the deadline loop on a
    background thread against wall time instead.
    """

    def __init__(self, *, machine_memory: float = 128.0,
                 batching: bool = True, max_wait_s: float = 0.002,
                 max_batch: int = 256, max_queue: int = 4096,
                 cache_predictions: bool = True, clock=None,
                 sync_timeout_s: float = 30.0):
        self.tenants = TenantRegistry(machine_memory=machine_memory)
        self.programs = ProgramCache()
        self.predictions = PredictionCache() if cache_predictions else None
        self.batching = bool(batching)
        self.sync_timeout_s = float(sync_timeout_s)
        self._batcher = MicroBatcher(
            self._dispatch, self._bucket_key,
            max_wait_s=max_wait_s if self.batching else 0.0,
            max_batch=max_batch if self.batching else 1,
            max_queue=max_queue, clock=clock)
        self.clock = self._batcher.clock
        self._threaded = False
        self._seg_lock = threading.Lock()
        self._segmodels: Dict[int, Optional[SegmentModel]] = {}
        # Per-sid hot-path memos (snapshots are immutable, so these are
        # write-once; plain dict reads keep the submit path lock-free).
        self._predict_keys: Dict[int, tuple] = {}
        self._gather_rows: Dict[int, tuple] = {}
        self.tenants.on_refit(self._on_refit)

    # --------------------------------------------------------- lifecycle
    def add_tenant(self, name: str) -> None:
        self.tenants.add_tenant(name)

    def seed_family(self, family: str,
                    method: Union[str, MemoryPredictor],
                    mems: Sequence[np.ndarray], dts: Sequence[float],
                    inputs: Sequence[float], *, k: int = 4,
                    default_limit: float = 8.0,
                    tenants: Optional[Sequence[str]] = None) -> ModelSnapshot:
        """Fit once, share the frozen snapshot across tenants (see
        :meth:`repro.serve.tenants.TenantRegistry.seed`)."""
        return self.tenants.seed(family, method, mems, dts, inputs, k=k,
                                 default_limit=default_limit, tenants=tenants)

    def client(self, tenant: str) -> "ServeClient":
        self.tenants._state(tenant)  # loud on unknown tenant
        return ServeClient(self, tenant)

    def start(self) -> None:
        """Serve on a background thread (wall-clock deadline flushes)."""
        self._threaded = True
        self._batcher.start()

    def stop(self) -> None:
        self._batcher.stop()
        self._threaded = False

    def close(self) -> None:
        """Shut down: stop the pump thread and fail still-queued
        requests with :class:`repro.serve.batcher.ServerClosed` instead
        of letting their callers hang (``stop`` drains; ``close``
        abandons).  Idempotent; later submits are rejected."""
        self._batcher.close()
        self._threaded = False

    def __enter__(self) -> "PredictionServer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    @property
    def threaded(self) -> bool:
        return self._threaded

    # --------------------------------------------------------- submission
    def submit(self, kind: str, tenant: str, family: str,
               payload: Any = None) -> ServeFuture:
        """Queue one request; prediction-cache hits resolve immediately
        (no batch wait, no dispatch — the ``serve.cache_hit`` fast path)."""
        try:  # inlined TenantRegistry.snapshot: two dict hops per request
            snap = self.tenants._tenants[tenant].families[family]
        except KeyError:
            snap = self.tenants.snapshot(tenant, family)  # loud errors
        if kind == "predict" and self.predictions is not None:
            hit = self.predictions.get(snap.sid, payload)
            if hit is not None:
                if _obs.enabled:
                    _met.counter("serve.requests").inc(kind=kind,
                                                       cache="hit")
                fut = ServeFuture()
                fut.set_result(hit)
                return fut
        if _obs.enabled:
            _met.counter("serve.requests").inc(kind=kind, cache="miss")
        req = ServeRequest(kind=kind, tenant=tenant, family=family,
                           payload=payload, arrival=self.clock())
        req.snapshot = snap
        return self._batcher.submit(req)

    def pump(self, now: Optional[float] = None) -> int:
        """Manual-clock driving: flush iff due (see MicroBatcher.pump)."""
        return self._batcher.pump(now)

    def drain(self) -> int:
        """Force-dispatch everything pending; returns requests served."""
        total = 0
        while True:
            n = self._batcher.flush()
            if n == 0:
                return total
            total += n

    @property
    def depth(self) -> int:
        return self._batcher.depth

    def oldest_deadline(self) -> Optional[float]:
        return self._batcher.oldest_deadline()

    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "batcher": dict(self._batcher.stats),
            "shapes": self.programs.shape_stats.as_dict(),
            "traces": self.programs.trace_stats.as_dict(),
            "distinct_shapes": self.programs.distinct_shapes,
        }
        if self.predictions is not None:
            out["predictions"] = self.predictions.stats.as_dict()
        return out

    # ------------------------------------------------------ cache plumbing
    def _sid_live(self, sid: int) -> bool:
        """Does any tenant still serve from snapshot ``sid``?"""
        for st in self.tenants._tenants.values():
            for snap in st.families.values():
                if snap.sid == sid:
                    return True
        return False

    def _on_refit(self, tenant: str, family: str, old: ModelSnapshot,
                  new: ModelSnapshot) -> None:
        # Refit-scoped invalidation.  The forked tenant's lookups move to
        # the new sid by construction; the old sid's entries stay valid
        # for any tenant still sharing that snapshot and are dropped only
        # once the last reference is gone.
        self.programs.invalidate_tenant_family(tenant, family)
        if not self._sid_live(old.sid):
            if self.predictions is not None:
                self.predictions.invalidate_sid(old.sid)
            with self._seg_lock:
                self._segmodels.pop(old.sid, None)
            self._predict_keys.pop(old.sid, None)
            self._gather_rows.pop(old.sid, None)

    def _segmodel(self, snap: ModelSnapshot) -> Optional[SegmentModel]:
        with self._seg_lock:
            if snap.sid not in self._segmodels:
                self._segmodels[snap.sid] = _segment_model(snap.method)
            return self._segmodels[snap.sid]

    # ---------------------------------------------------------- bucketing
    def _bucket_key(self, req: ServeRequest):
        snap = req.snapshot
        if req.kind == "predict":
            key = self._predict_keys.get(snap.sid)
            if key is None:
                seg = self._segmodel(snap)
                if seg is not None:
                    # Cross-snapshot gather: one program per (k, dtype).
                    key = ("predict-gather",
                           int(seg.start_reg.slope.shape[0]),
                           str(seg.start_reg.slope.dtype))
                else:
                    key = ("predict-packed", snap.sid)
                self._predict_keys[snap.sid] = key
            return key
        if req.kind in ("evaluate", "tune_offset"):
            return (req.kind, req.tenant, req.family, snap.sid)
        raise ValueError(f"unknown request kind: {req.kind!r}")

    # ----------------------------------------------------------- dispatch
    def _dispatch(self, key, reqs: List[ServeRequest]) -> None:
        record_dispatch("serve.batch")  # exactly one per bucket flush
        if key[0] == "predict-gather":
            self._predict_gathered(key, reqs)
        elif key[0] == "predict-packed":
            self._predict_packed(reqs)
        elif key[0] == "evaluate":
            self._evaluate(reqs)
        else:
            self._tune(reqs)

    def _scatter_plans(self, reqs, starts, peaks) -> None:
        # One vectorized cast to the plans' float64 — exact on float32
        # inputs, and per-row AllocationPlan construction then aliases
        # the rows instead of re-converting lane by lane.
        starts = np.asarray(starts, np.float64)
        peaks = np.asarray(peaks, np.float64)
        put = None if self.predictions is None else self.predictions.put
        for i, r in enumerate(reqs):
            plan = _plan_from_rows(starts[i], peaks[i])
            if put is not None:
                put(r.snapshot.sid, r.payload, plan)
            r.future.set_result(plan)

    def _rows_of(self, snap: ModelSnapshot) -> tuple:
        """Write-once per-sid gather rows: the SegmentModel's regression
        coefficients plus its offset factors as python floats (cast to
        the slope dtype at stack time — NumPy's weak-scalar promotion)."""
        rows = self._gather_rows.get(snap.sid)
        if rows is None:
            s = self._segmodel(snap)
            rows = (s.start_reg.slope, s.start_reg.intercept,
                    s.peak_reg.slope, s.peak_reg.intercept,
                    1.0 - s.start_offset, 1.0 + s.peak_offset)
            self._gather_rows[snap.sid] = rows
        return rows

    def _predict_gathered(self, key, reqs: List[ServeRequest]) -> None:
        """Batched SegmentModel evaluation across snapshots.

        Replicates :func:`repro.core.predictor.predict_plans_packed` with
        per-lane coefficient rows.  Precision contract: every op is
        elementwise per lane and the offset columns are cast to the slope
        dtype (matching NumPy's weak-scalar promotion in the per-model
        path), so results are bit-identical to single-request dispatch.
        """
        _, k, dtype_name = key
        dtype = np.dtype(dtype_name)
        B = len(reqs)
        # Lanes usually repeat a handful of snapshots (shared seeds), so
        # stack each distinct sid's coefficients once and fan them out to
        # lanes with one fancy index — bitwise the same rows, without a
        # per-lane np.stack loop.
        sid_slot: Dict[int, int] = {}
        uniq: List[tuple] = []
        lanes = np.empty(B, np.intp)
        for i, r in enumerate(reqs):
            sid = r.snapshot.sid
            slot = sid_slot.get(sid)
            if slot is None:
                slot = sid_slot[sid] = len(uniq)
                uniq.append(self._rows_of(r.snapshot))
            lanes[i] = slot
        I = np.asarray([r.payload for r in reqs], dtype)
        ss = np.stack([g[0] for g in uniq])[lanes]
        si = np.stack([g[1] for g in uniq])[lanes]
        ps = np.stack([g[2] for g in uniq])[lanes]
        pi = np.stack([g[3] for g in uniq])[lanes]
        so = np.asarray([g[4] for g in uniq], dtype)[lanes]
        po = np.asarray([g[5] for g in uniq], dtype)[lanes]
        I, ss, si, ps, pi, so, po = pad_lane_axis(
            (I, ss, si, ps, pi, so, po), (1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 1.0),
            lo=1)
        self.programs.note_shape("segment-gather", None, k, None, ss.shape)
        Ic = I[:, None]
        starts = (ss * Ic + si) * so[:, None]
        peaks = (ps * Ic + pi) * po[:, None]
        starts = np.maximum.accumulate(np.maximum(starts, 0.0), axis=1)
        starts[:, 0] = 0.0
        peaks = np.maximum.accumulate(np.maximum(peaks, 1e-6), axis=1)
        self._scatter_plans(reqs, starts[:B], peaks[:B])

    def _predict_packed(self, reqs: List[ServeRequest]) -> None:
        """One snapshot's bucket through its own ``predict_packed``.

        Snapshot-shared seeds make this batch across tenants too: every
        tenant still on the seed snapshot lands in the same bucket.
        """
        snap = reqs[0].snapshot
        B = len(reqs)
        inputs = np.asarray([float(r.payload) for r in reqs], np.float64)
        (inputs,) = pad_lane_axis((inputs,), (0.0,), lo=1)
        starts, peaks = snap.method.predict_packed(inputs)
        self.programs.note_shape(snap.method_name, snap.family,
                                 starts.shape[1], None, starts.shape)
        self._scatter_plans(reqs, starts[:B], peaks[:B])

    def _trace_batch(self, tenant: str, family: str, snap: ModelSnapshot):
        return self.programs.trace_batch(
            tenant, family, snap.sid,
            lambda: bucket_traces([np.asarray(m) for m in snap.train_mems]))

    def _evaluate(self, reqs: List[ServeRequest]) -> None:
        """Replay the snapshot's fitted history through the fleet engine.

        All requests in the bucket share one snapshot, so the result is
        computed once and fanned out.  Feeding the cached device-resident
        batch to ``simulate_fleet_many`` is bitwise-equal to passing the
        raw traces (its ``_as_batch`` builds the identical
        ``bucket_traces`` grouping).
        """
        r0 = reqs[0]
        snap = r0.snapshot
        batch = self._trace_batch(r0.tenant, r0.family, snap)
        starts, peaks, nseg = packed_predict(snap.method,
                                             list(snap.train_inputs))
        self.programs.note_shape(snap.method_name, snap.family,
                                 starts.shape[1], snap.dt,
                                 tuple(b.dmems.shape for b in batch.buckets))
        res = simulate_fleet_many(
            [((starts, peaks, nseg), snap.method.retry_spec)], batch,
            snap.dt, machine_memory=snap.machine_memory)[0]
        out = EvaluateResult(
            total_gbs=float(res.total_gbs), n=len(snap.train_mems),
            succeeded=int(res.succeeded.sum()),
            mean_attempts=float(res.attempts.mean()),
            sid=snap.sid, version=snap.version)
        for r in reqs:
            r.future.set_result(out)

    def _tune(self, reqs: List[ServeRequest]) -> None:
        """Offset auto-tuning on the snapshot's history — the body of
        :func:`repro.core.registry.tune_offset`, fed the cached device
        batch (bitwise-equal: same traces, same grouping)."""
        r0 = reqs[0]
        snap = r0.snapshot
        method = snap.method
        batch = self._trace_batch(r0.tenant, r0.family, snap)
        groups: Dict[Any, List[ServeRequest]] = {}
        for r in reqs:  # payload = candidates (None -> the default grid)
            cands = tuple(r.payload) if r.payload is not None \
                else registry.DEFAULT_OFFSET_GRID
            groups.setdefault(cands, []).append(r)
        for cands, group in groups.items():
            if not cands:
                raise ValueError("need at least one OffsetCandidate")
            starts, peaks, nseg = packed_predict(method,
                                                 list(snap.train_inputs))
            jobs = []
            for cand in cands:
                st, pk = apply_offsets(starts, peaks, nseg, cand)
                spec = method.retry_spec
                if cand.last_peak_bump is not None:
                    spec = spec._replace(bump=cand.last_peak_bump)
                jobs.append(((st.astype(np.float32), pk.astype(np.float32),
                              nseg), spec))
            self.programs.note_shape(snap.method_name, snap.family,
                                     starts.shape[1], snap.dt,
                                     tuple(b.dmems.shape
                                           for b in batch.buckets))
            results = simulate_fleet_many(jobs, batch, snap.dt,
                                          machine_memory=snap.machine_memory)
            totals = np.asarray([r.total_gbs for r in results])
            out = TuneResult(best=cands[int(np.argmin(totals))],
                             totals=totals, sid=snap.sid)
            for r in group:
                r.future.set_result(out)


class ServeClient:
    """Synchronous in-process client bound to one tenant.

    ``*_async`` methods return futures (manual pumping / threaded
    servers); the plain methods block — by draining the server when it
    has no background thread, by waiting on the future otherwise.
    ``observe`` / ``refit`` are tenant-local state writes and run inline.
    """

    def __init__(self, server: PredictionServer, tenant: str):
        self._server = server
        self.tenant = tenant

    # ----------------------------------------------------------- requests
    def predict_async(self, family: str, input_gb: float) -> ServeFuture:
        return self._server.submit("predict", self.tenant, family,
                                   float(input_gb))

    def predict(self, family: str, input_gb: float) -> AllocationPlan:
        return self._sync(self.predict_async(family, input_gb))

    def evaluate_async(self, family: str) -> ServeFuture:
        return self._server.submit("evaluate", self.tenant, family)

    def evaluate(self, family: str) -> EvaluateResult:
        return self._sync(self.evaluate_async(family))

    def tune_offset_async(
            self, family: str,
            candidates: Optional[Sequence[OffsetCandidate]] = None
    ) -> ServeFuture:
        return self._server.submit("tune_offset", self.tenant, family,
                                   tuple(candidates) if candidates else None)

    def tune_offset(self, family: str,
                    candidates: Optional[Sequence[OffsetCandidate]] = None
                    ) -> TuneResult:
        return self._sync(self.tune_offset_async(family, candidates))

    # -------------------------------------------------------------- state
    def observe(self, family: str, outcome: ExecutionOutcome) -> int:
        return self._server.tenants.observe(self.tenant, family, outcome)

    def refit(self, family: str,
              policy: Union[RefitPolicy, str] = "every_1") -> bool:
        return self._server.tenants.refit(self.tenant, family, policy)

    def snapshot(self, family: str) -> ModelSnapshot:
        return self._server.tenants.snapshot(self.tenant, family)

    # ------------------------------------------------------------ plumbing
    def _sync(self, fut: ServeFuture):
        if not fut.done:
            if self._server.threaded:
                return fut.result(self._server.sync_timeout_s)
            self._server.drain()
        return fut.result(self._server.sync_timeout_s)
