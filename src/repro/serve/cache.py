"""Serving caches: packed predictions + compiled-program/trace residency.

Two caches with one discipline — every entry is keyed by the *snapshot
id* of the model that produced it, so invalidation is **refit-scoped**:
when a tenant forks its snapshot (:mod:`repro.serve.tenants`), its
lookups move to the new sid (which misses naturally) while every tenant
still sharing the old snapshot keeps its warm entries; the old sid's
entries are dropped only once no tenant references it.  Snapshots are
immutable, so an entry keyed by a still-live sid can never go stale.

* :class:`PredictionCache` — memoizes packed plan rows per
  ``(sid, input_gb)``.  Production prediction traffic is heavily
  repeated (workflow engines resubmit the same task sizes all day), so
  hits resolve at *submit* time — no batch wait, no dispatch — and fire
  the ``serve.cache_hit`` dispatch tag for budget enforcement.  Bounded
  FIFO (oldest-inserted evicts first).

* :class:`ProgramCache` — two residency registries for the batched
  dispatch path:

  - **shapes**: the ``(method, family, k, dt, bucket_shape)`` keys of
    every batched program this server has dispatched.  Bucket shapes
    come from :func:`repro.core.fleet.pad_lane_axis` pow2 compaction,
    so the key set is bounded and warm traffic re-dispatches only
    already-seen shapes — the "never recompiles" half of the serving
    contract (`tests/test_contracts.py` pins it with
    ``dispatch_budget(compiles=0)``).
  - **traces**: device-resident :class:`repro.core.fleet.FleetBatch`
    uploads per snapshot, built once per ``(tenant, family, sid)`` —
    the ``serve.dev_sync`` tag fires only on the build, so repeated
    ``evaluate`` / ``tune_offset`` calls against an unchanged model
    re-use the uploaded traces instead of re-staging host memory.
"""

from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

from repro.analysis.contracts import record_dispatch

__all__ = ["CacheStats", "PredictionCache", "ProgramCache"]


@dataclasses.dataclass
class CacheStats:
    """Hit/miss/invalidation counters (``hit_rate`` for dashboards)."""

    hits: int = 0
    misses: int = 0
    invalidations: int = 0
    evictions: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "invalidations": self.invalidations,
                "evictions": self.evictions,
                "hit_rate": round(self.hit_rate, 4)}


class PredictionCache:
    """Packed plan rows keyed by ``(sid, input_gb)``.

    The snapshot id already encodes tenant lineage and refit version, so
    two tenants sharing a seed snapshot *share hits* until one of them
    refits — copy-on-refit for cache entries, mirroring the model state.
    """

    def __init__(self, max_entries: int = 65536):
        self.max_entries = int(max_entries)
        self._lock = threading.Lock()
        self._entries: OrderedDict = OrderedDict()
        self._by_sid: Dict[int, set] = {}
        self.stats = CacheStats()

    def get(self, sid: int, input_gb: float) -> Optional[tuple]:
        key = (sid, float(input_gb))
        with self._lock:
            hit = self._entries.get(key)
            if hit is None:
                self.stats.misses += 1
                return None
            self.stats.hits += 1
        record_dispatch("serve.cache_hit")
        return hit

    def put(self, sid: int, input_gb: float, plan_row: tuple) -> None:
        key = (sid, float(input_gb))
        with self._lock:
            if key in self._entries:
                return
            self._entries[key] = plan_row
            self._by_sid.setdefault(sid, set()).add(key)
            while len(self._entries) > self.max_entries:
                old, _ = self._entries.popitem(last=False)
                self._by_sid.get(old[0], set()).discard(old)
                self.stats.evictions += 1

    def invalidate_sid(self, sid: int) -> int:
        """Drop every entry produced by snapshot ``sid`` (refit scope)."""
        with self._lock:
            keys = self._by_sid.pop(sid, set())
            for k in keys:
                self._entries.pop(k, None)
            self.stats.invalidations += len(keys)
            return len(keys)

    def __len__(self) -> int:
        return len(self._entries)


class ProgramCache:
    """Dispatched-shape registry + per-snapshot device trace residency."""

    def __init__(self):
        self._lock = threading.Lock()
        self._shapes: Dict[tuple, int] = {}
        self._traces: Dict[Tuple[str, str, int], object] = {}
        self.shape_stats = CacheStats()
        self.trace_stats = CacheStats()

    # ------------------------------------------------------------- shapes
    def note_shape(self, method: str, family: Optional[str], k: int,
                   dt: Optional[float], bucket_shape: tuple) -> bool:
        """Record one batched-dispatch program key; True iff it was warm.

        ``family`` is None for cross-family gathered predict buckets (the
        program is shared by construction); ``dt`` is None for predict
        (no time axis in plan evaluation).
        """
        key = (method, family, int(k), dt if dt is None else float(dt),
               tuple(bucket_shape))
        with self._lock:
            warm = key in self._shapes
            self._shapes[key] = self._shapes.get(key, 0) + 1
            if warm:
                self.shape_stats.hits += 1
            else:
                self.shape_stats.misses += 1
        return warm

    @property
    def distinct_shapes(self) -> int:
        return len(self._shapes)

    # ------------------------------------------------------------- traces
    def trace_batch(self, tenant: str, family: str, sid: int,
                    build: Callable[[], object]):
        """The snapshot's device-resident trace batch, built at most once.

        The build (host packing + device upload) fires ``serve.dev_sync``;
        hits return the resident object without touching the device.
        """
        key = (tenant, family, sid)
        with self._lock:
            got = self._traces.get(key)
        if got is not None:
            self.trace_stats.hits += 1
            return got
        batch = build()  # outside the lock: uploads are slow
        record_dispatch("serve.dev_sync")
        with self._lock:
            self._traces.setdefault(key, batch)
            self.trace_stats.misses += 1
            return self._traces[key]

    def invalidate_tenant_family(self, tenant: str, family: str) -> int:
        """Drop the tenant+family's resident traces (refit scope)."""
        with self._lock:
            dead = [k for k in self._traces
                    if k[0] == tenant and k[1] == family]
            for k in dead:
                del self._traces[k]
            self.trace_stats.invalidations += len(dead)
            return len(dead)
