"""The ``serve_saturation`` harness: throughput, latency, cache discipline.

Three seeded, reproducible phases over a multi-tenant server fronting a
mixed method zoo (ks+, ks+auto, witt-p95, tovar-ppm — one per family):

* **throughput** — the same request tape through a batched server
  (micro-batches of up to ``max_batch``) and an unbatched one
  (``batching=False``: identical dispatch code, one request per bucket).
  Reports req/s for both, the speedup, and whether every batched plan is
  **bitwise equal** to its unbatched twin (the serve precision
  contract).  Prediction caching is off so every request is a real
  dispatch.
* **latency** — a hybrid discrete-event loop: a seeded open-loop Poisson
  arrival process and the batcher deadlines advance a *virtual* clock
  (deterministic coalescing), while each flush's *measured* wall-clock
  dispatch time advances it too (server-busy model).  p50/p99 are
  end-to-end: arrival → flush completion.
* **discipline** — repeat-heavy traffic against the prediction cache
  (hit-rate), then a warm evaluate/tune/predict sweep pinned under
  ``dispatch_budget(compiles=0)`` with ``serve.dev_sync`` forbidden:
  after warmup the serving path never compiles and never re-uploads
  traces.

``benchmarks/run.py`` wraps :func:`run_saturation` into
``BENCH_serve.json``; ``python -m repro.serve`` prints it standalone.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.analysis.contracts import DispatchBudgetError, dispatch_budget
from repro.serve.server import PredictionServer

__all__ = ["FAMILIES", "synth_family", "build_server", "request_tape",
           "measure_throughput", "measure_latency", "measure_discipline",
           "run_saturation"]

# One method per task family — the service fronts the whole registry,
# not one model (tovar-ppm is online=False: predict-only tenancy).
FAMILIES: Tuple[Tuple[str, str], ...] = (
    ("align", "ks+"),
    ("assemble", "ks+auto"),
    ("stats", "witt-p95"),
    ("report", "tovar-ppm"),
)


def synth_family(seed: int, n: int = 24, base: float = 2.0):
    """Seeded synthetic training executions: ramp-and-hold memory traces
    whose length and height scale with the input size."""
    rng = np.random.default_rng(seed)
    mems, dts, inputs = [], [], []
    for _ in range(n):
        size = float(rng.uniform(1.0, 5.0))
        length = int(24 + 6 * size)
        half = length // 2
        ramp = np.linspace(base, base + 1.2 * size, half)
        hold = np.full(length - half, base + 1.4 * size)
        mems.append(np.concatenate([ramp, hold]))
        dts.append(1.0)
        inputs.append(size)
    return mems, dts, inputs


def build_server(*, tenants: int = 8, batching: bool = True,
                 cache_predictions: bool = True,
                 max_wait_s: float = 0.002, max_batch: int = 256,
                 clock: Optional[Callable[[], float]] = None,
                 seed: int = 0) -> PredictionServer:
    """A server with ``tenants`` tenants all sharing the seeded zoo."""
    srv = PredictionServer(batching=batching, max_wait_s=max_wait_s,
                           max_batch=max_batch, clock=clock,
                           cache_predictions=cache_predictions)
    for t in range(tenants):
        srv.add_tenant(f"tenant{t}")
    for i, (family, method) in enumerate(FAMILIES):
        mems, dts, inputs = synth_family(seed + i)
        srv.seed_family(family, method, mems, dts, inputs)
    return srv


def request_tape(n: int, tenants: int, seed: int = 0,
                 repeat_pool: Optional[int] = None
                 ) -> List[Tuple[str, str, float]]:
    """A seeded ``(tenant, family, input_gb)`` tape; ``repeat_pool``
    draws inputs from that many distinct values (cache-phase traffic)."""
    rng = np.random.default_rng(seed)
    pool = (rng.uniform(1.0, 5.0, repeat_pool)
            if repeat_pool is not None else None)
    tape = []
    for i in range(n):
        family = FAMILIES[int(rng.integers(len(FAMILIES)))][0]
        size = (float(pool[int(rng.integers(len(pool)))])
                if pool is not None else float(rng.uniform(1.0, 5.0)))
        tape.append((f"tenant{i % tenants}", family, size))
    return tape


def _run_tape(srv: PredictionServer, tape) -> list:
    futs = [srv.submit("predict", t, f, x) for t, f, x in tape]
    srv.drain()
    return [f.result(0) for f in futs]


def measure_throughput(*, n_requests: int = 1024, tenants: int = 8,
                       max_batch: int = 256, seed: int = 0
                       ) -> Dict[str, object]:
    """Batched vs unbatched req/s on one tape + the bitwise contract."""
    tape = request_tape(n_requests, tenants, seed=seed)
    warm = request_tape(2 * max_batch, tenants, seed=seed + 1)
    out: Dict[str, object] = {"n_requests": n_requests, "tenants": tenants}
    plans: Dict[bool, list] = {}
    for batching in (True, False):
        srv = build_server(tenants=tenants, batching=batching,
                           cache_predictions=False, max_batch=max_batch,
                           seed=seed)
        _run_tape(srv, warm)
        t0 = time.perf_counter()
        plans[batching] = _run_tape(srv, tape)
        dt = time.perf_counter() - t0
        mode = "batched" if batching else "unbatched"
        out[f"req_s_{mode}"] = n_requests / dt
        if batching:
            out["mean_batch"] = (srv._batcher.stats["dispatched"]
                                 / max(srv._batcher.stats["batches"], 1))
    out["speedup_x"] = out["req_s_batched"] / out["req_s_unbatched"]
    out["bitwise"] = all(
        np.array_equal(p.starts, q.starts) and np.array_equal(p.peaks,
                                                              q.peaks)
        for p, q in zip(plans[True], plans[False]))
    return out


def measure_latency(*, rate_rps: float = 2000.0, n_requests: int = 512,
                    tenants: int = 8, max_wait_s: float = 0.002,
                    seed: int = 1) -> Dict[str, float]:
    """Open-loop Poisson arrivals through the virtual-clock event loop."""
    vnow = [0.0]
    srv = build_server(tenants=tenants, batching=True,
                       cache_predictions=False, max_wait_s=max_wait_s,
                       max_batch=4096, clock=lambda: vnow[0], seed=seed)
    _run_tape(srv, request_tape(128, tenants, seed=seed + 1))  # warm
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    tape = request_tape(n_requests, tenants, seed=seed + 2)
    pending: List[Tuple[float, object]] = []
    latencies: List[float] = []
    i = 0
    while i < n_requests or pending:
        next_arrival = arrivals[i] if i < n_requests else np.inf
        deadline = srv.oldest_deadline()
        deadline = np.inf if deadline is None else deadline
        if next_arrival <= deadline:
            vnow[0] = max(vnow[0], float(next_arrival))
            tenant, family, size = tape[i]
            pending.append((float(next_arrival),
                            srv.submit("predict", tenant, family, size)))
            i += 1
            continue
        vnow[0] = max(vnow[0], float(deadline))
        t0 = time.perf_counter()
        flushed = srv.pump(vnow[0])
        if flushed:
            vnow[0] += time.perf_counter() - t0  # server busy dispatching
            still = []
            for arrival, fut in pending:
                if fut.done:
                    latencies.append(vnow[0] - arrival)
                else:
                    still.append((arrival, fut))
            pending = still
    lat_ms = np.asarray(latencies) * 1e3
    return {"rate_rps": rate_rps,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "served": len(latencies),
            "sim_elapsed_s": vnow[0]}


def measure_discipline(*, tenants: int = 8, n_requests: int = 512,
                       repeat_pool: int = 16, seed: int = 2
                       ) -> Dict[str, object]:
    """Cache hit-rate on repeat traffic + the warm zero-compile pin."""
    srv = build_server(tenants=tenants, batching=True,
                       cache_predictions=True, max_batch=64, seed=seed)
    _run_tape(srv, request_tape(n_requests, tenants, seed=seed,
                                repeat_pool=repeat_pool))
    stats = srv.stats()
    hit_rate = stats["predictions"]["hit_rate"]
    # Warm the evaluate/tune path (compiles + trace uploads happen here)...
    for t in range(tenants):
        client = srv.client(f"tenant{t}")
        for family, _ in FAMILIES:
            client.evaluate(family)
    srv.client("tenant0").tune_offset("align")
    # ...then pin the warm path: no compiles, no re-uploads.
    warm_ok = True
    try:
        with dispatch_budget(compiles=0, forbid=("serve.dev_sync",)):
            for t in range(tenants):
                client = srv.client(f"tenant{t}")
                for family, _ in FAMILIES:
                    client.evaluate(family)
            srv.client("tenant0").tune_offset("align")
            _run_tape(srv, request_tape(64, tenants, seed=seed + 3))
    except DispatchBudgetError:
        warm_ok = False
    return {"cache_hit_rate": float(hit_rate),
            "cache_hit_ok": bool(hit_rate > 0.5),
            "warm_zero_compiles": warm_ok,
            "distinct_shapes": stats["distinct_shapes"]}


def run_saturation(*, tenants: int = 8, n_requests: int = 2048,
                   rate_rps: float = 2000.0, seed: int = 0
                   ) -> Dict[str, object]:
    """The full ``serve_saturation`` benchmark payload."""
    thr = measure_throughput(n_requests=n_requests, tenants=tenants,
                             seed=seed)
    lat = measure_latency(rate_rps=rate_rps, n_requests=min(n_requests, 512),
                          tenants=tenants, seed=seed + 1)
    disc = measure_discipline(tenants=tenants, seed=seed + 2)
    return {"throughput": thr, "latency": lat, "discipline": disc}
