"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    ssm_state=128, ssm_headdim=64, ssm_groups=1, ssm_chunk=256,
)
