"""Architecture registry: the 10 assigned configs + reduced smoke variants."""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, SMOKE_OVERRIDES

_MODULES = {
    "qwen3-1.7b": "qwen3_1_7b",
    "mistral-large-123b": "mistral_large_123b",
    "llama3-8b": "llama3_8b",
    "stablelm-12b": "stablelm_12b",
    "zamba2-2.7b": "zamba2_2_7b",
    "dbrx-132b": "dbrx_132b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "hubert-xlarge": "hubert_xlarge",
    "mamba2-780m": "mamba2_780m",
}

__all__ = ["ARCHS", "get_config", "smoke_config", "list_archs"]

ARCHS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    cfg = get_config(arch)
    over: Dict = dict(SMOKE_OVERRIDES)
    # preserve MHA-vs-GQA topology
    if cfg.n_heads and cfg.n_kv_heads == cfg.n_heads:
        over["n_kv_heads"] = over["n_heads"]
    if cfg.family == "ssm":
        over.update(n_heads=0, n_kv_heads=0, d_ff=0)
    if cfg.mrope_sections is not None:
        over["mrope_sections"] = (2, 3, 3)  # sums to smoke head_dim // 2
    if cfg.family == "hybrid":
        over["n_layers"] = 4  # 2 super-layers of (2 mamba + shared attn)
    if not cfg.n_experts:
        over.pop("n_experts", None)
        over.pop("topk", None)
        over["n_experts"] = 0
        over["topk"] = 0
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **over)


def list_archs() -> List[str]:
    return list(ARCHS)
