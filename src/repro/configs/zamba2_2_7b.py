"""zamba2-2.7b [hybrid] — Mamba2 + weight-shared attn blocks.  [arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, head_dim=80,
    ssm_state=64, ssm_headdim=64, ssm_groups=1, ssm_chunk=256,
    shared_attn_every=6,
)
