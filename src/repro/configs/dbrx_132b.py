"""dbrx-132b [moe] — 16 experts top-4, fine-grained.  [hf:databricks/dbrx-base; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, head_dim=128,
    n_experts=16, topk=4,
    moe_local_dispatch=True,  # §Perf it4: shard_map dispatch
)
