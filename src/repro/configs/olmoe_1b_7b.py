"""olmoe-1b-7b [moe] — 64 experts top-8.  [arXiv:2409.02060; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab=50304, head_dim=128,
    n_experts=64, topk=8,
    moe_local_dispatch=True,  # §Perf it4: shard_map dispatch
)
