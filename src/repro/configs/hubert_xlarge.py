"""hubert-xlarge [audio] — encoder-only; CNN frame frontend stubbed.
[arXiv:2106.07447; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, head_dim=80,
    causal=False,
)
