"""Assigned input shapes, per-cell applicability, and dry-run input specs.

Shapes (per the assignment):
  train_4k    — seq 4,096  × global_batch 256   (training step)
  prefill_32k — seq 32,768 × global_batch 32    (inference prefill / encode)
  decode_32k  — 1 new token, KV len 32,768, global_batch 128
  long_500k   — 1 new token, context 524,288, global_batch 1

Cell policy (documented in DESIGN.md §Shape×arch cell policy):
  * long_500k runs only for sub-quadratic families (ssm, hybrid); the
    hybrid's shared attention uses a 4,096 sliding window at 500k.
  * decode shapes are skipped for encoder-only archs (hubert).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import cache_shapes
from repro.models.config import ModelConfig

__all__ = ["ShapeCell", "SHAPES", "cell_supported", "cfg_for_cell",
           "input_specs", "step_kind"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str       # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_supported(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    cell = SHAPES[shape]
    if cfg.is_encoder_only and cell.kind == "decode":
        return False, "encoder-only: no autoregressive decode step"
    if shape == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("pure full-attention arch: 512k dense decode is "
                       "O(seq^2)/token with no sub-quadratic path")
    return True, ""


def cfg_for_cell(cfg: ModelConfig, shape: str) -> ModelConfig:
    """Per-cell config adaptation (documented): hybrid long-context decode
    windows its shared attention to 4,096."""
    if shape == "long_500k" and cfg.family == "hybrid":
        return dataclasses.replace(cfg, sliding_window=4096)
    return cfg


def step_kind(cfg: ModelConfig, shape: str) -> str:
    cell = SHAPES[shape]
    if cell.kind == "prefill" and cfg.is_encoder_only:
        return "encode"
    return cell.kind


def _token_specs(cfg: ModelConfig, batch: int, seq: int,
                 with_labels: bool) -> Dict:
    i32 = jnp.int32
    out: Dict = {}
    if cfg.family in ("vlm", "audio"):
        out["embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                             jnp.bfloat16)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((batch, seq), i32)
    if with_labels:
        out["labels"] = jax.ShapeDtypeStruct((batch, seq), i32)
    if cfg.mrope_sections is not None:
        out["positions"] = jax.ShapeDtypeStruct((batch, seq, 3), i32)
    return out


def input_specs(cfg: ModelConfig, shape: str) -> Dict:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn."""
    ok, why = cell_supported(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} × {shape} unsupported: {why}")
    cell = SHAPES[shape]
    cfg = cfg_for_cell(cfg, shape)
    kind = step_kind(cfg, shape)
    if kind == "train":
        return {"batch": _token_specs(cfg, cell.batch, cell.seq, True)}
    if kind in ("prefill", "encode"):
        return {"batch": _token_specs(cfg, cell.batch, cell.seq, False)}
    # decode: one new token against a cache of capacity `seq`
    i32 = jnp.int32
    batch: Dict = {}
    if cfg.family == "vlm":
        batch["embeds"] = jax.ShapeDtypeStruct((cell.batch, 1, cfg.d_model),
                                               jnp.bfloat16)
    else:
        batch["tokens"] = jax.ShapeDtypeStruct((cell.batch,), i32)
    return {
        "batch": batch,
        "cache": cache_shapes(cfg, cell.batch, cell.seq),
        "pos": jax.ShapeDtypeStruct((cell.batch,), i32),
    }
