import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Roofline analysis from compiled dry-run artifacts (single-pod mesh).

XLA's cost analysis counts a ``while`` body once, so a scanned-layer model
reports ~1/L of its true FLOPs.  We recover exact totals entirely from
compiled artifacts with a depth-reduction pair:

    body = (cost(unroll, L0) - cost(scan, L0)) / (L0 - 1)
    rest = cost(scan, L0) - body
    corrected(L) = rest + L * body

applied to FLOPs, bytes accessed, and per-chip collective traffic.  The
full-depth scan compile supplies the (realistic, buffer-reusing) per-device
memory analysis.  Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (see ``repro.launch.mesh.HW``).

Terms reported per (arch × shape), in seconds per step:
    compute_s    = FLOPs / (chips x peak)
    memory_s     = bytes / (chips x HBM bw)
    collective_s = per-chip collective bytes / link bw
plus MODEL_FLOPS (6·N_active·D for training; 2·N·D + attention reads for
serving) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""

import argparse
import dataclasses
import json
from typing import Dict, Optional

from repro.configs import ARCHS, get_config
from repro.launch.dryrun import run_cell
from repro.launch.mesh import HW
from repro.launch.shapes import SHAPES, cell_supported, cfg_for_cell, step_kind

__all__ = ["roofline_cell", "model_flops", "derive_terms"]

L0 = 4          # depth used for the reduction pair
L0_HYBRID = 2   # super-layers for hybrid models


def _depth_reduced(cfg, scan: bool):
    if cfg.family == "hybrid":
        n = L0_HYBRID * cfg.shared_attn_every
    else:
        n = L0
    return dataclasses.replace(cfg, n_layers=n, scan_layers=scan)


def model_flops(cfg, shape: str) -> float:
    """Analytic 'useful' FLOPs for the cell (6·N·D convention)."""
    cell = SHAPES[shape]
    cfg = cfg_for_cell(cfg, shape)
    n_active = cfg.active_params_count() - cfg.vocab * cfg.d_model  # no embed
    kind = step_kind(cfg, shape)
    tokens = cell.batch * cell.seq

    # attention context FLOPs (score + value matmuls)
    def attn_flops(n_ctx_pairs):
        if cfg.family == "ssm" or not cfg.n_heads:
            return 0.0
        n_attn_layers = (cfg.n_layers // cfg.shared_attn_every
                         if cfg.family == "hybrid" else cfg.n_layers)
        return 4.0 * cfg.n_heads * cfg.hd * n_ctx_pairs * n_attn_layers

    if kind == "train":
        causal_pairs = cell.batch * cell.seq * (cell.seq + 1) / 2
        return 6.0 * n_active * tokens + 3.0 * attn_flops(causal_pairs)
    if kind in ("prefill", "encode"):
        pairs = cell.batch * cell.seq * (cell.seq + 1) / 2
        if not cfg.causal:
            pairs = cell.batch * cell.seq * cell.seq
        return 2.0 * n_active * tokens + attn_flops(pairs)
    # decode: one token per sequence against a cap-length context
    ctx = cell.seq if cfg.family != "hybrid" or cfg.sliding_window is None \
        else min(cell.seq, cfg.sliding_window)
    return 2.0 * n_active * cell.batch + attn_flops(cell.batch * ctx)


def derive_terms(full: Dict, scan0: Dict, unroll0: Dict, L: int,
                 L_reduced: int) -> Dict:
    out = {}
    for key, full_key in [("flops", "flops_per_device"),
                          ("bytes", "bytes_per_device"),
                          ("hbm_bytes", "hbm_bytes_per_device")]:
        b = (unroll0[full_key] - scan0[full_key]) / (L_reduced - 1)
        rest = scan0[full_key] - b
        out[key] = rest + L * b
        out[key + "_body"] = b
    cb = (unroll0["collective"]["total_bytes"]
          - scan0["collective"]["total_bytes"]) / (L_reduced - 1)
    crest = scan0["collective"]["total_bytes"] - cb
    out["collective_bytes"] = crest + L * cb
    # fall back to raw values if the interpolation degenerates
    for k, fk in [("flops", "flops_per_device"),
                  ("bytes", "bytes_per_device"),
                  ("hbm_bytes", "hbm_bytes_per_device")]:
        if out[k] <= 0:
            out[k] = full[fk]
    if out["collective_bytes"] <= 0:
        out["collective_bytes"] = full["collective"]["total_bytes"]
    return out


def roofline_cell(arch: str, shape: str, out_dir: str = "experiments/roofline",
                  dry_dir: str = "experiments/dryrun",
                  cfg_override=None, tag: str = "",
                  rules_patch=None) -> Optional[Dict]:
    cfg = cfg_override or get_config(arch)
    ok, why = cell_supported(cfg, shape)
    cell_id = f"{arch}__{shape}" + (f"__{tag}" if tag else "")
    if not ok:
        rec = dict(cell=cell_id, status="skipped", reason=why)
        _write(out_dir, cell_id, rec)
        return rec

    full = run_cell(arch, shape, False, out_dir=dry_dir,
                    cfg_override=cfg, tag=tag, rules_patch=rules_patch)
    scan0 = run_cell(arch, shape, False, out_dir=dry_dir,
                     cfg_override=_depth_reduced(cfg, True),
                     tag=(tag + "+" if tag else "") + "L0scan",
                     rules_patch=rules_patch)
    unroll0 = run_cell(arch, shape, False, out_dir=dry_dir,
                       cfg_override=_depth_reduced(cfg, False),
                       tag=(tag + "+" if tag else "") + "L0unroll",
                       rules_patch=rules_patch)

    cfg_cell = cfg_for_cell(cfg, shape)
    L = (cfg_cell.n_layers // cfg_cell.shared_attn_every
         if cfg.family == "hybrid" else cfg_cell.n_layers)
    L_red = (L0_HYBRID if cfg.family == "hybrid" else L0)
    terms = derive_terms(full, scan0, unroll0, L, L_red)

    chips = full["n_devices"]
    compute_s = terms["flops"] * chips / (chips * HW.PEAK_FLOPS_BF16)
    memory_s = terms["hbm_bytes"] * chips / (chips * HW.HBM_BW)
    collective_s = terms["collective_bytes"] / HW.ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)], key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    hlo_total = terms["flops"] * chips
    step_s = max(compute_s, memory_s, collective_s)
    rec = dict(
        cell=cell_id, arch=arch, shape=shape, status="ok",
        kind=full["kind"], chips=chips,
        flops_per_device=terms["flops"],
        bytes_per_device=terms["bytes"],
        collective_bytes_per_chip=terms["collective_bytes"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        roofline_fraction=(mf / (chips * HW.PEAK_FLOPS_BF16)) / step_s
        if step_s > 0 else 0.0,
        peak_bytes_per_device=full["memory"]["peak_bytes"],
        fits_hbm=bool(full["memory"]["peak_bytes"] <= HW.HBM_BYTES),
        collective_per_op=full["collective"]["per_op"],
    )
    _write(out_dir, cell_id, rec)
    print(f"ROOFLINE {cell_id}: comp {compute_s*1e3:.1f}ms mem "
          f"{memory_s*1e3:.1f}ms coll {collective_s*1e3:.1f}ms -> {dominant}"
          f" | useful {rec['useful_ratio']:.2f} frac {rec['roofline_fraction']:.2f}"
          f" | peak {rec['peak_bytes_per_device']/2**30:.1f}GiB")
    return rec


def _write(out_dir, cell_id, rec):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    fails = []
    for a in archs:
        for s in shapes:
            try:
                roofline_cell(a, s, out_dir=args.out)
            except Exception as e:
                fails.append((a, s, repr(e)))
                print(f"FAIL roofline {a}x{s}: {e!r}")
    if fails:
        raise SystemExit(f"{len(fails)} roofline cells failed")
    print("ROOFLINE COMPLETE")


if __name__ == "__main__":
    main()
