"""Training driver: real steps on the local mesh, fault-tolerant.

Runs any ``--arch`` (smoke-reduced by default so it trains on CPU),
demonstrates the full production loop: sharded step, deterministic data,
async atomic checkpoints, --resume restart, simulated preemption
(--kill-at-step), straggler detection hooks, and the KS+ memory monitor
feeding the scheduler substrate.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 50
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_config, smoke_config
from repro.data import host_batch
from repro.launch.mesh import make_local_mesh
from repro.launch.partitioning import default_rules, mesh_context, tree_shardings
from repro.models import init_params, param_shapes, param_specs
from repro.optim import adamw_init
from repro.runtime import make_train_step
from repro.sched.monitor import MemoryMonitor

__all__ = ["train"]


def train(arch: str, *, steps: int = 50, seq: int = 128, batch: int = 8,
          smoke: bool = True, ckpt_dir: str | None = None,
          resume: bool = False, kill_at_step: int = -1,
          ckpt_every: int = 20, peak_lr: float = 3e-3,
          log_every: int = 10, seed: int = 0, monitor: bool = True):
    cfg = smoke_config(arch) if smoke else get_config(arch)
    cfg = dataclasses.replace(cfg, remat="none")
    mesh = make_local_mesh()
    rules = default_rules(mesh)

    mon = MemoryMonitor(job_type=f"train:{arch}",
                        input_size=float(batch * seq)) if monitor else None

    with mesh_context(mesh, rules):
        shapes = param_shapes(cfg)
        p_sh = tree_shardings(param_specs(cfg), shapes, mesh, rules)
        params = init_params(cfg, jax.random.PRNGKey(seed))
        params = jax.device_put(params, p_sh)
        opt = adamw_init(params)

        start_step = 0
        mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        if mgr and resume and mgr.latest_step() is not None:
            start_step = mgr.latest_step()
            state = mgr.restore(start_step, {"params": params, "opt": opt})
            params, opt = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step}")

        step_fn = jax.jit(make_train_step(
            cfg, peak_lr=peak_lr, total_steps=max(steps, 2),
            warmup_steps=max(min(100, steps // 5), 1)),
                          donate_argnums=(0, 1))
        losses = []
        t0 = time.time()
        slow_steps = 0
        step_times = []
        for step in range(start_step, steps):
            if step == kill_at_step:
                print(f"[train] simulated preemption at step {step}")
                if mgr:
                    mgr.wait()
                return dict(status="killed", step=step, losses=losses)
            bt = host_batch(cfg, seq, batch, step, seed=seed)
            bt = {k: jnp.asarray(v) for k, v in bt.items()}
            ts = time.time()
            params, opt, metrics = step_fn(params, opt,
                                           bt, jnp.int32(step))
            loss = float(metrics["loss"])
            losses.append(loss)
            step_times.append(time.time() - ts)
            # straggler hook: flag steps >3x the trailing median
            if len(step_times) > 5 and step_times[-1] > 3 * float(
                    np.median(step_times[-20:])):
                slow_steps += 1
            if mon:
                mon.sample()
            if mgr and (step + 1) % ckpt_every == 0:
                mgr.save_async(step + 1, {"params": params, "opt": opt},
                               meta=dict(loss=loss))
            if (step + 1) % log_every == 0 or step == start_step:
                print(f"[train] step {step + 1}/{steps} loss {loss:.4f} "
                      f"({step_times[-1]*1e3:.0f} ms)")
        if mgr:
            if steps % ckpt_every == 0:
                mgr.wait()  # final step already checkpointed asynchronously
            else:
                mgr.save(steps, {"params": params, "opt": opt},
                         meta=dict(loss=losses[-1] if losses else None))
        out = dict(status="done", steps=steps, final_loss=losses[-1],
                   first_loss=losses[0], elapsed_s=time.time() - t0,
                   slow_steps=slow_steps)
        if mon:
            mon.sample(force=True)
            out["rss_trace_gb"] = mon.trace().tolist()
        return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-smoke) config")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--kill-at-step", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, seq=args.seq, batch=args.batch,
                smoke=not args.full, ckpt_dir=args.checkpoint_dir,
                resume=args.resume, kill_at_step=args.kill_at_step,
                seed=args.seed)
    print(json.dumps({k: v for k, v in out.items() if k != "rss_trace_gb"},
                     indent=1))


if __name__ == "__main__":
    main()
