"""Serving driver: batched prefill + decode with KS+ admission control.

Requests with varying prompt lengths arrive in a queue; the server admits a
batch when the predicted memory envelope of (prefill spike → growing KV
cache) fits the device budget, then runs prefill and a decode loop.  The
envelope model is fit online from observed per-request memory curves —
the paper's observe → segment → predict loop applied to serving.

Envelope predictions go through :mod:`repro.serve`: an in-process
:class:`~repro.serve.PredictionServer` (``batching=False`` — admission is
a closed loop, one probe at a time) hosting a single ``kv-envelope``
family whose method is resolved by name through :mod:`repro.core.registry`
(``--method``, default ``ks+``), not constructed directly.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --requests 12
"""

from __future__ import annotations

import argparse
import json
import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.models import decode_step, prefill
from repro.runtime import make_decode_step, make_prefill_step
from repro.serve import PredictionServer

__all__ = ["serve_demo", "kv_envelope"]


def kv_envelope(cfg, batch: int, prompt: int, new_tokens: int) -> np.ndarray:
    """Analytic per-request memory-over-time curve (GB) for one batch:
    prefill spike, then linear KV growth during decode."""
    bytes_per_tok = 2 * cfg.n_kv_heads * cfg.hd * max(
        cfg.n_layers, 1) * 2  # k+v bf16
    kv0 = batch * prompt * bytes_per_tok / 2**30
    act_spike = batch * prompt * cfg.d_model * 4 * 2 / 2**30
    curve = [kv0 + act_spike]
    for t in range(new_tokens):
        curve.append(kv0 + batch * (t + 1) * bytes_per_tok / 2**30)
    return np.asarray(curve)


def serve_demo(arch: str, *, requests: int = 12, max_batch: int = 4,
               prompt_lens=(32, 64, 96), new_tokens: int = 16,
               budget_gb: float = 2.0, seed: int = 0, method: str = "ks+"):
    cfg = smoke_config(arch)
    if cfg.is_encoder_only:
        raise SystemExit(f"{arch} is encoder-only; use encode benchmarks")
    rng = np.random.default_rng(seed)
    queue: List[int] = [int(rng.choice(prompt_lens)) for _ in range(requests)]

    # Online envelope model over 'input size' = batch*prompt tokens,
    # served by the prediction service (method resolved via the registry).
    obs_m, obs_d, obs_i = [], [], []
    for b in (1, 2, max_batch):
        for p in prompt_lens:
            obs_m.append(kv_envelope(cfg, b, p, new_tokens))
            obs_d.append(1.0)
            obs_i.append(float(b * p))
    srv = PredictionServer(batching=False)
    srv.add_tenant("admission")
    srv.seed_family("kv-envelope", method, obs_m, obs_d, obs_i)
    env = srv.client("admission")

    params = None
    prefill_fn = None
    decode_fn = None
    served = 0
    batches = 0
    t0 = time.time()
    from repro.models import init_params
    params = init_params(cfg, jax.random.PRNGKey(seed))
    while queue:
        # Admission: largest batch whose predicted envelope peak fits.
        batch = []
        while queue and len(batch) < max_batch:
            cand = batch + [queue[0]]
            plan = env.predict("kv-envelope", float(len(cand) * max(cand)))
            if plan.peaks.max() > budget_gb and batch:
                break
            batch.append(queue.pop(0))
        S = max(batch)
        Bsz = len(batch)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (Bsz, S)), jnp.int32)
        feed = {"tokens": toks}
        if cfg.family == "vlm":
            feed = {"embeds": jnp.asarray(
                rng.standard_normal((Bsz, S, cfg.d_model)), jnp.float32)}
            feed["positions"] = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None, :, None], (Bsz, S, 3))
        logits, cache = prefill(params, cfg, feed, capacity=S + new_tokens)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        for t in range(new_tokens):
            pos = jnp.full((Bsz,), S + t, jnp.int32)
            db = ({"tokens": tok} if cfg.family != "vlm" else
                  {"embeds": jnp.zeros((Bsz, 1, cfg.d_model), jnp.float32)})
            logits, cache = decode_step(params, cfg, db, cache, pos)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        served += Bsz
        batches += 1
    return dict(served=served, batches=batches,
                elapsed_s=round(time.time() - t0, 2))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--method", default="ks+",
                    help="registry name of the envelope model")
    args = ap.parse_args()
    print(json.dumps(serve_demo(args.arch, requests=args.requests,
                                new_tokens=args.new_tokens,
                                method=args.method), indent=1))


if __name__ == "__main__":
    main()
