"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first init.
"""

from __future__ import annotations

import jax

from repro.launch.partitioning import auto_axis_types

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) ("data", "model"); two pods: (2, 16, 16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **auto_axis_types(len(axes)))


def make_local_mesh():
    """Degenerate mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"), **auto_axis_types(2))


class HW:
    """TPU v5e-class hardware constants for the roofline model."""

    PEAK_FLOPS_BF16 = 197e12   # per chip
    HBM_BW = 819e9             # bytes/s per chip
    ICI_BW = 50e9              # bytes/s per link
    HBM_BYTES = 16 * 2**30     # per chip
    CHIPS_PER_POD = 256
