"""Logical-axis partitioning context (MaxText-style).

Model code annotates tensors with *logical* axis names; the launcher
installs a mesh + rules mapping logical names to mesh axes.  Outside any
context (unit tests, single-device smoke runs) every annotation is a no-op.

Rules drop mappings that don't divide evenly (e.g. 8 KV heads on a 16-wide
``model`` axis fall back to replicated), which keeps one config portable
across meshes.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "default_rules", "mesh_context", "logical_constraint", "spec_for",
    "sharding_for", "tree_shardings", "current_mesh", "current_batch_shards",
    "current_batch_axes", "auto_axis_types",
]

AxisName = Union[str, Tuple[str, ...], None]

_state = threading.local()


def auto_axis_types(n_axes: int) -> Dict[str, tuple]:
    """``axis_types=(AxisType.Auto, ...)`` kwargs for ``jax.make_mesh``.

    ``jax.sharding.AxisType`` only exists on JAX versions with explicit
    sharding (>= 0.5); earlier releases neither expose it nor accept the
    ``axis_types`` kwarg, and their meshes are implicitly Auto.  Splat the
    result (``**auto_axis_types(n)``) so both eras build the same mesh.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def default_rules(mesh: Mesh) -> Dict[str, AxisName]:
    """Logical-axis → mesh-axis rules for the production meshes."""
    axes = mesh.axis_names
    batch: AxisName = ("pod", "data") if "pod" in axes else ("data",)
    return {
        "batch": batch,
        "vocab": "model",
        "embed_fsdp": "data",    # FSDP within a pod; never across pods
        "heads": "model",        # tensor parallel
        "ff": "model",
        "expert": "model",       # expert parallel
        "ssm_inner": "model",
        "q_heads": "model",
        "kv_heads": "model",
        "kv_seq": "model",       # flash-decoding style cache sharding
        "seq_sp": "model",       # sequence-parallel saved activations
        "layer": None,
        "seq": None,
    }


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Optional[Dict[str, AxisName]] = None):
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, rules or default_rules(mesh))
    try:
        with mesh:
            yield
    finally:
        _state.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def current_batch_axes() -> Tuple[str, ...]:
    """Mesh axes the 'batch' logical axis maps to (empty w/o context)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return ()
    mesh, rules = ctx
    target = rules.get("batch")
    if target is None:
        return ()
    names = (target,) if isinstance(target, str) else tuple(target)
    return tuple(n for n in names if n in mesh.axis_names)


def current_batch_shards() -> int:
    """Number of shards the 'batch' logical axis maps to (1 w/o context)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return 1
    mesh = ctx[0]
    size = 1
    for n in current_batch_axes():
        size *= mesh.shape[n]
    return size


def _resolve(axis: Optional[str], dim: int, mesh: Mesh,
             rules: Dict[str, AxisName], used: set) -> AxisName:
    if axis is None:
        return None
    target = rules.get(axis)
    if target is None:
        return None
    names = (target,) if isinstance(target, str) else tuple(target)
    names = tuple(n for n in names if n in mesh.axis_names and n not in used)
    if not names:
        return None
    size = 1
    for n in names:
        size *= mesh.shape[n]
    if dim % size != 0:
        return None  # non-divisible -> replicate (portable configs)
    used.update(names)
    return names if len(names) > 1 else names[0]


def spec_for(axes: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Mesh, rules: Dict[str, AxisName]) -> P:
    used: set = set()
    return P(*[_resolve(a, d, mesh, rules, used)
               for a, d in zip(axes, shape)])


def logical_constraint(x, *axes: Optional[str]):
    """with_sharding_constraint by logical axis names (no-op w/o context)."""
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for(axes: Sequence[Optional[str]], shape: Sequence[int],
                 mesh: Mesh, rules: Optional[Dict[str, AxisName]] = None
                 ) -> NamedSharding:
    rules = rules or default_rules(mesh)
    return NamedSharding(mesh, spec_for(axes, shape, mesh, rules))


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh,
                   rules: Optional[Dict[str, AxisName]] = None):
    """NamedSharding tree from (logical-axes tree, ShapeDtypeStruct tree)."""
    rules = rules or default_rules(mesh)
    return jax.tree.map(
        lambda axes, sds: sharding_for(axes, sds.shape, mesh, rules),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
