import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script builds the production mesh, constructs the step
function with fully specified in/out shardings, runs
``jax.jit(step).lower(**specs).compile()``, and records:

  * ``memory_analysis()``  — per-device bytes (proves the cell fits),
  * ``cost_analysis()``    — HLO FLOPs / bytes for the roofline,
  * per-chip collective traffic parsed from the post-SPMD HLO text,

into ``experiments/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.partitioning import (
    default_rules,
    mesh_context,
    sharding_for,
    spec_for,
)
from repro.launch.shapes import (
    SHAPES,
    cell_supported,
    cfg_for_cell,
    input_specs,
    step_kind,
)
from repro.models import param_shapes, param_specs
from repro.models.config import ModelConfig
from repro.optim import adamw_init
from repro.runtime import step_fn_for

# ---------------------------------------------------------------------------
# sharding construction
# ---------------------------------------------------------------------------

_BATCH_AXES = {
    1: ("batch",),
    2: ("batch", None),
    3: ("batch", None, None),
}


def _batch_shardings(specs: Dict, mesh, rules):
    return {
        k: sharding_for(_BATCH_AXES[len(v.shape)], v.shape, mesh, rules)
        for k, v in specs.items()
    }


def _cache_axes(cfg: ModelConfig, name: str, ndim: int, model_size: int):
    if name in ("k", "v"):
        # Prefer head sharding (no cross-shard softmax); fall back to
        # flash-decoding-style sequence sharding for K < model axis.
        if cfg.n_kv_heads % model_size == 0:
            return ("layer", "batch", None, "kv_heads", None)
        return ("layer", "batch", "kv_seq", None, None)
    if name == "kv_positions":
        return ("batch", None)
    if name == "ssm":
        return (("layer",) * (ndim - 4)) + ("batch", "ssm_heads", None, None)
    if name == "conv":
        return (("layer",) * (ndim - 3)) + ("batch", None, "ssm_inner")
    raise KeyError(name)


def _cache_shardings(cfg, cache_specs: Dict, mesh, rules):
    model_size = mesh.shape["model"]
    return {
        name: sharding_for(
            _cache_axes(cfg, name, len(sds.shape), model_size),
            sds.shape, mesh, rules)
        for name, sds in cache_specs.items()
    }


def _param_shardings(cfg, mesh, rules, dtype_override: Optional[str] = None):
    shapes = param_shapes(cfg)
    if dtype_override is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(dtype_override)),
            shapes)
    axes = param_specs(cfg)
    shardings = jax.tree.map(
        lambda ax, sds: sharding_for(ax, sds.shape, mesh, rules),
        axes, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
    return shapes, shardings


def _repl(mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# collective-traffic parser (post-SPMD HLO)
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUP_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*([a-z0-9]+)\[([0-9,]*)\]"
    r"[^ ]*\s+([a-z][\w\-]*)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*\{")
_NO_TRAFFIC_OPS = frozenset({
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
})

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}


def parse_collectives(hlo_text: str, default_group: int) -> Dict:
    """Per-chip collective traffic (bytes) from post-partitioning HLO.

    Ring-algorithm accounting on per-shard output shapes:
      all-gather          ~ output bytes            (each chip receives it)
      all-reduce          ~ 2 x bytes               (reduce-scatter + gather)
      reduce-scatter      ~ output bytes x group    (input passes through)
      all-to-all          ~ bytes
      collective-permute  ~ bytes
    """
    per_op: Dict[str, float] = {}
    count: Dict[str, int] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        dtype, dims, op = m.groups()
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        size = nbytes * int(np.prod([int(d) for d in dims.split(",") if d]
                                    or [1]))
        g = _GROUP_RE.search(line)
        group = len(g.group(1).split(",")) if g else default_group
        factor = {"all-gather": 1.0, "all-reduce": 2.0,
                  "reduce-scatter": float(group), "all-to-all": 1.0,
                  "collective-permute": 1.0}[op]
        traffic = size * factor
        per_op[op] = per_op.get(op, 0.0) + traffic
        count[op] = count.get(op, 0) + 1
        total += traffic
    return dict(total_bytes=total, per_op=per_op, counts=count)


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def parse_hbm_bytes(hlo_text: str) -> float:
    """Post-fusion HBM traffic proxy (bytes, per device).

    Sums output-shape bytes of every *top-level* instruction — i.e. in all
    computations except fusion bodies — and doubles it (each buffer is
    written once and read ~once).  Ops that move no HBM data (parameters,
    GTEs, bitcasts) and collectives (accounted in the collective term) are
    excluded.  XLA's raw ``bytes accessed`` counts every logical operand
    access pre-fusion and overstates HBM traffic by ~an order of magnitude;
    this proxy tracks what a fused program actually reads/writes.
    """
    total = 0.0
    in_fusion = False
    for line in hlo_text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            in_fusion = "fused" in mc.group(1)
            continue
        if in_fusion:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        dtype, dims, op = mi.groups()
        if op in _NO_TRAFFIC_OPS:
            continue
        nbytes = _DTYPE_BYTES.get(dtype)
        if nbytes is None:
            continue
        total += nbytes * int(np.prod(
            [int(d) for d in dims.split(",") if d] or [1]))
    return 2.0 * total


def run_cell(arch: str, shape: str, multi_pod: bool,
             out_dir: str = "experiments/dryrun",
             cfg_override: Optional[ModelConfig] = None,
             tag: str = "", rules_patch: Optional[Dict] = None) -> Dict:
    base_cfg = cfg_override or get_config(arch)
    ok, why = cell_supported(base_cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape}__{mesh_name}" + (f"__{tag}" if tag else "")
    if not ok:
        rec = dict(cell=cell_id, arch=arch, shape=shape, mesh=mesh_name,
                   status="skipped", reason=why)
        _write(out_dir, cell_id, rec)
        print(f"SKIP  {cell_id}: {why}")
        return rec

    cfg = cfg_for_cell(base_cfg, shape)
    kind = step_kind(cfg, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh)
    if rules_patch:
        rules.update(rules_patch)
    specs = input_specs(cfg, shape)
    step = step_fn_for(cfg, kind)

    t0 = time.time()
    with mesh_context(mesh, rules):
        if kind == "train":
            p_shapes, p_sh = _param_shardings(cfg, mesh, rules)
            opt_shapes = jax.eval_shape(adamw_init, p_shapes)
            opt_sh = {"m": p_sh, "v": p_sh, "count": _repl(mesh)}
            b_sh = _batch_shardings(specs["batch"], mesh, rules)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, opt_sh, b_sh, _repl(mesh)),
                out_shardings=(p_sh, opt_sh, None),
            )
            lowered = jitted.lower(
                p_shapes, opt_shapes, specs["batch"],
                jax.ShapeDtypeStruct((), jnp.int32))
        elif kind in ("prefill", "encode"):
            p_shapes, p_sh = _param_shardings(cfg, mesh, rules,
                                              dtype_override=cfg.dtype)
            b_sh = _batch_shardings(specs["batch"], mesh, rules)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(p_shapes, specs["batch"])
        else:  # decode
            p_shapes, p_sh = _param_shardings(cfg, mesh, rules,
                                              dtype_override=cfg.dtype)
            b_sh = _batch_shardings(specs["batch"], mesh, rules)
            c_sh = _cache_shardings(cfg, specs["cache"], mesh, rules)
            pos_sh = sharding_for(("batch",), specs["pos"].shape, mesh, rules)
            jitted = jax.jit(
                step,
                in_shardings=(p_sh, b_sh, c_sh, pos_sh),
                out_shardings=(None, c_sh),
            )
            lowered = jitted.lower(p_shapes, specs["batch"], specs["cache"],
                                   specs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # cost_analysis() returns a list of per-computation dicts on some JAX
    # versions and a flat dict on others; normalize both shapes.
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    n_dev = mesh.size
    hlo = compiled.as_text()
    coll = parse_collectives(hlo, default_group=n_dev)

    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    hbm_bytes = parse_hbm_bytes(hlo)
    mem_rec = dict(
        argument_bytes=getattr(mem, "argument_size_in_bytes", 0),
        output_bytes=getattr(mem, "output_size_in_bytes", 0),
        temp_bytes=getattr(mem, "temp_size_in_bytes", 0),
        peak_bytes=(getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)),
    )
    rec = dict(
        cell=cell_id, arch=arch, shape=shape, mesh=mesh_name, status="ok",
        kind=kind, n_devices=n_dev,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        flops_per_device=flops, bytes_per_device=bytes_accessed,
        hbm_bytes_per_device=hbm_bytes,
        collective=coll, memory=mem_rec,
        hlo_bytes=len(hlo),
    )
    _write(out_dir, cell_id, rec)
    print(f"OK    {cell_id}: lower {t_lower:.1f}s compile {t_compile:.1f}s "
          f"flops/dev {flops:.3e} temp/dev {mem_rec['temp_bytes']/2**30:.2f}GiB "
          f"coll/dev {coll['total_bytes']/2**30:.3f}GiB")
    return rec


def _write(out_dir: str, cell_id: str, rec: Dict):
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ARCHS if (args.all or args.arch == "all") else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape == "all") else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    run_cell(arch, shape, multi, out_dir=args.out)
                except Exception as e:  # a failing cell is a bug: surface it
                    failures.append((arch, shape, multi, repr(e)))
                    print(f"FAIL  {arch}__{shape}__"
                          f"{'multi' if multi else 'single'}: {e!r}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: "
                         + "; ".join(f"{a}x{s}" for a, s, _, _ in failures))
    print("DRY-RUN COMPLETE")


if __name__ == "__main__":
    main()
