"""Fault-tolerant checkpointing.

* Atomic: each checkpoint is written to ``step_<N>.tmp`` and renamed only
  after a full flush, so a killed writer can never corrupt the latest
  restore point.
* Asynchronous: ``save_async`` snapshots device arrays to host then writes
  on a background thread, overlapping I/O with the next training step.
* Multi-host ready: every process writes only its own ``proc<k>`` file;
  restore reads the local shard (single-process runs read proc0).
* Self-pruning: keeps the newest ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 process_index: Optional[int] = None):
        self.dir = directory
        self.keep = keep
        self.proc = (jax.process_index() if process_index is None
                     else process_index)
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ io
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               meta: Dict[str, Any]):
        final = self._step_dir(step)
        tmp = final + f".tmp{self.proc}"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, f"proc{self.proc}.npz"), **flat)
        with open(os.path.join(tmp, f"meta{self.proc}.json"), "w") as f:
            json.dump(meta, f)
        if os.path.isdir(final):
            shutil.rmtree(final)
        try:
            os.rename(tmp, final)
        except OSError:
            shutil.rmtree(final, ignore_errors=True)  # concurrent writer
            os.rename(tmp, final)
        self._prune()

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------- public
    def save(self, step: int, tree, meta: Optional[Dict[str, Any]] = None):
        self.wait()  # never share a tmp dir with an in-flight async save
        flat = _flatten(jax.device_get(tree))
        self._write(step, flat, dict(step=step, **(meta or {})))

    def save_async(self, step: int, tree, meta: Optional[Dict] = None):
        self.wait()  # one outstanding save at a time
        flat = _flatten(jax.device_get(tree))  # snapshot before returning
        m = dict(step=step, **(meta or {}))
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, m), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(
                    tuple(f".tmp{i}" for i in range(1024))):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template):
        """Restore into the structure of ``template`` (shapes must match)."""
        path = os.path.join(self._step_dir(step), f"proc{self.proc}.npz")
        data = np.load(path)
        leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for p, leaf in leaves:
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                           for q in p)
            arr = data[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            out.append(arr)
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), out)

    def meta(self, step: int) -> Dict[str, Any]:
        with open(os.path.join(self._step_dir(step),
                               f"meta{self.proc}.json")) as f:
            return json.load(f)
