"""Failure-handling strategies (KS+ §II-C and baseline strategies).

All strategies are pure functions ``(plan, t_fail, used_at_fail) -> plan`` so
the cluster simulator can drive any method through the same OOM/retry loop.

KS+ retry: memory peaks are usually right, the *timing* is wrong — so on OOM
before the last segment, re-time: scale every succeeding segment start so the
next one begins exactly at the failure time.  Only when the failure is
already inside the last segment is its peak raised (+20 %).

Each function here is the 1-lane view of the packed, vectorized rule in
:func:`repro.core.envelope.retry_packed` — there is exactly one float64
implementation of every rule, shared with the batched scheduler.
"""

from __future__ import annotations

import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.envelope import RetrySpec, retry_packed

__all__ = [
    "ksplus_retry",
    "ksegments_selective_retry",
    "ksegments_partial_retry",
    "double_retry",
    "max_machine_retry",
    "apply_retry_spec",
]


def apply_retry_spec(spec: RetrySpec, plan: AllocationPlan, t_fail: float,
                     used: float,
                     machine_memory: float = np.inf) -> AllocationPlan:
    """Apply a static :class:`RetrySpec` to one plan (1-lane packed view)."""
    starts, peaks = retry_packed(
        spec, plan.starts[None, :], plan.peaks[None, :],
        np.asarray([plan.n]), np.asarray([t_fail]), np.asarray([used]),
        machine_memory=machine_memory)
    return plan.with_(starts=starts[0], peaks=peaks[0])


def ksplus_retry(plan: AllocationPlan, t_fail: float, used: float,
                 *, last_peak_bump: float = 0.20) -> AllocationPlan:
    """KS+ §II-C: re-time succeeding segments, or bump the last peak."""
    return apply_retry_spec(RetrySpec("ksplus", bump=last_peak_bump),
                            plan, t_fail, used)


def ksegments_selective_retry(plan: AllocationPlan, t_fail: float, used: float,
                              *, margin: float = 0.10) -> AllocationPlan:
    """k-Segments 'Selective': raise only the failed segment's peak."""
    return apply_retry_spec(RetrySpec("kseg-selective", margin=margin),
                            plan, t_fail, used)


def ksegments_partial_retry(plan: AllocationPlan, t_fail: float, used: float,
                            *, margin: float = 0.10) -> AllocationPlan:
    """k-Segments 'Partial': raise the failed segment and every later one."""
    return apply_retry_spec(RetrySpec("kseg-partial", margin=margin),
                            plan, t_fail, used)


def double_retry(plan: AllocationPlan, t_fail: float, used: float,
                 *, cap: float = np.inf) -> AllocationPlan:
    """PPM-Improved / nf-core default: double the allocation (capped)."""
    return apply_retry_spec(RetrySpec("double"), plan, t_fail, used,
                            machine_memory=cap)


def max_machine_retry(plan: AllocationPlan, t_fail: float, used: float,
                      *, machine_memory: float) -> AllocationPlan:
    """Tovar-PPM: on failure, allocate the whole machine."""
    return apply_retry_spec(RetrySpec("max-machine"), plan, t_fail, used,
                            machine_memory=machine_memory)
