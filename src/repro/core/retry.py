"""Failure-handling strategies (KS+ §II-C and baseline strategies).

All strategies are pure functions ``(plan, t_fail, used_at_fail) -> plan`` so
the cluster simulator can drive any method through the same OOM/retry loop.

KS+ retry: memory peaks are usually right, the *timing* is wrong — so on OOM
before the last segment, re-time: scale every succeeding segment start so the
next one begins exactly at the failure time.  Only when the failure is
already inside the last segment is its peak raised (+20 %).
"""

from __future__ import annotations

import numpy as np

from repro.core.allocation import AllocationPlan

__all__ = [
    "ksplus_retry",
    "ksegments_selective_retry",
    "ksegments_partial_retry",
    "double_retry",
    "max_machine_retry",
]


def ksplus_retry(plan: AllocationPlan, t_fail: float, used: float,
                 *, last_peak_bump: float = 0.20) -> AllocationPlan:
    """KS+ §II-C: re-time succeeding segments, or bump the last peak."""
    j = plan.segment_at(t_fail)
    if j < plan.n - 1:
        nxt = plan.starts[j + 1]
        factor = t_fail / nxt if nxt > 0 else 0.0
        starts = plan.starts.copy()
        starts[j + 1:] = starts[j + 1:] * factor
        # The rule is "the next segment begins exactly at the failure time";
        # nxt * (t_fail / nxt) can round one ulp *above* t_fail, which would
        # leave the killed sample uncovered and re-fail it, so assign exactly.
        starts[j + 1] = t_fail
        # Re-timing keeps ordering (scaling by a common factor) and keeps
        # starts[0] == 0; clip for numeric safety.
        starts = np.maximum.accumulate(np.maximum(starts, 0.0))
        starts[0] = 0.0
        return plan.with_(starts=starts)
    peaks = plan.peaks.copy()
    peaks[-1] = peaks[-1] * (1.0 + last_peak_bump)
    return plan.with_(peaks=np.maximum.accumulate(peaks))


def _offset_target(used: float, margin: float) -> float:
    return used * (1.0 + margin)


def ksegments_selective_retry(plan: AllocationPlan, t_fail: float, used: float,
                              *, margin: float = 0.10) -> AllocationPlan:
    """k-Segments 'Selective': raise only the failed segment's peak."""
    j = plan.segment_at(t_fail)
    peaks = plan.peaks.copy()
    peaks[j] = max(peaks[j] * (1.0 + margin), _offset_target(used, margin))
    return plan.with_(peaks=peaks)


def ksegments_partial_retry(plan: AllocationPlan, t_fail: float, used: float,
                            *, margin: float = 0.10) -> AllocationPlan:
    """k-Segments 'Partial': raise the failed segment and every later one."""
    j = plan.segment_at(t_fail)
    peaks = plan.peaks.copy()
    target = max(peaks[j] * (1.0 + margin), _offset_target(used, margin))
    peaks[j:] = np.maximum(peaks[j:], target)
    return plan.with_(peaks=peaks)


def double_retry(plan: AllocationPlan, t_fail: float, used: float,
                 *, cap: float = np.inf) -> AllocationPlan:
    """PPM-Improved / nf-core default: double the allocation (capped)."""
    return plan.with_(peaks=np.minimum(plan.peaks * 2.0, cap))


def max_machine_retry(plan: AllocationPlan, t_fail: float, used: float,
                      *, machine_memory: float) -> AllocationPlan:
    """Tovar-PPM: on failure, allocate the whole machine."""
    return plan.with_(peaks=np.full_like(plan.peaks, machine_memory))
