"""Packed time-varying memory envelopes — the shared representation.

Every layer of this system speaks "allocation envelope": a monotone-indexable
step function ``alloc(t) = peaks[#{i : starts_i <= t} - 1]``.  This module is
the single implementation of that arithmetic, in *packed* ``(B, K)`` form —
``B`` lanes of up to ``K`` segments, unused slots marked by a sentinel start
(:data:`PAD_START`) and a replicated last peak so padded rows evaluate
identically to their originals.

Consumers:

* :mod:`repro.core.allocation` — per-plan scalar helpers, now 1-lane views
  of these functions,
* :mod:`repro.core.retry` — per-plan retry rules, 1-lane views of
  :func:`retry_packed`,
* :mod:`repro.core.fleet` — the jitted OOM/retry engine (same layout, cast
  to float32 on the way to the device),
* :mod:`repro.sched.cluster` / :mod:`repro.sched.elastic` — batched
  admission: node residual envelopes and fits-under-residual reductions over
  every queued job at once,
* :mod:`repro.sched.admission` — the shared fits-matrix runtime state; its
  ``backend="numpy"`` path is :func:`fits_column` verbatim, and its jitted
  ``backend="fused"`` kernel mirrors the same arithmetic in device float64
  (differentially pinned in ``tests/test_admission_fused.py``).

Everything here is plain float64 numpy (no JAX dependency): it is the bit
reference the float32 device paths are differentially tested against, and it
is the arithmetic the host-side scheduler control loop runs directly.

Times are seconds, memory is GB throughout ``repro.core``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import numpy as np

__all__ = [
    "PAD_START",
    "RetrySpec",
    "OffsetCandidate",
    "apply_offsets",
    "PackedEnvelopes",
    "alloc_at_packed",
    "first_violation_packed",
    "segment_sample_bounds",
    "span_alloc_sum",
    "usage_over",
    "residual_over",
    "fits_under",
    "fits_column",
    "retry_packed",
]

# Sentinel start for padded plan slots: far beyond any sample time, so the
# slot's interval is empty and the last real segment's peak is held forever.
PAD_START = 1e30


class RetrySpec(NamedTuple):
    """Static description of a method's failure-handling rule.

    kind:
      * ``"ksplus"``         — §II-C re-time, or bump the last peak,
      * ``"kseg-selective"`` — raise only the failed segment's peak,
      * ``"kseg-partial"``   — raise the failed segment and every later one,
      * ``"double"``         — double every peak (capped at machine memory),
      * ``"max-machine"``    — allocate the whole machine,
      * ``"none"``           — keep the plan (retry changes nothing).

    Hashable on purpose: it is a static argument of the jitted fleet engine
    and a dict key in the scheduler's sweep axes.
    """

    kind: str
    bump: float = 0.20    # ksplus last-segment peak bump
    margin: float = 0.10  # k-segments offset margin


@dataclasses.dataclass(frozen=True)
class OffsetCandidate:
    """One (peak, start, last_peak_bump) safety-offset assignment.

    Applied *on top of* the offsets the plans already carry: segment peaks
    are scaled by ``1 + peak``, starts by ``1 - start`` (then re-pinned and
    made monotone, exactly like the predictor's own offsets), and ksplus
    retries use ``last_peak_bump`` when given.  ``OffsetCandidate()`` is the
    identity — it reproduces the un-swept run decision for decision.

    Every field accepts a per-lane ``(B,)`` array as well as a scalar:
    ``peak``/``start`` flow through :func:`apply_offsets`, and a per-lane
    ``last_peak_bump`` rides the ``bump`` axis of :func:`retry_packed` /
    the fleet engine (NaN entries fall back to the retry spec's static
    bump) — so per-task-family tuning winners may disagree on all three.
    """

    peak: float | np.ndarray = 0.0
    start: float | np.ndarray = 0.0
    last_peak_bump: float | np.ndarray | None = None


def apply_offsets(starts: np.ndarray, peaks: np.ndarray, nseg: np.ndarray,
                  cand: OffsetCandidate):
    """Re-scale a packed plan batch under one offset candidate (O(BK)).

    Elementwise scaling only — the plans' own shape (including the
    non-monotone envelopes k-Segments emits) is preserved, so the identity
    candidate reproduces the input plans exactly.  Per-lane candidates are
    supported by passing ``(B,)``-shaped ``cand.peak`` / ``cand.start``
    arrays.  Returns new ``(starts, peaks)`` float64 arrays.
    """
    starts = np.asarray(starts, np.float64)
    peaks = np.asarray(peaks, np.float64)
    B, K = starts.shape
    real = np.arange(K)[None, :] < np.asarray(nseg).reshape(B, 1)
    p_off = np.asarray(cand.peak, np.float64).reshape(-1, 1)
    s_off = np.asarray(cand.start, np.float64).reshape(-1, 1)
    st = np.where(real, starts * (1.0 - s_off), PAD_START)
    st = np.maximum.accumulate(np.maximum(st, 0.0), axis=1)
    st[:, 0] = 0.0
    st = np.where(real, st, PAD_START)
    pk = np.maximum(peaks * (1.0 + p_off), 1e-6)
    return st, pk


@dataclasses.dataclass(frozen=True)
class PackedEnvelopes:
    """``(B, K)`` batch of step-function envelopes (float64, host-side).

    Attributes:
      starts: (B, K) ascending start offsets; padded slots = ``PAD_START``.
      peaks:  (B, K) allocation per segment; padded slots replicate the last
              real peak (so evaluation never needs the mask).
      nseg:   (B,)  real segment counts.
    """

    starts: np.ndarray
    peaks: np.ndarray
    nseg: np.ndarray

    @property
    def B(self) -> int:
        return int(self.starts.shape[0])

    @property
    def K(self) -> int:
        return int(self.starts.shape[1])

    @classmethod
    def from_plans(cls, plans: Sequence, k: int | None = None
                   ) -> "PackedEnvelopes":
        """Pack plan-like objects (``.starts``/``.peaks`` 1-D arrays).

        Padded slots get ``PAD_START`` starts (never active) and replicate
        the last real peak, so the packed row evaluates identically to the
        original plan.
        """
        K = int(k if k is not None else max(len(p.starts) for p in plans))
        B = len(plans)
        starts = np.full((B, K), PAD_START, np.float64)
        peaks = np.zeros((B, K), np.float64)
        nseg = np.zeros((B,), np.int64)
        for i, p in enumerate(plans):
            n = len(p.starts)
            if n > K:
                raise ValueError(f"plan {i} has {n} segments > K={K}")
            starts[i, :n] = p.starts
            peaks[i, :n] = p.peaks
            peaks[i, n:] = p.peaks[n - 1]
            nseg[i] = n
        return cls(starts=starts, peaks=peaks, nseg=nseg)

    def row(self, i: int):
        """``(starts, peaks)`` of lane ``i`` with padding stripped."""
        n = int(self.nseg[i])
        return self.starts[i, :n].copy(), self.peaks[i, :n].copy()


def alloc_at_packed(starts: np.ndarray, peaks: np.ndarray,
                    t: np.ndarray) -> np.ndarray:
    """Evaluate ``B`` packed step functions at times ``t`` (vectorized).

    ``alloc[b, j] = peaks[b, #{i : starts[b, i] <= t[b, j]} - 1]`` — exactly
    ``searchsorted(side='right') - 1`` per lane, duplicate starts and
    sentinel padding included.

    Args:
      starts/peaks: (B, K).
      t: (T,) shared across lanes, or (B, ...) per-lane times.

    Returns alloc of shape (B, T) (shared grid) or ``t.shape`` (per-lane).
    """
    starts = np.asarray(starts, np.float64)
    peaks = np.asarray(peaks, np.float64)
    t = np.asarray(t, np.float64)
    B, K = starts.shape
    shared = t.ndim == 1
    tt = np.broadcast_to(t, (B,) + t.shape) if shared else t
    flat = tt.reshape(B, -1)
    idx = np.sum(starts[:, None, :] <= flat[:, :, None], axis=2) - 1
    idx = np.clip(idx, 0, K - 1)
    return np.take_along_axis(peaks, idx, axis=1).reshape(tt.shape)


def first_violation_packed(starts: np.ndarray, peaks: np.ndarray,
                           mems: np.ndarray, lengths: np.ndarray,
                           dt: float) -> np.ndarray:
    """First sample per lane with ``mem > alloc + 1e-12``, or -1.

    The float64 OOM-killer oracle (`repro.core.allocation.first_violation`
    is the 1-lane view); the fleet engine's float32 probe is differentially
    tested against this.
    """
    mems = np.asarray(mems, np.float64)
    B, T = mems.shape
    t = np.arange(T, dtype=np.float64) * dt
    alloc = alloc_at_packed(starts, peaks, t)
    valid = np.arange(T)[None, :] < np.asarray(lengths).reshape(B, 1)
    bad = (mems > alloc + 1e-12) & valid
    any_v = bad.any(axis=1)
    vidx = bad.argmax(axis=1)
    return np.where(any_v, vidx, -1).astype(np.int64)


def segment_sample_bounds(starts: np.ndarray, dt) -> np.ndarray:
    """``b_k`` = first sample index ``i`` with ``i*dt >= starts_k`` — exact.

    ``ceil(start/dt)`` alone can be off by one ulp, so both neighbours are
    checked with the *same* float64 arithmetic the sample grid uses, making
    the spans bit-consistent with per-sample ``starts_k <= i*dt`` tests.
    ``dt`` may be a scalar or a per-lane ``(B, 1)`` array.
    """
    starts = np.asarray(starts, np.float64)
    dt = np.asarray(dt, np.float64)
    c = np.ceil(starts / dt)
    c = c - ((c - 1.0) * dt >= starts)
    c = c + (np.maximum(c, 0.0) * dt < starts)
    b = np.clip(c, 0, 2**62).astype(np.int64)
    # segment 0 is active from t=0 regardless (index clipping semantics)
    b[:, 0] = 0
    return b


def span_alloc_sum(peaks: np.ndarray, bounds: np.ndarray,
                   upto: np.ndarray) -> np.ndarray:
    """``sum_k peaks_k * |[b_k, b_{k+1}) ∩ [0, upto)|`` per lane.

    The allocation integral (in samples — multiply by ``dt`` for GB·s) over
    the first ``upto`` samples in O(K) per lane instead of a per-sample pass.
    """
    peaks = np.asarray(peaks, np.float64)
    B, K = peaks.shape
    upto = np.asarray(upto, np.int64).reshape(B, 1)
    hi = np.concatenate([bounds[:, 1:], np.full((B, 1), 2**62, np.int64)],
                        axis=1)
    lo = np.minimum(bounds, upto)
    hi = np.minimum(hi, upto)
    return np.sum(peaks * np.maximum(hi - lo, 0), axis=1)


def usage_over(starts: np.ndarray, peaks: np.ndarray, t0: np.ndarray,
               t: np.ndarray, dur: np.ndarray | None = None) -> np.ndarray:
    """Summed allocation of ``R`` time-shifted envelopes at absolute times.

    Envelope ``r`` is evaluated at ``max(t - t0[r], 0)``; with ``dur`` given
    it only counts inside its active window ``[t0[r], t0[r] + dur[r])`` (the
    cluster's anticipating residual — allocation is freed at the projected
    end), without it the envelope counts forever (the elastic planner's
    conservative headroom).

    Args:
      starts/peaks: (R, K) packed envelopes.
      t0:  (R,) absolute placement times.
      t:   (...,) absolute evaluation times, shared by all envelopes.
      dur: optional (R,) active-window lengths.

    Returns the summed usage, shaped like ``t``.
    """
    t = np.asarray(t, np.float64)
    R = int(np.asarray(starts).shape[0])
    if R == 0:
        return np.zeros(t.shape, np.float64)
    lead = (R,) + (1,) * t.ndim
    rel = t[None, ...] - np.asarray(t0, np.float64).reshape(lead)
    alloc = alloc_at_packed(
        starts, peaks, np.maximum(rel, 0.0).reshape(R, -1)).reshape(rel.shape)
    if dur is not None:
        active = (rel >= 0.0) & (
            rel < np.asarray(dur, np.float64).reshape(lead) + 1e-9)
        alloc = np.where(active, alloc, 0.0)
    return alloc.sum(axis=0)


def residual_over(capacity: float, starts: np.ndarray, peaks: np.ndarray,
                  t0: np.ndarray, t: np.ndarray,
                  dur: np.ndarray | None = None) -> np.ndarray:
    """Node residual envelope: ``capacity - usage_over(...)``."""
    return capacity - usage_over(starts, peaks, t0, t, dur)


def fits_under(need: np.ndarray, resid: np.ndarray,
               tol: float = 1e-9) -> np.ndarray:
    """Vectorized fits-under-residual reduction: ``all(need <= resid + tol)``
    over the trailing (grid) axis — the scheduler's admission predicate for
    every queued job at once."""
    return np.all(np.asarray(need) <= np.asarray(resid) + tol, axis=-1)


def fits_column(capacity: float, run_starts: np.ndarray,
                run_peaks: np.ndarray, run_t0: np.ndarray,
                need: np.ndarray, grid_abs: np.ndarray,
                dur: np.ndarray | None = None, tol: float = 1e-9):
    """One node's admission column: ``(fits, resid)`` for every queued job.

    The float64 reference the fused admission kernel is pinned to:
    ``resid`` is the node's residual envelope under its resident
    (time-shifted, optionally windowed) allocations evaluated on each
    queued job's absolute horizon grid, and ``fits`` the pointwise
    admission predicate.  Shapes: ``run_*`` are the ``R`` resident
    envelopes, ``need``/``grid_abs`` are ``(Q, G)``.
    """
    resid = residual_over(capacity, run_starts, run_peaks, run_t0,
                          grid_abs, dur)
    return fits_under(need, resid, tol), resid


def retry_packed(spec: RetrySpec, starts: np.ndarray, peaks: np.ndarray,
                 nseg: np.ndarray, t_fail: np.ndarray, used: np.ndarray,
                 machine_memory: float = np.inf,
                 bump: np.ndarray | None = None):
    """Vectorized ``(plan, t_fail, used) -> plan`` over every lane at once.

    The float64 reference for every retry rule; the per-plan functions in
    :mod:`repro.core.retry` are 1-lane views of this, and the fleet engine's
    jnp transform mirrors it rule for rule.  Returns ``(starts, peaks)``
    (new arrays; inputs are not modified).

    ``bump`` optionally overrides ``spec.bump`` *per lane* (a ``(B,)``
    array) — the ksplus last-peak bump is the one retry parameter offset
    tuning sweeps, and per-task-family winners may disagree on it within
    one packed batch.  ``None`` keeps the spec's static value everywhere.
    """
    starts = np.asarray(starts, np.float64)
    peaks = np.asarray(peaks, np.float64)
    B, K = starts.shape
    nseg = np.asarray(nseg, np.int64).reshape(B)
    t_fail = np.asarray(t_fail, np.float64).reshape(B)
    used = np.asarray(used, np.float64).reshape(B)
    idx = np.arange(K)[None, :]
    real = idx < nseg[:, None]

    if spec.kind == "none":
        return starts.copy(), peaks.copy()
    if spec.kind == "double":
        return starts.copy(), np.minimum(peaks * 2.0, machine_memory)
    if spec.kind == "max-machine":
        return starts.copy(), np.full_like(peaks, machine_memory)

    # Failed segment: last real slot with start <= t_fail (searchsorted-right
    # semantics; sentinel-padded slots never count).
    j = np.sum((starts <= t_fail[:, None]) & real, axis=1) - 1
    j = np.clip(j, 0, None)
    jcol = j[:, None]
    peak_j = np.take_along_axis(peaks, jcol, axis=1)[:, 0]

    if spec.kind == "kseg-selective":
        target = np.maximum(peak_j * (1.0 + spec.margin),
                            used * (1.0 + spec.margin))
        return starts.copy(), np.where(idx == jcol, target[:, None], peaks)

    if spec.kind == "kseg-partial":
        target = np.maximum(peak_j * (1.0 + spec.margin),
                            used * (1.0 + spec.margin))
        return starts.copy(), np.where(
            idx >= jcol, np.maximum(peaks, target[:, None]), peaks)

    if spec.kind == "ksplus":
        bump_col = (spec.bump if bump is None
                    else np.asarray(bump, np.float64).reshape(B, 1))
        is_last = (j >= nseg - 1)[:, None]
        # --- re-time branch: next segment begins exactly at the failure time,
        # every later one is scaled by the same factor.
        nxt = np.take_along_axis(
            starts, np.minimum(j + 1, K - 1)[:, None], axis=1)[:, 0]
        safe = np.where(nxt > 0, nxt, 1.0)
        factor = np.where(nxt > 0, t_fail / safe, 0.0)
        st = np.where(real & (idx > jcol), starts * factor[:, None], starts)
        st = np.where(real & (idx == jcol + 1), t_fail[:, None], st)
        st = np.maximum.accumulate(np.maximum(st, 0.0), axis=1)
        st[:, 0] = 0.0
        st = np.where(real, st, PAD_START)
        # --- last-segment branch: bump the final peak, keep monotone.
        pk = np.where(idx == (nseg - 1)[:, None],
                      peaks * (1.0 + bump_col), peaks)
        pk = np.maximum.accumulate(pk, axis=1)
        return (np.where(is_last, starts, st), np.where(is_last, pk, peaks))

    raise ValueError(f"unknown retry kind: {spec.kind!r}")
