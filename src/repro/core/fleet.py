"""Batched fleet-scale OOM/retry simulation engine.

This is the vectorized reformulation of :func:`repro.core.wastage.
simulate_execution`: instead of replaying every test execution through a
Python loop (``families × methods × executions × retry-attempts`` numpy
calls — the hot path behind the paper's Figs. 6–8), an entire batch of
(plan, trace) lanes runs the full OOM/retry protocol inside **one jitted
XLA program**:

1. plans are padded to ``(B, K)`` step functions (sentinel starts mark the
   unused slots) and traces to ``(B, T)`` with a validity length,
2. each attempt evaluates every lane at once — first violating sample
   (the simulated OOM killer), successful-attempt wastage and
   killed-attempt wastage come from one fused probe (the extended Pallas
   ``oom_probe`` kernel on TPU, a pure-``jnp`` formulation elsewhere),
3. failed lanes advance through a *vectorized* retry transform — the KS+
   §II-C re-timing rule and every baseline bump rule expressed as pure
   ``jnp`` plan rewrites,
4. a ``jax.lax.while_loop`` iterates attempts until all lanes either
   succeed or are unsatisfiable on the node class (``machine_memory``),
   capped at ``max_attempts``.

:func:`simulate_execution` remains the per-execution oracle; the
differential test in ``tests/test_fleet.py`` pins this engine to it
attempt-for-attempt.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.contracts import record_dispatch
from repro.core import envelope as _env
from repro.core.allocation import AllocationPlan
from repro.core.envelope import PackedEnvelopes, RetrySpec
from repro.obs import trace as _obs

__all__ = [
    "RetrySpec",
    "PackedTraces",
    "TraceBucket",
    "FleetBatch",
    "FleetResult",
    "pack_plans",
    "pack_traces",
    "pad_lane_axis",
    "group_lengths",
    "bucket_traces",
    "subset_batch",
    "fleet_eval",
    "first_attempt",
    "packed_predict",
    "concat_packed",
    "simulate_fleet",
    "simulate_fleet_many",
]

# Sentinel start for padded plan slots (float32 view of the shared
# envelope-layer sentinel): far beyond any sample time, so the slot's
# interval is empty and the last real segment's peak is held forever.
PAD_START = np.float32(_env.PAD_START)


@dataclasses.dataclass(frozen=True)
class PackedTraces:
    """Padded ``(B, T)`` trace batch, shareable across engine calls."""

    mems: np.ndarray      # (B, T) float32
    lengths: np.ndarray   # (B,)  int32


@dataclasses.dataclass(frozen=True)
class TraceBucket:
    """One length bucket of a :class:`FleetBatch` (lanes of similar T).

    Host copies (``mems``/``lengths``) feed failure-compaction; the
    device-resident, lane-padded copies (``dmems``/``dlengths``/``dsummem``)
    are uploaded once and shared by every probe over this bucket — per-call
    host-to-device transfer would otherwise repeat per method.
    """

    idx: np.ndarray       # (b,) lane indices into the original batch
    mems: np.ndarray      # (b, T_bucket) float32, host
    lengths: np.ndarray   # (b,) int32, host
    dmems: object         # (Bp, T_bucket) jnp, lane axis padded to pow2
    dmemsneg: object      # (Bp, T_bucket) jnp, -inf outside the valid span
    dlengths: object      # (Bp,) jnp int32
    dsummem: object       # (Bp,) jnp float32: sum of valid samples per lane


@dataclasses.dataclass(frozen=True)
class FleetBatch:
    """Traces grouped into power-of-two length buckets.

    Padding every trace to the global maximum length wastes most of the
    engine's (memory-bound) work on zeros — short tasks dominate real
    workflows while a few long ones set T.  Bucketing keeps the padded
    element count within ~2× of the real sample count.  Build once with
    :func:`bucket_traces` and share across methods / plan batches.
    """

    n: int
    buckets: tuple  # tuple[TraceBucket, ...]


@dataclasses.dataclass(frozen=True)
class FleetResult:
    """Per-lane outcome of a fleet simulation (mirrors ExecutionResult)."""

    wastage_gbs: np.ndarray  # (B,) float64
    attempts: np.ndarray     # (B,) int — evaluated attempts (>= 1)
    succeeded: np.ndarray    # (B,) bool

    @property
    def retries(self) -> np.ndarray:
        return self.attempts - 1

    @property
    def total_gbs(self) -> float:
        return float(self.wastage_gbs.sum())


def pack_plans(plans: Sequence[AllocationPlan], k: int | None = None):
    """Pad plans to a common segment count.

    Padded slots get ``PAD_START`` starts (never active) and replicate the
    last real peak, so the packed plan evaluates identically to the original.
    Returns ``(starts, peaks, nseg)`` of shapes (B, K), (B, K), (B,).
    """
    K = int(k if k is not None else max(p.n for p in plans))
    B = len(plans)
    ns = {p.n for p in plans}
    if ns == {K}:  # uniform-width fast path (the common per-method case)
        starts = np.stack([p.starts for p in plans]).astype(np.float32)
        peaks = np.stack([p.peaks for p in plans]).astype(np.float32)
        return starts, peaks, np.full((B,), K, np.int32)
    env = PackedEnvelopes.from_plans(plans, K)
    return (env.starts.astype(np.float32), env.peaks.astype(np.float32),
            env.nseg.astype(np.int32))


def packed_predict(method, inputs: Sequence[float], k: int | None = None):
    """Predict plans for a batch of inputs directly in packed form.

    Uses the method's vectorized ``predict_packed`` when it exposes one
    (every built-in method does — per-plan Python prediction costs more
    than the whole batched simulation at fleet scale), falling back to
    per-plan ``predict`` + :func:`pack_plans`.
    """
    fn = getattr(method, "predict_packed", None)
    if fn is None:
        return pack_plans([method.predict(i) for i in inputs], k)
    starts, peaks = fn(np.asarray(inputs, np.float64))
    starts = np.ascontiguousarray(starts, np.float32)
    peaks = np.ascontiguousarray(peaks, np.float32)
    B, K = starts.shape
    nseg = np.full((B,), K, np.int32)
    if k is not None and k > K:
        starts = np.concatenate(
            [starts, np.full((B, k - K), PAD_START, np.float32)], axis=1)
        peaks = np.concatenate(
            [peaks, np.repeat(peaks[:, -1:], k - K, axis=1)], axis=1)
    return starts, peaks, nseg


def concat_packed(parts: Sequence) -> tuple:
    """Concatenate packed plan triples along lanes, padding K to the max."""
    K = max(p[0].shape[1] for p in parts)
    outs, outp, outn = [], [], []
    for starts, peaks, nseg in parts:
        pad = K - starts.shape[1]
        if pad:
            B = starts.shape[0]
            starts = np.concatenate(
                [starts, np.full((B, pad), PAD_START, np.float32)], axis=1)
            peaks = np.concatenate(
                [peaks, np.repeat(peaks[:, -1:], pad, axis=1)], axis=1)
        outs.append(starts)
        outp.append(peaks)
        outn.append(nseg)
    return (np.concatenate(outs), np.concatenate(outp), np.concatenate(outn))


def pack_traces(mems: Sequence[np.ndarray], min_t: int = 128) -> PackedTraces:
    """Pad traces to a power-of-two length (bucketed to bound recompiles)."""
    T = max(max(len(m) for m in mems), min_t)
    T = 1 << (T - 1).bit_length()
    B = len(mems)
    padded = np.zeros((B, T), np.float32)
    lengths = np.zeros((B,), np.int32)
    for i, m in enumerate(mems):
        padded[i, : len(m)] = m
        lengths[i] = len(m)
    return PackedTraces(mems=padded, lengths=lengths)


def _make_bucket(idx: np.ndarray, mems_list, T: int) -> TraceBucket:
    packed = pack_traces(mems_list, min_t=T)
    b = len(idx)
    Bp = _bucket(b)
    pmems = packed.mems
    plen = packed.lengths
    if Bp != b:
        pmems = np.concatenate(
            [pmems, np.zeros((Bp - b, pmems.shape[1]), np.float32)])
        plen = np.concatenate([plen, np.zeros((Bp - b,), np.int32)])
    summem = np.asarray(
        [m.sum(dtype=np.float64) for m in mems_list]
        + [0.0] * (Bp - b), np.float32)
    memsneg = np.where(
        np.arange(pmems.shape[1])[None, :] < plen[:, None], pmems, -np.inf
    ).astype(np.float32)
    return TraceBucket(
        idx=idx, mems=packed.mems, lengths=packed.lengths,
        dmems=jnp.asarray(pmems), dmemsneg=jnp.asarray(memsneg),
        dlengths=jnp.asarray(plen), dsummem=jnp.asarray(summem))


def group_lengths(lengths: Sequence[int], min_t: int = 128,
                  min_lanes: int = 16, max_buckets: int = 4):
    """The bucket policy itself: lane indices grouped by power-of-two
    padded length.  Sparse buckets are merged into the next-longer one
    (below ``min_lanes`` lanes a bucket costs more in per-group overhead
    than its padding saves) and ``max_buckets`` bounds the orchestration
    fan-out.  Returns ``[(T, sorted index array), ...]`` ascending in T —
    shared by :func:`bucket_traces` and the workload generator's
    direct-to-packed-lanes path (:mod:`repro.workloads.generate`), so the
    two always agree on layout.
    """
    by_t: dict = {}
    for i, n in enumerate(lengths):
        T = max(int(n), min_t)
        T = 1 << (T - 1).bit_length()
        by_t.setdefault(T, []).append(i)
    groups = []  # ascending T, merged
    carry: list = []
    for T in sorted(by_t):
        cur = carry + by_t[T]
        if len(cur) < min_lanes and T != max(by_t):
            carry = cur
            continue
        groups.append((T, cur))
        carry = []
    # (the largest-T iteration always appends, so nothing is left in carry)
    while len(groups) > max_buckets:
        # merge the smallest group into the next-longer one
        i = min(range(len(groups) - 1), key=lambda g: len(groups[g][1]))
        T = groups[i + 1][0]
        groups[i + 1] = (T, groups[i][1] + groups[i + 1][1])
        del groups[i]
    return [(T, np.asarray(sorted(ids), np.int64)) for T, ids in groups]


def bucket_traces(mems: Sequence[np.ndarray], min_t: int = 128,
                  min_lanes: int = 16, max_buckets: int = 4) -> FleetBatch:
    """Group traces into power-of-two length buckets (see FleetBatch and
    :func:`group_lengths`, the shared grouping policy)."""
    buckets = []
    for T, idx in group_lengths([len(m) for m in mems], min_t,
                                min_lanes, max_buckets):
        buckets.append(_make_bucket(idx, [mems[i] for i in idx], T))
    return FleetBatch(n=len(mems), buckets=tuple(buckets))


def subset_batch(batch: FleetBatch, lanes) -> FleetBatch:
    """Restrict a :class:`FleetBatch` to a lane subset, keeping bucket widths.

    Every selected lane stays in (a copy of) its original bucket with the
    original padded length ``T``, so all per-lane engine arithmetic —
    probes, span sums, device-side trace reductions — is bit-identical to a
    run over the full batch.  The online replay harness leans on this: its
    round batches must reproduce the offline replay bitwise under
    ``refit="never"``.  ``n`` and the buckets' ``idx`` keep the *original*
    lane numbering, so full-batch plan/result arrays index unchanged.
    """
    want = {int(i) for i in np.asarray(lanes).ravel()}
    buckets = []
    for b in batch.buckets:
        local = np.asarray(
            [p for p, i in enumerate(b.idx) if int(i) in want], np.int64)
        if local.size == 0:
            continue
        nb, T = int(local.size), b.mems.shape[1]
        Bp = _bucket(nb)
        pmems = np.zeros((Bp, T), np.float32)
        pmems[:nb] = b.mems[local]
        plen = np.zeros((Bp,), np.int32)
        plen[:nb] = b.lengths[local]
        # Slice (never recompute) the per-lane trace sums: the originals
        # were reduced from the raw float64 traces, which the float32 host
        # rows kept here cannot reproduce bit-for-bit.
        summem = np.zeros((Bp,), np.float32)
        summem[:nb] = np.asarray(b.dsummem)[local]
        memsneg = np.where(
            np.arange(T)[None, :] < plen[:, None], pmems, -np.inf
        ).astype(np.float32)
        buckets.append(TraceBucket(
            idx=b.idx[local], mems=b.mems[local], lengths=b.lengths[local],
            dmems=jnp.asarray(pmems), dmemsneg=jnp.asarray(memsneg),
            dlengths=jnp.asarray(plen), dsummem=jnp.asarray(summem)))
    return FleetBatch(n=batch.n, buckets=tuple(buckets))


# --------------------------------------------------------------------- probe
def _first_violation_jnp(starts, peaks, memsneg, dt: float):
    """First sample with ``mem > alloc`` per lane, or -1.

    ``alloc(t) = peaks[#{i : starts_i <= t} - 1]`` reproduces the oracle's
    ``searchsorted(side='right') - 1`` segment lookup, duplicate starts and
    sentinel padding included; ``memsneg`` is -inf outside the valid span,
    folding the validity mask into the comparison itself.
    """
    B, T = memsneg.shape
    K = starts.shape[1]
    t = jnp.arange(T, dtype=jnp.float32) * dt
    idx = jnp.sum(starts[:, None, :] <= t[None, :, None], axis=2) - 1
    idx = jnp.clip(idx, 0, K - 1)
    alloc = jnp.take_along_axis(peaks, idx, axis=1)
    bad = memsneg > alloc
    any_v = jnp.any(bad, axis=1)
    vidx = jnp.argmax(bad, axis=1)
    return jnp.where(any_v, vidx, -1).astype(jnp.int32)


def _seg_bounds(starts, dt: float):
    """b_k = first sample index i with ``i*dt >= starts_k`` — exactly.

    ``ceil(start/dt)`` alone can be off by one ulp, so both neighbours are
    checked with the *same* float32 arithmetic the probe's time grid uses
    (``i.astype(f32) * dt``), making the boundaries bit-consistent with the
    per-sample comparisons.
    """
    c = jnp.clip(jnp.ceil(starts / dt), 0.0, 1.0e9)
    c = c - ((c - 1.0) * dt >= starts)
    c = c + (jnp.clip(c, 0.0, 1.0e9) * dt < starts)
    b = jnp.clip(c, 0.0, 2.0e9).astype(jnp.int32)
    # segment 0 is active from t=0 regardless (index clipping semantics)
    return b.at[:, 0].set(0)


def _span_alloc_sum(peaks, bounds, upto):
    """``sum_k peaks_k * |[b_k, b_{k+1}) ∩ [0, upto)|`` — the allocation
    integral over the first ``upto`` samples in O(K) per lane."""
    B, K = peaks.shape
    hi = jnp.concatenate(
        [bounds[:, 1:], jnp.full((B, 1), np.iinfo(np.int32).max, jnp.int32)],
        axis=1)
    lo = jnp.minimum(bounds, upto[:, None])
    hi = jnp.minimum(hi, upto[:, None])
    return jnp.sum(peaks * jnp.maximum(hi - lo, 0).astype(jnp.float32),
                   axis=1)


def _oom_probe_jnp(starts, peaks, mems, memsneg, lengths, summem, dt: float):
    """Full per-attempt probe: ``(viol, w_succ, w_kill, used)``.

    ``w_succ`` is exact only for lanes with ``viol < 0`` (for a successful
    attempt ``max(alloc, mem) == alloc`` everywhere, so the wastage
    integral collapses to segment-span arithmetic minus ``summem``); the
    engine never reads it otherwise.  ``w_kill`` integrates the allocation
    up to and including the kill sample, again in O(K) spans.
    """
    viol = _first_violation_jnp(starts, peaks, memsneg, dt)
    bounds = _seg_bounds(starts, dt)
    w_succ = (_span_alloc_sum(peaks, bounds, lengths) - summem) * dt
    v = jnp.maximum(viol, 0)
    w_kill = jnp.where(
        viol >= 0, _span_alloc_sum(peaks, bounds, v + 1), 0.0) * dt
    used = jnp.take_along_axis(mems, v[:, None], axis=1)[:, 0]
    return viol, w_succ, w_kill, used


@functools.partial(jax.jit, static_argnames=("dt", "backend", "block_t"))
def first_attempt(starts, peaks, mems, lengths, machine_memory, *,
                  dt: float, backend: str = "jnp", block_t: int = 512):
    """Probe attempt #1 for every lane: ``(viol, w_succ)``.

    Standalone-jit convenience around the phase-A probe of
    :func:`simulate_fleet_many` (which amortizes dispatch by batching many
    groups instead).  ``w_succ`` is meaningful where ``viol < 0``.
    """
    capped = jnp.minimum(peaks, machine_memory)
    if backend == "jnp":
        validb = jnp.arange(mems.shape[1])[None, :] < lengths[:, None]
        memsneg = jnp.where(validb, mems, -jnp.inf)
        summem = jnp.sum(jnp.where(validb, mems, 0.0), axis=1)
        viol, w_succ = _probe_first_jnp(
            starts, capped, memsneg, lengths, summem, dt)
    else:
        from repro.kernels.wastage.ops import oom_probe
        viol, w_succ, _ = oom_probe(
            starts, capped, mems, lengths, dt=dt, block_t=block_t,
            interpret=(backend == "pallas-interpret"))
    return viol, w_succ


# --------------------------------------------------------------- retry rules
def _retry_transform(spec: RetrySpec, starts, peaks, nseg, t_fail, used,
                     machine_memory, bump=None):
    """Vectorized ``(plan, t_fail, used) -> plan`` over every lane at once.

    Mirrors :mod:`repro.core.retry` rule for rule; lanes that are not
    retrying are masked out by the caller.  ``bump`` optionally overrides
    the static ``spec.bump`` per lane (a traced ``(B,)`` array — see
    :func:`repro.core.envelope.retry_packed`).
    """
    B, K = starts.shape
    idx = jnp.arange(K)[None, :]
    real = idx < nseg[:, None]

    if spec.kind == "none":
        return starts, peaks
    if spec.kind == "double":
        return starts, jnp.minimum(peaks * 2.0, machine_memory)
    if spec.kind == "max-machine":
        return starts, jnp.full_like(peaks, machine_memory)

    # Failed segment: last real slot with start <= t_fail (searchsorted-right
    # semantics; sentinel-padded slots never count).
    j = jnp.sum((starts <= t_fail[:, None]) & real, axis=1) - 1
    j = jnp.clip(j, 0, nseg - 1)
    peak_j = jnp.take_along_axis(peaks, j[:, None], axis=1)[:, 0]

    if spec.kind == "kseg-selective":
        target = jnp.maximum(peak_j * (1.0 + spec.margin),
                             used * (1.0 + spec.margin))
        return starts, jnp.where(idx == j[:, None], target[:, None], peaks)

    if spec.kind == "kseg-partial":
        target = jnp.maximum(peak_j * (1.0 + spec.margin),
                             used * (1.0 + spec.margin))
        raise_mask = real & (idx >= j[:, None])
        return starts, jnp.where(
            raise_mask, jnp.maximum(peaks, target[:, None]), peaks)

    if spec.kind == "ksplus":
        is_last = j >= nseg - 1
        # --- re-time branch: next segment begins exactly at the failure time,
        # every later one is scaled by the same factor.
        nxt = jnp.take_along_axis(
            starts, jnp.minimum(j + 1, K - 1)[:, None], axis=1)[:, 0]
        factor = jnp.where(nxt > 0, t_fail / jnp.maximum(nxt, 1e-30), 0.0)
        st = jnp.where(real & (idx > (j + 1)[:, None]),
                       starts * factor[:, None], starts)
        st = jnp.where(idx == (j + 1)[:, None], t_fail[:, None], st)
        st = jax.lax.cummax(jnp.maximum(st, 0.0), axis=1)
        st = st.at[:, 0].set(0.0)
        st = jnp.where(real, st, PAD_START)
        # --- last-segment branch: bump the final peak, keep monotone.
        bump_col = spec.bump if bump is None else bump[:, None]
        pk = jnp.where(idx == (nseg - 1)[:, None],
                       peaks * (1.0 + bump_col), peaks)
        pk = jax.lax.cummax(pk, axis=1)
        new_starts = jnp.where(is_last[:, None], starts, st)
        new_peaks = jnp.where(is_last[:, None], pk, peaks)
        return new_starts, new_peaks

    raise ValueError(f"unknown retry kind: {spec.kind!r}")


# -------------------------------------------------------------------- engine
def _engine_loop(starts, peaks, nseg, mems, lengths, machine_memory, *,
                 retry: RetrySpec, dt: float, max_attempts: int,
                 backend: str, block_t: int = 512, bump_lanes=None):
    """Traced body of the retry engine (shared by every jitted entry point).

    ``bump_lanes`` is an optional traced ``(B,)`` per-lane override of the
    ksplus ``retry.bump`` — tuned offsets may assign a different
    last-peak bump per task family within one lane batch.
    """
    B, T = mems.shape
    validb = jnp.arange(T)[None, :] < lengths[:, None]
    # Loop-invariant trace precomputes, amortized over every attempt.
    memsneg = jnp.where(validb, mems, -jnp.inf)
    summem = jnp.sum(jnp.where(validb, mems, 0.0), axis=1)
    peak_demand = jnp.max(memsneg, axis=1)
    unsat = peak_demand > machine_memory  # no allocation can satisfy

    if backend == "jnp":
        def probe(s, p):
            return _oom_probe_jnp(s, p, mems, memsneg, lengths, summem, dt)
    else:
        from repro.kernels.wastage.ops import oom_probe

        def probe(s, p):
            viol, w_succ, w_kill = oom_probe(
                s, p, mems, lengths, dt=dt, block_t=block_t,
                interpret=(backend == "pallas-interpret"))
            used = jnp.take_along_axis(
                mems, jnp.maximum(viol, 0)[:, None], axis=1)[:, 0]
            return viol, w_succ, w_kill, used

    def cond(state):
        it, _, _, active, _, _, _ = state
        return (it < max_attempts) & jnp.any(active)

    def body(state):
        it, sts, pks, active, succ, att, w = state
        capped = jnp.minimum(pks, machine_memory)
        viol, w_succ, w_kill, used = probe(sts, capped)
        failed = viol >= 0
        succ_now = active & ~failed
        w = w + jnp.where(succ_now, w_succ, 0.0) \
              + jnp.where(active & failed, w_kill, 0.0)
        att = att + active.astype(jnp.int32)
        succ = succ | succ_now
        retrying = active & failed & ~unsat
        t_fail = jnp.maximum(viol, 0).astype(jnp.float32) * dt
        nsts, npks = _retry_transform(
            retry, sts, capped, nseg, t_fail, used, machine_memory,
            bump=bump_lanes)
        sts = jnp.where(retrying[:, None], nsts, sts)
        pks = jnp.where(retrying[:, None], npks, capped)
        return (it + 1, sts, pks, retrying, succ, att, w)

    state = (
        jnp.int32(0),
        jnp.asarray(starts, jnp.float32),
        jnp.asarray(peaks, jnp.float32),
        jnp.ones((B,), bool),
        jnp.zeros((B,), bool),
        jnp.zeros((B,), jnp.int32),
        jnp.zeros((B,), jnp.float32),
    )
    _, _, _, _, succeeded, attempts, wastage = jax.lax.while_loop(
        cond, body, state)
    return wastage, attempts, succeeded


@functools.partial(
    jax.jit,
    static_argnames=("retry", "dt", "max_attempts", "backend", "block_t"),
)
def fleet_eval(starts, peaks, nseg, mems, lengths, machine_memory, *,
               retry: RetrySpec, dt: float, max_attempts: int = 25,
               backend: str = "jnp", block_t: int = 512, bump_lanes=None):
    """Run the full OOM/retry protocol for every lane in one XLA program.

    Args:
      starts/peaks: (B, K) packed plans (``pack_plans``).
      nseg:         (B,)  real segment counts.
      mems:         (B, T) padded traces; lengths: (B,) valid counts.
      machine_memory: scalar — node capacity cap (traced, so sweeping it
        does not recompile).
      retry: static :class:`RetrySpec`.
      backend: ``"jnp"`` | ``"pallas"`` | ``"pallas-interpret"``.
      bump_lanes: optional (B,) per-lane ksplus last-peak bump override
        (traced; ``None`` keeps ``retry.bump`` everywhere).

    Returns ``(wastage, attempts, succeeded)``, each (B,).
    """
    return _engine_loop(starts, peaks, nseg, mems, lengths, machine_memory,
                        retry=retry, dt=dt, max_attempts=max_attempts,
                        backend=backend, block_t=block_t,
                        bump_lanes=bump_lanes)


def _probe_first_jnp(starts, peaks, memsneg, lengths, summem, dt: float):
    """Attempt-#1 probe: ``(viol, w_succ)`` with w_succ valid where viol<0.

    The fast path of the fleet: one per-sample pass for the violation scan,
    O(K) span arithmetic for the wastage of the (majority) lanes that
    succeed immediately.
    """
    viol = _first_violation_jnp(starts, peaks, memsneg, dt)
    bounds = _seg_bounds(starts, dt)
    w_succ = (_span_alloc_sum(peaks, bounds, lengths) - summem) * dt
    return viol, w_succ


@functools.partial(jax.jit, static_argnames=("dt", "backend", "block_t"))
def _probe_many(groups, machine_memory, *, dt: float, backend: str = "jnp",
                block_t: int = 512):
    """Attempt #1 for many (plan batch, trace bucket) groups, ONE dispatch.

    ``groups`` is a pytree: a tuple of
    ``(starts, peaks, mems, memsneg, lengths, summem)`` per group.
    Per-call dispatch overhead (~0.5 ms on CPU) dwarfs the per-group
    compute for typical bucket sizes, so every method × length bucket of an
    experiment probes in a single XLA program.
    """
    out = []
    for starts, peaks, mems, memsneg, lengths, summem in groups:
        capped = jnp.minimum(peaks, machine_memory)
        if backend == "jnp":
            viol, w_succ = _probe_first_jnp(
                starts, capped, memsneg, lengths, summem, dt)
        else:
            from repro.kernels.wastage.ops import oom_probe
            viol, w_succ, _ = oom_probe(
                starts, capped, mems, lengths, dt=dt, block_t=block_t,
                interpret=(backend == "pallas-interpret"))
        out.append((viol, w_succ))
    return tuple(out)


@functools.partial(
    jax.jit,
    static_argnames=("specs", "dt", "max_attempts", "backend", "block_t"),
)
def _retry_many(groups, machine_memory, *, specs, dt: float,
                max_attempts: int = 25, backend: str = "jnp",
                block_t: int = 512):
    """Full retry loops for many compacted failure groups, ONE dispatch.

    ``groups`` is a tuple of ``(starts, peaks, nseg, mems, lengths, bump)``
    (``bump`` a per-lane ksplus bump array or ``None``); ``specs`` the
    matching static tuple of :class:`RetrySpec`.
    """
    out = []
    for spec, (starts, peaks, nseg, mems, lengths, bump) in zip(specs,
                                                                groups):
        out.append(_engine_loop(
            starts, peaks, nseg, mems, lengths, machine_memory,
            retry=spec, dt=dt, max_attempts=max_attempts, backend=backend,
            block_t=block_t, bump_lanes=bump))
    return tuple(out)


def _bucket(b: int, lo: int = 8) -> int:
    return max(lo, 1 << (b - 1).bit_length())


def pad_lane_axis(arrs: Sequence[np.ndarray], fills: Sequence,
                  lo: int = 8, fine: bool = False, sub: int = 8) -> tuple:
    """Pad every array's leading (lane) axis to a shared bucket size.

    The compaction trick shared by the fleet retry engine and the fused
    admission engine: gather the active minority into compact rows, then
    pad the lane axis to a bucketed size so the jitted consumers see a
    bounded set of shapes instead of one compile per lane count.
    ``fine=False`` pads to the next power of two (log2-many shapes, up to
    ~2x padding); ``fine=True`` pads to the next multiple of 1/``sub`` of
    the next power of two (``sub`` shapes per octave; the default 8 gives
    <= 25% worst-case padding waste — for the admission engine's deep
    queues, where a 2x pad would double the per-dispatch work).  Callers
    whose lane count wanders across octaves every dispatch can lower
    ``sub`` to trade padding waste for fewer compiled shapes.
    ``fills[i]`` is the pad value for ``arrs[i]``; dtypes are preserved.
    """
    B = int(arrs[0].shape[0])
    Bp = _bucket(B, lo)
    if fine and Bp > lo:
        step = max(Bp // sub, lo)
        Bp = ((B + step - 1) // step) * step
    if Bp == B:
        return tuple(arrs)
    return tuple(
        np.concatenate(
            [a, np.full((Bp - B,) + a.shape[1:], fill, a.dtype)])
        for a, fill in zip(arrs, fills))


def _pad_lanes(starts, peaks, nseg, mems, lengths):
    """Pad the lane axis to a power of two (dummy lanes trivially succeed)."""
    return pad_lane_axis(
        (starts, peaks, nseg, mems, lengths),
        (PAD_START, 1.0, 1, 0.0, 0))


def _as_batch(mems) -> FleetBatch:
    if isinstance(mems, FleetBatch):
        return mems
    if isinstance(mems, PackedTraces):
        B, T = mems.mems.shape
        rows = [mems.mems[i, : mems.lengths[i]] for i in range(B)]
        return FleetBatch(
            n=B, buckets=(_make_bucket(np.arange(B), rows, T),))
    return bucket_traces(mems)


def simulate_fleet_many(
    jobs: Sequence,
    mems: Union[FleetBatch, PackedTraces, Sequence[np.ndarray]],
    dt: float = 1.0,
    *,
    machine_memory: float = np.inf,
    max_attempts: int = 25,
    backend: str = "auto",
    k: int | None = None,
) -> List[FleetResult]:
    """Run many plan batches against one shared trace batch.

    ``jobs`` is a sequence of ``(plans, retry_spec)`` pairs — e.g. one per
    prediction method — all evaluated against the same executions.  Each
    job's ``plans`` may be a list of :class:`AllocationPlan` or an already
    packed ``(starts, peaks, nseg)`` triple (see :func:`pack_plans` /
    :func:`packed_predict`); an optional third element is a per-lane
    ``(B,)`` ksplus last-peak-bump array overriding ``retry_spec.bump``
    lane for lane (NaN entries keep the spec's static value) — tuned
    per-task-family offsets ride the lane batch this way.  The
    orchestration is built for a dispatch-bound host:

    * traces are grouped into power-of-two **length buckets** (padding every
      lane to the longest trace would spend most of the memory-bound probe
      on zeros),
    * **one** jitted call probes attempt #1 of every job × bucket — the
      usually-large majority of lanes that succeeds immediately is settled
      by that single dispatch,
    * the failing minority is **compacted** and a second jitted call runs
      the full retry while-loop per job × bucket group (re-evaluating their
      first attempt: a small price, on a small subset, for a state-free
      handoff).

    Per-call overhead (~0.5 ms) therefore amortizes over *all* methods and
    buckets instead of multiplying into them.
    """
    if _obs.enabled:
        with _obs.span("fleet.simulate_many", jobs=len(jobs)):
            return _simulate_fleet_many_impl(
                jobs, mems, dt, machine_memory=machine_memory,
                max_attempts=max_attempts, backend=backend, k=k)
    return _simulate_fleet_many_impl(
        jobs, mems, dt, machine_memory=machine_memory,
        max_attempts=max_attempts, backend=backend, k=k)


def _simulate_fleet_many_impl(
    jobs: Sequence,
    mems: Union[FleetBatch, PackedTraces, Sequence[np.ndarray]],
    dt: float = 1.0,
    *,
    machine_memory: float = np.inf,
    max_attempts: int = 25,
    backend: str = "auto",
    k: int | None = None,
) -> List[FleetResult]:
    batch = _as_batch(mems)
    B = batch.n
    norm = []
    for item in jobs:
        plans, r = item[0], item[1]
        spec = RetrySpec(r) if isinstance(r, str) else r
        bump = item[2] if len(item) > 2 else None
        if bump is not None:
            bump = np.where(np.isnan(np.asarray(bump, np.float64)),
                            spec.bump, bump).astype(np.float32)
        norm.append((plans, spec, bump))
    jobs = norm
    packed_jobs = []  # (starts, peaks, nseg) over ALL lanes, per job
    for plans, _, _ in jobs:
        sp = plans if isinstance(plans, tuple) else pack_plans(plans, k)
        if sp[0].shape[0] != B:
            raise ValueError(f"{sp[0].shape[0]} plans vs {B} traces")
        packed_jobs.append(sp)
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "jnp"
    mm = jnp.float32(machine_memory)

    # Phase A: slice each job's packed plans per bucket, probe everything in
    # one dispatch against the buckets' device-resident traces.
    groups = []
    for starts, peaks, nseg in packed_jobs:
        for bucket in batch.buckets:
            bs, bp = starts[bucket.idx], peaks[bucket.idx]
            Bp = bucket.dmems.shape[0]
            if Bp != bs.shape[0]:
                pad = Bp - bs.shape[0]
                bs = np.concatenate(
                    [bs, np.full((pad, bs.shape[1]), PAD_START, np.float32)])
                bp = np.concatenate(
                    [bp, np.ones((pad, bp.shape[1]), np.float32)])
            groups.append(
                (bs, bp, bucket.dmems, bucket.dmemsneg, bucket.dlengths,
                 bucket.dsummem))
    record_dispatch("fleet.probe")
    probes = _probe_many(tuple(groups), mm, dt=float(dt), backend=backend)

    results = [
        FleetResult(wastage_gbs=np.zeros((B,), np.float64),
                    attempts=np.ones((B,), np.int64),
                    succeeded=np.zeros((B,), bool))
        for _ in jobs
    ]

    # Phase B: compact failures per group, run every retry loop at once.
    fail_groups, fail_specs, fail_meta = [], [], []
    gi = 0
    for j, (_, spec, bump) in enumerate(jobs):
        starts, peaks, nseg = packed_jobs[j]
        for bucket in batch.buckets:
            b = len(bucket.idx)
            # lint: allow[host-sync-in-hot-path] one batched readback per bucket group; failures must be compacted on host for phase B
            viol, w_succ = jax.device_get(probes[gi])
            viol = viol[:b]
            w_succ = w_succ[:b].astype(np.float64)
            ok = viol < 0
            res = results[j]
            res.wastage_gbs[bucket.idx[ok]] = w_succ[ok]
            res.succeeded[bucket.idx[ok]] = True
            if not ok.all():
                local = np.nonzero(~ok)[0]
                fail = bucket.idx[local]
                padded = _pad_lanes(
                    starts[fail], peaks[fail], nseg[fail],
                    bucket.mems[local], bucket.lengths[local])
                fbump = None
                if bump is not None:
                    (fbump,) = pad_lane_axis(
                        (bump[fail],), (np.float32(spec.bump),))
                fail_groups.append(padded + (fbump,))
                fail_specs.append(spec)
                fail_meta.append((j, fail, len(fail)))
            gi += 1

    if fail_groups:
        record_dispatch("fleet.retry")
        outs = _retry_many(
            tuple(fail_groups), mm, specs=tuple(fail_specs),
            dt=float(dt), max_attempts=max_attempts, backend=backend)
        for (j, out_idx, nf), out in zip(fail_meta, outs):
            res = results[j]
            # lint: allow[host-sync-in-hot-path] one batched readback per fail group scatters the retry outcomes
            w, att, suc = jax.device_get(out)
            res.wastage_gbs[out_idx] = w[:nf].astype(np.float64)
            res.attempts[out_idx] = att[:nf]
            res.succeeded[out_idx] = suc[:nf]
    return results


def simulate_fleet(
    plans: Sequence[AllocationPlan],
    retry: Union[RetrySpec, str],
    mems: Union[FleetBatch, PackedTraces, Sequence[np.ndarray]],
    dt: float = 1.0,
    *,
    machine_memory: float = np.inf,
    max_attempts: int = 25,
    backend: str = "auto",
    k: int | None = None,
    bump_lanes: np.ndarray | None = None,
) -> FleetResult:
    """Simulate one execution per (plan, trace) lane — the fleet primitive.

    Drop-in batched equivalent of calling
    :func:`repro.core.wastage.simulate_execution` per lane; see
    :func:`simulate_fleet_many` for the orchestration (this is the
    single-job case).  ``bump_lanes`` optionally assigns a per-lane ksplus
    last-peak bump (NaN = keep ``retry``'s static value).
    """
    return simulate_fleet_many(
        [(plans, retry, bump_lanes)], mems, dt,
        machine_memory=machine_memory, max_attempts=max_attempts,
        backend=backend, k=k)[0]
