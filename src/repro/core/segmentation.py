"""KS+ dynamic segmentation (Algorithm 1 of the paper).

A memory trace ``M[0..L)`` is compressed into at most ``k`` variable-length
segments ``(S_i, P_i)`` (duration in samples, peak memory) forming a
monotonically non-decreasing step function that upper-bounds the trace.

Two phases:

1. *Monotone compression*: a new segment starts exactly at each strict
   running-maximum record of the trace; every other sample extends the
   current segment.  This yields strictly increasing peaks and guarantees
   ``M[t] <= P_seg(t)`` for every sample.

   Note on the published pseudocode: Algorithm 1 as printed appends a new
   segment when ``M_i < P_-1`` and extends when ``M_i >= P_-1``, which
   contradicts the paper's own prose ("merge every segment with its
   predecessor, if the peak value of the segment is smaller than the peak
   value of the preceding segment ... until the constraint of being
   monotonically increasing is fulfilled") and would produce non-monotone,
   under-allocating envelopes.  We implement the prose semantics (the
   branches of the printed pseudocode are evidently swapped).

2. *Greedy merging*: while more than ``k`` segments remain, merge the
   segment ``i`` with the smallest merge error
   ``e_i = (P_{i+1} - P_i) * S_i`` into its successor (the merged segment
   keeps the successor's larger peak).

Two implementations are provided:

* :func:`get_segments_ref` — plain-numpy oracle, variable-length output,
  used by tests and by the non-batched control plane.
* :func:`get_segments` — fixed-shape JAX implementation built from
  ``lax`` control flow so it ``jit``s and ``vmap``s across thousands of
  executions (the fleet-scale path).
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["get_segments_ref", "get_segments", "segments_to_starts"]


# ---------------------------------------------------------------------------
# numpy reference (oracle)
# ---------------------------------------------------------------------------


def get_segments_ref(M: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Reference implementation of Algorithm 1.

    Args:
      M: 1-D array of memory samples (length >= 1).
      k: maximum number of output segments (>= 1).

    Returns:
      ``(S, P)`` — integer durations (samples) and float peaks, with
      ``len(S) == len(P) <= k``, ``sum(S) == len(M)``, ``P`` strictly
      increasing, and ``P_seg(t) >= M[t]`` for all ``t``.
    """
    M = np.asarray(M, dtype=np.float64)
    if M.ndim != 1 or M.size == 0:
        raise ValueError("M must be a non-empty 1-D array")
    if k < 1:
        raise ValueError("k must be >= 1")

    # Phase 1: monotone compression — new segment at each strict record.
    S = [1]
    P = [float(M[0])]
    for m in M[1:]:
        if m > P[-1]:
            P.append(float(m))
            S.append(1)
        else:
            S[-1] += 1

    # Phase 2: greedy merging down to k segments.
    while len(P) > k:
        e = [(P[i + 1] - P[i]) * S[i] for i in range(len(P) - 1)]
        idx = int(np.argmin(e))  # first minimum on ties, as in the paper
        S[idx + 1] += S[idx]
        del S[idx]
        del P[idx]

    return np.asarray(S, dtype=np.int64), np.asarray(P, dtype=np.float64)


# ---------------------------------------------------------------------------
# JAX fixed-shape implementation
# ---------------------------------------------------------------------------


def _phase1_monotone(M: jnp.ndarray, valid: jnp.ndarray):
    """Vectorized monotone compression over a padded trace.

    Args:
      M:     (T,) float samples, padding arbitrary.
      valid: (T,) bool, True for real samples.  Must be a prefix mask.

    Returns:
      (P, S, n): (T,) peaks / (T,) durations compacted to the first ``n``
      entries (rest zero-padded), and the segment count ``n``.
    """
    T = M.shape[0]
    neg = jnp.asarray(-jnp.inf, M.dtype)
    m = jnp.where(valid, M, neg)
    run_max = jax.lax.associative_scan(jnp.maximum, m)
    prev_max = jnp.concatenate([jnp.full((1,), neg, M.dtype), run_max[:-1]])
    is_new = (m > prev_max) & valid
    seg_id = jnp.cumsum(is_new.astype(jnp.int32)) - 1  # -1 before first valid
    seg_id = jnp.where(valid, seg_id, T - 1)  # dump padding into a sink slot

    # Peak of each segment = value at its record point (= running max there).
    P = jnp.zeros((T,), M.dtype).at[seg_id].max(jnp.where(valid, m, neg))
    S = jnp.zeros((T,), jnp.int32).at[seg_id].add(valid.astype(jnp.int32))
    n = jnp.sum(is_new.astype(jnp.int32))
    # Clean the sink slot if no real segment landed there.
    slot_valid = jnp.arange(T) < n
    P = jnp.where(slot_valid, P, 0.0)
    S = jnp.where(slot_valid, S, 0)
    return P, S, n


def _merge_step(state):
    P, S, n, k = state
    T = P.shape[0]
    idx_range = jnp.arange(T - 1)
    e = (P[1:] - P[:-1]) * S[:-1].astype(P.dtype)
    e = jnp.where(idx_range < n - 1, e, jnp.inf)
    idx = jnp.argmin(e)  # first min on ties (argmin is first-occurrence)
    S = S.at[idx + 1].add(S[idx])
    # Shift entries left over the removed slot.
    ar = jnp.arange(T)
    src = jnp.where(ar >= idx, ar + 1, ar)
    src = jnp.clip(src, 0, T - 1)
    P = jnp.where(ar < n - 1, P[src], 0.0)
    S = jnp.where(ar < n - 1, S[src], 0)
    return (P, S, n - 1, k)


@partial(jax.jit, static_argnames=("k",))
def get_segments(M: jnp.ndarray, length: jnp.ndarray, k: int):
    """Fixed-shape JAX version of Algorithm 1 (jit/vmap friendly).

    Args:
      M:      (T,) padded float trace.
      length: scalar int — number of valid leading samples.
      k:      static maximum segment count.

    Returns:
      ``(S, P, n)``: (k,) int32 durations, (k,) float peaks, scalar int32
      actual segment count ``n <= k``.  Slots ``>= n`` are zero.
    """
    T = M.shape[0]
    valid = jnp.arange(T) < length
    P, S, n = _phase1_monotone(M, valid)

    def cond(state):
        _, _, cur, _ = state
        return cur > k

    P, S, n, _ = jax.lax.while_loop(cond, _merge_step, (P, S, n, jnp.int32(k)))
    return S[:k], P[:k], n


def segments_to_starts(S: jnp.ndarray, n: jnp.ndarray | int | None = None):
    """Durations -> start offsets (samples). Slot i starts at sum(S[:i]).

    Padding slots (>= n) get the total length so they never activate early.
    """
    starts = jnp.cumsum(S) - S  # exclusive prefix sum
    if n is not None:
        total = jnp.sum(S)
        starts = jnp.where(jnp.arange(S.shape[0]) < n, starts, total)
    return starts
