"""Method registry — the single source of predictor names and construction.

The paper's method zoo (§III-B) used to live as a hardcoded lambda dict in
``sched.simulator.default_methods``; this module replaces it with
first-class, user-extensible :class:`MethodSpec` records:

* **names** — the registry is the single source of display names
  (``MemoryPredictor.name`` resolves here via :func:`name_of`, including
  parameterized names like ``k-segments-selective`` / ``witt-p95``), with
  alias support (``witt`` → ``witt-p95``);
* **construction** — :func:`make` builds a method from a name and the
  per-family :class:`MethodContext` (segment count, machine memory, the
  family's default limit), so harness code never hardcodes constructors;
* **capability flags** — ``online`` (carries state worth feeding through
  ``observe``/``refit``), ``packed`` (vectorized ``predict_packed``),
  ``multi_segment`` (emits time-varying envelopes); the online replay
  harness and schedulers route on these instead of isinstance checks;
* **retry** — each spec pins the method's static :class:`RetrySpec`, so
  schedulers accept registry names anywhere they take retry rules;
* **offset auto-tuning** — :func:`tune_offset` picks the best
  :class:`OffsetCandidate` per task family from training replays, the way
  ``KSPlusAuto`` picks k (one batched fleet dispatch over the whole
  candidate grid).

Registering a custom method::

    @register_method("my-method", retry=RetrySpec("double"), cls=MyMethod)
    def _make_my_method(ctx):
        return MyMethod(machine_memory=ctx.machine_memory)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.baselines import (
    DefaultMethod,
    KSegments,
    PPMImproved,
    TovarFeedback,
    TovarPPM,
    WittPercentile,
)
from repro.core.envelope import OffsetCandidate, RetrySpec, apply_offsets
from repro.core.ksplus import KSPlus, KSPlusAuto, MemoryPredictor

__all__ = [
    "MethodContext",
    "MethodSpec",
    "MissingCapabilityError",
    "register_method",
    "unregister_method",
    "get_spec",
    "canonical_name",
    "method_names",
    "name_of",
    "make",
    "resolve",
    "check_capabilities",
    "try_retry_spec",
    "DEFAULT_OFFSET_GRID",
    "tune_offset",
    "tune_offset_map",
]


@dataclasses.dataclass(frozen=True)
class MethodContext:
    """Per-family construction context handed to method factories."""

    k: int = 4
    machine_memory: float = 128.0
    default_limit: float = 8.0


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """One registered prediction method.

    ``factory(ctx)`` builds a fresh instance; ``match`` narrows instance →
    spec resolution when several specs share a class (k-Segments variants);
    ``instance_name`` derives parameterized display names from an instance.
    """

    name: str
    cls: type
    factory: Callable[[MethodContext], MemoryPredictor]
    retry: RetrySpec
    online: bool = True
    packed: bool = True
    multi_segment: bool = False
    aliases: Tuple[str, ...] = ()
    match: Optional[Callable[[MemoryPredictor], bool]] = None
    instance_name: Optional[Callable[[MemoryPredictor], str]] = None


_SPECS: Dict[str, MethodSpec] = {}   # canonical name -> spec, insertion order
_ALIASES: Dict[str, str] = {}        # alias -> canonical name

# The flag names check_capabilities/make/resolve accept in ``require=``.
CAPABILITY_FLAGS: Tuple[str, ...] = ("online", "packed", "multi_segment")


class MissingCapabilityError(LookupError):
    """A resolve-time capability check failed.

    The caller asked for a path (``require=("packed",)``, ``("online",)``,
    ...) that the method's spec declares unsupported.  Raised by
    :func:`make` / :func:`resolve` / :func:`check_capabilities` so harness
    code fails loudly at construction instead of deep inside a batched
    dispatch.
    """

    def __init__(self, method: str, flag: str):
        super().__init__(
            f"method {method!r} does not support the {flag!r} path "
            f"(registered with {flag}=False)")
        self.method = method
        self.flag = flag


def register_method(name: str, *, retry: RetrySpec, cls: type,
                    online: bool = True, packed: bool = True,
                    multi_segment: bool = False,
                    aliases: Sequence[str] = (),
                    match: Optional[Callable] = None,
                    instance_name: Optional[Callable] = None):
    """Decorator: register ``factory`` as method ``name``.

    Raises on duplicate names/aliases — specs are global, collisions are
    always bugs.  Use :func:`unregister_method` to retract (tests, plugin
    teardown).
    """
    def deco(factory):
        spec = MethodSpec(
            name=name, cls=cls, factory=factory, retry=retry, online=online,
            packed=packed, multi_segment=multi_segment,
            aliases=tuple(aliases), match=match, instance_name=instance_name)
        taken = set(_SPECS) | set(_ALIASES)
        for n in (name, *spec.aliases):
            if n in taken:
                raise ValueError(f"method name already registered: {n!r}")
        _SPECS[name] = spec
        for a in spec.aliases:
            _ALIASES[a] = name
        return factory
    return deco


def unregister_method(name: str) -> None:
    spec = _SPECS.pop(canonical_name(name))
    for a in spec.aliases:
        _ALIASES.pop(a, None)


def canonical_name(name: str) -> str:
    """Resolve an alias to its canonical method name (identity otherwise)."""
    name = _ALIASES.get(name, name)
    if name not in _SPECS:
        raise KeyError(f"unknown method: {name!r} "
                       f"(registered: {', '.join(_SPECS)})")
    return name


def get_spec(name: str) -> MethodSpec:
    return _SPECS[canonical_name(name)]


def method_names() -> List[str]:
    """Canonical names in registration order — the default method zoo."""
    return list(_SPECS)


def make(name: str, *, k: int = 4, machine_memory: float = 128.0,
         default_limit: float = 8.0,
         require: Sequence[str] = ()) -> MemoryPredictor:
    """Construct a fresh method instance from its registry name.

    ``require`` names capability flags the caller's code path depends on
    (``"online"``, ``"packed"``, ``"multi_segment"``); a spec registered
    with any of them False raises :class:`MissingCapabilityError` here,
    at resolve time, with the method and flag named.
    """
    spec = get_spec(name)
    _check_spec(spec, require)
    ctx = MethodContext(k=k, machine_memory=machine_memory,
                        default_limit=default_limit)
    return spec.factory(ctx)


def resolve(method: Union[str, MemoryPredictor], *,
            require: Sequence[str] = (), **ctx) -> MemoryPredictor:
    """A method instance from a registry name (constructed) or pass-through.

    Capability validation (``require=``, see :func:`make`) applies to both
    forms: instances resolve back to their spec via the same exact-type +
    ``match`` rules as :func:`name_of`.
    """
    if isinstance(method, str):
        return make(method, require=require, **ctx)
    check_capabilities(method, require=require)
    return method


def _spec_of_instance(method: MemoryPredictor) -> Optional[MethodSpec]:
    """The spec an instance resolves to (``name_of``'s matching rules),
    or None for unregistered classes."""
    cls_specs = [s for s in _SPECS.values() if type(method) is s.cls]
    for spec in cls_specs:
        if spec.match is None or spec.match(method):
            return spec
    return cls_specs[0] if cls_specs else None


def _check_spec(spec: MethodSpec, require: Sequence[str]) -> None:
    for flag in require:
        if flag not in CAPABILITY_FLAGS:
            raise ValueError(
                f"unknown capability flag {flag!r} "
                f"(valid: {', '.join(CAPABILITY_FLAGS)})")
        if not getattr(spec, flag):
            raise MissingCapabilityError(spec.name, flag)


def check_capabilities(method: Union[str, MemoryPredictor],
                       require: Sequence[str] = ()) -> None:
    """Raise :class:`MissingCapabilityError` unless ``method`` carries
    every flag in ``require``.

    Accepts a registry name or an instance.  An instance of an
    *unregistered* class has no spec to consult; the one structurally
    visible capability (``packed`` ⇔ ``predict_packed`` exists) is still
    validated, the rest pass (custom methods opt into flags by
    registering).
    """
    if isinstance(method, str):
        _check_spec(get_spec(method), require)
        return
    spec = _spec_of_instance(method)
    if spec is not None:
        _check_spec(spec, require)
        return
    for flag in require:
        if flag not in CAPABILITY_FLAGS:
            raise ValueError(
                f"unknown capability flag {flag!r} "
                f"(valid: {', '.join(CAPABILITY_FLAGS)})")
        if flag == "packed" and not hasattr(method, "predict_packed"):
            raise MissingCapabilityError(name_of(method), flag)


def name_of(method: MemoryPredictor) -> str:
    """Display name of an instance — the registry is the single source.

    Exact-type specs win (with their ``match`` predicate, so k-Segments
    variants resolve to distinct names); an unregistered subclass falls
    back to its lowercased class name.
    """
    cls_specs = [s for s in _SPECS.values() if type(method) is s.cls]
    for spec in cls_specs:
        if spec.match is None or spec.match(method):
            return spec.instance_name(method) if spec.instance_name \
                else spec.name
    for spec in cls_specs:  # registered class, unmatched parameterization
        if spec.instance_name is not None:
            return spec.instance_name(method)
    return type(method).__name__.lower()


def try_retry_spec(name: str) -> Optional[RetrySpec]:
    """The registered method's retry rule, or None for unknown names (the
    schedulers then fall back to interpreting ``name`` as a RetrySpec
    kind)."""
    try:
        return get_spec(name).retry
    except KeyError:
        return None


# ------------------------------------------------------- the built-in zoo
@register_method("ks+", retry=RetrySpec("ksplus"), cls=KSPlus,
                 aliases=("ksplus", "ks-plus"), multi_segment=True)
def _make_ksplus(ctx: MethodContext) -> KSPlus:
    return KSPlus(k=ctx.k)


@register_method("ks+auto", retry=RetrySpec("ksplus"), cls=KSPlusAuto,
                 aliases=("ksplus-auto",), multi_segment=True)
def _make_ksplus_auto(ctx: MethodContext) -> KSPlusAuto:
    return KSPlusAuto(machine_memory=ctx.machine_memory)


@register_method("k-segments-selective",
                 retry=RetrySpec("kseg-selective", margin=0.10),
                 cls=KSegments, aliases=("kseg-selective",),
                 multi_segment=True,
                 match=lambda m: m.variant == "selective",
                 instance_name=lambda m: f"k-segments-{m.variant}")
def _make_kseg_selective(ctx: MethodContext) -> KSegments:
    return KSegments(k=ctx.k, variant="selective")


@register_method("k-segments-partial",
                 retry=RetrySpec("kseg-partial", margin=0.10),
                 cls=KSegments, aliases=("kseg-partial",),
                 multi_segment=True,
                 match=lambda m: m.variant == "partial",
                 instance_name=lambda m: f"k-segments-{m.variant}")
def _make_kseg_partial(ctx: MethodContext) -> KSegments:
    return KSegments(k=ctx.k, variant="partial")


@register_method("tovar-ppm", retry=RetrySpec("max-machine"), cls=TovarPPM,
                 aliases=("tovar",), online=False)
def _make_tovar(ctx: MethodContext) -> TovarPPM:
    # online=False: the paper's fit-once baseline stays frozen even in
    # online replays — tovar-feedback is the feedback-loop variant.
    return TovarPPM(machine_memory=ctx.machine_memory)


@register_method("tovar-feedback", retry=RetrySpec("max-machine"),
                 cls=TovarFeedback)
def _make_tovar_feedback(ctx: MethodContext) -> TovarFeedback:
    return TovarFeedback(machine_memory=ctx.machine_memory)


@register_method("ppm-improved", retry=RetrySpec("double"), cls=PPMImproved,
                 aliases=("ppm",))
def _make_ppm_improved(ctx: MethodContext) -> PPMImproved:
    return PPMImproved(machine_memory=ctx.machine_memory)


@register_method("witt-p95", retry=RetrySpec("double"), cls=WittPercentile,
                 aliases=("witt",),
                 match=lambda m: round(m.percentile) == 95,
                 instance_name=lambda m: f"witt-p{int(round(m.percentile))}")
def _make_witt(ctx: MethodContext) -> WittPercentile:
    return WittPercentile(percentile=95.0,
                          machine_memory=ctx.machine_memory)


@register_method("default", retry=RetrySpec("double"), cls=DefaultMethod,
                 aliases=("static-default",), online=False)
def _make_default(ctx: MethodContext) -> DefaultMethod:
    # online=False: a static limit has no state to update.
    return DefaultMethod(limit_gb=ctx.default_limit,
                         machine_memory=ctx.machine_memory)


# -------------------------------------------------- offset auto-tuning hook
DEFAULT_OFFSET_GRID: Tuple[OffsetCandidate, ...] = (
    OffsetCandidate(),                       # identity = the plan's own ±10/15%
    OffsetCandidate(peak=0.10),
    OffsetCandidate(peak=-0.05),
    OffsetCandidate(start=0.10),
    OffsetCandidate(peak=0.05, start=0.05),
    OffsetCandidate(peak=0.10, last_peak_bump=0.50),
)


def tune_offset(method: Union[str, MemoryPredictor],
                mems: Sequence[np.ndarray], dts: Sequence[float],
                inputs: Sequence[float], *,
                candidates: Optional[Sequence[OffsetCandidate]] = None,
                machine_memory: float = 128.0
                ) -> Tuple[OffsetCandidate, np.ndarray]:
    """Pick the best safety-offset candidate for one task family.

    The way :class:`KSPlusAuto` picks k: replay the *training* executions
    through the OOM/retry fleet engine once per candidate — all candidates
    share the device-resident trace batch and go out as one
    :func:`repro.core.fleet.simulate_fleet_many` call (per-candidate retry
    specs, e.g. a swept ``last_peak_bump``, ride along) — and keep the
    candidate with the lowest training wastage.

    ``method`` (a fitted instance or a registry name of a fit-free method)
    must already be fitted on ``mems``/``dts``/``inputs``.  Requires a
    uniform ``dt`` (the fleet lane batch shares one sampling period).

    Returns ``(best_candidate, per_candidate_total_gbs)``.
    """
    from repro.core.fleet import packed_predict, simulate_fleet_many

    method = resolve(method, machine_memory=machine_memory)
    cands = tuple(candidates if candidates is not None
                  else DEFAULT_OFFSET_GRID)
    if not cands:
        raise ValueError("need at least one OffsetCandidate")
    if len(set(float(d) for d in dts)) != 1:
        raise ValueError("tune_offset needs a uniform dt across executions")
    starts, peaks, nseg = packed_predict(method, list(inputs))
    jobs = []
    for cand in cands:
        st, pk = apply_offsets(starts, peaks, nseg, cand)
        spec = method.retry_spec
        if cand.last_peak_bump is not None:
            spec = spec._replace(bump=cand.last_peak_bump)
        jobs.append(((st.astype(np.float32), pk.astype(np.float32), nseg),
                     spec))
    results = simulate_fleet_many(jobs, list(mems), float(dts[0]),
                                  machine_memory=machine_memory)
    totals = np.asarray([r.total_gbs for r in results])
    return cands[int(np.argmin(totals))], totals


def tune_offset_map(fitted: Dict[str, Union[str, MemoryPredictor]],
                    data: Dict[str, Tuple[Sequence[np.ndarray],
                                          Sequence[float],
                                          Sequence[float]]], *,
                    candidates: Optional[Sequence[OffsetCandidate]] = None,
                    machine_memory: float = 128.0
                    ) -> Dict[str, OffsetCandidate]:
    """Per-family :func:`tune_offset` winners, scheduler-ready.

    ``fitted`` maps family -> fitted method (or fit-free registry name),
    ``data`` maps family -> ``(mems, dts, inputs)`` training executions.
    The returned mapping plugs straight into
    ``ClusterSim.run(offsets=mapping)`` — winners may disagree on every
    field *including* ``last_peak_bump``, which the scheduler folds into a
    per-lane bump array on :func:`repro.core.envelope.retry_packed`.
    """
    out: Dict[str, OffsetCandidate] = {}
    for fam, method in fitted.items():
        mems, dts, inputs = data[fam]
        out[fam], _ = tune_offset(
            method, mems, dts, inputs, candidates=candidates,
            machine_memory=machine_memory)
    return out
