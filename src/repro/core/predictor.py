"""Per-segment linear-regression predictors (KS+ §II-B).

For each task type we segment every historical execution with Algorithm 1
(k segments), then fit — *per segment index i* — two univariate linear
regressions on the execution's aggregated input size ``I``:

    start_i ~ a_i * I + b_i        (segment start offset, seconds)
    peak_i  ~ c_i * I + d_i        (segment peak memory, GB)

Safety offsets (paper §II-B): peaks are over-predicted by ``peak_offset``
(+10 %) and start times under-predicted by ``start_offset`` (−15 %); with a
monotone envelope, stepping up early is always safe.

The fitting path is batched JAX: all executions of a task are padded to a
common length, segmented with a single ``vmap`` of
:func:`repro.core.segmentation.get_segments`, and the 2k regressions are
solved in closed form with one vectorized expression.  Thousands of task
types / executions fit in a single XLA program — this is the TPU-native
reformulation of the paper's per-task sklearn loop.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.segmentation import get_segments

__all__ = [
    "ExecutionOutcome",
    "RefitPolicy",
    "MemoryPredictor",
    "refit_batched",
    "LinReg",
    "fit_linreg",
    "SegmentModel",
    "segment_rows",
    "solve_segment_model",
    "fit_segment_model",
    "predict_plan",
    "predict_plans_packed",
]


# ------------------------------------------------------------- lifecycle API
@dataclasses.dataclass(frozen=True)
class ExecutionOutcome:
    """One finished execution, as fed back into a predictor's online state.

    ``mem`` is the monitoring trace of the execution (GB per ``dt`` sample),
    ``succeeded`` whether the *replay* of that execution under the method's
    plans eventually succeeded (False = it exhausted its attempts or the
    machine), ``retries`` how many attempts were OOM-killed on the way, and
    ``peak_used`` the highest observed usage — defaulted from the trace
    when omitted.
    """

    mem: np.ndarray
    dt: float
    input_gb: float
    succeeded: bool = True
    retries: int = 0
    peak_used: Optional[float] = None

    @property
    def oomed(self) -> bool:
        """Did the OOM killer fire at least once (even if a retry then
        succeeded)?  This is the failure signal ``refit="on_failure"``
        triggers on — a method whose retry rule always rescues the
        execution would otherwise never see its own misses."""
        return self.retries > 0 or not self.succeeded

    @property
    def peak(self) -> float:
        if self.peak_used is not None:
            return float(self.peak_used)
        return float(np.max(self.mem))

    @property
    def runtime(self) -> float:
        return len(self.mem) * float(self.dt)


@dataclasses.dataclass(frozen=True)
class RefitPolicy:
    """When :meth:`MemoryPredictor.refit` actually re-fits.

    * ``"never"``      — today's offline behaviour: fit once, replay many.
    * ``"every_n"``    — re-fit once ``n`` new outcomes have been observed.
    * ``"on_failure"`` — re-fit as soon as an observed outcome failed.

    Accepts the string forms ``"never"``, ``"on_failure"``, ``"every_n"``
    (n defaults to 1) and ``"every_<n>"`` (e.g. ``"every_5"``) via
    :meth:`parse`.  Hashable on purpose — policies ride through the
    experiment harness as static configuration.
    """

    kind: str
    n: int = 1

    _KINDS = ("never", "every_n", "on_failure")

    def __post_init__(self):
        if self.kind not in self._KINDS:
            raise ValueError(
                f"unknown refit policy: {self.kind!r} "
                f"(expected one of {self._KINDS})")
        if self.kind == "every_n" and self.n < 1:
            raise ValueError(f"every_n needs n >= 1, got {self.n}")

    @classmethod
    def parse(cls, policy: Union["RefitPolicy", str]) -> "RefitPolicy":
        if isinstance(policy, cls):
            return policy
        if isinstance(policy, str) and policy.startswith("every_") \
                and policy != "every_n":
            return cls("every_n", int(policy[len("every_"):]))
        return cls(str(policy))

    def due(self, pending: int, failures: int) -> bool:
        """Is a refit due after ``pending`` unconsumed observations of
        which ``failures`` failed?"""
        if self.kind == "never" or pending == 0:
            return False
        if self.kind == "on_failure":
            return failures > 0
        return pending >= self.n


class _Lifecycle:
    """Per-predictor online state: the observed history and refit counters."""

    __slots__ = ("mems", "dts", "inputs", "pending", "failures", "observed")

    def __init__(self):
        self.mems: List[Optional[np.ndarray]] = []
        self.dts: List[float] = []
        self.inputs: List[float] = []
        self.pending = 0    # outcomes observed since the last (re)fit
        self.failures = 0   # of those, how many failed
        self.observed = 0   # lifetime outcome count


class MemoryPredictor:
    """Explicit predictor lifecycle shared by KS+ and every baseline.

    ``fit(mems, dts, inputs)`` (offline bootstrap) → ``observe(outcome)``
    (feed one finished execution into the per-family online state) →
    ``refit(policy)`` (maybe re-fit from the accumulated history) →
    ``predict`` / ``predict_packed`` → ``retry`` / ``retry_spec``.

    Subclasses implement :meth:`_fit` (estimation from raw history) plus
    the prediction/retry surface; the base class owns the history
    bookkeeping so ``refit`` policies behave identically across methods.
    A subclass whose refit consumes summary state instead of raw traces
    (e.g. :class:`repro.core.baselines.TovarFeedback`) sets
    ``_needs_traces = False`` — observed traces are then dropped after the
    summary update, keeping online state O(#executions), not O(samples) —
    and overrides :meth:`_refit`.

    ``name`` resolves through :mod:`repro.core.registry` — the registry is
    the single source of method names (``k-segments-selective``,
    ``witt-p95``, ... are derived there from instance parameters).
    """

    _needs_traces = True

    @property
    def _life(self) -> _Lifecycle:
        st = self.__dict__.get("_lifecycle")
        if st is None:
            st = self.__dict__["_lifecycle"] = _Lifecycle()
        return st

    # ------------------------------------------------------------- estimation
    def _fit(self, mems, dts, inputs) -> None:
        raise NotImplementedError

    def fit(self, mems, dts, inputs) -> None:
        """Offline bootstrap: (re)seed the history and fit from it."""
        st = self._life
        st.mems = [np.asarray(m) for m in mems] if self._needs_traces \
            else [None] * len(mems)
        st.dts = [float(d) for d in dts]
        st.inputs = [float(i) for i in inputs]
        st.pending = 0
        st.failures = 0
        self._fit(mems, dts, inputs)

    def observe(self, outcome: ExecutionOutcome) -> None:
        """Feed one finished execution into the online state."""
        st = self._life
        st.mems.append(np.asarray(outcome.mem) if self._needs_traces
                       else None)
        st.dts.append(float(outcome.dt))
        st.inputs.append(float(outcome.input_gb))
        st.pending += 1
        st.observed += 1
        if outcome.oomed:
            st.failures += 1

    def refit(self, policy: Union[RefitPolicy, str] = "never") -> bool:
        """Re-fit from the accumulated history when ``policy`` says so.

        Returns True iff a refit happened; the pending/failure counters
        reset either way only on refit, so ``every_n`` counts across calls.
        """
        st = self._life
        if not RefitPolicy.parse(policy).due(st.pending, st.failures):
            return False
        self._refit()
        st.pending = 0
        st.failures = 0
        return True

    def _refit(self) -> None:
        """Default refit: re-run :meth:`_fit` over the full history."""
        st = self._life
        self._fit(st.mems, st.dts, st.inputs)

    # Batched-refit protocol (optional): methods whose refit segments a
    # history *tail* (KS+-style) expose the tail so same-event-time refits
    # across many task families compact into one segmentation dispatch.
    def _segment_tail(self):
        """``(tail_mems, tail_dts, k)`` of unconsumed observations, or
        None when this method cannot take the batched path."""
        return None

    def _commit_tail_rows(self, starts_sec, peaks, runtimes) -> None:
        raise NotImplementedError

    # -------------------------------------------------------------- inference
    @property
    def name(self) -> str:
        from repro.core import registry  # deferred: registry imports methods
        return registry.name_of(self)

    def predict(self, input_size: float) -> AllocationPlan:
        raise NotImplementedError

    def retry(self, plan: AllocationPlan, t_fail: float,
              used: float) -> AllocationPlan:
        raise NotImplementedError

    @property
    def retry_spec(self):
        raise NotImplementedError


def refit_batched(methods: Sequence[MemoryPredictor],
                  policy: Union[RefitPolicy, str]) -> List[bool]:
    """Compacted same-event-time refits across many predictors.

    Method-for-method equivalent to calling ``m.refit(policy)`` on each —
    same due test, same rows, same solves — but every due method that
    exposes a segmentation tail (:meth:`MemoryPredictor._segment_tail`)
    has its tail segmented in ONE :func:`segment_rows` call per segment
    count: the per-dispatch cost of Algorithm 1 (a scan over the trace
    batch) amortizes over every task family refitting at this event time,
    mirroring the cluster engine's event-batched retries.  Methods without
    a tail fall back to their own ``_refit``.

    Returns the per-method refit flags (True = refitted).
    """
    pol = RefitPolicy.parse(policy)
    due = [m for m in methods
           if pol.due(m._life.pending, m._life.failures)]
    groups: dict = {}
    fallback = []
    for m in due:
        tail = m._segment_tail()
        if tail is None:
            fallback.append(m)
        else:
            groups.setdefault(int(tail[2]), []).append((m, tail))
    for k, items in groups.items():
        all_mems = [t for _, (mems, _, _) in items for t in mems]
        all_dts = [d for _, (_, dts, _) in items for d in dts]
        ss, pk, rt = segment_rows(all_mems, all_dts, k)
        off = 0
        for m, (mems, _, _) in items:
            n = len(mems)
            m._commit_tail_rows(ss[off:off + n], pk[off:off + n],
                                rt[off:off + n])
            off += n
    for m in fallback:
        m._refit()
    for m in due:
        m._life.pending = 0
        m._life.failures = 0
    due_ids = {id(m) for m in due}
    return [id(m) in due_ids for m in methods]


@dataclasses.dataclass(frozen=True)
class LinReg:
    """y ≈ slope * x + intercept (vectorized over leading dims)."""

    slope: np.ndarray
    intercept: np.ndarray

    def __call__(self, x):
        return self.slope * x + self.intercept


def _lstsq_1d(x: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray):
    """Closed-form weighted univariate least squares.

    ``w`` is a 0/1 validity weight over observations — padded rows are
    masked out of every sum (including through ``where``, so garbage or
    non-finite values in padded slots cannot poison the fit).  Degenerate
    x → mean predictor.  With all-ones weights and no padding this is
    bit-identical to the unweighted formulation (multiplying by exactly
    1.0 and dividing by the exact observation count).
    """
    x = jnp.where(w > 0, x, 0.0)
    y = jnp.where(w > 0, y, 0.0)
    sw = jnp.sum(w)
    xm = jnp.sum(w * x) / sw
    ym = jnp.sum(w * y) / sw
    var = jnp.sum(w * (x - xm) ** 2) / sw
    cov = jnp.sum(w * (x - xm) * (y - ym)) / sw
    slope = jnp.where(var > 1e-18, cov / jnp.maximum(var, 1e-18), 0.0)
    intercept = ym - slope * xm
    return slope, intercept


# vmap over the segment axis: x/w are shared, y differs per segment.
_fit_many = jax.jit(jax.vmap(_lstsq_1d, in_axes=(None, 1, None), out_axes=0))


def pad_obs_axis(n: int, lo: int = 8) -> int:
    """Bucketed observation count: the execution axis of every fitting
    program is padded to a power of two so *online refits* — where the
    history grows by a few executions at a time — reuse the already
    compiled XLA programs instead of recompiling per history length."""
    return max(lo, 1 << (n - 1).bit_length())


def fit_linreg(x: np.ndarray, y: np.ndarray,
               w: Optional[np.ndarray] = None) -> LinReg:
    """Fit y[:, j] ~ x for each column j (or a single vector y).

    ``w`` is an optional 0/1 observation weight (callers that pre-pad the
    execution axis pass it); the observation axis is bucketed to a power
    of two (zero-weighted padding) to bound jit recompiles across
    growing-history refits.
    """
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    xh = np.asarray(x, np.float64)
    n = xh.shape[0]
    y2 = np.atleast_2d(np.asarray(y, np.float64))
    ycols = y2 if y2.shape[0] == n and y2.ndim == 2 else y2.T
    wh = np.ones((n,), np.float64) if w is None else np.asarray(w, np.float64)
    np_ = pad_obs_axis(n)
    if np_ != n:
        pad = np_ - n
        xh = np.concatenate([xh, np.zeros(pad)])
        ycols = np.concatenate([ycols, np.zeros((pad, ycols.shape[1]))])
        wh = np.concatenate([wh, np.zeros(pad)])
    slope, intercept = _fit_many(jnp.asarray(xh, dtype),
                                 jnp.asarray(ycols, dtype),
                                 jnp.asarray(wh, dtype))
    # lint: allow[host-sync-in-hot-path] fitting is refit-time, not dispatch-time: one readback materializes the host-side model
    slope = np.asarray(slope)
    # lint: allow[host-sync-in-hot-path] same readback, second output
    intercept = np.asarray(intercept)
    if np.ndim(y) == 1:
        slope, intercept = slope[0], intercept[0]
    return LinReg(slope=slope, intercept=intercept)


@dataclasses.dataclass(frozen=True)
class SegmentModel:
    """Fitted per-segment regressions for one task type."""

    k: int
    start_reg: LinReg   # slopes/intercepts of shape (k,)
    peak_reg: LinReg    # slopes/intercepts of shape (k,)
    runtime_reg: LinReg  # scalar regression, used by the scheduler
    peak_offset: float = 0.10
    start_offset: float = 0.15


def _segment_executions(mems: jnp.ndarray, lengths: jnp.ndarray, k: int):
    """vmap Algorithm 1 across executions; return absolute starts + peaks."""
    seg = jax.vmap(lambda m, l: get_segments(m, l, k))
    S, P, n = seg(mems, lengths)  # (N,k), (N,k), (N,)
    starts = jnp.cumsum(S, axis=1) - S  # samples
    slot = jnp.arange(k)[None, :]
    real = slot < n[:, None]
    # Pad degenerate slots: start at end-of-run, peak = overall peak, so the
    # regression sees "this execution never reached segment i" as "segment i
    # starts when the run ends and needs no extra memory".
    last_peak = jnp.max(P, axis=1, keepdims=True)
    starts = jnp.where(real, starts, lengths[:, None])
    P = jnp.where(real, P, last_peak)
    return starts, P


def segment_rows(mems: Sequence[np.ndarray], dts: Sequence[float], k: int):
    """Per-execution segmentation rows: ``(starts_sec, peaks, runtimes)``.

    This is the *incremental unit* of segment-model fitting: an
    execution's row is a pure function of its own trace (Algorithm 1 is
    per-execution), so online refits segment only the newly observed tail
    and re-solve the regressions over cached rows — O(new executions) per
    refit instead of O(history).  Both padded axes are bucketed to powers
    of two so repeated calls (across families, splits and growing-history
    refits) reuse the same jitted segmentation program.

    Returns float64 arrays of shapes (N, k), (N, k), (N,).
    """
    if not (len(mems) == len(dts)) or not mems:
        raise ValueError("mems/dts must be equal-length and non-empty")
    N = len(mems)
    T = max(max(len(m) for m in mems), 64)
    T = 1 << (T - 1).bit_length()
    Np = pad_obs_axis(N)
    padded = np.zeros((Np, T), np.float32)
    lengths = np.ones((Np,), np.int32)  # dummy rows: 1-sample zero trace
    for i, m in enumerate(mems):
        padded[i, : len(m)] = m
        lengths[i] = len(m)
    starts_smp, peaks = _segment_executions(
        jnp.asarray(padded), jnp.asarray(lengths), k
    )
    dts_arr = np.asarray(dts, np.float64)
    starts_sec = np.asarray(starts_smp, np.float64)[:N] * dts_arr[:, None]
    runtimes = lengths[:N].astype(np.float64) * dts_arr
    return starts_sec, np.asarray(peaks, np.float64)[:N], runtimes


def solve_segment_model(
    inputs: Sequence[float],
    starts_sec: np.ndarray,
    peaks: np.ndarray,
    runtimes: np.ndarray,
    k: int,
    *,
    peak_offset: float = 0.10,
    start_offset: float = 0.15,
) -> SegmentModel:
    """Solve the 2k+1 regressions over pre-segmented rows in ONE dispatch.

    The vmap is per-column, so the solutions are bit-identical to separate
    per-regression calls; :func:`fit_linreg` buckets the execution axis, so
    the same jitted program serves every refit of a growing history.
    """
    I = np.asarray(inputs, np.float64)
    ys = np.concatenate([starts_sec, peaks, runtimes[:, None]], axis=1)
    reg = fit_linreg(I, ys)
    return SegmentModel(
        k=k,
        start_reg=LinReg(slope=reg.slope[:k], intercept=reg.intercept[:k]),
        peak_reg=LinReg(slope=reg.slope[k:2 * k],
                        intercept=reg.intercept[k:2 * k]),
        runtime_reg=LinReg(slope=reg.slope[2 * k],
                           intercept=reg.intercept[2 * k]),
        peak_offset=peak_offset,
        start_offset=start_offset,
    )


def fit_segment_model(
    mems: Sequence[np.ndarray],
    dts: Sequence[float],
    inputs: Sequence[float],
    k: int,
    *,
    peak_offset: float = 0.10,
    start_offset: float = 0.15,
) -> SegmentModel:
    """Fit a :class:`SegmentModel` from raw execution traces
    (:func:`segment_rows` + :func:`solve_segment_model`).

    Args:
      mems:   per-execution memory traces (GB), possibly different lengths.
      dts:    per-execution sampling periods (seconds).
      inputs: per-execution aggregated input sizes (GB).
      k:      number of segments.
    """
    if len(mems) != len(inputs):
        raise ValueError("mems/inputs must be equal-length")
    ss, pk, rt = segment_rows(mems, dts, k)
    return solve_segment_model(inputs, ss, pk, rt, k,
                               peak_offset=peak_offset,
                               start_offset=start_offset)


def predict_plan(model: SegmentModel, input_size: float) -> AllocationPlan:
    """Predict the KS+ allocation plan for a new execution.

    Applies the safety offsets, pins the first segment to t=0, and enforces
    monotonicity on both axes (cummax) so the plan never steps down.
    """
    starts = model.start_reg(input_size) * (1.0 - model.start_offset)
    peaks = model.peak_reg(input_size) * (1.0 + model.peak_offset)
    starts = np.maximum.accumulate(np.maximum(starts, 0.0))
    starts[0] = 0.0
    peaks = np.maximum.accumulate(np.maximum(peaks, 1e-6))
    return AllocationPlan(starts=starts, peaks=peaks)


def predict_plans_packed(model: SegmentModel, inputs: np.ndarray):
    """Vectorized :func:`predict_plan` over a batch of input sizes.

    Returns ``(starts, peaks)`` of shape (B, k), elementwise *bit-identical*
    to per-input calls — the input batch is cast to the regression dtype so
    broadcasting reproduces the scalar path's promotion (NumPy keeps python
    scalars "weak", so per-plan math runs in the slope's dtype).  The fleet
    engine consumes these without building plan objects.
    """
    I = np.asarray(inputs, model.start_reg.slope.dtype)[:, None]
    starts = (model.start_reg.slope[None, :] * I
              + model.start_reg.intercept[None, :]) \
        * (1.0 - model.start_offset)
    peaks = (model.peak_reg.slope[None, :] * I
             + model.peak_reg.intercept[None, :]) * (1.0 + model.peak_offset)
    starts = np.maximum.accumulate(np.maximum(starts, 0.0), axis=1)
    starts[:, 0] = 0.0
    peaks = np.maximum.accumulate(np.maximum(peaks, 1e-6), axis=1)
    return starts, peaks


def predict_runtime(model: SegmentModel, input_size: float,
                    margin: float = 0.10) -> float:
    """Scheduler-facing runtime estimate (over-predicted by ``margin``)."""
    return float(max(model.runtime_reg(input_size), 0.0)) * (1.0 + margin)
