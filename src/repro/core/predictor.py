"""Per-segment linear-regression predictors (KS+ §II-B).

For each task type we segment every historical execution with Algorithm 1
(k segments), then fit — *per segment index i* — two univariate linear
regressions on the execution's aggregated input size ``I``:

    start_i ~ a_i * I + b_i        (segment start offset, seconds)
    peak_i  ~ c_i * I + d_i        (segment peak memory, GB)

Safety offsets (paper §II-B): peaks are over-predicted by ``peak_offset``
(+10 %) and start times under-predicted by ``start_offset`` (−15 %); with a
monotone envelope, stepping up early is always safe.

The fitting path is batched JAX: all executions of a task are padded to a
common length, segmented with a single ``vmap`` of
:func:`repro.core.segmentation.get_segments`, and the 2k regressions are
solved in closed form with one vectorized expression.  Thousands of task
types / executions fit in a single XLA program — this is the TPU-native
reformulation of the paper's per-task sklearn loop.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.segmentation import get_segments

__all__ = [
    "LinReg",
    "fit_linreg",
    "SegmentModel",
    "fit_segment_model",
    "predict_plan",
    "predict_plans_packed",
]


@dataclasses.dataclass(frozen=True)
class LinReg:
    """y ≈ slope * x + intercept (vectorized over leading dims)."""

    slope: np.ndarray
    intercept: np.ndarray

    def __call__(self, x):
        return self.slope * x + self.intercept


def _lstsq_1d(x: jnp.ndarray, y: jnp.ndarray):
    """Closed-form univariate least squares; degenerate x -> mean predictor."""
    xm = jnp.mean(x)
    ym = jnp.mean(y)
    var = jnp.mean((x - xm) ** 2)
    cov = jnp.mean((x - xm) * (y - ym))
    slope = jnp.where(var > 1e-18, cov / jnp.maximum(var, 1e-18), 0.0)
    intercept = ym - slope * xm
    return slope, intercept


# vmap over the segment axis: x is shared, y differs per segment.
_fit_many = jax.jit(jax.vmap(_lstsq_1d, in_axes=(None, 1), out_axes=0))


def fit_linreg(x: np.ndarray, y: np.ndarray) -> LinReg:
    """Fit y[:, j] ~ x for each column j (or a single vector y)."""
    x = jnp.asarray(x, jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    y2 = jnp.atleast_2d(jnp.asarray(y, x.dtype))
    if y2.shape[0] == x.shape[0]:
        ycols = y2 if y2.ndim == 2 else y2[:, None]
    else:
        ycols = y2.T
    slope, intercept = _fit_many(x, ycols)
    slope = np.asarray(slope)
    intercept = np.asarray(intercept)
    if np.ndim(y) == 1:
        slope, intercept = slope[0], intercept[0]
    return LinReg(slope=slope, intercept=intercept)


@dataclasses.dataclass(frozen=True)
class SegmentModel:
    """Fitted per-segment regressions for one task type."""

    k: int
    start_reg: LinReg   # slopes/intercepts of shape (k,)
    peak_reg: LinReg    # slopes/intercepts of shape (k,)
    runtime_reg: LinReg  # scalar regression, used by the scheduler
    peak_offset: float = 0.10
    start_offset: float = 0.15


def _segment_executions(mems: jnp.ndarray, lengths: jnp.ndarray, k: int):
    """vmap Algorithm 1 across executions; return absolute starts + peaks."""
    seg = jax.vmap(lambda m, l: get_segments(m, l, k))
    S, P, n = seg(mems, lengths)  # (N,k), (N,k), (N,)
    starts = jnp.cumsum(S, axis=1) - S  # samples
    slot = jnp.arange(k)[None, :]
    real = slot < n[:, None]
    # Pad degenerate slots: start at end-of-run, peak = overall peak, so the
    # regression sees "this execution never reached segment i" as "segment i
    # starts when the run ends and needs no extra memory".
    last_peak = jnp.max(P, axis=1, keepdims=True)
    starts = jnp.where(real, starts, lengths[:, None])
    P = jnp.where(real, P, last_peak)
    return starts, P


def fit_segment_model(
    mems: Sequence[np.ndarray],
    dts: Sequence[float],
    inputs: Sequence[float],
    k: int,
    *,
    peak_offset: float = 0.10,
    start_offset: float = 0.15,
) -> SegmentModel:
    """Fit a :class:`SegmentModel` from raw execution traces.

    Args:
      mems:   per-execution memory traces (GB), possibly different lengths.
      dts:    per-execution sampling periods (seconds).
      inputs: per-execution aggregated input sizes (GB).
      k:      number of segments.
    """
    if not (len(mems) == len(dts) == len(inputs)) or not mems:
        raise ValueError("mems/dts/inputs must be equal-length and non-empty")
    N = len(mems)
    # Bucket the padded length to a power of two so repeated fits across
    # families/splits reuse the same jitted segmentation program.
    T = max(max(len(m) for m in mems), 64)
    T = 1 << (T - 1).bit_length()
    padded = np.zeros((N, T), np.float32)
    lengths = np.zeros((N,), np.int32)
    for i, m in enumerate(mems):
        padded[i, : len(m)] = m
        lengths[i] = len(m)

    starts_smp, peaks = _segment_executions(
        jnp.asarray(padded), jnp.asarray(lengths), k
    )
    dts_arr = np.asarray(dts, np.float64)
    starts_sec = np.asarray(starts_smp, np.float64) * dts_arr[:, None]
    runtimes = lengths.astype(np.float64) * dts_arr

    I = np.asarray(inputs, np.float64)
    start_reg = fit_linreg(I, starts_sec)
    peak_reg = fit_linreg(I, np.asarray(peaks, np.float64))
    runtime_reg = fit_linreg(I, runtimes)
    return SegmentModel(
        k=k,
        start_reg=start_reg,
        peak_reg=peak_reg,
        runtime_reg=runtime_reg,
        peak_offset=peak_offset,
        start_offset=start_offset,
    )


def predict_plan(model: SegmentModel, input_size: float) -> AllocationPlan:
    """Predict the KS+ allocation plan for a new execution.

    Applies the safety offsets, pins the first segment to t=0, and enforces
    monotonicity on both axes (cummax) so the plan never steps down.
    """
    starts = model.start_reg(input_size) * (1.0 - model.start_offset)
    peaks = model.peak_reg(input_size) * (1.0 + model.peak_offset)
    starts = np.maximum.accumulate(np.maximum(starts, 0.0))
    starts[0] = 0.0
    peaks = np.maximum.accumulate(np.maximum(peaks, 1e-6))
    return AllocationPlan(starts=starts, peaks=peaks)


def predict_plans_packed(model: SegmentModel, inputs: np.ndarray):
    """Vectorized :func:`predict_plan` over a batch of input sizes.

    Returns ``(starts, peaks)`` of shape (B, k), elementwise *bit-identical*
    to per-input calls — the input batch is cast to the regression dtype so
    broadcasting reproduces the scalar path's promotion (NumPy keeps python
    scalars "weak", so per-plan math runs in the slope's dtype).  The fleet
    engine consumes these without building plan objects.
    """
    I = np.asarray(inputs, model.start_reg.slope.dtype)[:, None]
    starts = (model.start_reg.slope[None, :] * I
              + model.start_reg.intercept[None, :]) \
        * (1.0 - model.start_offset)
    peaks = (model.peak_reg.slope[None, :] * I
             + model.peak_reg.intercept[None, :]) * (1.0 + model.peak_offset)
    starts = np.maximum.accumulate(np.maximum(starts, 0.0), axis=1)
    starts[:, 0] = 0.0
    peaks = np.maximum.accumulate(np.maximum(peaks, 1e-6), axis=1)
    return starts, peaks


def predict_runtime(model: SegmentModel, input_size: float,
                    margin: float = 0.10) -> float:
    """Scheduler-facing runtime estimate (over-predicted by ``margin``)."""
    return float(max(model.runtime_reg(input_size), 0.0)) * (1.0 + margin)
