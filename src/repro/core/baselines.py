"""State-of-the-art baselines evaluated in the paper (§III-B).

* :class:`TovarPPM` — Tovar et al. peak-probability sizing; on failure the
  whole machine is allocated for the re-execution.
* :class:`TovarFeedback` — Tovar's *full* feedback loop: the empirical
  peak distribution is carried across executions as online state, so every
  observed outcome (success or OOM) sharpens the next first allocation.
* :class:`PPMImproved` — same first allocation, but doubling on failure.
* :class:`KSegments` — the original k-Segments method (equal-length segments
  over a predicted runtime) with the 'Selective' / 'Partial' retry variants.
* :class:`WittPercentile` — Witt et al. percentile-of-peaks sizing with
  doubling on failure (the feedback-loop baseline family).
* :class:`DefaultMethod` — the workflow developers' static limits with the
  standard retry-with-doubled-memory behaviour.

All subclass :class:`repro.core.predictor.MemoryPredictor` — the explicit
``fit / observe / refit / predict / retry`` lifecycle — and are registered
(with their capability flags) in :mod:`repro.core.registry`, which is also
the single source of their display names.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.fleet import RetrySpec
from repro.core.predictor import (
    ExecutionOutcome,
    LinReg,
    MemoryPredictor,
    fit_linreg,
)
from repro.core.retry import (
    double_retry,
    ksegments_partial_retry,
    ksegments_selective_retry,
    max_machine_retry,
)

__all__ = ["TovarPPM", "TovarFeedback", "PPMImproved", "KSegments",
           "WittPercentile", "DefaultMethod"]


def _constant_plan(value: float) -> AllocationPlan:
    return AllocationPlan(starts=np.zeros(1), peaks=np.asarray([value]))


def _ppm_first_alloc(peaks: np.ndarray, runtimes: np.ndarray,
                     machine_memory: float) -> float:
    """Tovar's peak-probability sizing: the candidate allocation minimizing
    expected allocated GB·s under the empirical peak distribution, assuming
    failures surface at the end of a run (slow-peaks model) and are retried
    with the machine's full memory:
    ``cost(a) = sum_e a*r_e + sum_{p_e > a} M_max * r_e``."""
    candidates = np.unique(peaks)
    fail = peaks[None, :] > candidates[:, None] + 1e-12
    cost = candidates * runtimes.sum() + (
        fail * (machine_memory * runtimes)[None, :]
    ).sum(axis=1)
    return float(candidates[int(np.argmin(cost))])


@dataclasses.dataclass
class TovarPPM(MemoryPredictor):
    """Peak-probability model: pick the first allocation minimizing expected
    allocated GB·s under the empirical peak distribution, assuming failures
    surface at the end of a run (slow-peaks model) and are retried with the
    machine's full memory."""

    machine_memory: float = 128.0
    _first_alloc: float = dataclasses.field(default=0.0, repr=False)

    def _fit(self, mems: Sequence[np.ndarray], dts, inputs) -> None:
        peaks = np.asarray([float(np.max(m)) for m in mems])
        runtimes = np.asarray([len(m) * dt for m, dt in zip(mems, dts)])
        self._first_alloc = _ppm_first_alloc(peaks, runtimes,
                                             self.machine_memory)

    def predict(self, input_size: float) -> AllocationPlan:
        return _constant_plan(self._first_alloc)

    def predict_packed(self, inputs: np.ndarray):
        B = len(inputs)
        return np.zeros((B, 1)), np.full((B, 1), self._first_alloc)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return max_machine_retry(plan, t_fail, used,
                                 machine_memory=self.machine_memory)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("max-machine")


@dataclasses.dataclass
class TovarFeedback(MemoryPredictor):
    """Tovar's full feedback loop: peak-distribution state across executions.

    Same sizing rule and whole-machine retry as :class:`TovarPPM`, but the
    empirical ``(peak, runtime)`` distribution is *online state*: every
    :meth:`observe` appends the outcome's peak and runtime (O(1) summary —
    traces are not retained, ``_needs_traces = False``), and :meth:`refit`
    re-solves the expected-cost minimization over the accumulated
    distribution.  Under ``refit="on_failure"`` an OOMed execution (whose
    whole-machine retry is exactly what the cost model prices) immediately
    raises the next first allocation, which is where this method beats the
    fit-once ``tovar-ppm`` on drifting or under-sampled task families.
    """

    machine_memory: float = 128.0
    _needs_traces = False
    _first_alloc: float = dataclasses.field(default=0.0, repr=False)
    # Python lists on purpose: observe is truly O(1) amortized; arrays
    # materialize only when a refit actually re-solves.
    _peaks: list = dataclasses.field(default_factory=list, repr=False)
    _runtimes: list = dataclasses.field(default_factory=list, repr=False)

    def _fit(self, mems: Sequence[np.ndarray], dts, inputs) -> None:
        self._peaks = [float(np.max(m)) for m in mems]
        self._runtimes = [len(m) * dt for m, dt in zip(mems, dts)]
        self._solve()

    def observe(self, outcome: ExecutionOutcome) -> None:
        super().observe(outcome)
        self._peaks.append(outcome.peak)
        self._runtimes.append(outcome.runtime)

    def _refit(self) -> None:
        # Refit consumes the carried summary state, not raw traces.
        self._solve()

    def _solve(self) -> None:
        self._first_alloc = _ppm_first_alloc(
            np.asarray(self._peaks), np.asarray(self._runtimes),
            self.machine_memory)

    def predict(self, input_size: float) -> AllocationPlan:
        return _constant_plan(self._first_alloc)

    def predict_packed(self, inputs: np.ndarray):
        B = len(inputs)
        return np.zeros((B, 1)), np.full((B, 1), self._first_alloc)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return max_machine_retry(plan, t_fail, used,
                                 machine_memory=self.machine_memory)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("max-machine")


@dataclasses.dataclass
class PPMImproved(MemoryPredictor):
    """Tovar-PPM's sizing with doubling instead of whole-machine retries."""

    machine_memory: float = 128.0
    _inner: Optional[TovarPPM] = dataclasses.field(default=None, repr=False)

    def _fit(self, mems, dts, inputs) -> None:
        self._inner = TovarPPM(machine_memory=self.machine_memory)
        self._inner.fit(mems, dts, inputs)

    def predict(self, input_size: float) -> AllocationPlan:
        return self._inner.predict(input_size)

    def predict_packed(self, inputs: np.ndarray):
        return self._inner.predict_packed(inputs)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return double_retry(plan, t_fail, used, cap=self.machine_memory)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("double")


@dataclasses.dataclass
class KSegments(MemoryPredictor):
    """The original k-Segments method [19] (the paper's direct predecessor).

    Runtime is predicted by linear regression on input size and divided into
    ``k`` *equal* segments; each segment's peak is predicted by its own
    linear regression.  No monotonicity is enforced (that is a KS+ feature),
    so the envelope can step down — exactly the failure mode KS+ removes.
    """

    k: int = 4
    variant: str = "selective"  # or "partial"
    peak_offset: float = 0.10
    runtime_offset: float = 0.15
    _runtime_reg: Optional[LinReg] = dataclasses.field(default=None, repr=False)
    _peak_reg: Optional[LinReg] = dataclasses.field(default=None, repr=False)
    # Cached per-execution rows (runtimes, segment peaks, inputs): the
    # incremental unit of online refits (segmentation is per-execution).
    _rows: Optional[tuple] = dataclasses.field(default=None, repr=False)

    def _seg_rows(self, mems, dts):
        runtimes = np.asarray([len(m) * dt for m, dt in zip(mems, dts)],
                              np.float64)
        peaks = np.zeros((len(mems), self.k))
        for e, m in enumerate(mems):
            bounds = np.linspace(0, len(m), self.k + 1).astype(int)
            for i in range(self.k):
                lo, hi = bounds[i], max(bounds[i + 1], bounds[i] + 1)
                peaks[e, i] = np.max(m[lo:hi])
        return runtimes, peaks

    def _fit(self, mems, dts, inputs) -> None:
        rt, pk = self._seg_rows(mems, dts)
        self._rows = (rt, pk, np.asarray(inputs, np.float64))
        self._solve()

    def _solve(self) -> None:
        # One dispatch for runtime + k peak regressions (per-column vmap:
        # bit-identical to separate calls).
        rt, pk, I = self._rows
        reg = fit_linreg(I, np.concatenate([rt[:, None], pk], axis=1))
        self._runtime_reg = LinReg(slope=reg.slope[0],
                                   intercept=reg.intercept[0])
        self._peak_reg = LinReg(slope=reg.slope[1:], intercept=reg.intercept[1:])

    def _refit(self) -> None:
        """Incremental online refit: segment only the new tail, re-solve
        the regressions over cached rows (== a from-scratch fit)."""
        st = self._life
        have = 0 if self._rows is None else len(self._rows[2])
        if self._rows is None or have > len(st.mems):
            return super()._refit()
        if have < len(st.mems):
            rt, pk = self._seg_rows(st.mems[have:], st.dts[have:])
            I2 = np.asarray(st.inputs[have:], np.float64)
            self._rows = tuple(
                np.concatenate([a, b])
                for a, b in zip(self._rows, (rt, pk, I2)))
        self._solve()

    def predict(self, input_size: float) -> AllocationPlan:
        rt = max(float(self._runtime_reg(input_size)), 0.0)
        rt *= 1.0 - self.runtime_offset  # under-predict segment starts
        starts = np.arange(self.k, dtype=np.float64) * (rt / self.k)
        peaks = np.maximum(
            self._peak_reg(input_size) * (1.0 + self.peak_offset), 1e-6
        )
        return AllocationPlan(starts=starts, peaks=peaks)

    def predict_packed(self, inputs: np.ndarray):
        """Vectorized predict — elementwise bit-identical to per-input calls
        (the regression runs in its own dtype, the runtime math in float64,
        exactly like the scalar path's promotions)."""
        I = np.asarray(inputs, self._runtime_reg.slope.dtype)
        rt = self._runtime_reg(I).astype(np.float64)
        rt = np.maximum(rt, 0.0) * (1.0 - self.runtime_offset)
        starts = np.arange(self.k, dtype=np.float64)[None, :] \
            * (rt[:, None] / self.k)
        peaks = self._peak_reg.slope[None, :] * I[:, None] \
            + self._peak_reg.intercept[None, :]
        peaks = np.maximum(peaks * (1.0 + self.peak_offset), 1e-6)
        return starts, peaks

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        if self.variant == "selective":
            return ksegments_selective_retry(plan, t_fail, used,
                                             margin=self.peak_offset)
        return ksegments_partial_retry(plan, t_fail, used,
                                       margin=self.peak_offset)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec(f"kseg-{self.variant}", margin=self.peak_offset)


@dataclasses.dataclass
class WittPercentile(MemoryPredictor):
    """Witt et al. percentile predictors: size the first allocation at the
    q-th percentile of the observed peak distribution and double on failure.

    The classic feedback-loop baseline family ("Feedback-based resource
    allocation for workflow applications"): no time structure, just a
    quantile of history — deliberately over-allocating for the top
    ``100 - percentile`` percent of executions instead of modelling when
    memory is needed.  One :class:`RetrySpec` + ``predict_packed`` pair, so
    the fleet engine and the packed cluster scheduler run it unchanged.
    """

    percentile: float = 95.0
    machine_memory: float = 128.0
    _first_alloc: float = dataclasses.field(default=0.0, repr=False)

    def _fit(self, mems: Sequence[np.ndarray], dts, inputs) -> None:
        peaks = np.asarray([float(np.max(m)) for m in mems])
        self._first_alloc = float(np.percentile(peaks, self.percentile))

    def predict(self, input_size: float) -> AllocationPlan:
        return _constant_plan(self._first_alloc)

    def predict_packed(self, inputs: np.ndarray):
        B = len(inputs)
        return np.zeros((B, 1)), np.full((B, 1), self._first_alloc)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return double_retry(plan, t_fail, used, cap=self.machine_memory)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("double")


@dataclasses.dataclass
class DefaultMethod(MemoryPredictor):
    """Workflow developers' static limit + retry-with-doubled-memory."""

    limit_gb: float
    machine_memory: float = 128.0
    _needs_traces = False

    def _fit(self, mems, dts, inputs) -> None:  # nothing to learn
        pass

    def predict(self, input_size: float) -> AllocationPlan:
        return _constant_plan(self.limit_gb)

    def predict_packed(self, inputs: np.ndarray):
        B = len(inputs)
        return np.zeros((B, 1)), np.full((B, 1), float(self.limit_gb))

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return double_retry(plan, t_fail, used, cap=self.machine_memory)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("double")
