"""State-of-the-art baselines evaluated in the paper (§III-B).

* :class:`TovarPPM` — Tovar et al. peak-probability sizing; on failure the
  whole machine is allocated for the re-execution.
* :class:`PPMImproved` — same first allocation, but doubling on failure.
* :class:`KSegments` — the original k-Segments method (equal-length segments
  over a predicted runtime) with the 'Selective' / 'Partial' retry variants.
* :class:`WittPercentile` — Witt et al. percentile-of-peaks sizing with
  doubling on failure (the feedback-loop baseline family).
* :class:`DefaultMethod` — the workflow developers' static limits with the
  standard retry-with-doubled-memory behaviour.

All follow the ``fit / predict / retry`` protocol of
:class:`repro.core.ksplus.MemoryPredictor`.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.fleet import RetrySpec
from repro.core.predictor import LinReg, fit_linreg
from repro.core.retry import (
    double_retry,
    ksegments_partial_retry,
    ksegments_selective_retry,
    max_machine_retry,
)

__all__ = ["TovarPPM", "PPMImproved", "KSegments", "WittPercentile",
           "DefaultMethod"]


def _constant_plan(value: float) -> AllocationPlan:
    return AllocationPlan(starts=np.zeros(1), peaks=np.asarray([value]))


@dataclasses.dataclass
class TovarPPM:
    """Peak-probability model: pick the first allocation minimizing expected
    allocated GB·s under the empirical peak distribution, assuming failures
    surface at the end of a run (slow-peaks model) and are retried with the
    machine's full memory."""

    machine_memory: float = 128.0
    name: str = "tovar-ppm"
    _first_alloc: float = dataclasses.field(default=0.0, repr=False)

    def fit(self, mems: Sequence[np.ndarray], dts, inputs) -> None:
        peaks = np.asarray([float(np.max(m)) for m in mems])
        runtimes = np.asarray([len(m) * dt for m, dt in zip(mems, dts)])
        candidates = np.unique(peaks)
        # cost(a) = sum_e a*r_e + sum_{p_e > a} M_max * r_e   (allocated GB·s)
        fail = peaks[None, :] > candidates[:, None] + 1e-12
        cost = candidates * runtimes.sum() + (
            fail * (self.machine_memory * runtimes)[None, :]
        ).sum(axis=1)
        self._first_alloc = float(candidates[int(np.argmin(cost))])

    def predict(self, input_size: float) -> AllocationPlan:
        return _constant_plan(self._first_alloc)

    def predict_packed(self, inputs: np.ndarray):
        B = len(inputs)
        return np.zeros((B, 1)), np.full((B, 1), self._first_alloc)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return max_machine_retry(plan, t_fail, used,
                                 machine_memory=self.machine_memory)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("max-machine")


@dataclasses.dataclass
class PPMImproved:
    """Tovar-PPM's sizing with doubling instead of whole-machine retries."""

    machine_memory: float = 128.0
    name: str = "ppm-improved"
    _inner: Optional[TovarPPM] = dataclasses.field(default=None, repr=False)

    def fit(self, mems, dts, inputs) -> None:
        self._inner = TovarPPM(machine_memory=self.machine_memory)
        self._inner.fit(mems, dts, inputs)

    def predict(self, input_size: float) -> AllocationPlan:
        return self._inner.predict(input_size)

    def predict_packed(self, inputs: np.ndarray):
        return self._inner.predict_packed(inputs)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return double_retry(plan, t_fail, used, cap=self.machine_memory)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("double")


@dataclasses.dataclass
class KSegments:
    """The original k-Segments method [19] (the paper's direct predecessor).

    Runtime is predicted by linear regression on input size and divided into
    ``k`` *equal* segments; each segment's peak is predicted by its own
    linear regression.  No monotonicity is enforced (that is a KS+ feature),
    so the envelope can step down — exactly the failure mode KS+ removes.
    """

    k: int = 4
    variant: str = "selective"  # or "partial"
    peak_offset: float = 0.10
    runtime_offset: float = 0.15
    _runtime_reg: Optional[LinReg] = dataclasses.field(default=None, repr=False)
    _peak_reg: Optional[LinReg] = dataclasses.field(default=None, repr=False)

    @property
    def name(self) -> str:
        return f"k-segments-{self.variant}"

    def fit(self, mems, dts, inputs) -> None:
        runtimes = np.asarray([len(m) * dt for m, dt in zip(mems, dts)])
        peaks = np.zeros((len(mems), self.k))
        for e, m in enumerate(mems):
            bounds = np.linspace(0, len(m), self.k + 1).astype(int)
            for i in range(self.k):
                lo, hi = bounds[i], max(bounds[i + 1], bounds[i] + 1)
                peaks[e, i] = np.max(m[lo:hi])
        I = np.asarray(inputs, np.float64)
        self._runtime_reg = fit_linreg(I, runtimes)
        self._peak_reg = fit_linreg(I, peaks)

    def predict(self, input_size: float) -> AllocationPlan:
        rt = max(float(self._runtime_reg(input_size)), 0.0)
        rt *= 1.0 - self.runtime_offset  # under-predict segment starts
        starts = np.arange(self.k, dtype=np.float64) * (rt / self.k)
        peaks = np.maximum(
            self._peak_reg(input_size) * (1.0 + self.peak_offset), 1e-6
        )
        return AllocationPlan(starts=starts, peaks=peaks)

    def predict_packed(self, inputs: np.ndarray):
        """Vectorized predict — elementwise bit-identical to per-input calls
        (the regression runs in its own dtype, the runtime math in float64,
        exactly like the scalar path's promotions)."""
        I = np.asarray(inputs, self._runtime_reg.slope.dtype)
        rt = self._runtime_reg(I).astype(np.float64)
        rt = np.maximum(rt, 0.0) * (1.0 - self.runtime_offset)
        starts = np.arange(self.k, dtype=np.float64)[None, :] \
            * (rt[:, None] / self.k)
        peaks = self._peak_reg.slope[None, :] * I[:, None] \
            + self._peak_reg.intercept[None, :]
        peaks = np.maximum(peaks * (1.0 + self.peak_offset), 1e-6)
        return starts, peaks

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        if self.variant == "selective":
            return ksegments_selective_retry(plan, t_fail, used,
                                             margin=self.peak_offset)
        return ksegments_partial_retry(plan, t_fail, used,
                                       margin=self.peak_offset)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec(f"kseg-{self.variant}", margin=self.peak_offset)


@dataclasses.dataclass
class WittPercentile:
    """Witt et al. percentile predictors: size the first allocation at the
    q-th percentile of the observed peak distribution and double on failure.

    The classic feedback-loop baseline family ("Feedback-based resource
    allocation for workflow applications"): no time structure, just a
    quantile of history — deliberately over-allocating for the top
    ``100 - percentile`` percent of executions instead of modelling when
    memory is needed.  One :class:`RetrySpec` + ``predict_packed`` pair, so
    the fleet engine and the packed cluster scheduler run it unchanged.
    """

    percentile: float = 95.0
    machine_memory: float = 128.0
    _first_alloc: float = dataclasses.field(default=0.0, repr=False)

    @property
    def name(self) -> str:
        return f"witt-p{int(round(self.percentile))}"

    def fit(self, mems: Sequence[np.ndarray], dts, inputs) -> None:
        peaks = np.asarray([float(np.max(m)) for m in mems])
        self._first_alloc = float(np.percentile(peaks, self.percentile))

    def predict(self, input_size: float) -> AllocationPlan:
        return _constant_plan(self._first_alloc)

    def predict_packed(self, inputs: np.ndarray):
        B = len(inputs)
        return np.zeros((B, 1)), np.full((B, 1), self._first_alloc)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return double_retry(plan, t_fail, used, cap=self.machine_memory)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("double")


@dataclasses.dataclass
class DefaultMethod:
    """Workflow developers' static limit + retry-with-doubled-memory."""

    limit_gb: float
    machine_memory: float = 128.0
    name: str = "default"

    def fit(self, mems, dts, inputs) -> None:  # nothing to learn
        pass

    def predict(self, input_size: float) -> AllocationPlan:
        return _constant_plan(self.limit_gb)

    def predict_packed(self, inputs: np.ndarray):
        B = len(inputs)
        return np.zeros((B, 1)), np.full((B, 1), float(self.limit_gb))

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return double_retry(plan, t_fail, used, cap=self.machine_memory)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("double")
