"""Memory-wastage metric (GB·s) and the OOM/retry simulation loop.

Wastage of one task execution (paper §III-A): the integral of
``allocated − used`` over the successful attempt **plus** the integral of
``allocated`` over every failed attempt.  Failures happen at the first
sample whose demand exceeds the active allocation (the simulated OOM
killer), after which the method's retry strategy produces a new plan and the
execution restarts from t = 0.

The inner evaluation — step-function allocation vs. trace, summed — is the
fleet-scale hot loop (methods × seeds × executions × samples); a Pallas
kernel implementing the batched version lives in
``repro.kernels.wastage`` with :func:`wastage_eval_ref` as its oracle.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List

import numpy as np

from repro.core.allocation import AllocationPlan, alloc_series, first_violation

__all__ = [
    "AttemptRecord",
    "ExecutionResult",
    "simulate_execution",
    "oom_probe_ref",
]

RetryFn = Callable[[AllocationPlan, float, float], AllocationPlan]


@dataclasses.dataclass(frozen=True)
class AttemptRecord:
    plan: AllocationPlan
    failed_at: float  # seconds; -1 for the successful attempt
    wastage_gbs: float


@dataclasses.dataclass(frozen=True)
class ExecutionResult:
    attempts: List[AttemptRecord]
    wastage_gbs: float
    succeeded: bool

    @property
    def num_retries(self) -> int:
        return len(self.attempts) - 1


def simulate_execution(
    plan: AllocationPlan,
    retry: RetryFn,
    mem: np.ndarray,
    dt: float,
    *,
    max_attempts: int = 25,
    machine_memory: float = np.inf,
) -> ExecutionResult:
    """Run one task execution against a plan + retry strategy.

    ``machine_memory`` caps every allocation (a node cannot grant more than
    it has); a demand above the cap makes the execution unsatisfiable and is
    reported as ``succeeded=False`` with the accumulated wastage.
    """
    mem = np.asarray(mem, dtype=np.float64)
    attempts: List[AttemptRecord] = []
    total = 0.0
    for _ in range(max_attempts):
        capped = plan.with_(peaks=np.minimum(plan.peaks, machine_memory))
        v = first_violation(capped, mem, dt)
        alloc = alloc_series(capped, len(mem), dt)
        if v < 0:
            w = float(np.sum(alloc - mem) * dt)
            attempts.append(AttemptRecord(capped, -1.0, w))
            return ExecutionResult(attempts, total + w, True)
        # Failed attempt: everything allocated until the kill is wasted.
        w = float(np.sum(alloc[: v + 1]) * dt)
        total += w
        t_fail = v * dt
        attempts.append(AttemptRecord(capped, t_fail, w))
        if np.max(mem) > machine_memory:
            break  # no allocation can satisfy this job on this node class
        plan = retry(capped, t_fail, float(mem[v]))
    return ExecutionResult(attempts, total, False)


def wastage_eval_ref(
    starts: np.ndarray,
    peaks: np.ndarray,
    mems: np.ndarray,
    lengths: np.ndarray,
    dt: float,
) -> np.ndarray:
    """Batched successful-attempt wastage: oracle for the Pallas kernel.

    Args:
      starts: (B, k) plan start offsets (seconds).
      peaks:  (B, k) plan peaks (GB).
      mems:   (B, T) padded traces (GB).
      lengths: (B,) valid sample counts.
      dt:     sampling period.

    Returns:
      (B,) wastage in GB·s assuming each attempt succeeds (allocation
      clamped from below by the trace, mirroring the kernel contract).
    """
    B, T = mems.shape
    t = np.arange(T, dtype=np.float64)[None, :] * dt
    # alloc[b, t] = peaks[b, max i: starts[b, i] <= t]
    active = (starts[:, None, :] <= t[:, :, None]).astype(np.float64)
    idx = np.maximum(active.cumsum(axis=2).argmax(axis=2), 0)
    alloc = np.take_along_axis(peaks, idx.reshape(B, -1), axis=1).reshape(B, T)
    alloc = np.maximum(alloc, mems)  # successful attempt ⇒ alloc >= used
    valid = (np.arange(T)[None, :] < lengths[:, None]).astype(np.float64)
    return ((alloc - mems) * valid).sum(axis=1) * dt


def oom_probe_ref(
    starts: np.ndarray,
    peaks: np.ndarray,
    mems: np.ndarray,
    lengths: np.ndarray,
    dt: float,
):
    """Batched one-attempt OOM probe: oracle for the extended Pallas kernel.

    For every lane evaluates the plan against the trace once and returns

      viol:   (B,) int32  — first sample index with ``mem > alloc``, or -1,
      w_succ: (B,) float  — wastage assuming the attempt succeeds
                            (``max(alloc, mem) − mem`` integrated),
      w_kill: (B,) float  — wastage if the attempt is killed at ``viol``
                            (all allocation up to and including the kill
                            sample), 0 where ``viol < 0``.
    """
    B, T = mems.shape
    mems = np.asarray(mems, np.float64)
    t = np.arange(T, dtype=np.float64) * dt
    idx = np.stack([
        np.clip(np.searchsorted(s, t, side="right") - 1, 0, len(s) - 1)
        for s in np.asarray(starts, np.float64)
    ])
    alloc = np.take_along_axis(np.asarray(peaks, np.float64), idx, axis=1)
    valid = np.arange(T)[None, :] < lengths[:, None]
    bad = (mems > alloc) & valid
    any_v = bad.any(axis=1)
    vidx = bad.argmax(axis=1)
    viol = np.where(any_v, vidx, -1).astype(np.int32)
    w_succ = ((np.maximum(alloc, mems) - mems) * valid).sum(axis=1) * dt
    prefix = np.cumsum(alloc * valid, axis=1)
    w_kill = np.where(
        any_v, np.take_along_axis(prefix, vidx[:, None], axis=1)[:, 0], 0.0
    ) * dt
    return viol, w_succ, w_kill
