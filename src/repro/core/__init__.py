"""KS+ core — the paper's contribution as a composable JAX module.

Public API:

* :func:`get_segments` / :func:`get_segments_ref` — Algorithm 1.
* :class:`AllocationPlan` — time-varying allocation step function.
* :class:`KSPlus` — the full method (fit / predict / retry).
* Baselines: :class:`TovarPPM`, :class:`PPMImproved`, :class:`KSegments`,
  :class:`DefaultMethod`.
* :func:`simulate_execution` — OOM/retry simulation + GB·s wastage.
"""

from repro.core.allocation import (
    AllocationPlan,
    alloc_at,
    alloc_series,
    first_violation,
)
from repro.core.baselines import (
    DefaultMethod,
    KSegments,
    PPMImproved,
    TovarFeedback,
    TovarPPM,
    WittPercentile,
)
from repro.core.envelope import (
    OffsetCandidate,
    PackedEnvelopes,
    alloc_at_packed,
    apply_offsets,
    first_violation_packed,
    fits_under,
    residual_over,
    retry_packed,
    segment_sample_bounds,
    span_alloc_sum,
    usage_over,
)
from repro.core.fleet import (
    FleetBatch,
    FleetResult,
    PackedTraces,
    RetrySpec,
    TraceBucket,
    bucket_traces,
    concat_packed,
    first_attempt,
    fleet_eval,
    pack_plans,
    pack_traces,
    packed_predict,
    simulate_fleet,
    simulate_fleet_many,
    subset_batch,
)
from repro.core.ksplus import KSPlus, KSPlusAuto
from repro.core.predictor import (
    ExecutionOutcome,
    LinReg,
    MemoryPredictor,
    RefitPolicy,
    SegmentModel,
    fit_linreg,
    fit_segment_model,
    predict_plan,
    predict_runtime,
    refit_batched,
    segment_rows,
    solve_segment_model,
)
from repro.core import registry
from repro.core.retry import (
    double_retry,
    ksegments_partial_retry,
    ksegments_selective_retry,
    ksplus_retry,
    max_machine_retry,
)
from repro.core.segmentation import get_segments, get_segments_ref, segments_to_starts
from repro.core.wastage import (
    AttemptRecord,
    ExecutionResult,
    oom_probe_ref,
    simulate_execution,
    wastage_eval_ref,
)

__all__ = [
    "AllocationPlan", "alloc_at", "alloc_series", "first_violation",
    "DefaultMethod", "KSegments", "PPMImproved", "TovarFeedback", "TovarPPM",
    "WittPercentile",
    "OffsetCandidate", "PackedEnvelopes", "alloc_at_packed", "apply_offsets",
    "first_violation_packed",
    "fits_under", "residual_over", "retry_packed", "segment_sample_bounds",
    "span_alloc_sum", "usage_over",
    "FleetBatch", "FleetResult", "PackedTraces", "RetrySpec", "TraceBucket",
    "bucket_traces", "concat_packed", "first_attempt", "fleet_eval",
    "pack_plans", "pack_traces", "packed_predict", "simulate_fleet",
    "simulate_fleet_many", "subset_batch",
    "ExecutionOutcome", "KSPlus", "KSPlusAuto", "MemoryPredictor",
    "RefitPolicy", "refit_batched", "registry",
    "LinReg", "SegmentModel", "fit_linreg", "fit_segment_model",
    "predict_plan", "predict_runtime", "segment_rows", "solve_segment_model",
    "double_retry", "ksegments_partial_retry", "ksegments_selective_retry",
    "ksplus_retry", "max_machine_retry",
    "get_segments", "get_segments_ref", "segments_to_starts",
    "AttemptRecord", "ExecutionResult", "simulate_execution",
    "wastage_eval_ref", "oom_probe_ref",
]
