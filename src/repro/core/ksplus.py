"""KS+ — the paper's method, as a composable module.

Usage::

    model = KSPlus(k=4)
    model.fit(mems, dts, inputs)          # historical executions of one task
    plan = model.predict(input_size)      # AllocationPlan (monotone step fn)
    plan = model.retry(plan, t_fail, used)  # §II-C failure handling

Every method (KS+ and the baselines in :mod:`repro.core.baselines`) follows
this ``fit / predict / retry`` protocol, so the simulator and benchmark
harness treat them uniformly.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Sequence

import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.predictor import (
    SegmentModel,
    fit_segment_model,
    predict_plan,
    predict_runtime,
)
from repro.core.retry import ksplus_retry

__all__ = ["MemoryPredictor", "KSPlus", "KSPlusAuto"]


class MemoryPredictor(Protocol):
    """fit/predict/retry protocol shared by KS+ and all baselines."""

    name: str

    def fit(self, mems: Sequence[np.ndarray], dts: Sequence[float],
            inputs: Sequence[float]) -> None: ...

    def predict(self, input_size: float) -> AllocationPlan: ...

    def retry(self, plan: AllocationPlan, t_fail: float,
              used: float) -> AllocationPlan: ...


@dataclasses.dataclass
class KSPlus:
    """The KS+ method (dynamic segments + per-segment regression + re-timing).

    Attributes:
      k:            number of segments (paper sweeps 2–8; Fig. 7 minimum at 6).
      peak_offset:  over-prediction margin on segment peaks (+10 %).
      start_offset: under-prediction margin on segment starts (−15 %).
      last_peak_bump: peak increase when failing inside the last segment.
    """

    k: int = 4
    peak_offset: float = 0.10
    start_offset: float = 0.15
    last_peak_bump: float = 0.20
    name: str = "ks+"
    _model: Optional[SegmentModel] = dataclasses.field(default=None, repr=False)

    def fit(self, mems, dts, inputs) -> None:
        self._model = fit_segment_model(
            mems, dts, inputs, self.k,
            peak_offset=self.peak_offset, start_offset=self.start_offset,
        )

    @property
    def model(self) -> SegmentModel:
        if self._model is None:
            raise RuntimeError("KSPlus.fit() must be called before predict()")
        return self._model

    def predict(self, input_size: float) -> AllocationPlan:
        return predict_plan(self.model, input_size)

    def predict_runtime(self, input_size: float) -> float:
        return predict_runtime(self.model, input_size)

    def retry(self, plan: AllocationPlan, t_fail: float,
              used: float) -> AllocationPlan:
        return ksplus_retry(plan, t_fail, used,
                            last_peak_bump=self.last_peak_bump)


@dataclasses.dataclass
class KSPlusAuto:
    """KS+ with per-task automatic segment-count selection.

    The paper's stated future work ("dynamically determine the optimal
    number of segments for each task"): fit one KS+ model per candidate k,
    replay the *training* executions through the OOM/retry simulator, and
    keep the k with the lowest training wastage.  Costs |K| extra fits at
    training time; prediction/retry are unchanged.
    """

    candidates: Sequence[int] = (2, 3, 4, 6, 8)
    peak_offset: float = 0.10
    start_offset: float = 0.15
    last_peak_bump: float = 0.20
    machine_memory: float = 128.0
    name: str = "ks+auto"
    chosen_k: Optional[int] = None
    _model: Optional[KSPlus] = dataclasses.field(default=None, repr=False)

    def fit(self, mems, dts, inputs) -> None:
        from repro.core.wastage import simulate_execution  # cycle-free import
        best = (np.inf, None, None)
        for k in self.candidates:
            m = KSPlus(k=k, peak_offset=self.peak_offset,
                       start_offset=self.start_offset,
                       last_peak_bump=self.last_peak_bump)
            m.fit(mems, dts, inputs)
            total = 0.0
            for mem, dt, inp in zip(mems, dts, inputs):
                res = simulate_execution(
                    m.predict(inp), m.retry, mem, dt,
                    machine_memory=self.machine_memory)
                total += res.wastage_gbs
            if total < best[0]:
                best = (total, k, m)
        _, self.chosen_k, self._model = best

    def predict(self, input_size: float) -> AllocationPlan:
        return self._model.predict(input_size)

    def predict_runtime(self, input_size: float) -> float:
        return self._model.predict_runtime(input_size)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return self._model.retry(plan, t_fail, used)
