"""KS+ — the paper's method, as a composable module.

Usage::

    model = KSPlus(k=4)
    model.fit(mems, dts, inputs)          # historical executions of one task
    plan = model.predict(input_size)      # AllocationPlan (monotone step fn)
    plan = model.retry(plan, t_fail, used)  # §II-C failure handling
    model.observe(ExecutionOutcome(...))  # feed a finished execution back
    model.refit("on_failure")             # maybe re-fit from the history

Every method (KS+ and the baselines in :mod:`repro.core.baselines`)
subclasses :class:`repro.core.predictor.MemoryPredictor` — the explicit
``fit / observe / refit / predict / retry`` lifecycle — so the simulator,
the online replay harness and the benchmark suite treat them uniformly.
Construction and naming run through :mod:`repro.core.registry`.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence

import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.fleet import RetrySpec
from repro.core.predictor import (
    ExecutionOutcome,
    MemoryPredictor,
    RefitPolicy,
    SegmentModel,
    predict_plan,
    predict_plans_packed,
    predict_runtime,
    segment_rows,
    solve_segment_model,
)
from repro.core.retry import ksplus_retry

__all__ = ["ExecutionOutcome", "MemoryPredictor", "RefitPolicy",
           "HeteroDtWarning", "reset_hetero_dt_warnings",
           "KSPlus", "KSPlusAuto"]


class HeteroDtWarning(UserWarning):
    """Heterogeneous per-execution ``dt`` values hit a batched-engine path
    that needs a resample/fallback policy (see :class:`KSPlusAuto`)."""


# Deduplication registry for HeteroDtWarning: a 10k-task hetero-dt scenario
# fits one KSPlusAuto per task family, and every one of those fits would
# repeat the same diagnosis — the situation is a property of the *workload*,
# so identical (policy, target-dt) situations warn once per process.
_HETERO_WARNED: set = set()


def reset_hetero_dt_warnings() -> None:
    """Clear the :class:`HeteroDtWarning` dedup registry (tests; or after
    switching workloads, to re-surface the diagnosis once)."""
    _HETERO_WARNED.clear()


def _warn_hetero_once(policy: str, dt0: float, message: str) -> None:
    key = (policy, float(dt0))
    if key in _HETERO_WARNED:
        return
    _HETERO_WARNED.add(key)
    warnings.warn(message, HeteroDtWarning, stacklevel=3)


def _resample_trace(mem: np.ndarray, dt: float, dt0: float) -> np.ndarray:
    """Sample-and-hold resampling of a trace from period ``dt`` to ``dt0``.

    Sample ``i`` of the result reads the source sample active at
    ``i * dt0`` — exact for the step-function envelopes this system
    models; total duration is preserved to within one target sample.
    """
    if dt == dt0:
        return mem
    n_new = max(int(np.ceil(len(mem) * dt / dt0 - 1e-9)), 1)
    idx = np.minimum((np.arange(n_new) * dt0 / dt).astype(np.int64),
                     len(mem) - 1)
    return np.asarray(mem)[idx]


@dataclasses.dataclass
class KSPlus(MemoryPredictor):
    """The KS+ method (dynamic segments + per-segment regression + re-timing).

    Attributes:
      k:            number of segments (paper sweeps 2–8; Fig. 7 minimum at 6).
      peak_offset:  over-prediction margin on segment peaks (+10 %).
      start_offset: under-prediction margin on segment starts (−15 %).
      last_peak_bump: peak increase when failing inside the last segment.
    """

    k: int = 4
    peak_offset: float = 0.10
    start_offset: float = 0.15
    last_peak_bump: float = 0.20
    _model: Optional[SegmentModel] = dataclasses.field(default=None, repr=False)
    # Cached per-execution segmentation rows (starts_sec, peaks, runtimes,
    # inputs) for the fitted history — the incremental state online refits
    # extend instead of re-segmenting everything.
    _rows: Optional[tuple] = dataclasses.field(default=None, repr=False)

    def _fit(self, mems, dts, inputs) -> None:
        ss, pk, rt = segment_rows(mems, dts, self.k)
        self._rows = (ss, pk, rt, np.asarray(inputs, np.float64))
        self._solve()

    def _solve(self) -> None:
        ss, pk, rt, I = self._rows
        self._model = solve_segment_model(
            I, ss, pk, rt, self.k,
            peak_offset=self.peak_offset, start_offset=self.start_offset,
        )

    def _segment_tail(self):
        st = self._life
        have = 0 if self._rows is None else len(self._rows[3])
        if self._rows is None or have > len(st.mems):
            return None  # cache diverged from history: full refit
        return st.mems[have:], st.dts[have:], self.k

    def _commit_tail_rows(self, ss, pk, rt) -> None:
        st = self._life
        have = len(self._rows[3])
        I2 = np.asarray(st.inputs[have:], np.float64)
        self._rows = tuple(
            np.concatenate([a, b])
            for a, b in zip(self._rows, (ss, pk, rt, I2)))
        self._solve()

    def _refit(self) -> None:
        """Incremental online refit: Algorithm 1 is per-execution, so only
        the newly observed tail is segmented; the regressions re-solve over
        the cached rows — bit-identical to a from-scratch ``_fit`` on the
        full history, at O(new executions) cost."""
        tail = self._segment_tail()
        if tail is None:
            return super()._refit()
        mems, dts, k = tail
        if mems:
            self._commit_tail_rows(*segment_rows(mems, dts, k))
        else:
            self._solve()

    @property
    def model(self) -> SegmentModel:
        if self._model is None:
            raise RuntimeError("KSPlus.fit() must be called before predict()")
        return self._model

    def predict(self, input_size: float) -> AllocationPlan:
        return predict_plan(self.model, input_size)

    def predict_packed(self, inputs: np.ndarray):
        """Vectorized predict: (starts, peaks) of shape (B, k)."""
        return predict_plans_packed(self.model, inputs)

    def predict_runtime(self, input_size: float) -> float:
        return predict_runtime(self.model, input_size)

    def retry(self, plan: AllocationPlan, t_fail: float,
              used: float) -> AllocationPlan:
        return ksplus_retry(plan, t_fail, used,
                            last_peak_bump=self.last_peak_bump)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("ksplus", bump=self.last_peak_bump)


@dataclasses.dataclass
class KSPlusAuto(MemoryPredictor):
    """KS+ with per-task automatic segment-count selection.

    The paper's stated future work ("dynamically determine the optimal
    number of segments for each task"): fit one KS+ model per candidate k,
    replay the *training* executions through the OOM/retry simulator, and
    keep the k with the lowest training wastage.

    The replay runs on the batched fleet engine with the candidate axis
    folded into the lane batch — one XLA program evaluates every
    ``(candidate k, training execution)`` pair at once instead of |K|
    serial Python replays.  Set ``engine="oracle"`` to force the
    per-execution loop.

    The fleet engine's lane batch shares one sampling period, so
    heterogeneous per-execution ``dt`` values need a policy
    (``hetero_dt``, only consulted when ``engine="fleet"`` and the ``dts``
    actually differ — a :class:`HeteroDtWarning` is emitted either way,
    deduplicated per (policy, target dt) per process so a 10k-task
    hetero-dt scenario diagnoses the situation once, not once per task
    family; :func:`reset_hetero_dt_warnings` re-arms it):

    * ``"resample"`` (default) — sample-and-hold every training trace onto
      the finest observed ``dt`` and select k on the batched engine.  The
      envelope is a step function, so resampling preserves its shape; only
      OOM *timing* inside one coarse sample can shift, which perturbs the
      candidates' training-wastage totals equally and leaves the argmin
      (the chosen k) stable in practice.
    * ``"oracle"`` — replay each execution at its native ``dt`` through the
      per-execution Python loop (exact, |candidates|× slower).
    """

    candidates: Sequence[int] = (2, 3, 4, 6, 8)
    peak_offset: float = 0.10
    start_offset: float = 0.15
    last_peak_bump: float = 0.20
    machine_memory: float = 128.0
    engine: str = "fleet"
    hetero_dt: str = "resample"
    chosen_k: Optional[int] = None
    _model: Optional[KSPlus] = dataclasses.field(default=None, repr=False)

    def _fit(self, mems, dts, inputs) -> None:
        if self.hetero_dt not in ("resample", "oracle"):
            raise ValueError(
                f"unknown hetero_dt policy: {self.hetero_dt!r} "
                "(expected 'resample' or 'oracle')")
        models = []
        for k in self.candidates:
            m = KSPlus(k=k, peak_offset=self.peak_offset,
                       start_offset=self.start_offset,
                       last_peak_bump=self.last_peak_bump)
            m.fit(mems, dts, inputs)
            models.append(m)

        uniform_dt = len(set(float(d) for d in dts)) == 1
        if self.engine != "fleet":
            totals = self._training_wastage_oracle(models, mems, dts, inputs)
        elif uniform_dt:
            totals = self._training_wastage_fleet(models, mems, dts, inputs)
        elif self.hetero_dt == "resample":
            dt0 = float(min(float(d) for d in dts))
            _warn_hetero_once(
                "resample", dt0,
                "KSPlusAuto.fit: executions have heterogeneous dt values; "
                f"resampling training traces to the finest dt ({dt0}) for "
                "the batched k-selection replay (hetero_dt='resample'; use "
                "hetero_dt='oracle' for exact native-dt replays).  Warned "
                "once per process for this situation — see "
                "repro.core.ksplus.reset_hetero_dt_warnings")
            resampled = [_resample_trace(m_, float(d), dt0)
                         for m_, d in zip(mems, dts)]
            totals = self._training_wastage_fleet(
                models, resampled, [dt0] * len(mems), inputs)
        else:  # hetero_dt == "oracle" (validated above)
            _warn_hetero_once(
                "oracle", 0.0,
                "KSPlusAuto.fit: executions have heterogeneous dt values; "
                "falling back to the per-execution oracle replay "
                "(hetero_dt='oracle').  Warned once per process — see "
                "repro.core.ksplus.reset_hetero_dt_warnings")
            totals = self._training_wastage_oracle(models, mems, dts, inputs)

        best = (np.inf, None, None)
        for k, m, total in zip(self.candidates, models, totals):
            if total < best[0]:
                best = (total, k, m)
        _, self.chosen_k, self._model = best

    def _training_wastage_fleet(self, models, mems, dts, inputs):
        """One engine call: candidates become an extra lane-batch axis."""
        from repro.core.fleet import concat_packed, packed_predict, \
            simulate_fleet
        packed = concat_packed(
            [packed_predict(m, inputs) for m in models])
        fr = simulate_fleet(
            packed, RetrySpec("ksplus", bump=self.last_peak_bump),
            list(mems) * len(models), float(dts[0]),
            machine_memory=self.machine_memory)
        return fr.wastage_gbs.reshape(len(models), len(inputs)).sum(axis=1)

    def _training_wastage_oracle(self, models, mems, dts, inputs):
        from repro.core.wastage import simulate_execution  # cycle-free import
        totals = []
        for m in models:
            total = 0.0
            for mem, dt, inp in zip(mems, dts, inputs):
                res = simulate_execution(
                    m.predict(inp), m.retry, mem, dt,
                    machine_memory=self.machine_memory)
                total += res.wastage_gbs
            totals.append(total)
        return totals

    def observe(self, outcome: ExecutionOutcome) -> None:
        super().observe(outcome)
        if self._model is not None:  # mirror into the selected model's
            self._model.observe(outcome)  # incremental lifecycle state

    def _segment_tail(self):
        # Batched-refit protocol: delegate to the selected model (its
        # lifecycle mirrors this one's via `observe`).
        return None if self._model is None else self._model._segment_tail()

    def _commit_tail_rows(self, ss, pk, rt) -> None:
        self._model._commit_tail_rows(ss, pk, rt)
        self._model._life.pending = 0
        self._model._life.failures = 0

    def _refit(self) -> None:
        """Online refit: re-estimate the regressions at the *selected* k
        (incrementally, through the inner model's own lifecycle).

        Re-running the |candidates|× training-replay sweep on every online
        refit would dominate streaming replays (it is a full fleet
        simulation of the whole history per candidate); the segment count
        is a structural property of the task family, so it is re-selected
        only by an explicit :meth:`fit`.
        """
        if self._model is None:  # never fitted: fall back to full selection
            return super()._refit()
        self._model.refit(RefitPolicy("every_n", 1))

    @property
    def model(self) -> KSPlus:
        if self._model is None:
            raise RuntimeError(
                "KSPlusAuto.fit() must be called before predict()")
        return self._model

    def predict(self, input_size: float) -> AllocationPlan:
        return self.model.predict(input_size)

    def predict_packed(self, inputs: np.ndarray):
        return self.model.predict_packed(inputs)

    def predict_runtime(self, input_size: float) -> float:
        return self.model.predict_runtime(input_size)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return self.model.retry(plan, t_fail, used)

    @property
    def retry_spec(self) -> RetrySpec:
        return self.model.retry_spec
