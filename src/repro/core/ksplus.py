"""KS+ — the paper's method, as a composable module.

Usage::

    model = KSPlus(k=4)
    model.fit(mems, dts, inputs)          # historical executions of one task
    plan = model.predict(input_size)      # AllocationPlan (monotone step fn)
    plan = model.retry(plan, t_fail, used)  # §II-C failure handling

Every method (KS+ and the baselines in :mod:`repro.core.baselines`) follows
this ``fit / predict / retry`` protocol, so the simulator and benchmark
harness treat them uniformly.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Protocol, Sequence

import numpy as np

from repro.core.allocation import AllocationPlan
from repro.core.fleet import RetrySpec
from repro.core.predictor import (
    SegmentModel,
    fit_segment_model,
    predict_plan,
    predict_plans_packed,
    predict_runtime,
)
from repro.core.retry import ksplus_retry

__all__ = ["MemoryPredictor", "KSPlus", "KSPlusAuto"]


def _resample_trace(mem: np.ndarray, dt: float, dt0: float) -> np.ndarray:
    """Sample-and-hold resampling of a trace from period ``dt`` to ``dt0``.

    Sample ``i`` of the result reads the source sample active at
    ``i * dt0`` — exact for the step-function envelopes this system
    models; total duration is preserved to within one target sample.
    """
    if dt == dt0:
        return mem
    n_new = max(int(np.ceil(len(mem) * dt / dt0 - 1e-9)), 1)
    idx = np.minimum((np.arange(n_new) * dt0 / dt).astype(np.int64),
                     len(mem) - 1)
    return np.asarray(mem)[idx]


class MemoryPredictor(Protocol):
    """fit/predict/retry protocol shared by KS+ and all baselines.

    ``retry_spec`` is the static, batchable description of ``retry`` used by
    the fleet engine (:mod:`repro.core.fleet`); ``retry`` itself remains the
    per-plan oracle.
    """

    name: str

    def fit(self, mems: Sequence[np.ndarray], dts: Sequence[float],
            inputs: Sequence[float]) -> None: ...

    def predict(self, input_size: float) -> AllocationPlan: ...

    def retry(self, plan: AllocationPlan, t_fail: float,
              used: float) -> AllocationPlan: ...

    @property
    def retry_spec(self) -> RetrySpec: ...


@dataclasses.dataclass
class KSPlus:
    """The KS+ method (dynamic segments + per-segment regression + re-timing).

    Attributes:
      k:            number of segments (paper sweeps 2–8; Fig. 7 minimum at 6).
      peak_offset:  over-prediction margin on segment peaks (+10 %).
      start_offset: under-prediction margin on segment starts (−15 %).
      last_peak_bump: peak increase when failing inside the last segment.
    """

    k: int = 4
    peak_offset: float = 0.10
    start_offset: float = 0.15
    last_peak_bump: float = 0.20
    name: str = "ks+"
    _model: Optional[SegmentModel] = dataclasses.field(default=None, repr=False)

    def fit(self, mems, dts, inputs) -> None:
        self._model = fit_segment_model(
            mems, dts, inputs, self.k,
            peak_offset=self.peak_offset, start_offset=self.start_offset,
        )

    @property
    def model(self) -> SegmentModel:
        if self._model is None:
            raise RuntimeError("KSPlus.fit() must be called before predict()")
        return self._model

    def predict(self, input_size: float) -> AllocationPlan:
        return predict_plan(self.model, input_size)

    def predict_packed(self, inputs: np.ndarray):
        """Vectorized predict: (starts, peaks) of shape (B, k)."""
        return predict_plans_packed(self.model, inputs)

    def predict_runtime(self, input_size: float) -> float:
        return predict_runtime(self.model, input_size)

    def retry(self, plan: AllocationPlan, t_fail: float,
              used: float) -> AllocationPlan:
        return ksplus_retry(plan, t_fail, used,
                            last_peak_bump=self.last_peak_bump)

    @property
    def retry_spec(self) -> RetrySpec:
        return RetrySpec("ksplus", bump=self.last_peak_bump)


@dataclasses.dataclass
class KSPlusAuto:
    """KS+ with per-task automatic segment-count selection.

    The paper's stated future work ("dynamically determine the optimal
    number of segments for each task"): fit one KS+ model per candidate k,
    replay the *training* executions through the OOM/retry simulator, and
    keep the k with the lowest training wastage.

    The replay runs on the batched fleet engine with the candidate axis
    folded into the lane batch — one XLA program evaluates every
    ``(candidate k, training execution)`` pair at once instead of |K|
    serial Python replays.  Set ``engine="oracle"`` to force the
    per-execution loop.

    The fleet engine's lane batch shares one sampling period, so
    heterogeneous per-execution ``dt`` values need a policy
    (``hetero_dt``, only consulted when ``engine="fleet"`` and the ``dts``
    actually differ — a warning is emitted either way):

    * ``"resample"`` (default) — sample-and-hold every training trace onto
      the finest observed ``dt`` and select k on the batched engine.  The
      envelope is a step function, so resampling preserves its shape; only
      OOM *timing* inside one coarse sample can shift, which perturbs the
      candidates' training-wastage totals equally and leaves the argmin
      (the chosen k) stable in practice.
    * ``"oracle"`` — replay each execution at its native ``dt`` through the
      per-execution Python loop (exact, |candidates|× slower).
    """

    candidates: Sequence[int] = (2, 3, 4, 6, 8)
    peak_offset: float = 0.10
    start_offset: float = 0.15
    last_peak_bump: float = 0.20
    machine_memory: float = 128.0
    engine: str = "fleet"
    hetero_dt: str = "resample"
    name: str = "ks+auto"
    chosen_k: Optional[int] = None
    _model: Optional[KSPlus] = dataclasses.field(default=None, repr=False)

    def fit(self, mems, dts, inputs) -> None:
        if self.hetero_dt not in ("resample", "oracle"):
            raise ValueError(
                f"unknown hetero_dt policy: {self.hetero_dt!r} "
                "(expected 'resample' or 'oracle')")
        models = []
        for k in self.candidates:
            m = KSPlus(k=k, peak_offset=self.peak_offset,
                       start_offset=self.start_offset,
                       last_peak_bump=self.last_peak_bump)
            m.fit(mems, dts, inputs)
            models.append(m)

        uniform_dt = len(set(float(d) for d in dts)) == 1
        if self.engine != "fleet":
            totals = self._training_wastage_oracle(models, mems, dts, inputs)
        elif uniform_dt:
            totals = self._training_wastage_fleet(models, mems, dts, inputs)
        elif self.hetero_dt == "resample":
            dt0 = float(min(float(d) for d in dts))
            warnings.warn(
                "KSPlusAuto.fit: executions have heterogeneous dt values; "
                f"resampling training traces to the finest dt ({dt0}) for "
                "the batched k-selection replay (hetero_dt='resample'; use "
                "hetero_dt='oracle' for exact native-dt replays)",
                UserWarning, stacklevel=2)
            resampled = [_resample_trace(m_, float(d), dt0)
                         for m_, d in zip(mems, dts)]
            totals = self._training_wastage_fleet(
                models, resampled, [dt0] * len(mems), inputs)
        else:  # hetero_dt == "oracle" (validated above)
            warnings.warn(
                "KSPlusAuto.fit: executions have heterogeneous dt values; "
                "falling back to the per-execution oracle replay "
                "(hetero_dt='oracle')",
                UserWarning, stacklevel=2)
            totals = self._training_wastage_oracle(models, mems, dts, inputs)

        best = (np.inf, None, None)
        for k, m, total in zip(self.candidates, models, totals):
            if total < best[0]:
                best = (total, k, m)
        _, self.chosen_k, self._model = best

    def _training_wastage_fleet(self, models, mems, dts, inputs):
        """One engine call: candidates become an extra lane-batch axis."""
        from repro.core.fleet import concat_packed, packed_predict, \
            simulate_fleet
        packed = concat_packed(
            [packed_predict(m, inputs) for m in models])
        fr = simulate_fleet(
            packed, RetrySpec("ksplus", bump=self.last_peak_bump),
            list(mems) * len(models), float(dts[0]),
            machine_memory=self.machine_memory)
        return fr.wastage_gbs.reshape(len(models), len(inputs)).sum(axis=1)

    def _training_wastage_oracle(self, models, mems, dts, inputs):
        from repro.core.wastage import simulate_execution  # cycle-free import
        totals = []
        for m in models:
            total = 0.0
            for mem, dt, inp in zip(mems, dts, inputs):
                res = simulate_execution(
                    m.predict(inp), m.retry, mem, dt,
                    machine_memory=self.machine_memory)
                total += res.wastage_gbs
            totals.append(total)
        return totals

    @property
    def model(self) -> KSPlus:
        if self._model is None:
            raise RuntimeError(
                "KSPlusAuto.fit() must be called before predict()")
        return self._model

    def predict(self, input_size: float) -> AllocationPlan:
        return self.model.predict(input_size)

    def predict_packed(self, inputs: np.ndarray):
        return self.model.predict_packed(inputs)

    def predict_runtime(self, input_size: float) -> float:
        return self.model.predict_runtime(input_size)

    def retry(self, plan, t_fail, used) -> AllocationPlan:
        return self.model.retry(plan, t_fail, used)

    @property
    def retry_spec(self) -> RetrySpec:
        return self.model.retry_spec
