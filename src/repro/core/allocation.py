"""Time-varying memory allocation plans.

Every prediction method in this framework (KS+ and all baselines) emits an
:class:`AllocationPlan` — a monotone-indexable step function
``alloc(t) = peaks[max { i < n : starts[i] <= t }]`` with the last peak held
until the job completes.  The cluster simulator and the wastage metric are
therefore method-agnostic.

The arithmetic itself lives in :mod:`repro.core.envelope` in packed
``(B, K)`` form; the helpers here are the 1-lane views, kept for per-plan
callers (oracles, examples, small scripts).

Times are seconds, memory is GB throughout ``repro.core``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.envelope import alloc_at_packed, first_violation_packed

__all__ = ["AllocationPlan", "alloc_at", "alloc_series", "first_violation"]


@dataclasses.dataclass(frozen=True)
class AllocationPlan:
    """A step-function memory allocation.

    Attributes:
      starts: (n,) ascending start offsets in seconds; ``starts[0] == 0``.
      peaks:  (n,) allocation in GB active from ``starts[i]`` until the next
              start (or job end for the last segment).
    """

    starts: np.ndarray
    peaks: np.ndarray

    def __post_init__(self):
        starts = np.asarray(self.starts, dtype=np.float64)
        peaks = np.asarray(self.peaks, dtype=np.float64)
        if starts.ndim != 1 or peaks.shape != starts.shape or starts.size == 0:
            raise ValueError("starts/peaks must be equal-length 1-D arrays")
        object.__setattr__(self, "starts", starts)
        object.__setattr__(self, "peaks", peaks)

    @property
    def n(self) -> int:
        return int(self.starts.size)

    def is_monotone(self) -> bool:
        return bool(np.all(np.diff(self.peaks) >= -1e-12))

    def segment_at(self, t: float) -> int:
        """Index of the segment active at time ``t``."""
        return max(int(np.searchsorted(self.starts, t, side="right")) - 1, 0)

    def with_(self, *, starts: Optional[np.ndarray] = None,
              peaks: Optional[np.ndarray] = None) -> "AllocationPlan":
        return AllocationPlan(
            starts=self.starts if starts is None else starts,
            peaks=self.peaks if peaks is None else peaks,
        )


def alloc_at(plan: AllocationPlan, t: np.ndarray | float) -> np.ndarray:
    """Evaluate the plan at time(s) ``t`` — 1-lane view of
    :func:`repro.core.envelope.alloc_at_packed`."""
    t_arr = np.asarray(t, dtype=np.float64)
    out = alloc_at_packed(plan.starts[None, :], plan.peaks[None, :],
                          t_arr.reshape(1, -1))
    return out.reshape(t_arr.shape)


def alloc_series(plan: AllocationPlan, num_samples: int, dt: float) -> np.ndarray:
    """Allocation evaluated on the sampling grid ``t_i = i * dt``."""
    t = np.arange(num_samples, dtype=np.float64) * dt
    return alloc_at(plan, t)


def first_violation(plan: AllocationPlan, mem: np.ndarray, dt: float) -> int:
    """First sample index where usage exceeds the allocation, or -1.

    This is the simulator's OOM-killer: the job is terminated during the
    first sample whose memory demand is above the active limit.  1-lane view
    of :func:`repro.core.envelope.first_violation_packed`.
    """
    mem = np.asarray(mem, dtype=np.float64)
    return int(first_violation_packed(
        plan.starts[None, :], plan.peaks[None, :], mem[None, :],
        np.asarray([len(mem)]), dt)[0])
