"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses a depth/width-reduced llama3-family config (~106M params), the real
training stack (sharded train_step, AdamW + cosine, deterministic data,
async checkpoints) and the KS+ memory monitor.  On CPU this runs at
~2-5 s/step; pass --steps 300 for the full run or keep the default quick
pass.

  PYTHONPATH=src python examples/train_100m.py --steps 300
"""

import argparse
import dataclasses
import json

from repro.configs import get_config
import repro.launch.train as T


def make_100m_cfg():
    base = get_config("llama3-8b")
    return dataclasses.replace(
        base, name="llama3-100m",
        n_layers=8, d_model=768, n_heads=12, n_kv_heads=6, head_dim=64,
        d_ff=2048, vocab=32768, remat="none",
        attn_chunk_q=128, attn_chunk_kv=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/ks_train_100m")
    args = ap.parse_args()

    cfg = make_100m_cfg()
    n = cfg.params_count()
    print(f"config {cfg.name}: {n/1e6:.0f}M params")

    # monkey-patch the driver's config resolution to inject the 100M config
    orig_smoke = T.smoke_config
    T.smoke_config = lambda arch: cfg
    try:
        out = T.train("llama3-8b", steps=args.steps, seq=args.seq,
                      batch=args.batch, smoke=True, ckpt_dir=args.ckpt,
                      ckpt_every=50, peak_lr=3e-3, log_every=10)
    finally:
        T.smoke_config = orig_smoke
    rss = out.pop("rss_trace_gb", [])
    print(json.dumps(out, indent=1))
    if rss:
        print(f"host RSS envelope observed by the KS+ monitor: "
              f"{min(rss):.2f} -> {max(rss):.2f} GB over {len(rss)} samples")


if __name__ == "__main__":
    main()
