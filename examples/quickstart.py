"""Quickstart: the KS+ API in 60 lines.

Fit KS+ on historical executions of a BWA-like task, predict a
time-varying memory allocation for a new input size, survive an OOM via
the re-timing retry, and compare wastage against every baseline.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (
    DefaultMethod, KSegments, KSPlus, PPMImproved, TovarPPM,
    simulate_execution,
)
from repro.traces import eager


def main():
    # Historical executions of one task family (BWA from the eager workflow).
    wf = eager(30)
    execs = wf.generate(seed=0)["bwa"]
    train, test = execs[:20], execs[20:]

    model = KSPlus(k=4)
    model.fit([e.mem for e in train], [e.dt for e in train],
              [e.input_gb for e in train])

    e = test[0]
    plan = model.predict(e.input_gb)
    print(f"input {e.input_gb:.1f} GB  ->  predicted envelope:")
    for s, p in zip(plan.starts, plan.peaks):
        print(f"   from {s:7.1f}s allocate {p:6.2f} GB")
    print(f"   (true peak {e.peak:.2f} GB, runtime {e.runtime:.0f}s)")

    res = simulate_execution(plan, model.retry, e.mem, e.dt,
                             machine_memory=128.0)
    print(f"KS+  wastage {res.wastage_gbs:8.0f} GB·s  "
          f"retries {res.num_retries}")

    print("\nall methods on the same test executions:")
    methods = [KSPlus(k=4), KSegments(k=4), TovarPPM(), PPMImproved(),
               DefaultMethod(limit_gb=16.0)]
    for m in methods:
        m.fit([x.mem for x in train], [x.dt for x in train],
              [x.input_gb for x in train])
        total = retries = 0
        for t in test:
            r = simulate_execution(m.predict(t.input_gb), m.retry, t.mem,
                                   t.dt, machine_memory=128.0)
            total += r.wastage_gbs
            retries += r.num_retries
        print(f"  {m.name:22s} {total:10.0f} GB·s   retries {retries}")


if __name__ == "__main__":
    main()
