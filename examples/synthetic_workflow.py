"""End-to-end tour of `repro.workloads`: synthesize a DAG workload into
packed fleet lanes, evaluate the method zoo on it, replay it through the
DAG-aware cluster simulator with per-family tuned safety offsets, and
import a wfcommons instance.

  PYTHONPATH=src python examples/synthetic_workflow.py
"""

import os

from repro.core import KSPlus, RetrySpec, registry
from repro.sched import ClusterSim, Node, evaluate_workflow
from repro.workloads import assert_release_order, scenarios, wfc


def nodes():
    return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0)]


def main():
    # 1) Synthesize a burst-arrival DAG workload straight into packed lanes.
    wf = scenarios.get("burst_arrival", n_tasks=240, seed=0)
    shapes = [b.mems.shape for b in wf.batch.buckets]
    print(f"{wf.name}: {wf.B} tasks, "
          f"{len(set(wf.families))} families, packed buckets {shapes}")

    # 2) Method comparison through the standard harness (the WorkflowTrace
    #    adapts into evaluate_workflow; scenario *names* work too).
    res = evaluate_workflow(wf, seed=0, train_frac=0.5,
                            methods=["ks+", "k-segments-selective",
                                     "witt-p95"])
    for name, mr in res.methods.items():
        print(f"  {name:22s} wastage {mr.total_gbs:9.0f} GB·s  "
              f"retries {mr.retries}")

    # 3) DAG-aware cluster replay with per-family tuned offsets: winners
    #    may disagree on every field, including the ksplus last-peak bump.
    train, _ = wf.to_workflow().split(0, 0.5)
    fitted, data = {}, {}
    for fam, execs in train.items():
        m = KSPlus(k=3)
        mems = [e.mem for e in execs]
        dts = [e.dt for e in execs]
        inputs = [e.input_gb for e in execs]
        m.fit(mems, dts, inputs)
        fitted[fam], data[fam] = m, (mems, dts, inputs)
    mapping = registry.tune_offset_map(fitted, data, machine_memory=64.0)
    for fam, cand in mapping.items():
        print(f"  tuned {fam:12s} peak={cand.peak:+.2f} "
              f"start={cand.start:+.2f} bump={cand.last_peak_bump}")

    jobs = wf.to_jobs(under_frac=0.2, seed=0)
    base = ClusterSim(nodes()).run(wf.to_jobs(under_frac=0.2, seed=0),
                                   RetrySpec("ksplus"))
    tuned = ClusterSim(nodes()).run(jobs, RetrySpec("ksplus"),
                                    offsets=mapping)
    assert_release_order(jobs, tuned.placements)
    print(f"  cluster replay (DAG release order verified): base "
          f"{base.total_wastage_gbs:.0f} GB·s -> tuned "
          f"{tuned.total_wastage_gbs:.0f} GB·s, "
          f"makespan {tuned.makespan:.0f}s")

    # 4) wfcommons import: the same representation, the same consumers.
    mini = wfc.load_instance(
        os.path.join(os.path.dirname(__file__), os.pardir, "tests", "data",
                     "mini_wfcommons.json"))
    res = ClusterSim(nodes()).run(mini.to_jobs(margin=1.1),
                                  RetrySpec("ksplus"))
    print(f"  wfcommons '{mini.name}': {mini.B} tasks, "
          f"parents {mini.parents}, makespan {res.makespan:.0f}s")


if __name__ == "__main__":
    main()
