"""Fault-tolerance walkthrough: preemption, restart, and elastic re-mesh.

1. Train with periodic async checkpoints; kill the job mid-run.
2. Restart with --resume semantics: the deterministic data pipeline +
   atomic checkpoint give bit-consistent continuation.
3. Simulate losing 64 of 256 devices: plan_mesh() picks a new layout that
   keeps every sharded dim divisible, and the elastic planner requeues the
   evicted jobs.

  PYTHONPATH=src python examples/fault_tolerance.py
"""

import shutil

import numpy as np

from repro.core import AllocationPlan
from repro.launch.train import train
from repro.sched import ElasticPlanner, plan_mesh


def main():
    ckpt = "/tmp/ks_fault_demo"
    # Fresh demo dir: a finished checkpoint left by a previous run would
    # make the "resume" phase start past the final step.
    shutil.rmtree(ckpt, ignore_errors=True)
    print("== phase 1: train, checkpoint, die at step 14 ==")
    out1 = train("qwen3-1.7b", steps=30, seq=64, batch=4, ckpt_dir=ckpt,
                 ckpt_every=7, kill_at_step=14, monitor=False)
    print(f"  killed at step {out1['step']} (checkpoints survive)")

    print("== phase 2: restart and finish ==")
    out2 = train("qwen3-1.7b", steps=30, seq=64, batch=4, ckpt_dir=ckpt,
                 resume=True, ckpt_every=7, monitor=False)
    print(f"  resumed -> done, final loss {out2['final_loss']:.4f}")

    print("== phase 3: elastic re-mesh after losing 64/256 chips ==")
    for n in (256, 192, 128):
        d, m = plan_mesh(n, model_divisors=(96, 28672, 32768))
        print(f"  {n} devices -> mesh (data={d}, model={m})")

    planner = ElasticPlanner()
    for i in range(4):
        planner.node_join(f"slice{i}", 16.0 * 8)
    env = AllocationPlan(starts=np.asarray([0.0, 60.0]),
                         peaks=np.asarray([20.0, 55.0]))
    placed = {f"job{i}": planner.admit(f"job{i}", env, now=0.0)
              for i in range(4)}
    print(f"  placed: {placed}")
    evicted = planner.node_leave("slice0")
    print(f"  slice0 lost -> requeue {evicted}; "
          f"re-admitted on {[planner.admit(j, env, now=1.0) for j in evicted]}")


if __name__ == "__main__":
    main()
