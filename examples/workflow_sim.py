"""Cluster-level impact of KS+: pack a full nf-core-like workflow onto a
simulated cluster and compare makespan/wastage/utilization when the
resource manager uses (a) KS+ time-varying envelopes, (b) the original
k-Segments, (c) peak-only PPM-Improved allocations.

  PYTHONPATH=src python examples/workflow_sim.py
"""

import numpy as np

from repro.core import KSegments, KSPlus, PPMImproved
from repro.sched import ClusterSim, Job, Node
from repro.traces import eager


def build_jobs(method, train, test):
    jobs = []
    for j, e in enumerate(test):
        plan = method.predict(e.input_gb)
        est = getattr(method, "predict_runtime", lambda i: e.runtime)(e.input_gb)
        jobs.append(Job(jid=j, family=e.family, input_gb=e.input_gb,
                        mem=e.mem, dt=e.dt, plan=plan,
                        est_runtime=float(est)))
    return jobs


def main():
    wf = eager(30)
    train, test = wf.split(seed=0, train_frac=0.5)
    # one busy task family keeps the comparison crisp
    tr, te = train["bwa"], test["bwa"]

    for method in (KSPlus(k=4), KSegments(k=4), PPMImproved()):
        method.fit([e.mem for e in tr], [e.dt for e in tr],
                   [e.input_gb for e in tr])
        nodes = [Node(i, 64.0) for i in range(4)]
        sim = ClusterSim(nodes)
        res = sim.run(build_jobs(method, tr, te), method.retry)
        print(f"{method.name:22s} makespan {res.makespan:7.0f}s  "
              f"wastage {res.total_wastage_gbs:9.0f} GB·s  "
              f"util {100*res.avg_utilization:5.1f}%  "
              f"retries {res.retries}  unsched {res.unschedulable}")


if __name__ == "__main__":
    main()
