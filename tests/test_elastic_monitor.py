"""Dedicated coverage for the elastic planner (join/leave churn) and the
online memory monitor — previously only smoke-tested through test_sched."""

import numpy as np
import pytest

from repro.core import AllocationPlan
from repro.sched import ElasticPlanner
from repro.sched.elastic import plan_mesh
from repro.sched.monitor import MemoryMonitor, read_rss_gb


def _env(peak, n=1):
    return AllocationPlan(starts=np.arange(n, dtype=float) * 10.0,
                          peaks=np.linspace(peak / 2, peak, n + 1)[1:])


class TestElasticChurn:
    def test_submit_queues_when_full_and_join_drains(self):
        pl = ElasticPlanner()
        pl.node_join("n0", 32.0)
        placed = [pl.submit(f"j{i}", _env(10.0), now=0.0) for i in range(4)]
        # three 10-GB jobs fit a 32-GB slice; the fourth must queue
        assert placed[:3] == ["n0"] * 3 and placed[3] is None
        assert pl.queued == ["j3"]
        newly = pl.node_join("n1", 32.0, now=5.0)
        assert newly == {"j3": "n1"}
        assert pl.queued == []

    def test_leave_evicts_requeues_and_readmits(self):
        pl = ElasticPlanner()
        pl.node_join("n0", 32.0)
        pl.node_join("n1", 32.0)
        for i in range(4):
            assert pl.submit(f"j{i}", _env(10.0), now=0.0) is not None
        on_n0 = [jid for jid, _, _ in pl.slices["n0"].jobs]
        evicted = pl.node_leave("n0", now=10.0)
        assert evicted == on_n0  # checkpoint/requeue decision list
        # survivors: n1 had 32 GB; whatever fits was re-admitted, rest queued
        resident = [jid for jid, _, _ in pl.slices["n1"].jobs]
        assert set(resident) | set(pl.queued) == {f"j{i}" for i in range(4)}
        assert len(resident) == 3  # 3 × 10 GB under 32 GB
        # capacity returns → the queue drains
        pl.node_join("n2", 32.0, now=20.0)
        assert pl.queued == []

    def test_leave_unknown_slice_raises_keyerror(self):
        """A typoed or double leave must fail loudly, naming the slice —
        silently ignoring it would leave the planner admitting against
        capacity that no longer exists."""
        pl = ElasticPlanner()
        pl.node_join("n0", 32.0)
        with pytest.raises(KeyError, match="'nope'"):
            pl.node_leave("nope")
        pl.node_leave("n0")
        with pytest.raises(KeyError, match="'n0'"):
            pl.node_leave("n0")  # double leave

    def test_join_without_now_does_not_drain(self):
        """Draining needs the current time — resident envelopes are costed
        relative to it — so a time-less join must leave the queue alone."""
        pl = ElasticPlanner()
        pl.node_join("n0", 16.0)
        assert pl.submit("a", _env(10.0), now=0.0) == "n0"
        assert pl.submit("b", _env(10.0), now=0.0) is None
        assert pl.node_join("n1", 16.0) == {}
        assert pl.queued == ["b"]
        assert pl.drain(now=5.0) == {"b": "n1"}

    def test_eviction_order_prefers_checkpointed_jobs(self):
        pl = ElasticPlanner()
        pl.node_join("n0", 16.0)
        assert pl.submit("running", _env(10.0), now=0.0) == "n0"
        assert pl.submit("waiter", _env(10.0), now=0.0) is None
        pl.node_leave("n0")  # no `now`: nothing to re-admit onto
        # the evicted (checkpoint-holding) job re-admits before the waiter
        assert pl.queued == ["running", "waiter"]
        pl.node_join("n1", 16.0, now=1.0)
        assert [jid for jid, _, _ in pl.slices["n1"].jobs] == ["running"]

    def test_headroom_is_time_varying(self):
        pl = ElasticPlanner()
        pl.node_join("n0", 32.0)
        # stepped envelope: 5 GB for t<10, 20 GB afterwards
        stepped = AllocationPlan(starts=np.asarray([0.0, 10.0]),
                                 peaks=np.asarray([5.0, 20.0]))
        assert pl.admit("big", stepped, now=0.0) == "n0"
        head = pl.slices["n0"].headroom(now=0.0)
        assert np.isclose(head, 12.0)  # 32 − 20 over the default horizon
        # a 25-GB peak cannot co-reside with the 20-GB tail
        assert pl.admit("too-big", _env(25.0), now=0.0) is None

    def test_finish_frees_and_forgets(self):
        pl = ElasticPlanner()
        pl.node_join("n0", 16.0)
        pl.submit("a", _env(10.0), now=0.0)
        pl.submit("b", _env(10.0), now=0.0)
        assert pl.queued == ["b"]
        pl.finish("b")  # cancelled while queued
        assert pl.queued == []
        pl.finish("a")
        assert pl.slices["n0"].jobs == []
        assert pl.submit("c", _env(15.0), now=1.0) == "n0"

    def test_plan_mesh_divisibility(self):
        assert plan_mesh(8, (32, 64)) == (1, 8)
        assert plan_mesh(6, (32, 64)) == (3, 2)
        assert plan_mesh(7, (32, 64)) == (7, 1)


class TestMemoryMonitor:
    def test_read_rss_positive(self):
        assert read_rss_gb() > 0.0

    def test_sample_respects_dt_gate(self):
        mon = MemoryMonitor(job_type="train", input_size=1e6, dt=3600.0)
        mon.sample()          # first: last = -inf → records
        mon.sample()          # within dt → dropped
        mon.sample()
        assert len(mon.samples) == 1
        mon.sample(force=True)
        assert len(mon.samples) == 2

    def test_trace_never_empty(self):
        mon = MemoryMonitor(job_type="serve", input_size=1.0)
        tr = mon.trace()  # no samples yet → one live reading
        assert tr.shape == (1,) and tr[0] > 0
        mon.sample(force=True)
        mon.sample(force=True)
        tr = mon.trace()
        assert tr.shape == (2,)
        assert np.all(tr > 0)

    def test_traces_feed_ksplus_fit(self):
        """The closed loop: monitor traces become KS+ training data."""
        from repro.core import KSPlus
        rng = np.random.default_rng(0)
        mems, dts, inputs = [], [], []
        for i in range(6):
            base = read_rss_gb()
            trace = base + np.abs(rng.normal(0.1 * (i + 1), 0.01, 40))
            mems.append(trace)
            dts.append(0.5)
            inputs.append(float(i + 1))
        m = KSPlus(k=2)
        m.fit(mems, dts, inputs)
        plan = m.predict(3.0)
        assert plan.is_monotone() and plan.peaks[-1] > 0
