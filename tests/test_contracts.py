"""Runtime dispatch/compile contracts (``repro.analysis.contracts``).

The engine's performance story rests on three invariants that every
differential test is blind to — placements stay bitwise-identical
whether the engine compiles one program or one per event.  This suite
makes them fail loudly instead:

* **one program per drain** — a queue within ``DRAIN_CAP`` dispatches
  whole: one ``admission.drain`` dispatch per ``drain()`` call and zero
  new compiles once the pow2 buckets are warm;
* **bounded compiled-shape count under bucket routing** — a 200-task
  DAG replay whose dependency frontier wanders stays within a fixed
  compile budget, and a second replay with a different seed compiles
  NOTHING new (every frontier size lands in an already-compiled pow2/
  pow4 bucket);
* **zero rebuild on churn** — node join/leave never re-uploads the
  device-resident lane state; ``admission.dev_sync`` fires exactly once
  per replay (the initial upload).

Mechanics: compiles are counted through jax's monitoring hook (fires
once per backend compilation, never on a cache hit); dispatches are
self-reported by the engine's call sites via ``record_dispatch``.
"""

import numpy as np
import pytest

from repro.analysis.contracts import (Budget, DispatchBudgetError,
                                      dispatch_budget, record_dispatch)
from repro.core import RetrySpec
from repro.sched import ClusterSim, ElasticPlanner, FaultSchedule

from test_admission_fused import _mk_lanes, _mk_state, _storm_env
from test_cluster_packed import _nodes, _workload
from test_faults import _workload as _timed_workload


# ------------------------------------------------------- budget mechanics
class TestDispatchBudgetUnit:
    def test_compile_counting_and_cache_hits(self):
        import jax
        import jax.numpy as jnp
        jnp.ones(16).block_until_ready()  # warm implicit constructors

        fn = jax.jit(lambda x: x * 3 + 1)
        with dispatch_budget() as cold:
            fn(jnp.ones(16)).block_until_ready()
        assert cold.compiles == 1
        with dispatch_budget(compiles=0) as warm:
            fn(jnp.ones(16)).block_until_ready()
        assert warm.compiles == 0

    def test_compile_budget_violation_raises(self):
        import jax
        import jax.numpy as jnp
        with pytest.raises(DispatchBudgetError, match="compiled"):
            with dispatch_budget(compiles=0):
                jax.jit(lambda x: x - 7)(jnp.ones(4)).block_until_ready()

    def test_dispatch_tags_and_forbid(self):
        record_dispatch("t.outside")  # before the scope: not counted
        with dispatch_budget(dispatches=3, tags=("t.a",)) as b:
            record_dispatch("t.a", 2)
            record_dispatch("t.b", 5)  # untagged for this budget
        assert b.tag_counts["t.a"] == 2
        assert b.tag_counts["t.b"] == 5
        assert b.dispatches == 2
        with pytest.raises(DispatchBudgetError, match="forbidden"):
            with dispatch_budget(forbid=("t.boom",)):
                record_dispatch("t.boom")

    def test_dispatch_ceiling_violation(self):
        with pytest.raises(DispatchBudgetError, match="launched"):
            with dispatch_budget(dispatches=1):
                record_dispatch("t.c", 2)

    def test_budget_readable_after_exit(self):
        with dispatch_budget() as b:
            record_dispatch("t.after", 4)
        assert isinstance(b, Budget)
        assert b.tag_counts["t.after"] == 4
        assert b.violations() == []


# -------------------------------------------------- one program per drain
class TestOneProgramPerDrain:
    @staticmethod
    def _scripted_drains(seed=8, caps=(40.0, 20.0, 36.0)):
        """Deterministic drain sequence: admit 14 lanes, drain three
        times with a release in between — walks the empty AND occupied
        pow4 resident buckets."""
        adm = _mk_state("fused", caps=caps)
        lanes = _mk_lanes(adm, np.random.default_rng(seed), 14)
        placed = adm.drain(0.0, lanes)
        if placed:
            ji, ni = placed[0]
            adm.release(ni, ji)
        adm.drain(7.0, lanes)
        adm.drain(40.0, lanes)
        return adm

    def test_warm_drains_compile_nothing(self):
        """A second identically-shaped drain sequence on a FRESH state
        reuses every cached while-loop program: zero new compiles,
        exactly one ``admission.drain`` dispatch per ``drain()`` call.
        Values (caps, `now`, residency) change between the drains inside
        the scope; shapes are what the bucket routing must keep stable."""
        self._scripted_drains()  # warm every pow2/pow4 bucket the script hits
        with dispatch_budget(compiles=0) as b:
            adm = self._scripted_drains()
        assert b.tag_counts["admission.drain"] == 3
        assert adm.stats["drain_dispatches"] == adm.stats["drains"] == 3

    def test_different_caps_same_program(self):
        """Capacity values are operands, not shapes: once each scripted
        config has warmed its buckets, fresh states under either config
        compile nothing new."""
        self._scripted_drains(caps=(40.0, 20.0, 36.0))
        self._scripted_drains(caps=(24.0, 64.0, 18.0))
        with dispatch_budget(compiles=0) as b:
            self._scripted_drains(caps=(40.0, 20.0, 36.0))
            self._scripted_drains(caps=(24.0, 64.0, 18.0))
        assert b.compiles == 0
        assert b.tag_counts["admission.drain"] == 6

    def test_elastic_drain_shares_program(self):
        """ElasticPlanner's fused drain rides the same compiled program
        family; a scripted submit/churn run stays one dispatch per
        drain with no recompiles once warm."""
        def run(seed):
            rng = np.random.default_rng(seed)
            pl = ElasticPlanner(backend="fused")
            pl.node_join("n0", 48.0)
            pl.node_join("n1", 32.0)
            for step in range(12):
                pl.submit(f"j{step}",
                          _storm_env(rng, float(rng.uniform(6, 24))),
                          float(step))
            pl.drain(20.0)
            return pl

        run(0)  # warm every bucket this script reaches
        with dispatch_budget(compiles=0) as b:
            pl = run(0)  # same script, fresh planner: all shapes cached
        assert b.tag_counts["admission.drain"] >= 1
        del pl


# ------------------------------------- bounded shapes while frontier wanders
class TestBoundedShapesUnderWander:
    # Measured cold on jax 0.4.37 CPU: 14 compiles for the full replay
    # (drain program per queue bucket + columns + scatter + probe).  The
    # bound is deliberately loose — without pow2/pow4 bucketing the
    # wandering frontier compiles per distinct size and blows through it
    # by an order of magnitude.
    COLD_COMPILE_BUDGET = 40

    def _replay(self, seed):
        from repro.workloads import scenarios
        wf = scenarios.get("workload_replay", n_tasks=200, seed=seed)
        sim = ClusterSim(_nodes(), engine="fused", drain="device")
        return sim.run(wf.to_jobs(under_frac=0.2, seed=seed),
                       RetrySpec("ksplus"))

    def test_dag_frontier_compiles_stay_bucketed(self):
        with dispatch_budget(compiles=self.COLD_COMPILE_BUDGET) as cold:
            self._replay(seed=0)
        assert cold.tag_counts["admission.drain"] > 50  # frontier wandered
        # A different workload, same scenario family: every frontier
        # size lands in an already-compiled bucket.
        with dispatch_budget(compiles=0) as warm:
            self._replay(seed=3)
        assert warm.tag_counts["admission.drain"] > 50
        assert warm.compiles == 0


# --------------------------------------------------- zero rebuild on churn
class TestZeroRebuildOnChurn:
    def test_node_churn_never_resyncs_device_state(self):
        """Joins and leaves only change the next dispatch's operands;
        the packed lane buffers upload exactly once per replay."""
        faults = FaultSchedule.node_churn(_nodes(), rate=0.04,
                                          horizon=250.0, seed=5)
        sim = ClusterSim(_nodes(), engine="fused", drain="device")
        with dispatch_budget() as b:
            res = sim.run(_timed_workload(48, seed=5, under_frac=0.4),
                          RetrySpec("ksplus"), faults=faults)
        assert res.evictions > 0  # churn actually happened
        assert b.tag_counts["admission.dev_sync"] == 1
        assert b.tag_counts["admission.drain"] >= res.evictions // 2

    def test_storm_rejoin_no_rebuild(self):
        faults = FaultSchedule.preemption_storm(
            _nodes(), t=30.0, frac=0.9, seed=2, down_time=35.0)
        sim = ClusterSim(_nodes(), engine="fused", drain="device")
        with dispatch_budget(forbid=()) as b:
            res = sim.run(_timed_workload(40, seed=3, under_frac=0.5),
                          RetrySpec("ksplus"), faults=faults)
        assert res.evictions > 0
        assert b.tag_counts["admission.dev_sync"] == 1


# ------------------------------------------------------- serving contracts
class TestServeContracts:
    """The serving path's dispatch discipline (see repro.serve):

    * one ``serve.batch`` dispatch per bucket flush,
    * zero compiles on warm traffic (pow2 lane padding + per-snapshot
      trace residency bound the shape set),
    * ``serve.dev_sync`` fires once per (tenant, family, snapshot) and
      never again until a refit forks the snapshot.
    """

    def _warm_server(self, tenants=2):
        from repro.serve.bench import FAMILIES, build_server, request_tape

        srv = build_server(tenants=tenants, batching=True, max_batch=64,
                           seed=0)
        futs = [srv.submit("predict", t, f, x)
                for t, f, x in request_tape(128, tenants, seed=1)]
        srv.drain()
        [f.result(0) for f in futs]
        for t in range(tenants):
            client = srv.client(f"tenant{t}")
            for family, _ in FAMILIES:
                client.evaluate(family)
        srv.client("tenant0").tune_offset("align")
        return srv

    def test_warm_serve_zero_compiles_one_batch_per_bucket(self):
        from repro.serve.bench import FAMILIES, request_tape

        srv = self._warm_server()
        before = srv._batcher.stats["batches"]
        with dispatch_budget(compiles=0,
                             forbid=("serve.dev_sync",)) as warm:
            futs = [srv.submit("predict", t, f, x)
                    for t, f, x in request_tape(96, 2, seed=7)]
            srv.drain()
            [f.result(0) for f in futs]
            for t in range(2):
                client = srv.client(f"tenant{t}")
                for family, _ in FAMILIES:
                    client.evaluate(family)
            srv.client("tenant0").tune_offset("align")
        flushed_buckets = srv._batcher.stats["batches"] - before
        # exactly one serve.batch dispatch per bucket flush, nothing else
        assert warm.tag_counts["serve.batch"] == flushed_buckets
        assert warm.compiles == 0

    def test_dev_sync_once_per_snapshot_then_refit_scoped(self):
        import numpy as np

        from repro.core.predictor import ExecutionOutcome
        from repro.serve.bench import build_server

        srv = build_server(tenants=2, batching=True, seed=0)
        client = srv.client("tenant0")
        with dispatch_budget() as b:
            client.evaluate("align")
            client.evaluate("align")          # warm: resident traces
            srv.client("tenant1").evaluate("align")  # own (tenant, sid) key
        assert b.tag_counts["serve.dev_sync"] == 2
        client.observe("align", ExecutionOutcome(
            mem=np.full(40, 9.0), dt=1.0, input_gb=3.0, succeeded=True))
        assert client.refit("align")
        with dispatch_budget() as after:
            client.evaluate("align")          # forked sid: one new upload
            client.evaluate("align")
            srv.client("tenant1").evaluate("align")  # old sid: still warm
        assert after.tag_counts["serve.dev_sync"] == 1

    def test_cache_hit_tag_fires_on_submit_fast_path(self):
        from repro.serve.bench import build_server

        srv = build_server(tenants=1, batching=True, seed=0)
        client = srv.client("tenant0")
        client.predict("align", 2.0)
        with dispatch_budget() as b:
            assert client.predict("align", 2.0) is not None
        assert b.tag_counts["serve.cache_hit"] == 1
        assert b.tag_counts.get("serve.batch", 0) == 0  # no dispatch at all
