"""Fault-injection & arrivals: differential engine tests under churn.

The robustness contract extends the packed/fused differential suites to
time-structured workloads: under any seeded ``FaultSchedule`` and any
per-job release times, all three engines must produce bitwise-identical
decision logs (placements, retries, evictions, unschedulable, makespan)
and wastage/utilization within 1e-6 relative.  On top of that the fault
semantics themselves are pinned: eviction wastage, attempt accounting,
doomed-descendant breakouts, parking/starvation, and the loud unknown-
node errors.
"""

import numpy as np
import pytest

from repro.core import AllocationPlan, RetrySpec, ksplus_retry
from repro.sched import ClusterSim, FaultEvent, FaultSchedule, Job, Node
from repro.workloads import (
    SuiteCase,
    diurnal_arrivals,
    make_suite,
    poisson_arrivals,
    run_suite,
    suite_table,
    trace_arrivals,
)


def _nodes():
    return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0)]


def _workload(n_jobs=40, seed=0, under_frac=0.25, dt=1.0, arrivals=None):
    rng = np.random.default_rng(seed)
    jobs = []
    rel = np.zeros(n_jobs)
    if arrivals is not None:
        rel = arrivals(n_jobs)
    for j in range(n_jobs):
        L = int(rng.integers(24, 90))
        split = int(rng.uniform(0.4, 0.8) * L)
        lo = float(rng.uniform(1.5, 3.0))
        hi = float(rng.uniform(5.0, 11.0))
        mem = np.concatenate([np.full(split, lo), np.full(L - split, hi)])
        mem = mem * (1.0 + 0.02 * np.sin(np.arange(L)))
        under = rng.uniform() < under_frac
        scale = 0.9 if under else 1.12
        plan = AllocationPlan(
            starts=np.asarray([0.0, max(split * dt - 2.0, 1.0)]),
            peaks=np.asarray([lo * 1.15, hi * scale]))
        jobs.append(Job(jid=j, family="t", input_gb=1.0, mem=mem, dt=dt,
                        plan=plan, est_runtime=float(L * dt),
                        release_time=float(rel[j])))
    return jobs


def _dag_jobs(max_peak=20.0):
    """A parent with a 3-deep descendant chain plus independent fillers —
    the doom-on-eviction scenario (parent lands on node 0, first fit)."""
    def mk(jid, peak, L=20, parents=()):
        mem = np.full(L, peak * 0.8)
        return Job(jid=jid, family="t", input_gb=1.0, mem=mem, dt=1.0,
                   plan=AllocationPlan(np.zeros(1), np.asarray([peak])),
                   est_runtime=float(L), parents=tuple(parents))
    return [mk(0, max_peak, L=100), mk(1, 5.0, parents=(0,)),
            mk(2, 5.0, parents=(0,)), mk(3, 5.0, parents=(1,))]


def _assert_equivalent(a, b):
    assert b.placements == a.placements  # bitwise decision log
    assert b.retries == a.retries
    assert b.unschedulable == a.unschedulable
    assert b.evictions == a.evictions
    assert b.doomed == a.doomed
    assert b.starved == a.starved
    assert b.finished == a.finished
    assert b.makespan == a.makespan
    np.testing.assert_allclose(b.total_wastage_gbs, a.total_wastage_gbs,
                               rtol=1e-6)
    np.testing.assert_allclose(b.avg_utilization, a.avg_utilization,
                               rtol=1e-6)
    np.testing.assert_allclose(b.starvation_s, a.starvation_s, rtol=1e-6,
                               atol=1e-9)


def _run_three(jobs_builder, faults=None, **sim_kw):
    legacy = ClusterSim(_nodes(), engine="legacy", **sim_kw).run(
        jobs_builder(), ksplus_retry, faults=faults)
    packed = ClusterSim(_nodes(), engine="packed", **sim_kw).run(
        jobs_builder(), RetrySpec("ksplus"), faults=faults)
    fused = ClusterSim(_nodes(), engine="fused", **sim_kw).run(
        jobs_builder(), RetrySpec("ksplus"), faults=faults)
    return legacy, packed, fused


# ---------------------------------------------------------------- schedules
class TestFaultSchedule:
    def test_events_sorted_stably(self):
        fs = FaultSchedule([FaultEvent(5.0, "leave", 1),
                            FaultEvent(1.0, "leave", 0),
                            FaultEvent(5.0, "join", 2, 8.0)])
        assert [e.t for e in fs] == [1.0, 5.0, 5.0]
        assert [e.nid for e in fs] == [0, 1, 2]  # equal-t keeps input order

    def test_event_validation(self):
        with pytest.raises(ValueError, match="kind"):
            FaultEvent(1.0, "explode", 0)
        with pytest.raises(ValueError, match="finite"):
            FaultEvent(-1.0, "leave", 0)
        with pytest.raises(ValueError, match="capacity_gb"):
            FaultEvent(1.0, "join", 0)

    def test_validate_replays_membership(self):
        fs = FaultSchedule([FaultEvent(1.0, "leave", 0),
                            FaultEvent(2.0, "join", 0, 48.0)])
        fs.validate([0, 1])
        with pytest.raises(KeyError, match="node 7"):
            FaultSchedule([FaultEvent(1.0, "leave", 7)]).validate([0, 1])
        with pytest.raises(ValueError, match="already-active"):
            FaultSchedule([FaultEvent(1.0, "join", 0, 8.0)]).validate([0])

    def test_constructors_deterministic(self):
        a = FaultSchedule.preemption_storm(_nodes(), t=10.0, seed=3,
                                           down_time=5.0)
        b = FaultSchedule.preemption_storm(_nodes(), t=10.0, seed=3,
                                           down_time=5.0)
        assert a.events == b.events
        c = FaultSchedule.node_churn(_nodes(), rate=0.05, horizon=200.0,
                                     seed=1)
        d = FaultSchedule.node_churn(_nodes(), rate=0.05, horizon=200.0,
                                     seed=1)
        assert c.events == d.events
        assert c.events != FaultSchedule.node_churn(
            _nodes(), rate=0.05, horizon=200.0, seed=2).events

    def test_storm_and_churn_validate(self):
        nids = [n.nid for n in _nodes()]
        FaultSchedule.preemption_storm(_nodes(), t=10.0, frac=0.9, seed=0,
                                       down_time=3.0).validate(nids)
        FaultSchedule.node_churn(_nodes(), rate=0.1, horizon=300.0,
                                 seed=4).validate(nids)

    def test_rack_failure_groups(self):
        rack_of = {0: "a", 1: "b", 2: "a"}
        fs = FaultSchedule.rack_failure(_nodes(), rack_of, "a", t=7.0,
                                        down_time=2.0)
        kinds = [(e.kind, e.nid) for e in fs]
        assert kinds == [("leave", 0), ("leave", 2),
                         ("join", 0), ("join", 2)]
        with pytest.raises(ValueError, match="rack 'z'"):
            FaultSchedule.rack_failure(_nodes(), rack_of, "z", t=7.0)

    def test_add_merges(self):
        a = FaultSchedule([FaultEvent(5.0, "leave", 0)])
        b = FaultSchedule([FaultEvent(1.0, "leave", 1)])
        assert [e.nid for e in a + b] == [1, 0]


# ----------------------------------------------------------------- arrivals
class TestArrivals:
    def test_poisson_seeded_and_increasing(self):
        a = poisson_arrivals(64, rate=0.5, seed=9)
        assert np.array_equal(a, poisson_arrivals(64, rate=0.5, seed=9))
        assert (np.diff(a) > 0).all() and a[0] > 0

    def test_roots_only(self):
        parents = ((), (0,), (), (2,))
        a = poisson_arrivals(4, rate=1.0, seed=0, parents=parents)
        assert a[1] == 0.0 and a[3] == 0.0
        assert a[0] > 0 and a[2] > a[0]

    def test_diurnal_modulates(self):
        a = diurnal_arrivals(128, base_rate=1.0, period=120.0, depth=0.9,
                             seed=2)
        assert (np.diff(a) > 0).all()
        assert np.array_equal(a, diurnal_arrivals(
            128, base_rate=1.0, period=120.0, depth=0.9, seed=2))

    def test_trace_normalized_and_checked(self):
        a = trace_arrivals(3, [50.0, 10.0, 30.0])
        assert np.array_equal(a, [0.0, 20.0, 40.0])
        with pytest.raises(ValueError, match="root tasks"):
            trace_arrivals(5, [1.0, 2.0])

    def test_release_times_flow_into_jobs(self):
        from repro.workloads import scenarios, with_arrivals
        wf = scenarios.get("wide_fanout", n_tasks=24, seed=0)
        rel = poisson_arrivals(wf.B, rate=1.0, seed=5, parents=wf.parents)
        jobs = with_arrivals(wf, rel).to_jobs()
        assert [j.release_time for j in jobs] == list(rel)
        assert all(j.release_time == 0.0
                   for j in wf.to_jobs())  # original untouched


# ---------------------------------------------------------------- fail fast
class TestSubmitValidation:
    def test_oversized_attempt1_rejected_naming_ids(self):
        jobs = _workload(6, seed=1)
        jobs[2].plan = AllocationPlan(np.zeros(1), np.asarray([200.0]))
        jobs[5].plan = AllocationPlan(np.zeros(1), np.asarray([99.0]))
        with pytest.raises(ValueError, match=r"job ids \[2, 5\]"):
            ClusterSim(_nodes()).run(jobs, RetrySpec("ksplus"))

    def test_bad_release_time_rejected(self):
        jobs = _workload(3, seed=0)
        jobs[1].release_time = -2.0
        with pytest.raises(ValueError, match="release_time"):
            ClusterSim(_nodes()).run(jobs, RetrySpec("ksplus"))

    def test_legacy_engine_validates_too(self):
        jobs = _workload(3, seed=0)
        jobs[0].plan = AllocationPlan(np.zeros(1), np.asarray([500.0]))
        with pytest.raises(ValueError, match="job ids"):
            ClusterSim(_nodes(), engine="legacy").run(jobs, ksplus_retry)


# ------------------------------------------------------------- differential
class TestDifferentialUnderFaults:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_storm_matches_across_engines(self, seed):
        faults = FaultSchedule.preemption_storm(
            _nodes(), t=30.0, frac=0.67, seed=seed, down_time=40.0)
        legacy, packed, fused = _run_three(
            lambda: _workload(40, seed=seed), faults=faults)
        assert legacy.evictions > 0
        _assert_equivalent(legacy, packed)
        _assert_equivalent(legacy, fused)

    def test_churn_matches_across_engines(self):
        faults = FaultSchedule.node_churn(_nodes(), rate=1.0 / 40.0,
                                          horizon=400.0, seed=7,
                                          mean_down=30.0)
        legacy, packed, fused = _run_three(
            lambda: _workload(48, seed=3), faults=faults)
        assert legacy.evictions > 0
        _assert_equivalent(legacy, packed)
        _assert_equivalent(legacy, fused)

    def test_arrivals_plus_storm_matches(self):
        arrivals = lambda n: poisson_arrivals(n, rate=0.4, seed=11)
        faults = FaultSchedule.preemption_storm(
            _nodes(), t=40.0, frac=0.67, seed=5, down_time=50.0)
        legacy, packed, fused = _run_three(
            lambda: _workload(40, seed=2, arrivals=arrivals), faults=faults)
        assert min(t for t, _, _ in legacy.placements) > 0.0
        _assert_equivalent(legacy, packed)
        _assert_equivalent(legacy, fused)

    def test_eviction_wastage_stops_at_kill_time(self):
        """One job, one eviction at a known time: wastage is the plan
        area over the elapsed whole samples, in every engine."""
        def build():
            mem = np.full(60, 8.0)
            return [Job(jid=0, family="t", input_gb=1.0, mem=mem, dt=1.0,
                        plan=AllocationPlan(np.zeros(1), np.asarray([10.0])),
                        est_runtime=60.0)]
        faults = [FaultEvent(10.5, "leave", 0)]
        legacy, packed, fused = _run_three(build, faults=faults)
        _assert_equivalent(legacy, packed)
        _assert_equivalent(legacy, fused)
        # 10 whole samples of the 10 GB envelope + the retried full run
        assert legacy.evictions == 1 and legacy.finished == 1
        assert legacy.total_wastage_gbs >= 10 * 10.0

    def test_no_faults_keeps_prior_results(self):
        """faults=None must be byte-for-byte the pre-fault code path —
        including the closed-form utilization denominator."""
        base = ClusterSim(_nodes()).run(_workload(40, seed=4),
                                        RetrySpec("ksplus"))
        with_none = ClusterSim(_nodes()).run(_workload(40, seed=4),
                                             RetrySpec("ksplus"),
                                             faults=None)
        assert base.placements == with_none.placements
        assert base.avg_utilization == with_none.avg_utilization
        assert base.total_wastage_gbs == with_none.total_wastage_gbs


# --------------------------------------------------------- doom on eviction
class TestDoomOnEviction:
    @pytest.mark.parametrize("backend", ["numpy", "fused"])
    def test_parent_evicted_mid_storm_dooms_descendants(self, backend):
        """Parent loses its node twice (max_attempts=2): attempt budget
        exhausts through evictions alone and the whole descendant chain
        is doomed — same counts in the fused engine on both admission
        backends as in the legacy oracle."""
        faults = (FaultEvent(10.0, "leave", 0), FaultEvent(30.0, "leave", 1))
        legacy = ClusterSim(_nodes(), engine="legacy", max_attempts=2).run(
            _dag_jobs(), ksplus_retry, faults=FaultSchedule(faults))
        assert legacy.evictions == 2
        assert legacy.doomed == 3          # both children + grandchild
        assert legacy.unschedulable == 4   # parent + doomed descendants
        assert legacy.finished == 0
        sim = ClusterSim(_nodes(), engine="fused", max_attempts=2)
        fused = sim._run_fused(_dag_jobs(), RetrySpec("ksplus"), None, None,
                               True, admission_backend=backend,
                               faults=faults)
        _assert_equivalent(legacy, fused)

    def test_surviving_parent_releases_children(self):
        """With a rejoin before the second kill, the parent survives on
        its remaining attempts and the chain completes."""
        faults = FaultSchedule([FaultEvent(10.0, "leave", 0),
                                FaultEvent(50.0, "join", 0, 48.0)])
        legacy, packed, fused = _run_three(lambda: _dag_jobs(),
                                           faults=faults)
        assert legacy.finished == 4 and legacy.doomed == 0
        _assert_equivalent(legacy, packed)
        _assert_equivalent(legacy, fused)


# ------------------------------------------------------ parking / starvation
class TestParking:
    def test_unfittable_job_parks_until_join(self):
        def build():
            def mk(jid, peak, L):
                return Job(jid=jid, family="t", input_gb=1.0,
                           mem=np.full(L, peak * 0.8), dt=1.0,
                           plan=AllocationPlan(np.zeros(1),
                                               np.asarray([peak])),
                           est_runtime=float(L))
            return [mk(0, 40.0, 50), mk(1, 10.0, 30)]
        faults = FaultSchedule([FaultEvent(5.0, "leave", 0),
                                FaultEvent(5.0, "leave", 1),
                                FaultEvent(100.0, "join", 1, 64.0)])
        legacy, packed, fused = _run_three(build, faults=faults)
        assert legacy.starvation_s > 0      # the 40 GB job waited
        assert legacy.finished == 2         # ...but completed after join
        _assert_equivalent(legacy, packed)
        _assert_equivalent(legacy, fused)

    def test_never_rejoined_job_counts_starved(self):
        def build():
            return [Job(jid=0, family="t", input_gb=1.0,
                        mem=np.full(30, 30.0), dt=1.0,
                        plan=AllocationPlan(np.zeros(1), np.asarray([40.0])),
                        est_runtime=30.0)]
        faults = FaultSchedule([FaultEvent(5.0, "leave", 0),
                                FaultEvent(5.0, "leave", 1)])
        legacy, packed, fused = _run_three(build, faults=faults)
        assert legacy.starved == 1 and legacy.finished == 0
        assert legacy.unschedulable == 0    # parked, not failed
        _assert_equivalent(legacy, packed)
        _assert_equivalent(legacy, fused)


# ------------------------------------------------------------- loud errors
class TestUnknownNode:
    @pytest.mark.parametrize("engine", ["legacy", "packed", "fused"])
    def test_leave_unknown_node_raises(self, engine):
        retry = ksplus_retry if engine == "legacy" else RetrySpec("ksplus")
        with pytest.raises(KeyError, match="node 77"):
            ClusterSim(_nodes(), engine=engine).run(
                _workload(6, seed=0), retry,
                faults=[FaultEvent(5.0, "leave", 77)])

    @pytest.mark.parametrize("engine", ["legacy", "packed", "fused"])
    def test_double_leave_raises(self, engine):
        retry = ksplus_retry if engine == "legacy" else RetrySpec("ksplus")
        with pytest.raises(KeyError, match="node 0"):
            ClusterSim(_nodes(), engine=engine).run(
                _workload(6, seed=0), retry,
                faults=[FaultEvent(5.0, "leave", 0),
                        FaultEvent(6.0, "leave", 0)])

    @pytest.mark.parametrize("engine", ["legacy", "packed", "fused"])
    def test_join_active_node_raises(self, engine):
        retry = ksplus_retry if engine == "legacy" else RetrySpec("ksplus")
        with pytest.raises(ValueError, match="already active"):
            ClusterSim(_nodes(), engine=engine).run(
                _workload(6, seed=0), retry,
                faults=[FaultEvent(5.0, "join", 1, 8.0)])


# ------------------------------------------------------------------- suite
class TestSuite:
    def test_grid_shape_and_names(self):
        cases = make_suite(seeds=(0, 1))
        assert len(cases) == 3 * 3 * 3 * 2
        assert cases[0].name == "burst_arrival/none/none/s0"
        with pytest.raises(KeyError):
            make_suite(scenarios=("nope",))
        with pytest.raises(ValueError):
            make_suite(faults=("quake",))

    def test_smoke_grid_checks_oracle(self):
        cases = [SuiteCase("burst_arrival", "poisson", "storm", seed=0),
                 SuiteCase("deep_chain", "none", "churn", seed=0),
                 SuiteCase("wide_fanout", "diurnal", "none", seed=0)]
        rows = run_suite(cases, n_tasks=32, check_oracle=True)
        assert [r["case"] for r in rows] == [c.name for c in cases]
        assert all(r["finished"] + r["unschedulable"] + r["starved"]
                   == r["jobs"] for r in rows)
        table = suite_table(rows)
        assert "burst_arrival/poisson/storm/s0" in table
        assert "evictions" in table.splitlines()[0]
