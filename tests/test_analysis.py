"""The JAX-aware lint pass (``repro.analysis``): rules, suppressions,
baseline ratchet, and the repo-wide dogfood gate.

Rule tests run the real driver over synthetic fixture modules written to
``tmp_path`` — each fixture isolates one hazard shape the repo actually
uses (kernel factories, donated buffers, ``enable_x64`` scoping, static
float args) plus the clean twin that must NOT be flagged.  The dogfood
test pins the acceptance criterion directly: ``python -m repro.analysis
src/`` exits 0 against the committed baseline.
"""

import json
import os

import pytest

from repro.analysis.lint import (LintConfig, apply_baseline, load_baseline,
                                 main as lint_main, run_lint,
                                 write_baseline)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lint_src(tmp_path, source, config=None, name="mod.py"):
    p = tmp_path / name
    p.write_text(source)
    active, suppressed, _ = run_lint([str(p)], config=config)
    return active, suppressed


def _by_rule(findings, rule):
    return [f for f in findings if f.rule == rule]


# --------------------------------------------------------- use-after-donation
class TestUseAfterDonation:
    def test_read_after_donating_call_flagged(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
import functools
import jax

@functools.partial(jax.jit, donate_argnums=(0,))
def upd(buf, x):
    return buf + x

def bad(b, x):
    out = upd(b, x)
    return b + out

def good(b, x):
    b = upd(b, x)
    return b + 1
""")
        found = _by_rule(active, "use-after-donation")
        assert len(found) == 1
        assert "`b`" in found[0].message and "upd" in found[0].message

    def test_factory_kernel_and_same_statement_rebind(self, tmp_path):
        """The repo's `_KERNEL_CACHE` idiom: a factory returns an inner
        jitted def with donations; call sites bind it to a local name.
        Rebinding in the donating statement itself is the safe pattern."""
        active, _ = _lint_src(tmp_path, """
import functools
import jax

def _scatter_fn():
    @functools.partial(jax.jit, donate_argnums=(0,))
    def scatter(buf, rows, vals):
        return buf.at[rows].set(vals)
    return scatter

class State:
    def safe(self, rows, vals):
        scatter = _scatter_fn()
        self._dbuf = scatter(self._dbuf, rows, vals)
        return self._dbuf

    def leak(self, rows, vals):
        scatter = _scatter_fn()
        out = scatter(self._dbuf, rows, vals)
        return self._dbuf.sum() + out.sum()
""")
        found = _by_rule(active, "use-after-donation")
        assert len(found) == 1
        assert "self._dbuf" in found[0].message

    def test_rebind_on_next_line_is_safe(self, tmp_path):
        """The drain idiom: donate, unpack fresh buffers, rebind before
        any read."""
        active, _ = _lint_src(tmp_path, """
import functools
import jax

@functools.partial(jax.jit, donate_argnums=(2,))
def kernel(a, b, admit):
    return a, admit * 2

class S:
    def drain(self):
        out, admit_new = kernel(self._a, self._b, self._dadmit)
        self._dadmit = admit_new
        return out
""")
        assert _by_rule(active, "use-after-donation") == []


# ----------------------------------------------------- host-sync-in-hot-path
_SYNC_CFG = LintConfig(entry_points=((None, "loop"),), allow_paths=(),
                       allow_funcs=("bench_",))


class TestHostSyncInHotPath:
    SRC = """
import jax
import numpy as np

@jax.jit
def step(x):
    return x * 2

def helper(x):
    y = step(x)
    return np.asarray(y)

def loop(x):
    for _ in range(3):
        x = helper(x)
    v = step(x)
    return v.item()

def bench_probe(x):
    return np.asarray(step(x))

def unreachable(x):
    y = step(x)
    return np.asarray(y)
"""

    def test_reachable_syncs_flagged_allowlist_respected(self, tmp_path):
        active, _ = _lint_src(tmp_path, self.SRC, config=_SYNC_CFG)
        found = _by_rule(active, "host-sync-in-hot-path")
        msgs = sorted(f.message for f in found)
        assert len(found) == 2, msgs
        assert any("np.asarray" in m for m in msgs)  # helper (reachable)
        assert any(".item()" in m for m in msgs)     # loop (entry itself)
        # bench_ prefix and the unreachable function stay silent

    def test_bound_method_dispatch_counts_as_reachable(self, tmp_path):
        """``engine = self._run; engine(x)`` must not hide the callee."""
        active, _ = _lint_src(tmp_path, """
import jax
import numpy as np

@jax.jit
def step(x):
    return x + 1

class Sim:
    def loop(self, x):
        engine = self._run
        return engine(x)

    def _run(self, x):
        v = step(x)
        return float(v)
""", config=LintConfig(entry_points=(("Sim", "loop"),), allow_paths=(),
                       allow_funcs=()))
        found = _by_rule(active, "host-sync-in-hot-path")
        assert len(found) == 1 and "float" in found[0].message

    def test_device_get_is_a_declared_sync(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
import jax

@jax.jit
def step(x):
    return x

def loop(x):
    return jax.device_get(step(x))
""", config=_SYNC_CFG)
        assert len(_by_rule(active, "host-sync-in-hot-path")) == 1


# ------------------------------------------------------------------ x64-scope
class TestX64Scope:
    def test_outside_scope_flagged_inside_clean(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

def good():
    with enable_x64():
        return jnp.zeros(4, jnp.float64)

def bad():
    a = jnp.asarray([1.0], dtype="float64")
    return a + jnp.float64(2.0)
""")
        found = _by_rule(active, "x64-scope")
        assert len(found) == 2
        assert all(f.line >= 10 for f in found)  # both in bad()

    def test_runtime_guard_suppresses(self, tmp_path):
        """predictor.py idiom: dtype picked off jax.config at runtime."""
        active, _ = _lint_src(tmp_path, """
import jax
import jax.numpy as jnp

def pick():
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    return dtype
""")
        assert _by_rule(active, "x64-scope") == []

    def test_pure_numpy_module_ignored(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
import numpy as np

PAD = np.float64(1e30)

def host_math(x):
    return np.asarray(x, np.float64)
""")
        assert _by_rule(active, "x64-scope") == []


# ----------------------------------------------- tracer-unsafe control flow
class TestTracerUnsafeControlFlow:
    def test_branch_on_jit_result_flagged(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
import jax

@jax.jit
def pred(x):
    return x > 0

def bad(x):
    flag = pred(x)
    if flag:
        return 1
    return 0

def converted(x):
    flag = pred(x)
    if bool(flag):
        return 1
    return 0

def host_only(x):
    n = len(x)
    while n > 0:
        n -= 1
    return n
""")
        found = _by_rule(active, "tracer-unsafe-control-flow")
        assert len(found) == 1
        assert "`flag`" in found[0].message and "`if`" in found[0].message

    def test_while_on_jit_result_flagged(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
import jax

@jax.jit
def step(x):
    return x - 1

def bad(x):
    x = step(x)
    while x:
        x = step(x)
    return x
""")
        found = _by_rule(active, "tracer-unsafe-control-flow")
        assert found and "`while`" in found[0].message


# ----------------------------------------------------------- recompile-hazard
class TestRecompileHazard:
    def test_float_static_arg_flagged(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
import functools
import jax

@functools.partial(jax.jit, static_argnames=("dt", "mode"))
def f(x, *, dt: float = 1.0, mode: str = "a"):
    return x * dt
""")
        found = _by_rule(active, "recompile-hazard")
        assert len(found) == 1
        assert "`dt: float`" in found[0].message  # mode: str is fine

    def test_unhashable_static_arg_flagged(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
import functools
import jax

@functools.partial(jax.jit, static_argnums=(1,))
def g(x, opts: list):
    return x
""")
        found = _by_rule(active, "recompile-hazard")
        assert found and "unhashable" in found[0].message

    def test_raw_len_shape_feeding_jit_flagged(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
import jax
import jax.numpy as jnp
import numpy as np

@jax.jit
def run(buf):
    return buf.sum()

def _bucket(n, lo=8):
    return max(lo, 1 << (n - 1).bit_length())

def bad(xs):
    buf = np.zeros((len(xs), 4))
    return run(jnp.asarray(buf))

def good(xs):
    buf = np.zeros((_bucket(len(xs)), 4))
    return run(jnp.asarray(buf))
""")
        found = _by_rule(active, "recompile-hazard")
        assert len(found) == 1
        assert "`buf`" in found[0].message and "len()" in found[0].message


# ---------------------------------------------------- suppressions + baseline
class TestSuppressionsAndBaseline:
    SRC = """
import jax

@jax.jit
def step(x):
    return x

def loop(x):
    y = step(x)
    a = float(y)  # lint: allow[host-sync-in-hot-path] readback is the API
    # lint: allow[host-sync-in-hot-path] standalone comment form
    b = float(y)
    c = float(y)
    return a + b + c
"""

    def test_inline_allow_suppresses_with_reason(self, tmp_path):
        active, suppressed = _lint_src(tmp_path, self.SRC, config=_SYNC_CFG)
        assert len(suppressed) == 2  # same-line and next-line forms
        remaining = _by_rule(active, "host-sync-in-hot-path")
        assert len(remaining) == 1  # the un-suppressed float(y)

    def test_bare_allow_is_itself_a_finding(self, tmp_path):
        active, _ = _lint_src(tmp_path, """
def f():
    return 1  # lint: allow[x64-scope]
""")
        found = _by_rule(active, "bare-suppression")
        assert found and "justification" in found[0].message

    def test_wrong_rule_allow_does_not_suppress(self, tmp_path):
        active, suppressed = _lint_src(tmp_path, """
import jax

@jax.jit
def step(x):
    return x

def loop(x):
    y = step(x)
    return float(y)  # lint: allow[x64-scope] wrong rule named
""", config=_SYNC_CFG)
        assert suppressed == []
        assert len(_by_rule(active, "host-sync-in-hot-path")) == 1

    def test_baseline_ratchet(self, tmp_path):
        active, _ = _lint_src(tmp_path, self.SRC, config=_SYNC_CFG)
        findings = _by_rule(active, "host-sync-in-hot-path")
        assert len(findings) == 1
        key = findings[0].key

        # equal count -> clean; over -> new; under -> stale
        new, baselined, stale = apply_baseline(
            findings, {key: {"count": 1, "why": "pinned"}})
        assert new == [] and baselined == [key] and stale == []
        new, _, _ = apply_baseline(findings, {})
        assert new == findings
        new, _, stale = apply_baseline(
            findings, {key: {"count": 3, "why": "was worse"}})
        assert new == [] and len(stale) == 1 and "shrink" in stale[0]

    def test_write_and_load_roundtrip(self, tmp_path):
        active, _ = _lint_src(tmp_path, self.SRC, config=_SYNC_CFG)
        findings = _by_rule(active, "host-sync-in-hot-path")
        bpath = tmp_path / "baseline.json"
        write_baseline(str(bpath), findings,
                       {findings[0].key: {"count": 9, "why": "kept"}})
        data = load_baseline(str(bpath))
        assert data[findings[0].key] == {"count": 1, "why": "kept"}
        raw = json.loads(bpath.read_text())
        assert raw["_comment"]  # self-describing file


# ----------------------------------------------------------------- dogfooding
class TestDogfood:
    def test_repo_src_exits_zero(self, monkeypatch):
        """Acceptance criterion: `python -m repro.analysis src/` is clean
        against the committed baseline — and strictly so (no stale
        entries; the ratchet is tight)."""
        monkeypatch.chdir(REPO_ROOT)
        assert lint_main(["src", "--strict"]) == 0

    def test_repo_findings_all_have_reasons(self, monkeypatch):
        monkeypatch.chdir(REPO_ROOT)
        baseline = load_baseline("analysis_baseline.json")
        assert baseline  # the intentional findings are recorded
        for key, entry in baseline.items():
            assert entry["why"] and not entry["why"].startswith("TODO"), key

    def test_list_rules_runs(self, capsys):
        assert lint_main(["--list-rules"]) == 0
        out = capsys.readouterr().out
        for rule_name in ("use-after-donation", "host-sync-in-hot-path",
                          "x64-scope", "tracer-unsafe-control-flow",
                          "recompile-hazard", "unguarded-obs-in-hot-path"):
            assert rule_name in out

    def test_new_finding_fails_the_gate(self, tmp_path, monkeypatch):
        p = tmp_path / "regression.py"
        # `simulate_fleet_many` is one of the default entry roots, so
        # the sync is in the hot path under the shipped config.
        p.write_text("""
import jax

@jax.jit
def step(x):
    return x

def simulate_fleet_many(x):
    return step(x).item()
""")
        monkeypatch.chdir(tmp_path)
        rc = lint_main([str(p), "--baseline", str(tmp_path / "none.json")])
        assert rc == 1


# ---------------------------------------------------- unguarded-obs-in-hot-path
_OBS_CFG = LintConfig(entry_points=((None, "loop"),), allow_paths=(),
                      allow_funcs=("bench_",))


class TestUnguardedObsInHotPath:
    SRC = """
from repro.obs import metrics as _met
from repro.obs import trace as _obs

def helper():
    _obs.instant("tick")          # reachable via loop -> flagged

def loop(x):
    helper()
    with _obs.span("work"):       # unguarded -> flagged
        x = x + 1
    if _obs.enabled:
        _met.counter("c").inc()   # guarded -> clean
        with _obs.span("ok") as sp:
            sp.add(n=1)
    return x

def unreachable(x):
    _met.gauge("g").set(x)        # not in the hot path -> silent

def bench_loop(x):
    _obs.instant("bench")         # allow_funcs prefix -> silent
"""

    def test_unguarded_calls_flagged_guarded_clean(self, tmp_path):
        active, _ = _lint_src(tmp_path, self.SRC, config=_OBS_CFG)
        found = _by_rule(active, "unguarded-obs-in-hot-path")
        msgs = sorted(f.message for f in found)
        assert len(found) == 2, msgs
        assert any("_obs.instant" in m and "helper" in m for m in msgs)
        assert any("_obs.span" in m and "loop" in m for m in msgs)

    def test_obs_subsystem_itself_exempt(self, tmp_path):
        sub = tmp_path / "repro" / "obs"
        sub.mkdir(parents=True)
        p = sub / "trace.py"
        p.write_text("""
def span(name):
    import trace
    trace.instant("self")
""")
        active, _, _ = run_lint([str(p)], config=_OBS_CFG)
        assert _by_rule(active, "unguarded-obs-in-hot-path") == []

    def test_dogfooded_instrumentation_is_guarded(self):
        """The repo's own hot-path instrumentation must satisfy the rule
        it ships — the shipped entry points cover cluster/admission/
        fleet/serve."""
        paths = [os.path.join(REPO_ROOT, "src", "repro", p) for p in
                 ("sched/cluster.py", "sched/admission.py",
                  "core/fleet.py", "serve/batcher.py", "serve/server.py")]
        active, _, _ = run_lint(paths)
        assert _by_rule(active, "unguarded-obs-in-hot-path") == []
