"""Per-architecture smoke tests + prefill/decode consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, smoke_config
from repro.models import (
    decode_step,
    forward_train,
    init_params,
    param_shapes,
    prefill,
)

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(cfg, rng, seq=S, batch=B):
    if cfg.family in ("vlm", "audio"):
        out = {"embeds": jnp.asarray(
            rng.standard_normal((batch, seq, cfg.d_model)), jnp.float32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)}
        if cfg.mrope_sections:
            out["positions"] = jnp.broadcast_to(
                jnp.arange(seq, dtype=jnp.int32)[None, :, None],
                (batch, seq, 3))
        return out
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one forward/train step, output shapes + no NaNs."""
    cfg = smoke_config(arch)
    rng = np.random.default_rng(1)
    params = init_params(cfg, KEY)
    loss, metrics = jax.jit(
        lambda p, b: forward_train(p, cfg, b))(params, _batch(cfg, rng))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # parameter count of the full config matches the declared family scale
    full = get_config(arch)
    n = full.params_count()
    assert n > 1e8, (arch, n)


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).is_encoder_only])
def test_prefill_decode_matches_forward(arch):
    """decode(pos=S) after prefill(S) == forward over S+1 tokens."""
    cfg = smoke_config(arch)
    rng = np.random.default_rng(2)
    params = init_params(cfg, KEY)
    seq = 32
    full = _batch(cfg, rng, seq=seq + 1, batch=1)

    def head_only(b):
        from repro.models.model import _embed_inputs, _forward_seq, \
            _head_logits, _default_positions
        h = _embed_inputs(params, cfg, b)
        pos = b.get("positions")
        if pos is None:
            pos = _default_positions(cfg, 1, seq + 1)
        h, _, _ = _forward_seq(params, cfg, h, pos, collect_cache=False)
        return _head_logits(params, cfg, h)

    logits_full = head_only({k: v for k, v in full.items() if k != "labels"})

    pre = {k: v[:, :seq] for k, v in full.items() if k != "labels"}
    _, cache = prefill(params, cfg, pre, capacity=seq + 4)
    if cfg.family == "vlm":
        db = {"embeds": full["embeds"][:, seq:seq + 1]}
    else:
        db = {"tokens": full["tokens"][:, seq]}
    logits_dec, _ = decode_step(params, cfg, db, cache,
                                jnp.full((1,), seq, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_dec[0, 0], np.float32),
        np.asarray(logits_full[0, seq], np.float32),
        atol=3e-2, rtol=3e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_shapes(arch):
    """Full configs build ShapeDtypeStruct trees without allocation."""
    cfg = get_config(arch)
    shapes = param_shapes(cfg)
    total = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes))
    declared = cfg.params_count()
    assert abs(total - declared) / declared < 0.05, (arch, total, declared)


def test_qk_norm_changes_output():
    cfg = smoke_config("qwen3-1.7b")
    assert cfg.qk_norm
    cfg_off = dataclasses.replace(cfg, qk_norm=False)
    rng = np.random.default_rng(3)
    batch = _batch(cfg, rng)
    p_on = init_params(cfg, KEY)
    loss_on, _ = forward_train(p_on, cfg, batch)
    # same params minus the norm scales
    p_off = {k: v for k, v in p_on.items()}
    p_off["blocks"] = jax.tree.map(lambda x: x, p_on["blocks"])
    p_off["blocks"]["attn"] = {
        k: v for k, v in p_on["blocks"]["attn"].items()
        if k not in ("q_norm", "k_norm")}
    loss_off, _ = forward_train(p_off, cfg_off, batch)
    assert not np.isclose(float(loss_on), float(loss_off))


def test_moe_routing_properties():
    from repro.models.moe import moe_block, moe_capacity
    cfg = smoke_config("olmoe-1b-7b")
    rng = np.random.default_rng(4)
    d, E, ff = 32, 8, 64
    x = jnp.asarray(rng.standard_normal((2, 16, d)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((d, E)) * 0.1, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((E, d, ff)) * 0.05, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((E, d, ff)) * 0.05, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((E, ff, d)) * 0.05, jnp.float32)
    y, aux = moe_block(x, router, wg, wu, wd, topk=2, capacity_factor=2.0)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["moe_dropped_frac"]) <= 0.5
    assert moe_capacity(1024, 8, 2, 1.25) % 8 == 0


def test_mamba_chunk_invariance():
    """SSD output must not depend on the chunk size."""
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.default_rng(5)
    B_, S_, H, P, G, N = 1, 64, 2, 8, 1, 8
    X = jnp.asarray(rng.standard_normal((B_, S_, H, P)) * 0.5, jnp.float32)
    A = jnp.asarray(-np.abs(rng.standard_normal((B_, S_, H))) * 0.3,
                    jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((B_, S_, G, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((B_, S_, G, N)) * 0.5, jnp.float32)
    y16, s16 = ssd_chunked(X, A, Bm, Cm, 16)
    y64, s64 = ssd_chunked(X, A, Bm, Cm, 64)
    np.testing.assert_allclose(y16, y64, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(s16, s64, atol=1e-4, rtol=1e-4)


def test_scan_vs_unroll_equivalence():
    """scan_layers=False must produce equivalent losses (dry-run validity).

    Not bitwise: the residual stream is bfloat16, and XLA rounds
    intermediates at different fusion boundaries in the scan-compiled body
    vs the inlined unroll.  Matmul/attention accumulation is already
    float32 (``preferred_element_type``, f32 online-softmax state), so the
    remaining divergence is one bf16 ulp per layer injected into the
    carry: measured, a single block already differs by 2^-12 absolute
    (~2.4e-4 at unit hidden-state scale) and the end-to-end loss drifts by
    ~1.2e-5 relative.  rtol=1e-4 keeps ~8x margin over that measured
    drift while still catching any semantic divergence, which would shift
    the loss by far more than 1e-4.
    """
    cfg = smoke_config("llama3-8b")
    rng = np.random.default_rng(6)
    batch = _batch(cfg, rng)
    params = init_params(cfg, KEY)
    l1, _ = forward_train(params, cfg, batch)
    cfg_u = dataclasses.replace(cfg, scan_layers=False)
    l2, _ = forward_train(params, cfg_u, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)
