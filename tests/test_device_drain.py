"""Device-resident drain: differential + dispatch-accounting coverage.

The fused engine's default ``drain="device"`` path folds the whole
greedy admission loop — fits refresh, (queue, node)-order argmax,
residual scatter, repeat — into ONE jitted dispatch per event
(:meth:`repro.sched.admission.AdmissionState.drain`).  This suite pins
it three ways:

* ``AdmissionState.drain`` unit level — fused placements must equal the
  numpy host drain *bitwise* for both node-selection rules
  (``"first"``/``"headroom"``), with and without durations, across
  repeated drains, and the post-drain fits cache must stay
  oracle-fresh;
* engine level — ``ClusterSim(drain="device")`` must reproduce the host
  fused drain's decision log bitwise (and the legacy engine's wastage to
  1e-6) under DAG replay, churn/storm fault schedules, offset sweeps,
  parking/starvation, and joins landing mid-drain;
* scaling level — a ≥2-shard ``shard_map`` drain (subprocess with forced
  host devices, same idiom as ``test_moe_distributed``) must match the
  unsharded device drain and the numpy drain decision-for-decision.

Dispatch accounting rides along: ``AdmissionState.stats`` must report
exactly one dispatch per device drain — the tentpole's whole point.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import AllocationPlan, RetrySpec
from repro.sched import (
    ClusterSim,
    ElasticPlanner,
    FaultEvent,
    FaultSchedule,
    Job,
    Node,
    OffsetCandidate,
)
from repro.sched.admission import AdmissionState

from test_admission_fused import (
    _assert_same,
    _mk_lanes,
    _mk_state,
    _scratch_fits,
    _storm_env,
)
from test_cluster_packed import _nodes, _workload
from test_faults import _workload as _timed_workload


def _host_sim(**kw):
    return ClusterSim(_nodes(), engine="fused", drain="host", **kw)


def _dev_sim(**kw):
    return ClusterSim(_nodes(), engine="fused", drain="device", **kw)


# ------------------------------------------------------------- unit level
class TestDrainUnit:
    @pytest.mark.parametrize("select", ["first", "headroom"])
    @pytest.mark.parametrize("use_dur", [True, False])
    def test_fused_matches_numpy_host_drain(self, select, use_dur):
        rng = np.random.default_rng(0)
        out = {}
        for backend in ("numpy", "fused"):
            r = np.random.default_rng(7)
            adm = _mk_state(backend, caps=(32.0, 48.0, 24.0),
                            use_dur=use_dur)
            lanes = _mk_lanes(adm, r, 14)
            out[backend] = adm.drain(3.0, lanes, select=select)
        assert out["fused"] == out["numpy"]
        assert len(out["fused"]) > 0
        del rng

    def test_repeated_drains_and_cache_coherence(self):
        """Drain, mutate residency, drain again — the device path must
        keep agreeing with the host drain AND leave the shared fits
        cache in a state the invalidation protocol can serve fresh."""
        states = {}
        for backend in ("numpy", "fused"):
            rng = np.random.default_rng(11)
            adm = _mk_state(backend, caps=(24.0, 40.0))
            lanes = _mk_lanes(adm, rng, 16)
            states[backend] = (adm, list(lanes))
        placed0 = {}
        for backend, (adm, lanes) in states.items():
            placed0[backend] = adm.drain(0.0, lanes)
        assert placed0["fused"] == placed0["numpy"]
        done = {ji for ji, _ in placed0["fused"]}
        rest = [ji for ji in states["fused"][1] if ji not in done]
        for backend, (adm, _) in states.items():
            # release one resident, advance time, drain the remainder
            ji, ni = placed0[backend][0]
            adm.release(ni, ji)
            placed0[backend] = adm.drain(9.0, rest + [ji])
        assert placed0["fused"] == placed0["numpy"]
        adm, lanes = states["fused"]
        np.testing.assert_array_equal(
            adm.columns(9.0, lanes), _scratch_fits(adm, 9.0, lanes))

    def test_one_dispatch_per_drain(self):
        rng = np.random.default_rng(3)
        adm = _mk_state("fused")
        lanes = _mk_lanes(adm, rng, 12)
        remaining = list(lanes)
        for now in (0.0, 5.0, 50.0):
            placed = adm.drain(now, remaining)
            done = {ji for ji, _ in placed}
            remaining = [ji for ji in remaining if ji not in done]
        assert adm.stats["drains"] == 3
        # Queues within DRAIN_CAP go straight into the program, whole:
        # exactly ONE dispatch per drain, multi-placement or empty.
        assert adm.stats["drain_dispatches"] == adm.stats["drains"]

    def test_wide_queue_prefilter_caps_dispatch(self):
        # Above DRAIN_CAP the drain pre-filters candidates through the
        # cached columns and dispatches at most the cap; placements
        # must still match the host oracle exactly.
        rng = np.random.default_rng(9)
        adm = _mk_state("fused")
        ref = _mk_state("fused")
        old_cap = type(adm).DRAIN_CAP
        lanes = _mk_lanes(adm, rng, 48)
        _mk_lanes(ref, np.random.default_rng(9), 48)
        try:
            type(adm).DRAIN_CAP = 16  # force the wide path on `adm`
            got = adm.drain(0.0, lanes)
        finally:
            type(adm).DRAIN_CAP = old_cap
        assert got == ref.drain(0.0, lanes)
        assert got  # the scenario actually places

    def test_select_validation(self):
        adm = _mk_state("fused")
        with pytest.raises(ValueError, match="select"):
            adm.drain(0.0, [], select="best")

    def test_shard_requires_fused_backend(self):
        with pytest.raises(ValueError, match="shard"):
            AdmissionState([32.0], K=2, G=8, backend="numpy", shard=2)

    def test_shard_requires_devices(self):
        import jax
        n = jax.device_count()
        with pytest.raises(ValueError, match="device"):
            AdmissionState([32.0], K=2, G=8, backend="fused", shard=n + 1)


# ----------------------------------------------------------- engine level
class TestDeviceDrainDifferential:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_matches_host_drain(self, seed):
        host = _host_sim().run(_workload(48, seed=seed), RetrySpec("ksplus"))
        dev = _dev_sim().run(_workload(48, seed=seed), RetrySpec("ksplus"))
        assert host.retries > 0
        _assert_same(dev, host)

    def test_retry_storm(self):
        host = _host_sim().run(_workload(64, seed=11, under_frac=0.8),
                               RetrySpec("ksplus"))
        dev = _dev_sim().run(_workload(64, seed=11, under_frac=0.8),
                             RetrySpec("ksplus"))
        assert host.retries >= 20
        _assert_same(dev, host)

    def test_wastage_vs_legacy(self):
        from repro.core import ksplus_retry
        legacy = ClusterSim(_nodes(), engine="legacy").run(
            _workload(40, seed=1), ksplus_retry)
        dev = _dev_sim().run(_workload(40, seed=1), RetrySpec("ksplus"))
        assert dev.placements == legacy.placements
        np.testing.assert_allclose(dev.total_wastage_gbs,
                                   legacy.total_wastage_gbs, rtol=1e-6)

    def test_dag_replay(self):
        from repro.workloads import assert_release_order, scenarios
        wf = scenarios.get("workload_replay", n_tasks=300, seed=0)
        host = _host_sim().run(wf.to_jobs(under_frac=0.2, seed=0),
                               RetrySpec("ksplus"))
        dev = _dev_sim().run(wf.to_jobs(under_frac=0.2, seed=0),
                             RetrySpec("ksplus"))
        _assert_same(dev, host)
        assert_release_order(wf.to_jobs(seed=0), dev.placements)

    @pytest.mark.parametrize("seed", [0, 5])
    def test_node_churn(self, seed):
        faults = FaultSchedule.node_churn(_nodes(), rate=0.04,
                                          horizon=250.0, seed=seed)
        jobs = lambda: _timed_workload(48, seed=seed, under_frac=0.4)
        host = _host_sim().run(jobs(), RetrySpec("ksplus"), faults=faults)
        dev = _dev_sim().run(jobs(), RetrySpec("ksplus"), faults=faults)
        assert host.evictions > 0
        _assert_same(dev, host)
        assert dev.evictions == host.evictions
        assert dev.starvation_s == host.starvation_s

    def test_preemption_storm_join_mid_drain(self):
        """A storm kills most nodes at t=30 (mass eviction → long queue),
        then staggered rejoins land while that queue is still draining —
        every join triggers a fresh device drain over the backlog."""
        faults = FaultSchedule.preemption_storm(
            _nodes(), t=30.0, frac=0.9, seed=2, down_time=35.0)
        jobs = lambda: _timed_workload(56, seed=3, under_frac=0.5)
        host = _host_sim().run(jobs(), RetrySpec("ksplus"), faults=faults)
        dev = _dev_sim().run(jobs(), RetrySpec("ksplus"), faults=faults)
        assert host.evictions > 0
        _assert_same(dev, host)

    def test_parking_and_starvation(self):
        """Jobs bigger than every surviving node park (not spin) and
        unpark on rejoin; the device path must reproduce the host's
        starvation accounting exactly."""
        def jobs():
            out = _timed_workload(24, seed=4)
            # Fits only the 64 GB node, arrives while that node is down
            # -> parks until the t=120 rejoin.
            big = np.full(40, 56.0)
            out.append(Job(jid=900, family="t", input_gb=1.0, mem=big,
                           dt=1.0,
                           plan=AllocationPlan(np.zeros(1),
                                               np.asarray([60.0])),
                           est_runtime=40.0, release_time=30.0))
            return out
        faults = FaultSchedule([FaultEvent(20.0, "leave", 1),
                                FaultEvent(120.0, "join", 1, 96.0)])
        host = _host_sim().run(jobs(), RetrySpec("ksplus"), faults=faults)
        dev = _dev_sim().run(jobs(), RetrySpec("ksplus"), faults=faults)
        assert host.starvation_s > 0
        _assert_same(dev, host)
        assert dev.starvation_s == host.starvation_s

    def test_offset_sweep(self):
        cands = [OffsetCandidate(), OffsetCandidate(peak=0.25),
                 OffsetCandidate(peak=0.5)]
        host = _host_sim().run(_workload(32, seed=6), RetrySpec("ksplus"),
                               offsets=cands)
        dev = _dev_sim().run(_workload(32, seed=6), RetrySpec("ksplus"),
                             offsets=cands)
        for h, d in zip(host, dev):
            _assert_same(d, h)

    def test_drain_arg_validation(self):
        with pytest.raises(ValueError, match="drain"):
            ClusterSim(_nodes(), drain="gpu")
        with pytest.raises(ValueError, match="shard"):
            ClusterSim(_nodes(), drain="host", shard=2)


# ---------------------------------------------------------- elastic level
class TestElasticDeviceDrain:
    def test_fused_drain_matches_numpy(self):
        """Scripted submit/churn sequence: the fused planner (device
        drain) and the numpy planner must make identical placement and
        queueing decisions throughout."""
        logs = {}
        for backend in ("numpy", "fused"):
            rng = np.random.default_rng(21)
            pl = ElasticPlanner(backend=backend)
            pl.node_join("n0", 48.0)
            pl.node_join("n1", 32.0)
            alive = ["n0", "n1"]
            nxt, now, log = 2, 0.0, []
            for step in range(50):
                now += float(rng.uniform(0.0, 4.0))
                op = rng.uniform()
                if op < 0.5:
                    jid = f"j{step}"
                    log.append(("submit", jid, pl.submit(
                        jid, _storm_env(rng, float(rng.uniform(6, 30))),
                        now)))
                elif op < 0.7:
                    name = f"x{nxt}"
                    nxt += 1
                    alive.append(name)
                    placed = pl.node_join(name,
                                          float(rng.uniform(24, 64)),
                                          now=now)
                    log.append(("join", name, sorted(placed.items())))
                elif op < 0.9 and len(alive) > 1:
                    victim = alive.pop(int(rng.integers(0, len(alive))))
                    log.append(("leave", victim,
                                pl.node_leave(victim, now=now)))
                else:
                    log.append(("drain", None,
                                sorted(pl.drain(now).items())))
                log.append(("queued", None, pl.queued))
            logs[backend] = log
        assert logs["fused"] == logs["numpy"]

    def test_duplicate_jid_falls_back(self):
        """A queue holding the same jid twice takes the per-job admit
        loop (second occurrence is a resident live re-size) — both
        backends must agree on the outcome."""
        outs = {}
        for backend in ("numpy", "fused"):
            pl = ElasticPlanner(backend=backend)
            env = AllocationPlan(np.zeros(1), np.asarray([20.0]))
            pl.pending.append(("dup", env))
            pl.pending.append(("dup", env))
            pl.node_join("n0", 32.0)
            outs[backend] = (sorted(pl.drain(0.0).items()), pl.queued)
        assert outs["fused"] == outs["numpy"]
        assert outs["fused"][0] == [("dup", "n0")]


# ---------------------------------------------------------- sharded level
_SHARD_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax
assert jax.device_count() >= 4, jax.device_count()
import sys
sys.path.insert(0, {tests_dir!r})
from test_admission_fused import _mk_lanes, _mk_state
from test_cluster_packed import _nodes, _workload
from repro.core import RetrySpec
from repro.sched import ClusterSim, Node
from repro.sched.admission import AdmissionState

# Unit: sharded drain == unsharded == numpy, both node-selection rules.
for select in ("first", "headroom"):
    out = {{}}
    for shard in (None, 2, 4):
        rng = np.random.default_rng(13)
        adm = AdmissionState((32.0, 48.0, 24.0, 40.0, 28.0, 36.0), K=3,
                             G=16, backend="fused", use_dur=True,
                             shard=shard)
        lanes = _mk_lanes(adm, rng, 18)
        out[shard] = adm.drain(2.0, lanes, select=select)
        assert adm.stats["drain_dispatches"] == 1, adm.stats
    rng = np.random.default_rng(13)
    ref = AdmissionState((32.0, 48.0, 24.0, 40.0, 28.0, 36.0), K=3,
                         G=16, backend="numpy", use_dur=True)
    lanes = _mk_lanes(ref, rng, 18)
    out["numpy"] = ref.drain(2.0, lanes, select=select)
    assert out[2] == out[None] == out["numpy"], (select, out)
    assert out[4] == out[None], (select, out)
    assert len(out[None]) > 0

# Engine: sharded ClusterSim replay matches the unsharded device drain.
plain = ClusterSim(_nodes() + [Node(3, 96.0)], engine="fused",
                   drain="device").run(_workload(48, seed=2),
                                       RetrySpec("ksplus"))
shard = ClusterSim(_nodes() + [Node(3, 96.0)], engine="fused",
                   drain="device", shard=2).run(_workload(48, seed=2),
                                                RetrySpec("ksplus"))
assert shard.placements == plain.placements
assert shard.retries == plain.retries
assert shard.makespan == plain.makespan
print("SHARDED-DRAIN-OK")
"""


class TestShardedDrain:
    def test_sharded_matches_unsharded(self):
        tests_dir = os.path.dirname(os.path.abspath(__file__))
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        src = os.path.join(os.path.dirname(tests_dir), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-c",
             _SHARD_CODE.format(tests_dir=tests_dir)],
            capture_output=True, text=True, env=env, timeout=540)
        assert out.returncode == 0, out.stderr[-4000:]
        assert "SHARDED-DRAIN-OK" in out.stdout
