"""Fused admission engine: differential + invalidation-protocol coverage.

Three layers:

* ``TestFusedDifferential`` — the fused ClusterSim engine must reproduce
  the packed (host-side float64) engine's placement log bitwise, across
  retry rules, unsatisfiable jobs, callable retries and offset sweeps.
* ``TestAdmissionProtocol`` — unit coverage of the shared
  :class:`AdmissionState` invalidation protocol (time advance, place,
  release, plan change, node churn), with every refresh cross-checked
  against a from-scratch float64 oracle: the fits matrix must never serve
  a stale column.
* ``TestChurnStorm`` — the high-churn shared-state scenario: ElasticPlanner
  join/leave while a retry storm keeps re-planning lanes, on both
  backends, every decision checked against the scratch oracle.
"""

import numpy as np
import pytest

from repro.core import AllocationPlan, RetrySpec, ksplus_retry
from repro.core.envelope import fits_column
from repro.sched import ClusterSim, ElasticPlanner, Job, Node, OffsetCandidate
from repro.sched.admission import AdmissionState

from test_cluster_packed import _nodes, _workload


def _assert_same(a, b):
    assert a.placements == b.placements  # bitwise decision log
    assert a.retries == b.retries
    assert a.unschedulable == b.unschedulable
    assert a.makespan == b.makespan
    np.testing.assert_allclose(a.total_wastage_gbs, b.total_wastage_gbs,
                               rtol=1e-12)


class TestFusedDifferential:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_ksplus_matches_packed(self, seed):
        packed = ClusterSim(_nodes(), engine="packed").run(
            _workload(48, seed=seed), RetrySpec("ksplus"))
        fused = ClusterSim(_nodes(), engine="fused").run(
            _workload(48, seed=seed), RetrySpec("ksplus"))
        assert packed.retries > 0
        _assert_same(fused, packed)

    @pytest.mark.parametrize("kind", ["kseg-partial", "double",
                                      "max-machine"])
    def test_other_retry_rules_match(self, kind):
        spec = RetrySpec(kind)
        packed = ClusterSim(_nodes(), engine="packed").run(
            _workload(32, seed=5), spec)
        fused = ClusterSim(_nodes(), engine="fused").run(
            _workload(32, seed=5), spec)
        _assert_same(fused, packed)

    def test_retry_storm_matches_packed(self):
        """Heavy-failure workload: most jobs under-allocated, so same-time
        OOM batches and repeated re-plans dominate the event stream."""
        packed = ClusterSim(_nodes(), engine="packed").run(
            _workload(64, seed=11, under_frac=0.8), RetrySpec("ksplus"))
        fused = ClusterSim(_nodes(), engine="fused").run(
            _workload(64, seed=11, under_frac=0.8), RetrySpec("ksplus"))
        assert packed.retries >= 20
        _assert_same(fused, packed)

    def test_unsatisfiable_job_matches(self):
        def build():
            jobs = _workload(12, seed=7)
            big = np.full(30, 200.0)
            jobs.append(Job(jid=99, family="t", input_gb=1.0, mem=big,
                            dt=1.0,
                            plan=AllocationPlan(np.zeros(1),
                                                np.asarray([8.0])),
                            est_runtime=30.0))
            return jobs
        packed = ClusterSim(_nodes(), engine="packed").run(
            build(), RetrySpec("ksplus"))
        fused = ClusterSim(_nodes(), engine="fused").run(
            build(), RetrySpec("ksplus"))
        assert packed.unschedulable >= 1
        _assert_same(fused, packed)

    def test_callable_retry_matches(self):
        def bump(plan, t_fail, used):
            return plan.with_(peaks=np.maximum(plan.peaks * 2.0, used * 1.1))
        packed = ClusterSim(_nodes(), engine="packed").run(
            _workload(24, seed=9), bump)
        fused = ClusterSim(_nodes(), engine="fused").run(
            _workload(24, seed=9), bump)
        _assert_same(fused, packed)

    def test_numpy_admission_backend_matches(self):
        """Same protocol, host compute backend — pins the protocol itself
        (batched events, incremental invalidation) independently of XLA."""
        packed = ClusterSim(_nodes(), engine="packed").run(
            _workload(32, seed=2), RetrySpec("ksplus"))
        sim = ClusterSim(_nodes(), engine="fused")
        host = sim._run_fused(_workload(32, seed=2), RetrySpec("ksplus"),
                              None, None, True, admission_backend="numpy")
        _assert_same(host, packed)

    def test_offset_sweep_on_fused_engine(self):
        base = ClusterSim(_nodes(), engine="packed").run(
            _workload(24, seed=4), RetrySpec("ksplus"))
        swept = ClusterSim(_nodes(), engine="fused").run(
            _workload(24, seed=4), RetrySpec("ksplus"),
            offsets=[OffsetCandidate(), OffsetCandidate(peak=0.25)])
        assert swept[0].placements == base.placements
        assert swept[0].retries == base.retries
        assert swept[1].retries <= swept[0].retries

    def test_write_back_matches_packed(self):
        jobs_p = _workload(24, seed=2)
        jobs_f = _workload(24, seed=2)
        ClusterSim(_nodes(), engine="packed").run(jobs_p, RetrySpec("ksplus"))
        ClusterSim(_nodes(), engine="fused").run(jobs_f, RetrySpec("ksplus"))
        for jp, jf in zip(jobs_p, jobs_f):
            assert jp.attempts == jf.attempts
            assert jp.wasted_gbs == jf.wasted_gbs
            assert np.array_equal(jp.plan.starts, jf.plan.starts)
            assert np.array_equal(jp.plan.peaks, jf.plan.peaks)

    def test_fused_engine_rejects_preseeded_running(self):
        jobs = _workload(4, seed=0)
        nodes = _nodes()
        nodes[1].running.append((0.0, jobs[0]))
        with pytest.raises(ValueError, match="Node.running"):
            ClusterSim(nodes, engine="fused").run(jobs[1:],
                                                  RetrySpec("ksplus"))


# --------------------------------------------------------------------------
def _scratch_fits(adm: AdmissionState, now: float, lanes) -> np.ndarray:
    """From-scratch float64 oracle for the fits matrix slice — recomputes
    every (node, lane) entry directly from the current resident sets,
    ignoring all cached state."""
    lanes = np.asarray(lanes, np.int64)
    out = np.zeros((adm.N, len(lanes)), bool)
    for ni in range(adm.N):
        run = adm.running[ni]
        out[ni], _ = fits_column(
            adm.caps[ni], adm.starts[run], adm.peaks[run],
            adm.admit_t[run], adm.need[lanes], now + adm.grid[lanes],
            dur=adm.dur[run] if adm.use_dur else None, tol=adm.tol)
    return out


def _mk_state(backend, caps=(32.0, 48.0), use_dur=True, K=3, G=16):
    adm = AdmissionState(caps, K=K, G=G, backend=backend, use_dur=use_dur)
    return adm


def _mk_lanes(adm, rng, n):
    from repro.core.envelope import PAD_START, alloc_at_packed
    K, G = adm.K, adm.G
    starts = np.full((n, K), PAD_START)
    peaks = np.zeros((n, K))
    grid = np.linspace(0.0, rng.uniform(30, 120, n), G, axis=1)
    for i in range(n):
        k = int(rng.integers(1, K + 1))
        starts[i, :k] = np.sort(np.concatenate(
            [[0.0], rng.uniform(1.0, 60.0, k - 1)]))
        peaks[i, :k] = np.sort(rng.uniform(2.0, 20.0, k))
        peaks[i, k:] = peaks[i, k - 1]
    need = alloc_at_packed(starts, peaks, grid)
    dur = rng.uniform(20.0, 100.0, n) if adm.use_dur else None
    return adm.add_lanes(starts, peaks, need, grid, dur=dur)


@pytest.mark.parametrize("backend", ["numpy", "fused"])
class TestAdmissionProtocol:
    def test_refresh_matches_scratch_oracle(self, backend):
        rng = np.random.default_rng(0)
        adm = _mk_state(backend)
        lanes = _mk_lanes(adm, rng, 12)
        got = adm.columns(0.0, lanes)
        np.testing.assert_array_equal(got, _scratch_fits(adm, 0.0, lanes))

    def test_place_invalidates_only_true_entries(self, backend):
        rng = np.random.default_rng(1)
        adm = _mk_state(backend)
        lanes = _mk_lanes(adm, rng, 10)
        cols = adm.columns(0.0, lanes).copy()
        ji = int(lanes[np.argmax(cols.any(axis=0))])
        ni = int(np.argmax(cols[:, np.argmax(cols.any(axis=0))]))
        adm.place(ni, ji, 0.0)
        # False entries on the placed node stay valid (monotonicity) ...
        false_lanes = lanes[~cols[ni, :]]
        assert adm.valid[ni, false_lanes].all()
        # ... True entries were invalidated,
        true_lanes = lanes[cols[ni, :]]
        assert not adm.valid[ni, true_lanes].any()
        # and the next read is oracle-fresh either way.
        np.testing.assert_array_equal(adm.columns(0.0, lanes),
                                      _scratch_fits(adm, 0.0, lanes))

    def test_release_invalidates_column(self, backend):
        rng = np.random.default_rng(2)
        adm = _mk_state(backend)
        lanes = _mk_lanes(adm, rng, 8)
        cols = adm.columns(0.0, lanes)
        ji = int(lanes[np.argmax(cols.any(axis=0))])
        ni = int(np.argmax(cols[:, np.argmax(cols.any(axis=0))]))
        adm.place(ni, ji, 0.0)
        adm.columns(0.0, lanes)
        adm.release(ni, ji)
        assert not adm.valid[ni].any()
        np.testing.assert_array_equal(adm.columns(0.0, lanes),
                                      _scratch_fits(adm, 0.0, lanes))

    def test_time_advance_invalidates_everything(self, backend):
        rng = np.random.default_rng(3)
        adm = _mk_state(backend)
        lanes = _mk_lanes(adm, rng, 8)
        adm.columns(0.0, lanes)
        assert adm.valid[:, lanes].all()
        adm.sync_now(17.0)
        assert not adm.valid.any()
        np.testing.assert_array_equal(adm.columns(17.0, lanes),
                                      _scratch_fits(adm, 17.0, lanes))

    def test_plan_change_invalidates_lane_everywhere(self, backend):
        from repro.core.envelope import alloc_at_packed
        rng = np.random.default_rng(4)
        adm = _mk_state(backend)
        lanes = _mk_lanes(adm, rng, 6)
        adm.columns(0.0, lanes)
        ji = int(lanes[0])
        st = adm.starts[ji].copy()
        pk = adm.peaks[ji] * 3.0
        need = alloc_at_packed(st[None], pk[None], adm.grid[ji][None])[0]
        adm.update_lane(ji, st, pk, need)
        assert not adm.valid[:, ji].any()
        np.testing.assert_array_equal(adm.columns(0.0, lanes),
                                      _scratch_fits(adm, 0.0, lanes))

    def test_resident_replan_invalidates_host_node_row(self, backend):
        """Re-planning a lane that is currently resident changes its host
        node's residual for *every* queued lane — the whole row must go
        stale, not just the re-planned lane's column."""
        from repro.core.envelope import alloc_at_packed
        rng = np.random.default_rng(6)
        adm = _mk_state(backend)
        lanes = _mk_lanes(adm, rng, 6)
        cols = adm.columns(0.0, lanes)
        ji = int(lanes[np.argmax(cols.any(axis=0))])
        ni = int(np.argmax(cols[:, np.argmax(cols.any(axis=0))]))
        adm.place(ni, ji, 0.0)
        adm.columns(0.0, lanes)  # everything valid again
        # live re-size of the *resident* lane: shrink its envelope
        st = adm.starts[ji].copy()
        pk = adm.peaks[ji] * 0.1
        need = alloc_at_packed(st[None], pk[None], adm.grid[ji][None])[0]
        adm.update_lane(ji, st, pk, need)
        assert not adm.valid[ni].any()  # host node's whole row is stale
        np.testing.assert_array_equal(adm.columns(0.0, lanes),
                                      _scratch_fits(adm, 0.0, lanes))

    def test_node_churn_keeps_matrix_fresh(self, backend):
        rng = np.random.default_rng(5)
        adm = _mk_state(backend)
        lanes = _mk_lanes(adm, rng, 8)
        adm.columns(0.0, lanes)
        adm.add_node(24.0)
        np.testing.assert_array_equal(adm.columns(0.0, lanes),
                                      _scratch_fits(adm, 0.0, lanes))
        evicted = adm.remove_node(0)
        assert evicted == []
        np.testing.assert_array_equal(adm.columns(0.0, lanes),
                                      _scratch_fits(adm, 0.0, lanes))


# --------------------------------------------------------------------------
def _storm_env(rng, peak):
    k = int(rng.integers(1, 4))
    starts = np.sort(np.concatenate([[0.0], rng.uniform(5.0, 200.0, k - 1)]))
    return AllocationPlan(starts=starts,
                          peaks=np.sort(rng.uniform(peak / 2, peak, k)))


@pytest.mark.parametrize("backend", ["numpy", "fused"])
class TestChurnStorm:
    def test_planner_join_leave_during_retry_storm(self, backend):
        """High-churn shared-state scenario: nodes join/leave while a
        retry storm keeps re-planning queued jobs.  After every membership
        or plan change, the shared fits matrix the planner reads must
        match a from-scratch recompute — stale columns would either admit
        into occupied memory or starve a fitting job."""
        rng = np.random.default_rng(0)
        pl = ElasticPlanner(backend=backend)
        adm = pl._adm
        now = 0.0
        pl.node_join("n0", 48.0)
        pl.node_join("n1", 32.0)
        alive = ["n0", "n1"]
        nxt = 2
        log = []
        for step in range(60):
            now += float(rng.uniform(0.0, 5.0))
            op = rng.uniform()
            if op < 0.45:  # submit a new job
                jid = f"j{step}"
                log.append((jid, pl.submit(
                    jid, _storm_env(rng, float(rng.uniform(6, 30))), now)))
            elif op < 0.65 and pl.queued:  # retry storm: re-plan a waiter
                jid = pl.pending[0][0]
                new = _storm_env(rng, float(rng.uniform(6, 20)))
                pl.pending[0] = (jid, new)
                pl._ensure_lane(jid, new)  # plan change -> invalidation
                pl.drain(now)
            elif op < 0.85:  # join
                name = f"x{nxt}"
                nxt += 1
                alive.append(name)
                pl.node_join(name, float(rng.uniform(24, 64)), now=now)
            elif len(alive) > 1:  # leave
                victim = alive.pop(int(rng.integers(0, len(alive))))
                pl.node_leave(victim, now=now)
            # The invariant: every queued lane's fits column is fresh.
            queued_lanes = [pl._lane[j] for j in pl.queued]
            resident_lanes = [pl._lane[j] for sl in pl.slices.values()
                              for j, _, _ in sl.jobs]
            check = queued_lanes + resident_lanes
            if check and adm.N:
                np.testing.assert_array_equal(
                    adm.columns(now, check),
                    _scratch_fits(adm, now, check),
                    err_msg=f"stale fits column at step {step}")
        # the storm must actually have exercised placements and queueing
        assert any(p is not None for _, p in log)
        assert any(p is None for _, p in log)

    def test_resident_resize_frees_headroom_for_waiters(self, backend):
        """The reviewed starvation case: resubmitting a *running* job with
        a smaller envelope must not re-place it, must free its slice's
        head-room for waiters, and must not leak a phantom resident."""
        pl = ElasticPlanner(backend=backend)
        pl.node_join("n0", 32.0)
        big = AllocationPlan(starts=np.zeros(1), peaks=np.asarray([20.0]))
        small = AllocationPlan(starts=np.zeros(1), peaks=np.asarray([5.0]))
        assert pl.submit("A", big, now=0.0) == "n0"
        assert pl.submit("B", big, now=0.0) is None  # 20+20 > 32: queued
        # live re-size of resident A: same slice, no double placement
        assert pl.submit("A", small, now=1.0) == "n0"
        assert pl._adm.running[0].count(pl._lane["A"]) == 1
        assert [j for j, _, _ in pl.slices["n0"].jobs] == ["A"]
        # B now fits beside the shrunk A (5 + 20 <= 32)
        assert pl.drain(now=1.0) == {"B": "n0"}
        pl.finish("A")
        assert pl._adm.running[0] == [pl._lane["B"]]

    def test_cluster_retry_storm_stays_pinned(self, backend):
        """ClusterSim under a retry storm on the same shared-state class:
        the packed host engine is the oracle — any stale fits column in
        the fused path would desynchronize the placement log."""
        packed = ClusterSim(_nodes(), engine="packed").run(
            _workload(40, seed=13, under_frac=0.7), RetrySpec("ksplus"))
        sim = ClusterSim(_nodes(), engine="fused")
        fused = sim._run_fused(
            _workload(40, seed=13, under_frac=0.7), RetrySpec("ksplus"),
            None, None, True, admission_backend=backend)
        assert packed.retries >= 10
        _assert_same(fused, packed)
