"""Differential tests: packed ClusterSim vs the legacy per-job event loop.

The packed engine must reproduce the legacy loop's *decisions* bitwise —
the full admission log (time, node, job), retry and unschedulable counts,
makespan — and its wastage within 1e-6 relative (span arithmetic vs the
per-sample float64 sums).  Workloads are seeded multi-node mixes with
multi-segment plans, deliberate under-allocations (retries) and an
unsatisfiable job.
"""

import numpy as np
import pytest

from repro.core import AllocationPlan, RetrySpec, ksplus_retry
from repro.sched import ClusterSim, Job, Node, OffsetCandidate


def _workload(n_jobs=48, seed=0, under_frac=0.25, dt=1.0):
    """Seeded jobs with 2–3-segment plans; ``under_frac`` of them
    under-allocated in some segment so the OOM/retry path is exercised.
    Margins are kept ≳1e-3 relative so the float32 device probe and the
    float64 oracle agree on every violation sample."""
    rng = np.random.default_rng(seed)
    jobs = []
    for j in range(n_jobs):
        L = int(rng.integers(24, 90))
        split = int(rng.uniform(0.4, 0.8) * L)
        lo = float(rng.uniform(1.5, 3.0))
        hi = float(rng.uniform(5.0, 11.0))
        mem = np.concatenate([np.full(split, lo), np.full(L - split, hi)])
        mem = mem * (1.0 + 0.02 * np.sin(np.arange(L)))  # mild structure
        under = rng.uniform() < under_frac
        scale = 0.9 if under else 1.12
        plan = AllocationPlan(
            starts=np.asarray([0.0, max(split * dt - 2.0, 1.0)]),
            peaks=np.asarray([lo * 1.15, hi * scale]))
        jobs.append(Job(jid=j, family="t", input_gb=1.0, mem=mem, dt=dt,
                        plan=plan, est_runtime=float(L * dt)))
    return jobs


def _nodes():
    return [Node(0, 48.0), Node(1, 64.0), Node(2, 32.0)]


def _run_both(jobs_builder, retry_spec, retry_fn, **sim_kw):
    legacy = ClusterSim(_nodes(), engine="legacy", **sim_kw).run(
        jobs_builder(), retry_fn)
    packed = ClusterSim(_nodes(), engine="packed", **sim_kw).run(
        jobs_builder(), retry_spec)
    return legacy, packed


def _assert_equivalent(legacy, packed):
    assert packed.placements == legacy.placements  # bitwise decision log
    assert packed.retries == legacy.retries
    assert packed.unschedulable == legacy.unschedulable
    assert packed.makespan == legacy.makespan
    np.testing.assert_allclose(packed.total_wastage_gbs,
                               legacy.total_wastage_gbs, rtol=1e-6)
    np.testing.assert_allclose(packed.avg_utilization,
                               legacy.avg_utilization, rtol=1e-6)


class TestDifferential:
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_ksplus_retry_matches_legacy(self, seed):
        legacy, packed = _run_both(
            lambda: _workload(48, seed=seed),
            RetrySpec("ksplus"), ksplus_retry)
        assert legacy.retries > 0  # the workload must exercise retries
        _assert_equivalent(legacy, packed)

    @pytest.mark.parametrize("kind", ["kseg-partial", "double", "max-machine"])
    def test_other_retry_rules_match(self, kind):
        spec = RetrySpec(kind)
        legacy, packed = _run_both(
            lambda: _workload(32, seed=5), spec, spec)
        _assert_equivalent(legacy, packed)

    def test_machine_bound_retries_stay_schedulable(self):
        """RetrySpec rules that reference 'the machine' (max-machine,
        double's cap) are bounded by the largest node, so a retried job is
        either re-admitted or counted unschedulable — never silently lost."""
        jobs = _workload(24, seed=3, under_frac=0.5)
        res = ClusterSim(_nodes()).run(jobs, RetrySpec("max-machine"))
        assert res.retries > 0
        finished = len(res.placements) - res.retries
        assert finished + res.unschedulable == len(jobs)
        assert all(j.plan.peaks.max() <= 64.0 for j in jobs)  # largest node

    def test_unsatisfiable_job_matches(self):
        def build():
            jobs = _workload(12, seed=7)
            big = np.full(30, 200.0)  # above every node's capacity
            jobs.append(Job(jid=99, family="t", input_gb=1.0, mem=big,
                            dt=1.0,
                            plan=AllocationPlan(np.zeros(1), np.asarray([8.0])),
                            est_runtime=30.0))
            return jobs
        legacy, packed = _run_both(build, RetrySpec("ksplus"), ksplus_retry)
        assert legacy.unschedulable >= 1
        _assert_equivalent(legacy, packed)

    def test_callable_retry_on_packed_engine(self):
        """The packed engine accepts legacy callables (per-lane repack)."""
        def bump(plan, t_fail, used):
            return plan.with_(peaks=np.maximum(plan.peaks * 2.0, used * 1.1))
        legacy = ClusterSim(_nodes(), engine="legacy").run(
            _workload(24, seed=9), bump)
        packed = ClusterSim(_nodes(), engine="packed").run(
            _workload(24, seed=9), bump)
        _assert_equivalent(legacy, packed)

    def test_write_back_matches_legacy_job_state(self):
        jobs_l = _workload(24, seed=2)
        jobs_p = _workload(24, seed=2)
        ClusterSim(_nodes(), engine="legacy").run(jobs_l, ksplus_retry)
        ClusterSim(_nodes(), engine="packed").run(jobs_p, RetrySpec("ksplus"))
        for jl, jp in zip(jobs_l, jobs_p):
            assert jl.attempts == jp.attempts
            np.testing.assert_allclose(jp.wasted_gbs, jl.wasted_gbs,
                                       rtol=1e-6, atol=1e-9)
            assert np.array_equal(jl.plan.starts, jp.plan.starts)
            assert np.array_equal(jl.plan.peaks, jp.plan.peaks)


class TestOffsetSweep:
    def test_identity_candidate_reproduces_base_run(self):
        base = ClusterSim(_nodes()).run(_workload(32, seed=4),
                                        RetrySpec("ksplus"))
        swept = ClusterSim(_nodes()).run(
            _workload(32, seed=4), RetrySpec("ksplus"),
            offsets=[OffsetCandidate()])
        assert len(swept) == 1
        assert swept[0].placements == base.placements
        assert swept[0].retries == base.retries
        np.testing.assert_allclose(swept[0].total_wastage_gbs,
                                   base.total_wastage_gbs, rtol=1e-12)

    def test_identity_preserves_non_monotone_plans(self):
        """k-Segments can emit envelopes that step *down*; the identity
        candidate must not flatten them."""
        def build():
            jobs = _workload(12, seed=6)
            for j in jobs[:4]:  # high-then-low plans (still covering mem)
                j.plan = AllocationPlan(
                    starts=j.plan.starts,
                    peaks=np.asarray([float(j.mem.max()) * 1.1,
                                      float(j.mem[-1]) * 1.3]))
            return jobs
        base = ClusterSim(_nodes()).run(build(), RetrySpec("ksplus"))
        swept = ClusterSim(_nodes()).run(build(), RetrySpec("ksplus"),
                                         offsets=[OffsetCandidate()])
        assert swept[0].placements == base.placements
        np.testing.assert_allclose(swept[0].total_wastage_gbs,
                                   base.total_wastage_gbs, rtol=1e-12)

    def test_sweep_does_not_mutate_jobs(self):
        jobs = _workload(16, seed=4)
        peaks0 = [j.plan.peaks.copy() for j in jobs]
        ClusterSim(_nodes()).run(jobs, RetrySpec("ksplus"),
                                 offsets=[OffsetCandidate(peak=0.3),
                                          OffsetCandidate()])
        assert all(j.attempts == 0 for j in jobs)
        assert all(np.array_equal(p, j.plan.peaks)
                   for p, j in zip(peaks0, jobs))

    def test_offsets_trade_retries_for_wastage(self):
        """Raising the peak offset eliminates retries (over-allocating);
        the identity candidate keeps the base run's failures."""
        res = ClusterSim(_nodes()).run(
            _workload(40, seed=1, under_frac=0.4), RetrySpec("ksplus"),
            offsets=[OffsetCandidate(),
                     OffsetCandidate(peak=0.25),
                     OffsetCandidate(peak=0.25, last_peak_bump=0.5)])
        assert [r.offset for r in res] == [
            OffsetCandidate(), OffsetCandidate(peak=0.25),
            OffsetCandidate(peak=0.25, last_peak_bump=0.5)]
        assert res[0].retries > res[1].retries
        # a bigger envelope can only start jobs later or equally packed
        assert res[1].total_wastage_gbs > 0

    def test_last_peak_bump_requires_spec(self):
        with pytest.raises(ValueError):
            ClusterSim(_nodes()).run(
                _workload(4, seed=0), ksplus_retry,
                offsets=[OffsetCandidate(last_peak_bump=0.5)])

    def test_packed_engine_rejects_preseeded_running(self):
        """Resident jobs live outside the packed batch — refuse loudly
        instead of silently admitting into occupied memory."""
        jobs = _workload(4, seed=0)
        nodes = _nodes()
        nodes[1].running.append((0.0, jobs[0]))
        with pytest.raises(ValueError, match="Node.running"):
            ClusterSim(nodes).run(jobs[1:], RetrySpec("ksplus"))

    def test_legacy_engine_rejects_offsets(self):
        with pytest.raises(ValueError):
            ClusterSim(_nodes(), engine="legacy").run(
                _workload(4, seed=0), ksplus_retry,
                offsets=[OffsetCandidate()])
