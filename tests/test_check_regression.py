"""The bench-regression guard (``benchmarks/check_regression.py``).

Synthetic baseline/current BENCH pairs over every gated key family:
``*_speedup_x`` (higher-better), ``*_overhead_x`` /
``*_dispatches_per_drain`` (lower-better), and the boolean correctness
suffixes (``*_match`` / ``*_ok`` / ``*_bitwise``).
"""

import json

import pytest

from benchmarks.check_regression import _load, compare, main


def _kv(**kw):
    """baseline/fresh dicts in the loader's key -> (src, value) shape."""
    return {k: ("BENCH_t.json", v) for k, v in kw.items()}


class TestCompare:
    def test_speedup_drop_beyond_tolerance_fails(self):
        failures, _ = compare(_kv(drain_speedup_x=10.0),
                              _kv(drain_speedup_x=7.9), tolerance=0.2)
        assert len(failures) == 1 and "drain_speedup_x" in failures[0]

    def test_speedup_drop_within_tolerance_passes(self):
        failures, _ = compare(_kv(drain_speedup_x=10.0),
                              _kv(drain_speedup_x=8.1), tolerance=0.2)
        assert failures == []

    def test_speedup_improvement_passes(self):
        failures, _ = compare(_kv(drain_speedup_x=10.0),
                              _kv(drain_speedup_x=30.0), tolerance=0.2)
        assert failures == []

    def test_overhead_rise_beyond_tolerance_fails(self):
        failures, _ = compare(_kv(sync_overhead_x=1.0),
                              _kv(sync_overhead_x=1.3), tolerance=0.2)
        assert len(failures) == 1 and "ceiling" in failures[0]

    def test_dispatches_per_drain_is_lower_better(self):
        failures, _ = compare(_kv(drain_dispatches_per_drain=1.0),
                              _kv(drain_dispatches_per_drain=2.0),
                              tolerance=0.2)
        assert len(failures) == 1

    def test_bool_gate_flip_fails_tolerance_free(self):
        for suffix in ("_match", "_ok", "_bitwise"):
            failures, _ = compare(_kv(**{f"placements{suffix}": True}),
                                  _kv(**{f"placements{suffix}": False}),
                                  tolerance=0.2)
            assert len(failures) == 1, suffix
            assert "flip" in failures[0]

    def test_bool_false_to_true_is_not_a_flip(self):
        failures, _ = compare(_kv(x_match=False), _kv(x_match=True),
                              tolerance=0.2)
        assert failures == []

    def test_new_key_is_a_note_not_a_failure(self):
        failures, notes = compare(
            _kv(a_speedup_x=2.0),
            _kv(a_speedup_x=2.0, brand_new_speedup_x=1.0),
            tolerance=0.2)
        assert failures == []
        assert any("new key" in n for n in notes)

    def test_missing_key_is_a_note_not_a_failure(self):
        failures, notes = compare(_kv(gone_speedup_x=2.0), _kv(),
                                  tolerance=0.2)
        assert failures == []
        assert any("missing" in n for n in notes)

    def test_ungated_keys_ignored(self):
        failures, _ = compare(_kv(raw_us=100.0, count=5),
                              _kv(raw_us=9999.0, count=1), tolerance=0.2)
        assert failures == []


class TestEndToEnd:
    def _dump(self, d, name, payload):
        (d / name).write_text(json.dumps(payload))

    def test_main_green_and_red(self, tmp_path, monkeypatch, capsys):
        base, fresh = tmp_path / "base", tmp_path / "fresh"
        base.mkdir(), fresh.mkdir()
        self._dump(base, "BENCH_drain.json",
                   {"drain_speedup_x": 9.0, "placements_match": True})
        self._dump(fresh, "BENCH_drain.json",
                   {"drain_speedup_x": 8.5, "placements_match": True})
        monkeypatch.setattr("sys.argv", [
            "check_regression", "--baseline", str(base),
            "--fresh", str(fresh)])
        assert main() == 0
        assert "OK" in capsys.readouterr().out

        self._dump(fresh, "BENCH_drain.json",
                   {"drain_speedup_x": 2.0, "placements_match": True})
        assert main() == 1
        assert "FAILURES" in capsys.readouterr().out

    def test_unreadable_dump_exits_loudly(self, tmp_path):
        (tmp_path / "BENCH_bad.json").write_text("{not json")
        with pytest.raises(SystemExit, match="unreadable"):
            _load(str(tmp_path))

    def test_empty_baseline_dir_exits_loudly(self, tmp_path, monkeypatch):
        base, fresh = tmp_path / "base", tmp_path / "fresh"
        base.mkdir(), fresh.mkdir()
        self._dump(fresh, "BENCH_x.json", {"a_speedup_x": 1.0})
        monkeypatch.setattr("sys.argv", [
            "check_regression", "--baseline", str(base),
            "--fresh", str(fresh)])
        with pytest.raises(SystemExit, match="no BENCH"):
            main()


class TestMetadataKeys:
    def test_schema_key_is_never_gated_or_noted(self):
        failures, notes = compare(
            _kv(schema=1, drain_speedup_x=10.0),
            _kv(schema=2, drain_speedup_x=10.0), tolerance=0.2)
        assert failures == []
        assert notes == []  # no "new key" / "missing" chatter either

    def test_schema_only_in_fresh_is_silent(self):
        """Dumps gaining the stamp must not spam the notes list."""
        failures, notes = compare(_kv(x_speedup_x=1.0),
                                  _kv(x_speedup_x=1.0, schema=1),
                                  tolerance=0.2)
        assert failures == [] and notes == []

    def test_schema_only_in_baseline_is_silent(self):
        failures, notes = compare(_kv(x_speedup_x=1.0, schema=1),
                                  _kv(x_speedup_x=1.0), tolerance=0.2)
        assert failures == [] and notes == []
