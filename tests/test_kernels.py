"""Per-kernel interpret-mode validation: shape/dtype sweeps vs jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.wastage import oom_probe_ref, wastage_eval_ref
from repro.kernels import flash_attention, ssd_pallas, wastage_eval
from repro.kernels.wastage.ops import oom_probe
from repro.kernels.flash_attention.ref import mha_reference
from repro.kernels.ssd.ref import ssd_reference

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


class TestFlashAttention:
    @pytest.mark.parametrize("B,Sq,Skv,H,K,hd", [
        (1, 128, 128, 4, 2, 64),
        (2, 64, 192, 4, 4, 32),
        (1, 256, 256, 8, 2, 16),
        (2, 128, 128, 2, 1, 64),   # MQA
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_sweep_f32(self, B, Sq, Skv, H, K, hd, causal):
        q = jnp.asarray(RNG.standard_normal((B, Sq, H, hd)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((B, Skv, K, hd)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((B, Skv, K, hd)), jnp.float32)
        out = flash_attention(q, k, v, causal=causal,
                              block_q=64, block_k=64, interpret=True)
        ref = jnp.moveaxis(mha_reference(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), causal=causal), 1, 2)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q = jnp.asarray(RNG.standard_normal((1, 128, 4, 32)), dtype)
        k = jnp.asarray(RNG.standard_normal((1, 128, 2, 32)), dtype)
        v = jnp.asarray(RNG.standard_normal((1, 128, 2, 32)), dtype)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
        ref = jnp.moveaxis(mha_reference(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), causal=True), 1, 2)
        np.testing.assert_allclose(out.astype(jnp.float32),
                                   ref.astype(jnp.float32), **_tol(dtype))

    def test_sliding_window(self):
        q = jnp.asarray(RNG.standard_normal((1, 256, 2, 32)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((1, 256, 2, 32)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((1, 256, 2, 32)), jnp.float32)
        out = flash_attention(q, k, v, causal=True, window=64,
                              block_q=64, block_k=64, interpret=True)
        ref = jnp.moveaxis(mha_reference(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), causal=True, window=64), 1, 2)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))

    def test_unaligned_seq_padding(self):
        q = jnp.asarray(RNG.standard_normal((1, 100, 2, 32)), jnp.float32)
        k = jnp.asarray(RNG.standard_normal((1, 100, 2, 32)), jnp.float32)
        v = jnp.asarray(RNG.standard_normal((1, 100, 2, 32)), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
        ref = jnp.moveaxis(mha_reference(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), causal=True), 1, 2)
        np.testing.assert_allclose(out, ref, **_tol(jnp.float32))


class TestSSD:
    @pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
        (1, 128, 2, 16, 1, 32, 32),
        (2, 256, 4, 64, 2, 64, 64),
        (1, 96, 2, 32, 1, 16, 32),    # padded sequence
        (1, 128, 8, 16, 4, 16, 128),  # single chunk
    ])
    def test_sweep(self, B, S, H, P, G, N, chunk):
        X = jnp.asarray(RNG.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
        A = jnp.asarray(-np.abs(RNG.standard_normal((B, S, H))) * 0.3,
                        jnp.float32)
        Bm = jnp.asarray(RNG.standard_normal((B, S, G, N)) * 0.5, jnp.float32)
        Cm = jnp.asarray(RNG.standard_normal((B, S, G, N)) * 0.5, jnp.float32)
        y, st = ssd_pallas(X, A, Bm, Cm, chunk=chunk, interpret=True)
        yr, sr = ssd_reference(
            jnp.moveaxis(X, 1, 2), jnp.moveaxis(A, 1, 2),
            jnp.moveaxis(Bm, 1, 2), jnp.moveaxis(Cm, 1, 2), chunk=chunk)
        np.testing.assert_allclose(y, jnp.moveaxis(yr, 1, 2),
                                   atol=5e-3, rtol=5e-3)
        np.testing.assert_allclose(st, sr, atol=5e-3, rtol=5e-3)

    def test_matches_sequential_recurrence(self):
        """Chunked SSD == naive per-step recurrence (ground truth)."""
        from repro.models.mamba2 import ssd_decode_step
        B, S, H, P, G, N = 1, 32, 2, 8, 1, 8
        X = jnp.asarray(RNG.standard_normal((B, S, H, P)) * 0.5, jnp.float32)
        A = jnp.asarray(-np.abs(RNG.standard_normal((B, S, H))) * 0.3,
                        jnp.float32)
        Bm = jnp.asarray(RNG.standard_normal((B, S, G, N)) * 0.5, jnp.float32)
        Cm = jnp.asarray(RNG.standard_normal((B, S, G, N)) * 0.5, jnp.float32)
        y, st = ssd_pallas(X, A, Bm, Cm, chunk=16, interpret=True)
        # sequential: state' = exp(a) state + B x ; y = C state'
        state = np.zeros((B, H, P, N))
        ys = []
        for t in range(S):
            a = np.asarray(A[:, t])                       # (B,H)
            x = np.asarray(X[:, t])                       # (B,H,P)
            b = np.repeat(np.asarray(Bm[:, t]), H // G, 1)  # (B,H,N)
            c = np.repeat(np.asarray(Cm[:, t]), H // G, 1)
            state = state * np.exp(a)[..., None, None] + \
                np.einsum("bhn,bhp->bhpn", b, x)
            ys.append(np.einsum("bhn,bhpn->bhp", c, state))
        np.testing.assert_allclose(y, np.stack(ys, 1), atol=5e-3, rtol=5e-3)
        np.testing.assert_allclose(st, state, atol=5e-3, rtol=5e-3)


class TestWastageKernel:
    @pytest.mark.parametrize("B,T,k", [(8, 512, 4), (16, 700, 8), (3, 64, 1)])
    def test_sweep(self, B, T, k):
        starts = np.sort(RNG.uniform(0, T * 0.8, (B, k)), axis=1)
        starts[:, 0] = 0
        peaks = np.sort(RNG.uniform(1, 10, (B, k)), axis=1)
        mems = np.abs(RNG.normal(3, 1, (B, T)))
        lengths = RNG.integers(T // 4, T, B)
        out = np.asarray(wastage_eval(starts, peaks, mems, lengths,
                                      dt=1.0, interpret=True))
        ref = wastage_eval_ref(starts, peaks, mems, lengths, 1.0)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-2)

    def test_non_monotone_plans(self):
        """k-Segments plans can step DOWN; kernel must match the oracle."""
        B, T, k = 6, 256, 4
        starts = np.sort(RNG.uniform(0, 200, (B, k)), axis=1)
        starts[:, 0] = 0
        peaks = RNG.uniform(1, 10, (B, k))  # unordered
        mems = np.abs(RNG.normal(2, 0.5, (B, T)))
        lengths = np.full(B, T)
        out = np.asarray(wastage_eval(starts, peaks, mems, lengths,
                                      dt=1.0, interpret=True))
        ref = wastage_eval_ref(starts, peaks, mems, lengths, 1.0)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-2)


class TestOOMProbeKernel:
    """Fused first-violation + success/kill wastage (fleet-engine probe)."""

    @pytest.mark.parametrize("B,T,k", [(8, 512, 4), (16, 700, 8), (3, 64, 1)])
    def test_sweep(self, B, T, k):
        starts = np.sort(RNG.uniform(0, T * 0.8, (B, k)), axis=1)
        starts[:, 0] = 0
        peaks = np.sort(RNG.uniform(1, 6, (B, k)), axis=1)
        mems = np.abs(RNG.normal(3, 1.5, (B, T)))
        lengths = RNG.integers(1, T, B)
        viol, w_succ, w_kill = (np.asarray(x) for x in oom_probe(
            starts, peaks, mems, lengths, dt=1.0, interpret=True))
        vr, wsr, wkr = oom_probe_ref(
            starts.astype(np.float32), peaks.astype(np.float32),
            mems.astype(np.float32), lengths, 1.0)
        np.testing.assert_array_equal(viol, vr)
        np.testing.assert_allclose(w_succ, wsr, rtol=1e-4, atol=1e-2)
        np.testing.assert_allclose(w_kill, wkr, rtol=1e-4, atol=1e-2)

    def test_sentinel_padded_slots_inactive(self):
        """Plan slots with huge sentinel starts must never grab samples."""
        B, T, k = 4, 128, 4
        starts = np.sort(RNG.uniform(0, 80, (B, k)), axis=1)
        starts[:, 0] = 0
        starts[:, 2:] = 1e30  # padded
        peaks = np.sort(RNG.uniform(1, 6, (B, k)), axis=1)
        mems = np.abs(RNG.normal(2, 1, (B, T)))
        lengths = np.full(B, T)
        viol, w_succ, w_kill = (np.asarray(x) for x in oom_probe(
            starts, peaks, mems, lengths, dt=1.0, interpret=True))
        vr, wsr, wkr = oom_probe_ref(
            starts.astype(np.float32), peaks.astype(np.float32),
            mems.astype(np.float32), lengths, 1.0)
        np.testing.assert_array_equal(viol, vr)
        np.testing.assert_allclose(w_succ, wsr, rtol=1e-4, atol=1e-2)
        np.testing.assert_allclose(w_kill, wkr, rtol=1e-4, atol=1e-2)

    @pytest.mark.parametrize("dt", [0.5, 1.0, 2.5])
    @pytest.mark.parametrize("block_t", [64, 512])
    def test_dt_blocking_sweep(self, dt, block_t):
        """dt scaling x grid blocking (T % block_t != 0 hits the pad
        path) — the interpret-mode sweep the perf job used to run
        bench-only; promoted to tier-1 so a kernel change cannot land
        with a silently skewed probe."""
        B, T, k = 12, 700, 4
        starts = np.sort(RNG.uniform(0, T * 0.8 * dt, (B, k)), axis=1)
        starts[:, 0] = 0
        peaks = np.sort(RNG.uniform(1, 6, (B, k)), axis=1)
        mems = np.abs(RNG.normal(3, 1.5, (B, T)))
        lengths = RNG.integers(1, T, B)
        viol, w_succ, w_kill = (np.asarray(x) for x in oom_probe(
            starts, peaks, mems, lengths, dt=dt, block_t=block_t,
            interpret=True))
        vr, wsr, wkr = oom_probe_ref(
            starts.astype(np.float32), peaks.astype(np.float32),
            mems.astype(np.float32), lengths, dt)
        np.testing.assert_array_equal(viol, vr)
        np.testing.assert_allclose(w_succ, wsr, rtol=1e-4, atol=1e-2)
        np.testing.assert_allclose(w_kill, wkr, rtol=1e-4, atol=1e-2)

    def test_violation_edges(self):
        """All-fit lanes report viol == -1 / w_kill == 0; a zero-capacity
        plan violates at the very first valid sample."""
        B, T, k = 6, 96, 3
        starts = np.sort(RNG.uniform(0, 60, (B, k)), axis=1)
        starts[:, 0] = 0
        mems = np.abs(RNG.normal(2, 0.5, (B, T)))
        lengths = RNG.integers(1, T, B)

        fat = np.full((B, k), float(mems.max()) + 1.0)
        viol, _, w_kill = (np.asarray(x) for x in oom_probe(
            starts, fat, mems, lengths, dt=1.0, interpret=True))
        np.testing.assert_array_equal(viol, np.full(B, -1, np.int32))
        np.testing.assert_array_equal(w_kill, np.zeros(B))

        zero = np.zeros((B, k))
        viol, _, _ = (np.asarray(x) for x in oom_probe(
            starts, zero, mems, lengths, dt=1.0, interpret=True))
        np.testing.assert_array_equal(viol, np.zeros(B, np.int32))
